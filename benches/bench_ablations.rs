//! Ablations over the design choices DESIGN.md calls out:
//!
//! 1. **Overlap boost (eq. 7)** — the paper's Sec. III-B claim: the 2× step
//!    on overlapping layers improves the global model. On/off accuracy, real
//!    training.
//! 2. **Cost-profile fidelity** — Table I under the paper's uniform-F layer
//!    model vs the per-layer ResNet-18 profile (does the greedy conclusion
//!    survive cost-model refinement?).
//! 3. **α/β objective weights** — round-time across the eq. (5) tradeoff.
//!
//! Requires `make artifacts` for ablation 1 (2 and 3 always run).

#[path = "common.rs"]
mod common;

use fedpairing::config::{Algorithm, ExperimentConfig, PairingStrategy};
use fedpairing::coordinator::run_experiment;
use fedpairing::pairing::pair_clients;
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::{fedpairing_round, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::rng::Rng;

fn main() {
    // --- 1. overlap boost on/off ---
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("== ablation 1: eq.(7) overlap 2x step ==");
        // Unequal splits (heterogeneous freqs) guarantee overlapping layers.
        let mut accs = Vec::new();
        for boost in [true, false] {
            let mut cfg = ExperimentConfig::preset("fig2").unwrap();
            cfg.algorithm = Algorithm::FedPairing;
            cfg.n_clients = 8;
            cfg.samples_per_client = 160;
            cfg.rounds = 12;
            cfg.test_samples = 600;
            cfg.seed = 17;
            cfg.overlap_boost = boost;
            let res = run_experiment(cfg).expect("run");
            println!(
                "  overlap_boost={boost:<5} final={:.4} best={:.4}",
                res.final_acc(),
                res.best_acc()
            );
            accs.push(res.final_acc());
        }
        println!(
            "  delta (boost - no-boost): {:+.2}pp (paper claims positive)",
            (accs[0] - accs[1]) * 100.0
        );
    } else {
        println!("== ablation 1 SKIPPED (no artifacts) ==");
    }

    // --- 2. uniform-F vs per-layer ResNet profile ---
    println!("== ablation 2: cost-profile fidelity (Table I under uniform F) ==");
    let cfg = ExperimentConfig::default();
    let resnet = ModelProfile::resnet18_cifar();
    // Uniform profile with the same totals: W=10 equal layers.
    let uniform = ModelProfile::uniform(
        resnet.w(),
        resnet.fwd_flops(0, resnet.w()) / resnet.w() as f64,
        resnet.layers.iter().map(|l| l.act_bytes).sum::<f64>() / resnet.w() as f64,
    );
    for (name, profile) in [("resnet18 (per-layer)", &resnet), ("uniform-F (paper model)", &uniform)] {
        let mut rng = Rng::new(17);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let ch = Channel::new(cfg.channel);
        let sched = Schedule { batch_size: 32, epochs: 2 };
        print!("  {name:<26}");
        for strat in [
            PairingStrategy::Greedy,
            PairingStrategy::Random,
            PairingStrategy::Location,
            PairingStrategy::Compute,
        ] {
            let pairs = pair_clients(strat, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng.fork(7));
            let t = fedpairing_round(&fleet, &pairs, profile, &sched, &ch, &cfg.compute, true).total_s;
            print!(" {}={:.0}s", strat.name(), t);
        }
        println!();
    }
    println!("  (shape check: greedy < random under BOTH cost models)");

    // --- 3. α/β sweep ---
    println!("== ablation 3: eq.(5) objective weights ==");
    let mut rng = Rng::new(17);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    let sched = Schedule { batch_size: 32, epochs: 2 };
    let profile = ModelProfile::resnet18_cifar();
    for &(alpha, beta) in &[
        (1.0, 0.0),
        (1.0, 1e-10),
        (1.0, 5e-10),
        (1.0, 2e-9),
        (0.0, 1.0),
    ] {
        let pairs = pair_clients(
            PairingStrategy::Greedy,
            &fleet,
            &ch,
            alpha,
            beta,
            &mut rng.fork(3),
        );
        let t = fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, true).total_s;
        println!("  alpha={alpha:<4} beta={beta:<8.0e} round={t:>7.0}s");
    }
}
