//! Paper Table I: average round time under different pairing mechanisms.
//!
//! Workload: the paper's setup — 20 clients in a 50 m disk, CPU ~ U[0.1,2] GHz,
//! ResNet-18 cost profile on 3×32×32, 2500 samples/client, 2 local epochs,
//! eq. (3) channel. Reports the single-draw table (the paper reports one
//! fleet realization) and a 20-draw mean, plus the wall-cost of the pairing
//! algorithms themselves.
//!
//! Paper row: greedy 1553 s < compute 1807 s < random 4063 s < location 7275 s.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::pairing::pair_clients;
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::{fedpairing_round, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::rng::Rng;
use fedpairing::util::stats::Summary;

const STRATEGIES: [(PairingStrategy, Option<f64>); 5] = [
    (PairingStrategy::Greedy, Some(1553.0)),
    (PairingStrategy::Random, Some(4063.0)),
    (PairingStrategy::Location, Some(7275.0)),
    (PairingStrategy::Compute, Some(1807.0)),
    (PairingStrategy::Exact, None),
];

fn round_time(cfg: &ExperimentConfig, seed: u64, strat: PairingStrategy) -> f64 {
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let profile = ModelProfile::resnet18_cifar();
    let pairs = pair_clients(strat, &fleet, &ch, cfg.alpha, cfg.beta, &mut rng.fork(7));
    fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, true).total_s
}

fn main() {
    let cfg = ExperimentConfig::default();
    println!("== Table I: avg round time by pairing mechanism ==");
    println!("-- single draw (seed 17), paper-comparable --");
    let mut single = Vec::new();
    for (strat, paper) in STRATEGIES {
        let t = round_time(&cfg, 17, strat);
        common::paper_row(strat.name(), t, paper);
        single.push((strat, t));
    }
    let get = |s: PairingStrategy| single.iter().find(|(x, _)| *x == s).unwrap().1;
    common::check_shape(
        "greedy beats random",
        get(PairingStrategy::Greedy) < get(PairingStrategy::Random),
    );
    common::check_shape(
        "greedy beats location",
        get(PairingStrategy::Greedy) < get(PairingStrategy::Location),
    );
    common::check_shape(
        "greedy within 10% of compute-based or better",
        get(PairingStrategy::Greedy) <= 1.10 * get(PairingStrategy::Compute),
    );
    common::check_shape(
        "random beats location (paper draw)",
        get(PairingStrategy::Random) < get(PairingStrategy::Location),
    );

    println!("-- 20-draw mean ± std --");
    for (strat, _) in STRATEGIES {
        let mut s = Summary::new();
        for seed in 0..20 {
            s.push(round_time(&cfg, 1000 + seed, strat));
        }
        println!("  {:<28} {:>9.0} ± {:>5.0} s", strat.name(), s.mean(), s.std());
    }

    println!("-- pairing algorithm wall cost (N=20, complete graph) --");
    common::report_header();
    let mut rng = Rng::new(5);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    for strat in [
        PairingStrategy::Greedy,
        PairingStrategy::Random,
        PairingStrategy::Location,
        PairingStrategy::Compute,
        PairingStrategy::Exact,
    ] {
        let mut r2 = Rng::new(9);
        common::bench(strat.name(), 3, 10, || {
            common::black_box(pair_clients(strat, &fleet, &ch, 1.0, 5e-10, &mut r2));
        })
        .report();
    }
}
