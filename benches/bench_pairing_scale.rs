//! Fleet-scale pairing bench: sparse candidate-graph build + greedy matching
//! and one incremental churn repair at n ∈ {1k, 10k, 100k}, plus the
//! dense-vs-sparse crossover at n = 1k. Emits `BENCH_pairing.json` so CI can
//! track the perf trajectory across PRs.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::fleet::{maintain_matching, FleetDynamics};
use fedpairing::pairing::graph::ClientGraph;
use fedpairing::pairing::greedy::greedy_matching;
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::rng::Rng;

fn metro_cfg(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = n;
    cfg.seed = 17;
    cfg
}

/// Pairing + one churn step + incremental repair through the real fleet path.
fn churn_round_trip(cfg: &ExperimentConfig) -> usize {
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut matching = None;
    for round in 1..=2 {
        let ev = dynamics.step(round);
        let channel = dynamics.channel();
        maintain_matching(&mut matching, &dynamics, &ev, &channel, cfg, None, &mut pairing_rng);
    }
    matching.expect("matching").pairs.len()
}

fn main() {
    println!("== sparse candidate-graph pairing scale ==");
    common::report_header();
    let mut rows: Vec<Json> = Vec::new();
    for n in [1_000usize, 10_000, 100_000] {
        let cfg = metro_cfg(n);
        let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let channel = Channel::new(cfg.channel);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        };
        let members: Vec<usize> = (0..n).collect();
        let iters = if n >= 100_000 { 3 } else { 10 };
        let mut n_edges = 0usize;
        let mut n_pairs = 0usize;
        let pair_stats = common::bench(&format!("sparse pair    n={n}"), 1, iters, || {
            let g = SparseCandidateGraph::build(
                &fleet,
                &channel,
                spec,
                cfg.backend.k_near,
                cfg.backend.k_freq,
            );
            n_edges = g.edges().len();
            let m = match_candidates(&g, &members);
            n_pairs = m.pairs.len();
            common::black_box(m);
        });
        pair_stats.report();
        let repair_stats =
            common::bench(&format!("pair+churn+fix n={n}"), 0, iters.min(5), || {
                common::black_box(churn_round_trip(&cfg));
            });
        repair_stats.report();
        common::check_shape(
            &format!("n={n}: candidate set O(n·k)"),
            n_edges <= n * (cfg.backend.k_near + cfg.backend.k_freq),
        );
        common::check_shape(&format!("n={n}: near-perfect"), n_pairs >= n / 2 - 1);
        let mut row = JsonObj::new();
        row.insert("n", Json::num(n as f64));
        row.insert("candidate_edges", Json::num(n_edges as f64));
        row.insert("pairs", Json::num(n_pairs as f64));
        row.insert("sparse_pair_mean_s", Json::num(pair_stats.mean_s));
        row.insert("sparse_pair_min_s", Json::num(pair_stats.min_s));
        row.insert("churn_repair_mean_s", Json::num(repair_stats.mean_s));
        rows.push(Json::Obj(row));
    }

    println!("== dense vs sparse crossover (n=1000, greedy) ==");
    let cfg = metro_cfg(1_000);
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    let dense_stats = common::bench("dense greedy  n=1000", 1, 10, || {
        common::black_box(greedy_matching(&ClientGraph::build(
            &fleet, &channel, cfg.alpha, cfg.beta,
        )));
    });
    dense_stats.report();

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("pairing_scale"));
    out.insert("strategy", Json::str(PairingStrategy::Greedy.name()));
    out.insert("dense_n1000_mean_s", Json::num(dense_stats.mean_s));
    out.insert("results", Json::Arr(rows));
    let path = "BENCH_pairing.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
