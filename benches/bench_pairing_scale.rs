//! Fleet-scale pairing bench: sparse candidate-graph build + greedy matching
//! and one incremental churn repair at n ∈ {1k, 10k, 100k, 1M}, the
//! dense-vs-sparse crossover at n = 1k, and the headline cross-round race:
//! persistent incremental matcher vs full rebuild over repeated metro churn
//! epochs at n = 100k (acceptance: ≥ 10×, outputs bit-identical). Emits
//! `BENCH_pairing.json` (including peak RSS) so CI can track the perf
//! trajectory across PRs; CI greps the log for `FAIL` shape checks.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::fleet::{maintain_matching, FleetDynamics};
use fedpairing::pairing::graph::ClientGraph;
use fedpairing::pairing::greedy::greedy_matching;
use fedpairing::pairing::{
    match_candidates, EdgeWeightSpec, IncrementalMatcher, SparseCandidateGraph,
};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::pool::FixedPool;
use fedpairing::util::rng::Rng;
use std::time::Instant;

fn metro_cfg(n: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = n;
    cfg.seed = 17;
    cfg
}

/// Pairing + one churn step + incremental repair through the real fleet path.
fn churn_round_trip(cfg: &ExperimentConfig) -> usize {
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut matching = None;
    for round in 1..=2 {
        let ev = dynamics.step(round);
        let channel = dynamics.channel();
        maintain_matching(&mut matching, &dynamics, &ev, &channel, cfg, None, &mut pairing_rng);
    }
    matching.expect("matching").pairs.len()
}

/// The tentpole race: per-epoch incremental matcher vs full rebuild over
/// `epochs` metro churn rounds at `n`. Returns (speedup, bit_identical,
/// mean incremental epoch seconds, mean rebuild epoch seconds).
fn incremental_vs_rebuild(n: usize, epochs: usize) -> (f64, bool, f64, f64) {
    let cfg = metro_cfg(n);
    let base = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(&cfg, base);
    let spec = EdgeWeightSpec::Eq5 {
        alpha: cfg.alpha,
        beta: cfg.beta,
    };
    let pool = FixedPool::new(cfg.engine.threads);
    let mut matcher =
        IncrementalMatcher::new(dynamics.universe().n(), cfg.backend.k_near, cfg.backend.k_freq);
    // Epoch 1 initializes both sides (unmeasured — the race is the steady
    // state, where the rebuild's work is flat and the matcher's is
    // O(affected)).
    dynamics.step(1);
    {
        let channel = dynamics.channel();
        let alive = dynamics.alive_indices();
        common::black_box(
            matcher
                .update(dynamics.universe(), &channel, dynamics.grid(), &alive, &spec, &pool)
                .pairs
                .len(),
        );
    }
    let mut t_inc = 0.0f64;
    let mut t_reb = 0.0f64;
    let mut identical = true;
    for round in 2..=(1 + epochs) {
        dynamics.step(round);
        let channel = dynamics.channel();
        let alive = dynamics.alive_indices();
        let t = Instant::now();
        let inc = matcher
            .update(dynamics.universe(), &channel, dynamics.grid(), &alive, &spec, &pool)
            .clone();
        t_inc += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let g = SparseCandidateGraph::over_members_pooled(
            dynamics.universe(),
            &channel,
            dynamics.grid(),
            &alive,
            spec,
            cfg.backend.k_near,
            cfg.backend.k_freq,
            &pool,
        );
        let reb = match_candidates(&g, &alive);
        t_reb += t.elapsed().as_secs_f64();
        identical &= inc == reb;
    }
    let e = epochs as f64;
    (t_reb / t_inc.max(1e-12), identical, t_inc / e, t_reb / e)
}

fn main() {
    println!("== sparse candidate-graph pairing scale ==");
    common::report_header();
    let mut rows: Vec<Json> = Vec::new();
    let mut million_pair_s = f64::NAN;
    for n in [1_000usize, 10_000, 100_000, 1_000_000] {
        let cfg = metro_cfg(n);
        let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
        let channel = Channel::new(cfg.channel);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        };
        let members: Vec<usize> = (0..n).collect();
        let (warmup, iters) = match n {
            1_000_000 => (0, 2),
            100_000 => (1, 3),
            _ => (1, 10),
        };
        let mut n_edges = 0usize;
        let mut n_pairs = 0usize;
        let pair_stats = common::bench(&format!("sparse pair    n={n}"), warmup, iters, || {
            let g = SparseCandidateGraph::build(
                &fleet,
                &channel,
                spec,
                cfg.backend.k_near,
                cfg.backend.k_freq,
            );
            n_edges = g.edges().len();
            let m = match_candidates(&g, &members);
            n_pairs = m.pairs.len();
            common::black_box(m);
        });
        pair_stats.report();
        let repair_stats =
            common::bench(&format!("pair+churn+fix n={n}"), 0, iters.min(5), || {
                common::black_box(churn_round_trip(&cfg));
            });
        repair_stats.report();
        common::check_shape(
            &format!("n={n}: candidate set O(n·k)"),
            n_edges <= n * (cfg.backend.k_near + cfg.backend.k_freq),
        );
        common::check_shape(&format!("n={n}: near-perfect"), n_pairs >= n / 2 - 1);
        if n == 1_000_000 {
            million_pair_s = pair_stats.min_s;
            common::check_shape("n=1000000: full pairing under 60 s", pair_stats.min_s < 60.0);
        }
        let mut row = JsonObj::new();
        row.insert("n", Json::num(n as f64));
        row.insert("candidate_edges", Json::num(n_edges as f64));
        row.insert("pairs", Json::num(n_pairs as f64));
        row.insert("sparse_pair_mean_s", Json::num(pair_stats.mean_s));
        row.insert("sparse_pair_min_s", Json::num(pair_stats.min_s));
        row.insert("churn_repair_mean_s", Json::num(repair_stats.mean_s));
        rows.push(Json::Obj(row));
    }

    println!("== incremental matcher vs full rebuild (n=100_000, metro churn) ==");
    let (speedup, identical, inc_s, reb_s) = incremental_vs_rebuild(100_000, 10);
    println!(
        "  incremental epoch {:>10}   rebuild epoch {:>10}   speedup {speedup:.1}x",
        common::fmt_time(inc_s),
        common::fmt_time(reb_s)
    );
    common::check_shape("n=100k churn: incremental == rebuild bit-for-bit", identical);
    common::check_shape("n=100k churn: incremental >= 10x rebuild", speedup >= 10.0);

    println!("== dense vs sparse crossover (n=1000, greedy) ==");
    let cfg = metro_cfg(1_000);
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    let dense_stats = common::bench("dense greedy  n=1000", 1, 10, || {
        common::black_box(greedy_matching(&ClientGraph::build(
            &fleet, &channel, cfg.alpha, cfg.beta,
        )));
    });
    dense_stats.report();

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("pairing_scale"));
    out.insert("strategy", Json::str(PairingStrategy::Greedy.name()));
    out.insert("dense_n1000_mean_s", Json::num(dense_stats.mean_s));
    out.insert("matcher_speedup_100k", Json::num(speedup));
    out.insert("matcher_epoch_100k_s", Json::num(inc_s));
    out.insert("rebuild_epoch_100k_s", Json::num(reb_s));
    out.insert("million_pair_min_s", Json::num(million_pair_s));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    out.insert("results", Json::Arr(rows));
    let path = "BENCH_pairing.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
