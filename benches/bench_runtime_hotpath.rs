//! L3 hot path microbenchmarks: artifact execution latency and the host-side
//! parameter math. This is the bench that drives the §Perf iteration log in
//! EXPERIMENTS.md (before/after per optimization).
//!
//! Requires `make artifacts`.

#[path = "common.rs"]
mod common;

use fedpairing::nn;
use fedpairing::runtime::Engine;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let mut e = Engine::load("artifacts").expect("engine");
    let meta = e.meta().clone();
    println!(
        "== runtime hot path (W={}, {} params, train_batch={}) ==",
        meta.layers, meta.n_params, meta.train_batch
    );
    let params = e.init_params(1).unwrap();
    let b = meta.train_batch;
    let x: Vec<f32> = (0..b * meta.input_dim)
        .map(|i| ((i * 2654435761usize) % 1000) as f32 / 500.0 - 1.0)
        .collect();
    let mut y = vec![0f32; b * meta.classes];
    for r in 0..b {
        y[r * meta.classes + r % meta.classes] = 1.0;
    }
    let xe = vec![0.05f32; meta.eval_batch * meta.input_dim];
    let mut ye = vec![0f32; meta.eval_batch * meta.classes];
    for r in 0..meta.eval_batch {
        ye[r * meta.classes + r % meta.classes] = 1.0;
    }

    common::report_header();
    common::bench("full_step (FL local step)", 3, 30, || {
        common::black_box(e.full_step(&params, &x, &y).unwrap());
    })
    .report();

    let k = meta.layers / 2;
    let pf = params[..2 * k].to_vec();
    let pb = params[2 * k..].to_vec();
    common::bench("front_fwd (k=W/2)", 3, 30, || {
        common::black_box(e.front_fwd(k, &pf, &x).unwrap());
    })
    .report();
    let act = e.front_fwd(k, &pf, &x).unwrap();
    common::bench("back_fwd", 3, 30, || {
        common::black_box(e.back_fwd(k, &pb, &act).unwrap());
    })
    .report();
    let logits = e.back_fwd(k, &pb, &act).unwrap();
    common::bench("loss_grad", 3, 30, || {
        common::black_box(e.loss_grad(&logits, &y).unwrap());
    })
    .report();
    let (_, gl) = e.loss_grad(&logits, &y).unwrap();
    common::bench("back_bwd", 3, 30, || {
        common::black_box(e.back_bwd(k, &pb, &act, &gl).unwrap());
    })
    .report();
    let (_, ga) = e.back_bwd(k, &pb, &act, &gl).unwrap();
    common::bench("front_bwd", 3, 30, || {
        common::black_box(e.front_bwd(k, &pf, &x, &ga).unwrap());
    })
    .report();
    let five = common::bench("split 5-step (one direction)", 2, 15, || {
        let act = e.front_fwd(k, &pf, &x).unwrap();
        let logits = e.back_fwd(k, &pb, &act).unwrap();
        let (_, gl) = e.loss_grad(&logits, &y).unwrap();
        let (_gb, ga) = e.back_bwd(k, &pb, &act, &gl).unwrap();
        common::black_box(e.front_bwd(k, &pf, &x, &ga).unwrap());
    });
    five.report();
    println!(
        "  => split-direction throughput: {:.0} samples/s",
        b as f64 / five.mean_s
    );
    common::bench("eval_batch (256 rows)", 2, 15, || {
        common::black_box(e.eval_batch(&params, &xe, &ye).unwrap());
    })
    .report();

    println!("-- host-side parameter math (1.2M params) --");
    let grads = params.clone();
    let mut model = params.clone();
    common::bench("sgd_apply", 3, 50, || {
        nn::sgd_apply(&mut model, &grads, 1e-6);
    })
    .report();
    let locals: Vec<nn::Params> = (0..20).map(|_| params.clone()).collect();
    let mut global = params.clone();
    common::bench("aggregate_deltas (20 clients)", 2, 10, || {
        nn::aggregate_deltas(&mut global, &locals);
    })
    .report();
    let weights = vec![0.05f64; 20];
    common::bench("fedavg_weighted (20 clients)", 2, 10, || {
        common::black_box(nn::fedavg_weighted(&locals, &weights));
    })
    .report();
}
