//! Telemetry overhead bench: 50k-client metro-scale engine rounds with the
//! registry off, on, and on with full trace export. The acceptance
//! criteria ride on the first two:
//!
//! * **disabled < 1 %** — hooks cost one relaxed load + branch when off, so
//!   two timed passes of the *same* disabled configuration (an A/A
//!   comparison) must agree within the noise floor;
//! * **enabled < 5 %** — counters and lane collection may not tax the honest
//!   metro workload (per-round fading → real misses every round).
//!
//! Emits `BENCH_telemetry.json` for CI.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, TelemetryConfig};
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::latency::{Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::telemetry::registry::{self, Counter};
use fedpairing::telemetry::Telemetry;
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::rng::Rng;
use std::time::Instant;

const N_CLIENTS: usize = 50_000;
const ROUNDS: usize = 100;

/// Per-round channels under metro-scale block fading (2 dB log-normal) —
/// every pass replays the identical sequence.
fn faded_channels(cfg: &ExperimentConfig, rounds: usize) -> Vec<Channel> {
    let mut rng = Rng::with_stream(cfg.seed, 0xFADE);
    (0..rounds)
        .map(|_| {
            let mut ch = cfg.channel;
            ch.ref_gain *= 10f64.powf(rng.normal_ms(0.0, 2.0) / 10.0);
            Channel::new(ch)
        })
        .collect()
}

fn main() {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = N_CLIENTS;
    cfg.seed = 23;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    let members: Vec<usize> = (0..N_CLIENTS).collect();
    let graph = SparseCandidateGraph::build(
        &fleet,
        &channel,
        EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        },
        cfg.backend.k_near,
        cfg.backend.k_freq,
    );
    let matching = match_candidates(&graph, &members);
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let channels = faded_channels(&cfg, ROUNDS);

    // One timed pass: a fresh engine over the fade sequence, optionally
    // feeding the telemetry sink exactly like the drivers do.
    let run_pass = |sink: &mut Option<Telemetry>| -> f64 {
        let mut engine = RoundEngine::new(&cfg.engine);
        let mut sim_total = 0.0f64;
        let t = Instant::now();
        for (r, ch) in channels.iter().enumerate() {
            if let Some(s) = sink.as_mut() {
                s.begin_round(r + 1);
            }
            let rt = engine.fedpairing_round(
                &fleet,
                &matching.pairs,
                &matching.solos,
                &profile,
                &sched,
                ch,
                &cfg.compute,
                true,
            );
            sim_total += rt.total_s;
            if let Some(s) = sink.as_mut() {
                s.mark("engine");
                let lanes = engine.pair_lanes().to_vec();
                s.end_round(&rt, N_CLIENTS, &lanes, sim_total - rt.total_s);
            }
            common::black_box(rt.total_s);
        }
        t.elapsed().as_secs_f64()
    };

    println!(
        "== telemetry overhead (n={N_CLIENTS}, {} pairs, {ROUNDS} faded engine rounds) ==",
        matching.pairs.len()
    );

    // Warmup (untimed), then the A/A disabled pair.
    registry::set_enabled(false);
    let mut none: Option<Telemetry> = None;
    run_pass(&mut none);
    let off_a = run_pass(&mut none);
    let off_b = run_pass(&mut none);

    // Enabled: registry counts + lane collection, no exporters.
    registry::reset();
    let mut on_sink = Some(Telemetry::new(&TelemetryConfig {
        enabled: true,
        ..TelemetryConfig::default()
    }));
    let on = run_pass(&mut on_sink);
    let snap = registry::snapshot();

    // Enabled + full trace export (spans, lanes, prom, jsonl), sampled 1:10
    // so the trace of a 100-round metro run stays small.
    std::fs::create_dir_all("target").ok();
    let trace_path = "target/bench-telemetry-trace.json".to_string();
    let mut trace_sink = Some(Telemetry::new(&TelemetryConfig {
        enabled: true,
        sample_every: 10,
        trace_out: Some(trace_path),
        top_k_pairs: 8,
        ..TelemetryConfig::default()
    }));
    let mut trace = run_pass(&mut trace_sink);
    let t = Instant::now();
    let written = trace_sink.as_mut().unwrap().finish().expect("trace export");
    trace += t.elapsed().as_secs_f64();
    registry::set_enabled(false);
    registry::reset();

    let off_min = off_a.min(off_b);
    let disabled_pct = 100.0 * (off_b - off_a) / off_a;
    let enabled_pct = 100.0 * (on - off_min) / off_min;
    let trace_pct = 100.0 * (trace - off_min) / off_min;
    println!("  {:<22} {:>10.2} rounds/s", "off (pass A)", ROUNDS as f64 / off_a);
    println!("  {:<22} {:>10.2} rounds/s", "off (pass B)", ROUNDS as f64 / off_b);
    println!("  {:<22} {:>10.2} rounds/s", "on", ROUNDS as f64 / on);
    println!("  {:<22} {:>10.2} rounds/s", "on + trace export", ROUNDS as f64 / trace);
    println!(
        "  disabled A/A delta: {disabled_pct:+.2} %   enabled: {enabled_pct:+.2} %   \
         trace: {trace_pct:+.2} %"
    );
    println!(
        "  enabled-pass registry: {} misses, {} analytic kernel evals, {} pool chunks",
        snap.counter(Counter::MemoMisses.name()),
        snap.counter(Counter::KernelEvalsAnalytic.name()),
        snap.counter(Counter::PoolChunks.name()),
    );
    for p in &written {
        println!("  wrote {p}");
    }
    common::check_shape(
        "disabled-path overhead (A/A noise) < 1%",
        disabled_pct.abs() < 1.0,
    );
    common::check_shape("enabled overhead < 5%", enabled_pct < 5.0);

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("telemetry"));
    out.insert(
        "workload",
        Json::str("fedpairing metro-scale fading, telemetry off / on / trace"),
    );
    out.insert("n", Json::num(N_CLIENTS as f64));
    out.insert("pairs", Json::num(matching.pairs.len() as f64));
    out.insert("rounds", Json::num(ROUNDS as f64));
    out.insert("off_a_rounds_per_s", Json::num(ROUNDS as f64 / off_a));
    out.insert("off_b_rounds_per_s", Json::num(ROUNDS as f64 / off_b));
    out.insert("on_rounds_per_s", Json::num(ROUNDS as f64 / on));
    out.insert("trace_rounds_per_s", Json::num(ROUNDS as f64 / trace));
    out.insert("disabled_aa_delta_pct", Json::num(disabled_pct));
    out.insert("enabled_overhead_pct", Json::num(enabled_pct));
    out.insert("trace_overhead_pct", Json::num(trace_pct));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    let path = "BENCH_telemetry.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
