//! Paper Fig. 3: convergence under Non-IID data (2 random classes per
//! client). Paper shape: FedPairing keeps the top accuracy and the margins
//! over SL/SplitFed widen dramatically vs the IID case (+38.2 / +44.6 pp).
//!
//! Real training through the AOT artifacts at reduced scale (see
//! bench_fig2); full-scale curves via `examples/noniid_convergence.rs`.

#[path = "common.rs"]
mod common;

use fedpairing::config::{Algorithm, DataDistribution, ExperimentConfig};
use fedpairing::coordinator::run_experiment;

const ROUNDS: usize = 12;

fn cfg_for(algo: Algorithm) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("fig3").unwrap();
    cfg.algorithm = algo;
    cfg.n_clients = 8;
    cfg.samples_per_client = 96;
    cfg.noise_level = 2.5;
    cfg.rounds = ROUNDS;
    cfg.test_samples = 600;
    cfg.seed = 17;
    assert_eq!(
        cfg.distribution,
        DataDistribution::ClassShards { classes_per_client: 2 }
    );
    cfg
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    println!("== Fig. 3: Non-IID (2-class shards) convergence ==");
    let algos = [
        Algorithm::FedPairing,
        Algorithm::VanillaFL,
        Algorithm::VanillaSL,
        Algorithm::SplitFed,
    ];
    let mut results = Vec::new();
    for algo in algos {
        let res = run_experiment(cfg_for(algo)).expect("run");
        println!(
            "  {:<12} final={:.4} best={:.4}",
            algo.name(),
            res.final_acc(),
            res.best_acc()
        );
        print!("    curve:");
        for (round, acc) in res.acc_curve() {
            if round % 3 == 0 || round == 1 || round == ROUNDS {
                print!(" {round}:{acc:.3}");
            }
        }
        println!();
        results.push((algo, res));
    }
    let acc = |a: Algorithm| {
        results
            .iter()
            .find(|(x, _)| *x == a)
            .map(|(_, r)| r.final_acc())
            .unwrap()
    };
    println!("-- paper deltas (Non-IID): FL +5.3pp SL +38.2pp SplitFed +44.6pp --");
    println!(
        "  measured: FL {:+.1}pp  SL {:+.1}pp  SplitFed {:+.1}pp",
        (acc(Algorithm::FedPairing) - acc(Algorithm::VanillaFL)) * 100.0,
        (acc(Algorithm::FedPairing) - acc(Algorithm::VanillaSL)) * 100.0,
        (acc(Algorithm::FedPairing) - acc(Algorithm::SplitFed)) * 100.0
    );
    common::check_shape(
        "fedpairing ties the federated band (FL/SplitFed) under non-iid",
        acc(Algorithm::FedPairing) >= acc(Algorithm::VanillaFL) - 0.02
            && acc(Algorithm::FedPairing) >= acc(Algorithm::SplitFed) - 0.02,
    );
    common::check_shape(
        "label skew hurts all federated algorithms vs IID (task is genuinely non-iid-hard)",
        acc(Algorithm::FedPairing) < 0.95,
    );
    common::check_shape(
        "fedpairing learns despite label skew (>= 3x chance)",
        acc(Algorithm::FedPairing) > 0.3,
    );
}
