//! Pairing algorithm scaling + optimality: greedy vs exact bitmask DP.
//!
//! * wall-clock of both matchers as the fleet grows (greedy O(N² log N) vs
//!   DP O(2ᴺ·N)),
//! * the greedy/optimal weight ratio (theory guarantees ≥ ½; in practice on
//!   eq. (5) graphs it is ≈ 0.9+),
//! * round-time consequences of weight-vs-time mismatch.

#[path = "common.rs"]
mod common;

use fedpairing::config::ExperimentConfig;
use fedpairing::pairing::{exact::exact_matching, graph::ClientGraph, greedy::greedy_matching};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::rng::Rng;
use fedpairing::util::stats::Summary;

fn main() {
    let ch = Channel::new(ExperimentConfig::default().channel);
    println!("== pairing algorithm scaling ==");
    common::report_header();
    for n in [8usize, 12, 16, 20, 22] {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(n as u64));
        let g = ClientGraph::build(&fleet, &ch, cfg.alpha, cfg.beta);
        common::bench(&format!("greedy  n={n}"), 2, 20, || {
            common::black_box(greedy_matching(&g));
        })
        .report();
        common::bench(&format!("exactDP n={n}"), 1, if n <= 16 { 10 } else { 3 }, || {
            common::black_box(exact_matching(&g));
        })
        .report();
    }

    println!("== greedy/optimal weight ratio (eq. 5 graphs, n=20, 30 draws) ==");
    let mut ratio = Summary::new();
    for seed in 0..30u64 {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = seed;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(seed));
        let g = ClientGraph::build(&fleet, &ch, cfg.alpha, cfg.beta);
        let wg = g.matching_weight(&greedy_matching(&g));
        let we = g.matching_weight(&exact_matching(&g));
        ratio.push(wg / we);
    }
    println!(
        "  greedy/exact weight: mean {:.4}, min {:.4} (theory bound 0.5)",
        ratio.mean(),
        ratio.min()
    );
    common::check_shape("greedy >= 1/2 optimal", ratio.min() >= 0.5);
    common::check_shape("greedy near-optimal in practice (>0.85)", ratio.mean() > 0.85);
}
