//! Split-planning bench: cost-aware cut optimization vs the paper's
//! proportional rule, on the metro-scale preset at n ∈ {1k, 10k, 50k}.
//!
//! For each fleet size the *same* sparse greedy matching is evaluated by the
//! round engine under the `paper`, `balanced` and `optimal` split policies
//! across per-round shadowing fades (honest memo-cache workload), reporting
//! the mean simulated round latency per policy and the achieved reduction.
//! A separate pass times raw `optimal` planner throughput (unmemoized
//! argmin searches per second). Emits `BENCH_split.json` for the CI `scale`
//! job, which tracks the acceptance criteria: `optimal` is never slower
//! than `paper`, and on the metro-scale preset it shows a measured
//! mean-round-latency reduction.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, SplitConfig, SplitPolicy};
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::latency::{Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::split::{plan, PairContext};
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::rng::Rng;
use std::time::Instant;

/// Per-round channels under metro-scale block fading (2 dB log-normal),
/// replayed identically for every policy.
fn faded_channels(cfg: &ExperimentConfig, rounds: usize) -> Vec<Channel> {
    let mut rng = Rng::with_stream(cfg.seed, 0xFADE);
    (0..rounds)
        .map(|_| {
            let mut ch = cfg.channel;
            ch.ref_gain *= 10f64.powf(rng.normal_ms(0.0, 2.0) / 10.0);
            Channel::new(ch)
        })
        .collect()
}

struct Case {
    n: usize,
    pairs: usize,
    mean_round_s: [f64; 3], // paper, balanced, optimal
    reduction_pct: f64,     // optimal vs paper
    plans_per_s: f64,       // raw optimal argmin throughput
}

fn run_case(n: usize, rounds: usize) -> Case {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = n;
    cfg.seed = 17;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    // One shared matching off the sparse eq. (5) graph, so the policy
    // comparison isolates the cut decision (co-design benched separately by
    // the CLI paths; here paper-vs-optimal must be 1:1 on identical pairs).
    let members: Vec<usize> = (0..n).collect();
    let graph = SparseCandidateGraph::build(
        &fleet,
        &channel,
        EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        },
        cfg.backend.k_near,
        cfg.backend.k_freq,
    );
    let matching = match_candidates(&graph, &members);
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let channels = faded_channels(&cfg, rounds);

    let policies = [SplitPolicy::Paper, SplitPolicy::Balanced, SplitPolicy::Optimal];
    let mut mean_round_s = [0.0f64; 3];
    for (slot, policy) in policies.into_iter().enumerate() {
        let split = SplitConfig {
            policy,
            ..SplitConfig::default()
        };
        let mut engine = RoundEngine::new(&cfg.engine).with_split(split);
        let mut acc = 0.0f64;
        for ch in &channels {
            acc += engine
                .fedpairing_round(
                    &fleet,
                    &matching.pairs,
                    &matching.solos,
                    &profile,
                    &sched,
                    ch,
                    &cfg.compute,
                    true,
                )
                .total_s;
        }
        mean_round_s[slot] = acc / rounds as f64;
    }
    assert!(
        mean_round_s[2] <= mean_round_s[0] + 1e-9,
        "optimal mean {} slower than paper {}",
        mean_round_s[2],
        mean_round_s[0]
    );

    // Raw planner throughput: unmemoized optimal argmin per pair (the cost a
    // cache miss pays on top of the single paper-cut kernel evaluation).
    let split = SplitConfig {
        policy: SplitPolicy::Optimal,
        ..SplitConfig::default()
    };
    let probe: Vec<(usize, usize)> = matching.pairs.iter().copied().take(4096).collect();
    let t = Instant::now();
    let mut acc = 0.0f64;
    for &(i, j) in &probe {
        let d = plan(
            &split,
            &PairContext {
                profile: &profile,
                sched: &sched,
                comp: &cfg.compute,
                f_i_hz: fleet.freqs_hz[i],
                f_j_hz: fleet.freqs_hz[j],
                n_i: fleet.n_samples[i],
                n_j: fleet.n_samples[j],
                rate_bps: channel.rate(&fleet.positions[i], &fleet.positions[j]),
            },
        );
        acc += d.predicted_round_s;
    }
    common::black_box(acc);
    let plans_per_s = probe.len() as f64 / t.elapsed().as_secs_f64();

    Case {
        n,
        pairs: matching.pairs.len(),
        mean_round_s,
        reduction_pct: 100.0 * (1.0 - mean_round_s[2] / mean_round_s[0]),
        plans_per_s,
    }
}

fn main() {
    println!("== split planning: paper vs balanced vs optimal (metro-scale fading) ==");
    println!(
        "  {:>7} {:>9} {:>12} {:>12} {:>12} {:>9} {:>12}",
        "n", "pairs", "paper s", "balanced s", "optimal s", "gain%", "plans/s"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut metro_reduction = 0.0;
    for (n, rounds) in [(1_000, 40), (10_000, 20), (50_000, 10)] {
        let case = run_case(n, rounds);
        println!(
            "  {:>7} {:>9} {:>12.1} {:>12.1} {:>12.1} {:>8.2}% {:>12.0}",
            case.n,
            case.pairs,
            case.mean_round_s[0],
            case.mean_round_s[1],
            case.mean_round_s[2],
            case.reduction_pct,
            case.plans_per_s
        );
        if n == 50_000 {
            metro_reduction = case.reduction_pct;
        }
        let mut row = JsonObj::new();
        row.insert("n", Json::num(case.n as f64));
        row.insert("pairs", Json::num(case.pairs as f64));
        row.insert("paper_mean_round_s", Json::num(case.mean_round_s[0]));
        row.insert("balanced_mean_round_s", Json::num(case.mean_round_s[1]));
        row.insert("optimal_mean_round_s", Json::num(case.mean_round_s[2]));
        row.insert("optimal_reduction_pct", Json::num(case.reduction_pct));
        row.insert("optimal_plans_per_s", Json::num(case.plans_per_s));
        rows.push(Json::Obj(row));
    }
    common::check_shape(
        "metro (n=50k): optimal strictly reduces the mean round vs paper",
        metro_reduction > 0.0,
    );

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("split_planning"));
    out.insert(
        "workload",
        Json::str("fedpairing metro-scale fading, shared sparse matching, per-policy engines"),
    );
    out.insert("metro_reduction_pct_50k", Json::num(metro_reduction));
    out.insert("results", Json::Arr(rows));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    let path = "BENCH_split.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
