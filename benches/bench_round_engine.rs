//! Round-engine bench: per-round FedPairing latency evaluation, analytic
//! engine vs the DES-per-pair oracle, at n ∈ {1k, 10k, 50k}. Every round
//! re-draws the metro-scale shadowing fade (so the memo cache faces honest
//! per-round rate changes, exactly like the `metro-scale` scenario); a frozen-
//! channel pass shows the 100 %-hit cache ceiling. Emits
//! `BENCH_round_engine.json` so CI tracks the acceptance criterion: the
//! 50k-client / 200-round metro evaluation must be ≥ 20× faster than the DES
//! path.

#[path = "common.rs"]
mod common;

use fedpairing::config::ExperimentConfig;
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::latency::{self, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::rng::Rng;
use std::time::Instant;

/// Per-round channels under metro-scale block fading (2 dB log-normal),
/// replayed identically for both backends.
fn faded_channels(cfg: &ExperimentConfig, rounds: usize) -> Vec<Channel> {
    let mut rng = Rng::with_stream(cfg.seed, 0xFADE);
    (0..rounds)
        .map(|_| {
            let mut ch = cfg.channel;
            ch.ref_gain *= 10f64.powf(rng.normal_ms(0.0, 2.0) / 10.0);
            Channel::new(ch)
        })
        .collect()
}

struct Case {
    n: usize,
    pairs: usize,
    engine_rps: f64,
    des_rps: f64,
    speedup: f64,
    cached_rps: f64,
    cache_hit_rate: f64,
}

fn run_case(n: usize, engine_rounds: usize, des_rounds: usize) -> Case {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = n;
    cfg.seed = 17;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    // Near-perfect matching off the sparse candidate graph (the real metro
    // pairing path; pair ids are fleet-compact already).
    let members: Vec<usize> = (0..n).collect();
    let graph = SparseCandidateGraph::build(
        &fleet,
        &channel,
        EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        },
        cfg.backend.k_near,
        cfg.backend.k_freq,
    );
    let matching = match_candidates(&graph, &members);
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };

    // Analytic engine under per-round fading (cache must recompute moved
    // rates every round — the honest metro workload).
    let mut engine = RoundEngine::new(&cfg.engine);
    let channels = faded_channels(&cfg, engine_rounds);
    let t = Instant::now();
    let mut acc = 0.0f64;
    for ch in &channels {
        acc += engine
            .fedpairing_round(
                &fleet,
                &matching.pairs,
                &matching.solos,
                &profile,
                &sched,
                ch,
                &cfg.compute,
                true,
            )
            .total_s;
    }
    let engine_rps = engine_rounds as f64 / t.elapsed().as_secs_f64();
    common::black_box(acc);

    // DES-per-pair oracle over the same fade sequence (fewer rounds — it is
    // the slow side being measured; rounds/s normalizes).
    let channels = faded_channels(&cfg, des_rounds);
    let t = Instant::now();
    let mut des_acc = 0.0f64;
    for ch in &channels {
        des_acc += latency::fedpairing_round_with_solos(
            &fleet,
            &matching.pairs,
            &matching.solos,
            &profile,
            &sched,
            ch,
            &cfg.compute,
            true,
        )
        .total_s;
    }
    let des_rps = des_rounds as f64 / t.elapsed().as_secs_f64();
    common::black_box(des_acc);

    // Frozen channel: rounds 2.. are 100 % cache hits — the stable-scenario
    // ceiling.
    let mut cached_engine = RoundEngine::new(&cfg.engine);
    let t = Instant::now();
    for _ in 0..engine_rounds {
        common::black_box(
            cached_engine
                .fedpairing_round(
                    &fleet,
                    &matching.pairs,
                    &matching.solos,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    true,
                )
                .total_s,
        );
    }
    let cached_rps = engine_rounds as f64 / t.elapsed().as_secs_f64();
    let looked_up = cached_engine.cache_hits() + cached_engine.cache_misses();
    let cache_hit_rate = cached_engine.cache_hits() as f64 / looked_up.max(1) as f64;

    Case {
        n,
        pairs: matching.pairs.len(),
        engine_rps,
        des_rps,
        speedup: engine_rps / des_rps,
        cached_rps,
        cache_hit_rate,
    }
}

fn main() {
    println!("== round engine vs DES-per-pair oracle (metro-scale fading, FedPairing) ==");
    println!(
        "  {:>7} {:>9} {:>12} {:>12} {:>9} {:>12} {:>7}",
        "n", "pairs", "engine r/s", "des r/s", "speedup", "cached r/s", "hit%"
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut metro_speedup = 0.0;
    for (n, engine_rounds, des_rounds) in [(1_000, 200, 40), (10_000, 200, 10), (50_000, 200, 5)] {
        let case = run_case(n, engine_rounds, des_rounds);
        println!(
            "  {:>7} {:>9} {:>12.1} {:>12.2} {:>8.1}x {:>12.1} {:>6.1}%",
            case.n,
            case.pairs,
            case.engine_rps,
            case.des_rps,
            case.speedup,
            case.cached_rps,
            100.0 * case.cache_hit_rate
        );
        if n == 50_000 {
            metro_speedup = case.speedup;
        }
        let mut row = JsonObj::new();
        row.insert("n", Json::num(case.n as f64));
        row.insert("pairs", Json::num(case.pairs as f64));
        row.insert("engine_rounds_per_s", Json::num(case.engine_rps));
        row.insert("des_rounds_per_s", Json::num(case.des_rps));
        row.insert("speedup", Json::num(case.speedup));
        row.insert("cached_rounds_per_s", Json::num(case.cached_rps));
        row.insert("stable_cache_hit_rate", Json::num(case.cache_hit_rate));
        rows.push(Json::Obj(row));
    }
    common::check_shape(
        "metro (n=50k, 200 rounds): engine >= 20x DES-per-pair",
        metro_speedup >= 20.0,
    );

    // Million-client engine round, analytic path only (the DES oracle is
    // the measured slow side above and has no business at 1M). Adjacent-id
    // pairs: matching quality is irrelevant to engine throughput.
    println!("== million-client engine round (analytic, per-round fading) ==");
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = 1_000_000;
    cfg.seed = 17;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let pairs: Vec<(usize, usize)> = (0..cfg.n_clients / 2).map(|i| (2 * i, 2 * i + 1)).collect();
    let solos: Vec<usize> = Vec::new();
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let mut engine = RoundEngine::new(&cfg.engine);
    let rounds_1m = 10usize;
    let channels = faded_channels(&cfg, rounds_1m);
    let t = Instant::now();
    let mut acc = 0.0f64;
    for ch in &channels {
        acc += engine
            .fedpairing_round(&fleet, &pairs, &solos, &profile, &sched, ch, &cfg.compute, true)
            .total_s;
    }
    let million_round_s = t.elapsed().as_secs_f64() / rounds_1m as f64;
    common::black_box(acc);
    println!(
        "  1M clients, {} pairs: {} per round",
        pairs.len(),
        common::fmt_time(million_round_s)
    );
    common::check_shape("n=1M: analytic engine round under 5 s", million_round_s < 5.0);

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("round_engine"));
    out.insert("workload", Json::str("fedpairing metro-scale fading, 200-round engine runs"));
    out.insert("metro_speedup_50k", Json::num(metro_speedup));
    out.insert("million_round_s", Json::num(million_round_s));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    out.insert("results", Json::Arr(rows));
    let path = "BENCH_round_engine.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
