//! Shared bench harness (substrate — `criterion` is unavailable offline).
//!
//! Provides warmup+repeat wall-clock timing with mean/std/min reporting, and
//! table-printing helpers shared by the paper-table benches. Each bench
//! target includes this file via `#[path = "common.rs"] mod common;`.

#![allow(dead_code)]

use std::time::Instant;

/// Timing summary of a benched closure.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn report(&self) {
        println!(
            "  {:<36} {:>10} {:>10} {:>10}   ({} iters)",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.min_s),
            fmt_time(self.std_s),
            self.iters
        );
    }
}

/// Human-friendly seconds formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Header for `BenchStats::report` rows.
pub fn report_header() {
    println!(
        "  {:<36} {:>10} {:>10} {:>10}",
        "benchmark", "mean", "min", "std"
    );
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(0.0, f64::max),
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a paper-comparison table row.
pub fn paper_row(label: &str, measured: f64, paper: Option<f64>) {
    match paper {
        Some(p) => println!("  {label:<28} {measured:>9.0} s    (paper: {p:.0} s)"),
        None => println!("  {label:<28} {measured:>9.0} s    (paper: —)"),
    }
}

/// Assert-with-report: prints PASS/FAIL for a shape property without
/// aborting the bench (benches report; CI greps the logs for FAIL).
pub fn check_shape(what: &str, ok: bool) {
    println!("  shape[{}]: {}", what, if ok { "PASS" } else { "FAIL (see EXPERIMENTS.md)" });
}

/// Peak resident set size of this process (`VmHWM` from
/// `/proc/self/status`) in bytes. Linux only — `None` elsewhere, so bench
/// JSON fields stay optional rather than lying with zeros.
pub fn peak_rss_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
        let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
        Some(kb * 1024)
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Report peak RSS on stdout and return it in MiB for JSON (when known).
pub fn report_peak_rss() -> Option<f64> {
    let mb = peak_rss_bytes()? as f64 / (1024.0 * 1024.0);
    println!("  peak RSS (VmHWM): {mb:.0} MiB");
    Some(mb)
}
