//! Fault-injection overhead and deadline-vs-wait-for-all bench (DESIGN.md
//! §11) on the 50k-client lossy-radio preset.
//!
//! Two acceptance shapes:
//!
//! 1. **Disabled-path overhead** — the fault machinery must be free when
//!    nothing fires. Measured A/B (best of 3): hazards disarmed vs an armed
//!    model with zero hazards and a never-binding deadline. The armed side
//!    replays every unit through the fault pass, so its delta is an upper
//!    bound on what a disarmed run (which skips the pass entirely) can pay.
//!    Gate: < 1 %.
//! 2. **Deadline beats wait-for-all** — under injected stragglers (link
//!    drops with exponential-backoff retries), a server deadline at 75 % of
//!    the fault-free mean round must finish the run in less simulated time
//!    than waiting for every retry, at the price of lost updates (reported,
//!    and required > 0 so the tradeoff is real, not vacuous).
//!
//! Emits `BENCH_faults.json` for CI; FAIL lines are grepped like the other
//! scale benches.

#[path = "common.rs"]
mod common;

use fedpairing::config::{Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind};
use fedpairing::fleet::{simulate_scenario, ScenarioRun};
use fedpairing::util::json::{Json, JsonObj};

const N: usize = 50_000;
const ROUNDS: usize = 15;

/// Far beyond any makespan: arms the fault pass without ever binding.
const NEVER_BINDS_S: f64 = 1e30;

fn cfg() -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = N;
    c.rounds = ROUNDS;
    c.algorithm = Algorithm::FedPairing;
    c.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
    c
}

fn sim_total(run: &ScenarioRun) -> f64 {
    run.result.rounds.last().expect("rounds").sim_total_s
}

fn lost_updates(run: &ScenarioRun) -> usize {
    run.result.rounds.iter().map(|r| r.faults.n_lost_updates).sum()
}

fn main() {
    println!("bench_faults — fault-pass overhead and deadline cutoff (n={N}, lossy radio)\n");

    // ── Shape 1: the fault pass is free when nothing fires ────────────────
    let disarmed = cfg();
    let mut armed = disarmed.clone();
    armed.faults.deadline_s = NEVER_BINDS_S;

    // One untimed run each: warmup, calibration (fault-free round times) and
    // the zero-hazard counter check.
    let clean = simulate_scenario(&disarmed).expect("disarmed run");
    let armed_run = simulate_scenario(&armed).expect("armed zero-hazard run");
    let counters_clean = armed_run
        .result
        .rounds
        .iter()
        .all(|r| r.faults.n_failed == 0 && r.faults.n_retries == 0 && r.faults.n_lost_updates == 0);

    common::report_header();
    let off = common::bench("faults disarmed", 0, 3, || {
        common::black_box(simulate_scenario(&disarmed).expect("disarmed run"));
    });
    off.report();
    let on = common::bench("armed, zero hazards (replay only)", 0, 3, || {
        common::black_box(simulate_scenario(&armed).expect("armed run"));
    });
    on.report();
    let overhead = on.min_s / off.min_s - 1.0;
    println!("  armed no-op delta (best of 3): {:+.2}%\n", overhead * 100.0);

    // ── Shape 2: deadline partial aggregation vs wait-for-all ─────────────
    let mean_clean =
        clean.result.rounds.iter().map(|r| r.sim_round_s).sum::<f64>() / ROUNDS as f64;
    let mut waitall = disarmed.clone();
    waitall.faults.link_drop = 0.15;
    waitall.faults.uplink_loss = 0.05;
    let mut deadline = waitall.clone();
    deadline.faults.deadline_s = 0.75 * mean_clean;

    let w = simulate_scenario(&waitall).expect("wait-for-all run");
    let d = simulate_scenario(&deadline).expect("deadline run");
    let (w_total, d_total) = (sim_total(&w), sim_total(&d));
    let (w_lost, d_lost) = (lost_updates(&w), lost_updates(&d));
    let w_retries: usize = w.result.rounds.iter().map(|r| r.faults.n_retries).sum();
    println!(
        "  {:<28} {:>14} {:>12} {:>10}",
        "recovery policy", "sim total", "lost upd", "retries"
    );
    println!("  {:<28} {w_total:>12.0} s {w_lost:>12} {w_retries:>10}", "wait-for-all");
    println!(
        "  {:<28} {d_total:>12.0} s {d_lost:>12} {:>10}",
        format!("deadline @ {:.0} s", deadline.faults.deadline_s),
        d.result.rounds.iter().map(|r| r.faults.n_retries).sum::<usize>(),
    );
    println!("  deadline speedup: {:.2}x\n", w_total / d_total);

    common::check_shape("armed zero-hazard counters all zero", counters_clean);
    common::check_shape("fault machinery when disabled costs < 1%", overhead < 0.01);
    common::check_shape("deadline beats wait-for-all sim time", d_total < w_total);
    common::check_shape("deadline tradeoff is real (loses more updates)", d_lost > w_lost);
    let rss_mb = common::report_peak_rss();

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("faults"));
    out.insert(
        "workload",
        Json::str("fedpairing lossy-radio 50k, fault-pass A/B + deadline cutoff"),
    );
    out.insert("n", Json::num(N as f64));
    out.insert("rounds", Json::num(ROUNDS as f64));
    out.insert("disarmed_wall_s", Json::num(off.min_s));
    out.insert("armed_zero_wall_s", Json::num(on.min_s));
    out.insert("armed_noop_overhead_frac", Json::num(overhead));
    out.insert("mean_clean_round_s", Json::num(mean_clean));
    out.insert("deadline_s", Json::num(deadline.faults.deadline_s));
    out.insert("waitall_sim_total_s", Json::num(w_total));
    out.insert("deadline_sim_total_s", Json::num(d_total));
    out.insert("deadline_speedup", Json::num(w_total / d_total));
    out.insert("waitall_lost_updates", Json::num(w_lost as f64));
    out.insert("deadline_lost_updates", Json::num(d_lost as f64));
    out.insert("waitall_retries", Json::num(w_retries as f64));
    if let Some(mb) = rss_mb {
        out.insert("peak_rss_mib", Json::num(mb));
    }
    let path = "BENCH_faults.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
