//! Buffered-aggregation throughput bench: synchronous lockstep rounds vs the
//! event-driven bounded-staleness server (DESIGN.md §9) on the lossy-radio
//! preset at n = 1k / 10k / 50k.
//!
//! The comparison runs VanillaFL so "update" means the same thing on both
//! sides — one client delivery — and throughput is updates merged per
//! *simulated* second. Sync merges `n_alive` updates once per straggler-bound
//! round; async merges a quorum as soon as it lands, so its rate approaches
//! `Σ 1/dᵢ` (harmonic) instead of `n / max dᵢ`. The acceptance shape: at
//! n = 50k the async server sustains ≥ 2× the sync update throughput.
//!
//! Emits `BENCH_async.json` for CI.

#[path = "common.rs"]
mod common;

use fedpairing::config::{
    AggregationMode, Algorithm, ExperimentConfig, ScenarioConfig, ScenarioKind,
};
use fedpairing::fleet::{simulate_scenario, ScenarioRun};
use fedpairing::util::json::{Json, JsonObj};
use std::time::Instant;

const WINDOWS: usize = 30;
const SIZES: [usize; 3] = [1_000, 10_000, 50_000];
const STALENESS_CAP: usize = 32;

fn cfg(n: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default();
    c.n_clients = n;
    c.rounds = WINDOWS;
    c.algorithm = Algorithm::VanillaFL;
    c.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
    c
}

/// Updates merged per simulated second over a finished run.
fn sync_throughput(run: &ScenarioRun) -> f64 {
    let updates: usize = run.result.rounds.iter().map(|r| r.n_alive).sum();
    updates as f64 / run.result.rounds.last().expect("rounds").sim_total_s
}

fn async_throughput(run: &ScenarioRun) -> f64 {
    let updates: usize = run.events.iter().map(|e| e.n_updates).sum();
    updates as f64 / run.events.last().expect("events").t_wall_s
}

fn main() {
    println!("bench_async_engine — sync barrier vs buffered aggregation (lossy radio)\n");
    println!(
        "  {:<10} {:>14} {:>14} {:>8} {:>12} {:>14} {:>10}",
        "n", "sync upd/s", "async upd/s", "ratio", "staleness", "wait saved", "wall"
    );
    let mut rows = Vec::new();
    let mut ratio_50k = 0.0f64;
    for n in SIZES {
        let base = cfg(n);
        let mut asy = base.clone();
        asy.aggregation = AggregationMode::Async;
        asy.async_agg.buffer_size = (n / 8).max(1);
        asy.async_agg.staleness_cap = STALENESS_CAP;

        let t = Instant::now();
        let sync_run = simulate_scenario(&base).expect("sync run");
        let sync_wall = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let async_run = simulate_scenario(&asy).expect("async run");
        let async_wall = t.elapsed().as_secs_f64();

        let s_thpt = sync_throughput(&sync_run);
        let a_thpt = async_throughput(&async_run);
        let ratio = a_thpt / s_thpt;
        let merged: usize = async_run.events.iter().map(|e| e.n_updates).sum();
        let staleness = async_run
            .events
            .iter()
            .map(|e| e.staleness_mean * e.n_updates as f64)
            .sum::<f64>()
            / merged as f64;
        let stale_max = async_run.events.iter().map(|e| e.staleness_max).max().unwrap_or(0);
        let wait_saved: f64 = async_run.events.iter().map(|e| e.wait_eliminated_s).sum();
        if n == 50_000 {
            ratio_50k = ratio;
        }
        println!(
            "  {n:<10} {s_thpt:>14.1} {a_thpt:>14.1} {ratio:>7.2}x {staleness:>12.2} \
             {:>12.0} s {:>10}",
            wait_saved,
            common::fmt_time(sync_wall + async_wall),
        );
        common::black_box((s_thpt, a_thpt));

        let mut row = JsonObj::new();
        row.insert("n", Json::num(n as f64));
        row.insert("windows", Json::num(WINDOWS as f64));
        row.insert("buffer_size", Json::num(asy.async_agg.buffer_size as f64));
        row.insert("staleness_cap", Json::num(STALENESS_CAP as f64));
        row.insert("sync_updates_per_sim_s", Json::num(s_thpt));
        row.insert("async_updates_per_sim_s", Json::num(a_thpt));
        row.insert("throughput_ratio", Json::num(ratio));
        row.insert("async_staleness_mean", Json::num(staleness));
        row.insert("async_staleness_max", Json::num(stale_max as f64));
        row.insert("async_wait_eliminated_s", Json::num(wait_saved));
        row.insert("sync_wall_s", Json::num(sync_wall));
        row.insert("async_wall_s", Json::num(async_wall));
        rows.push(Json::Obj(row));
    }
    println!();
    common::check_shape("async >= 2x sync update throughput at n=50k", ratio_50k >= 2.0);

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("async_engine"));
    out.insert(
        "workload",
        Json::str("vanilla-fl lossy-radio, sync barrier vs bounded-staleness buffer"),
    );
    out.insert("rows", Json::Arr(rows));
    out.insert("throughput_ratio_50k", Json::num(ratio_50k));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    let path = "BENCH_async.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
