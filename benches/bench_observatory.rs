//! Distribution-observatory overhead bench: 50k-client metro-scale engine
//! rounds with per-unit attribution + observatory feeds off vs on. The
//! drivers feed the observatory unconditionally, so the acceptance
//! criterion pins the cost of that decision:
//!
//! * **observatory < 5 %** — per-unit time/split recording, the quantile
//!   sketch lanes, the per-round exact lanes and the per-client ledger may
//!   not tax the honest metro workload (per-round fading → re-priced units
//!   every round).
//!
//! Emits `BENCH_observatory.json` (including peak RSS) for the CI scale job.

#[path = "common.rs"]
mod common;

use fedpairing::config::ExperimentConfig;
use fedpairing::pairing::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::engine::RoundEngine;
use fedpairing::sim::latency::{Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::telemetry::{export, Observatory};
use fedpairing::util::json::{Json, JsonObj};
use fedpairing::util::rng::Rng;
use std::time::Instant;

const N_CLIENTS: usize = 50_000;
const ROUNDS: usize = 100;

/// Per-round channels under metro-scale block fading (2 dB log-normal) —
/// every pass replays the identical sequence.
fn faded_channels(cfg: &ExperimentConfig, rounds: usize) -> Vec<Channel> {
    let mut rng = Rng::with_stream(cfg.seed, 0xFADE);
    (0..rounds)
        .map(|_| {
            let mut ch = cfg.channel;
            ch.ref_gain *= 10f64.powf(rng.normal_ms(0.0, 2.0) / 10.0);
            Channel::new(ch)
        })
        .collect()
}

fn main() {
    let mut cfg = ExperimentConfig::preset("metro-scale").expect("metro-scale preset");
    cfg.n_clients = N_CLIENTS;
    cfg.seed = 29;
    let fleet = Fleet::sample(&cfg, &mut Rng::new(cfg.seed));
    let channel = Channel::new(cfg.channel);
    let members: Vec<usize> = (0..N_CLIENTS).collect();
    let graph = SparseCandidateGraph::build(
        &fleet,
        &channel,
        EdgeWeightSpec::Eq5 {
            alpha: cfg.alpha,
            beta: cfg.beta,
        },
        cfg.backend.k_near,
        cfg.backend.k_freq,
    );
    let matching = match_candidates(&graph, &members);
    let profile = ModelProfile::resnet18_cifar();
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let channels = faded_channels(&cfg, ROUNDS);

    // One timed pass: a fresh engine over the fade sequence, optionally
    // recording per-unit attribution and feeding the observatory exactly
    // like the drivers do (roster build included — it is per-round work).
    let run_pass = |observe: bool| -> (f64, Observatory) {
        let mut engine = RoundEngine::new(&cfg.engine);
        engine.set_record_units(observe);
        let mut obs = Observatory::new();
        let t = Instant::now();
        for ch in &channels {
            let rt = engine.fedpairing_round(
                &fleet,
                &matching.pairs,
                &matching.solos,
                &profile,
                &sched,
                ch,
                &cfg.compute,
                true,
            );
            if observe {
                let units: Vec<(usize, Option<usize>)> = matching
                    .pairs
                    .iter()
                    .map(|&(a, b)| (a, Some(b)))
                    .chain(matching.solos.iter().map(|&s| (s, None)))
                    .collect();
                let mk = obs.note_sync_round(
                    &units,
                    engine.unit_times(),
                    engine.unit_splits(),
                    rt.total_s,
                    &[],
                );
                obs.note_stages(&rt.stages);
                common::black_box(mk.p99_s);
            }
            common::black_box(rt.total_s);
        }
        (t.elapsed().as_secs_f64(), obs)
    };

    println!(
        "== observatory overhead (n={N_CLIENTS}, {} pairs, {ROUNDS} faded engine rounds) ==",
        matching.pairs.len()
    );

    // Warmup (untimed), then the A/A off pair and the observed pass.
    run_pass(false);
    let (off_a, _) = run_pass(false);
    let (off_b, _) = run_pass(false);
    let (on, obs) = run_pass(true);

    let off_min = off_a.min(off_b);
    let noise_pct = 100.0 * (off_b - off_a) / off_a;
    let overhead_pct = 100.0 * (on - off_min) / off_min;
    println!("  {:<22} {:>10.2} rounds/s", "off (pass A)", ROUNDS as f64 / off_a);
    println!("  {:<22} {:>10.2} rounds/s", "off (pass B)", ROUNDS as f64 / off_b);
    println!("  {:<22} {:>10.2} rounds/s", "observatory on", ROUNDS as f64 / on);
    println!("  off A/A delta: {noise_pct:+.2} %   observatory: {overhead_pct:+.2} %");

    // Sanity of the collected distribution + the export render cost.
    let t = Instant::now();
    let prom = export::observatory(&obs, 8);
    let render_s = t.elapsed().as_secs_f64();
    let jain = obs.ledger.jain();
    println!(
        "  sketch: {} units, sum {:.0} s   fairness (Jain): {jain:.4}   \
         prom render: {} ({} bytes)",
        obs.unit_makespan.count(),
        obs.unit_makespan.sum_secs(),
        common::fmt_time(render_s),
        prom.len(),
    );
    common::check_shape(
        "observatory feed overhead < 5% at n=50k",
        overhead_pct < 5.0,
    );
    common::check_shape(
        "sketch saw every unit every round",
        obs.unit_makespan.count()
            == ((matching.pairs.len() + matching.solos.len()) * ROUNDS) as u64,
    );
    common::check_shape("fairness index well-formed", jain > 0.0 && jain <= 1.0 + 1e-12);

    let mut out = JsonObj::new();
    out.insert("bench", Json::str("observatory"));
    out.insert(
        "workload",
        Json::str("fedpairing metro-scale fading, observatory feeds off / on"),
    );
    out.insert("n", Json::num(N_CLIENTS as f64));
    out.insert("pairs", Json::num(matching.pairs.len() as f64));
    out.insert("rounds", Json::num(ROUNDS as f64));
    out.insert("off_a_rounds_per_s", Json::num(ROUNDS as f64 / off_a));
    out.insert("off_b_rounds_per_s", Json::num(ROUNDS as f64 / off_b));
    out.insert("on_rounds_per_s", Json::num(ROUNDS as f64 / on));
    out.insert("off_aa_delta_pct", Json::num(noise_pct));
    out.insert("observatory_overhead_pct", Json::num(overhead_pct));
    out.insert("fairness_jain", Json::num(jain));
    out.insert("sketch_units", Json::num(obs.unit_makespan.count() as f64));
    out.insert("prom_render_s", Json::num(render_s));
    if let Some(mb) = common::report_peak_rss() {
        out.insert("peak_rss_mb", Json::num(mb));
    }
    let path = "BENCH_observatory.json";
    std::fs::write(path, Json::Obj(out).to_string_pretty(2)).expect("write bench json");
    println!("wrote {path}");
}
