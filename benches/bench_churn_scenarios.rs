//! Fleet-dynamics benchmarks: scenario-simulation throughput per preset, and
//! the cost of *incremental* matching repair vs. a full re-pair after a
//! single departure — the optimization that makes per-round churn handling
//! O(affected²) instead of O(n²).
//!
//! ```bash
//! cargo bench --bench bench_churn_scenarios
//! ```

#[path = "common.rs"]
mod common;

use common::{bench, report_header};
use fedpairing::config::{Algorithm, ExperimentConfig, PairingStrategy, ScenarioConfig, ScenarioKind};
use fedpairing::fleet::simulate_scenario;
use fedpairing::pairing::{pair_members, repair_matching};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::Fleet;
use fedpairing::util::rng::Rng;

fn scenario_sim_benches() {
    println!("— scenario simulation (FedPairing, 20 clients × 50 rounds, latency only) —");
    report_header();
    for kind in ScenarioKind::ALL {
        let mut cfg = ExperimentConfig::default();
        cfg.rounds = 50;
        cfg.algorithm = Algorithm::FedPairing;
        cfg.scenario = ScenarioConfig::preset(kind);
        let stats = bench(kind.name(), 1, 5, || {
            let run = simulate_scenario(&cfg).expect("scenario run");
            common::black_box(run.result.rounds.len());
        });
        stats.report();
    }
}

fn repair_vs_full_benches() {
    println!("\n— one departure: incremental repair vs full re-pair —");
    report_header();
    for &n in &[20usize, 50, 100, 200] {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        let fleet = Fleet::sample(&cfg, &mut Rng::new(7));
        let channel = Channel::new(cfg.channel);
        let all: Vec<usize> = (0..n).collect();
        let mut rng = Rng::new(8);
        let base = pair_members(
            PairingStrategy::Greedy,
            &fleet,
            &channel,
            cfg.alpha,
            cfg.beta,
            &mut rng,
            &all,
        );
        // The departed client and the resulting alive set.
        let members: Vec<usize> = (0..n).filter(|&c| c != n / 2).collect();
        let freqs = fleet.freqs_hz.clone();
        let pos = fleet.positions.clone();
        let ch = channel.clone();
        let weight = move |a: usize, b: usize| {
            let df = (freqs[a] - freqs[b]) / 1e9;
            df * df + 2e-9 * ch.rate(&pos[a], &pos[b])
        };
        let stats = bench(&format!("repair n={n}"), 3, 20, || {
            let mut m = base.clone();
            let rep = repair_matching(&mut m, &members, &weight);
            common::black_box(rep.changed());
        });
        stats.report();
        let stats = bench(&format!("full re-pair n={n}"), 3, 20, || {
            let mut rng = Rng::new(9);
            let m = pair_members(
                PairingStrategy::Greedy,
                &fleet,
                &channel,
                cfg.alpha,
                cfg.beta,
                &mut rng,
                &members,
            );
            common::black_box(m.pairs.len());
        });
        stats.report();
    }
    println!("\nshape: repair cost stays near-constant in n (pool = widow only), while a");
    println!("full re-pair rebuilds all O(n²) eq.(5) edges and re-sorts them.");
}

fn main() {
    println!("bench_churn_scenarios — fleet dynamics\n");
    scenario_sim_benches();
    repair_vs_full_benches();
}
