//! Paper Table II: average round time under different FL algorithms.
//!
//! Same paper-scale workload as bench_table1. Paper row:
//! SL 106 s < FedPairing 1553 s < SplitFed 1798 s < FL 8716 s.
//!
//! Known, documented deviation (EXPERIMENTS.md): vanilla SL's 106 s implies
//! negligible activation traffic; charging eq. (3) honestly puts SL near (not
//! far below) FedPairing. We report both the honest SL and a comm-free SL
//! matching the paper's accounting.

#[path = "common.rs"]
mod common;

use fedpairing::config::{ExperimentConfig, PairingStrategy};
use fedpairing::pairing::pair_clients;
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::{fedpairing_round, fl_round, sl_round, splitfed_round, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::util::rng::Rng;
use fedpairing::util::stats::Summary;

struct Row {
    fp: f64,
    sf: f64,
    fl: f64,
    sl: f64,
    sl_commfree: f64,
}

fn rows(cfg: &ExperimentConfig, seed: u64) -> Row {
    let mut cfg = cfg.clone();
    cfg.seed = seed;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let ch = Channel::new(cfg.channel);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let profile = ModelProfile::resnet18_cifar();
    let pairs = pair_clients(
        PairingStrategy::Greedy,
        &fleet,
        &ch,
        cfg.alpha,
        cfg.beta,
        &mut rng.fork(7),
    );
    let server = cfg.compute.server_freq_ghz * 1e9;
    let fp = fedpairing_round(&fleet, &pairs, &profile, &sched, &ch, &cfg.compute, true).total_s;
    let sf = splitfed_round(
        &fleet, &profile, &sched, &ch, &cfg.compute, cfg.splitfed_cut_layer, server, true,
    )
    .total_s;
    let fl = fl_round(&fleet, &profile, &sched, &ch, &cfg.compute, true).total_s;
    let sl = sl_round(&fleet, &profile, &sched, &ch, &cfg.compute, cfg.sl_cut_layer, server).total_s;
    // Comm-free SL: the paper's accounting — infinite-rate links.
    let mut free = cfg.clone();
    free.channel.ref_gain = 1e6; // effectively infinite SNR
    let ch_free = Channel::new(free.channel);
    let sl_commfree =
        sl_round(&fleet, &profile, &sched, &ch_free, &cfg.compute, cfg.sl_cut_layer, server).total_s;
    Row {
        fp,
        sf,
        fl,
        sl,
        sl_commfree,
    }
}

fn main() {
    let cfg = ExperimentConfig::default();
    println!("== Table II: avg round time by algorithm ==");
    println!("-- single draw (seed 17), paper-comparable --");
    let r = rows(&cfg, 17);
    common::paper_row("fedpairing", r.fp, Some(1553.0));
    common::paper_row("splitfed", r.sf, Some(1798.0));
    common::paper_row("vanilla_fl", r.fl, Some(8716.0));
    common::paper_row("vanilla_sl (honest comm)", r.sl, Some(106.0));
    common::paper_row("vanilla_sl (comm-free)", r.sl_commfree, Some(106.0));
    common::check_shape("fedpairing beats splitfed", r.fp < r.sf);
    common::check_shape("fedpairing beats fl", r.fp < r.fl);
    common::check_shape("splitfed beats fl", r.sf < r.fl);
    common::check_shape(
        "fl/fedpairing speedup in paper ballpark (>3x)",
        r.fl / r.fp > 3.0,
    );
    common::check_shape("comm-free sl fastest (paper accounting)", r.sl_commfree < r.fp);

    println!("-- 20-draw mean ± std --");
    let mut s = [(); 5].map(|_| Summary::new());
    for seed in 0..20 {
        let r = rows(&cfg, 2000 + seed);
        for (i, v) in [r.fp, r.sf, r.fl, r.sl, r.sl_commfree].into_iter().enumerate() {
            s[i].push(v);
        }
    }
    for (name, sum) in [
        "fedpairing",
        "splitfed",
        "vanilla_fl",
        "vanilla_sl (honest)",
        "vanilla_sl (comm-free)",
    ]
    .iter()
    .zip(&s)
    {
        println!("  {:<28} {:>9.0} ± {:>5.0} s", name, sum.mean(), sum.std());
    }

    println!("-- latency-sim wall cost (full 20-client round) --");
    common::report_header();
    common::bench("fedpairing_round (DES)", 2, 10, || {
        common::black_box(rows(&cfg, 99).fp);
    })
    .report();
}
