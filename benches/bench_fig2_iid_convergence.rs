//! Paper Fig. 2: convergence (top-1 accuracy vs round) on IID data, for
//! FedPairing / vanilla FL / vanilla SL / SplitFed — real training through
//! the AOT artifacts (requires `make artifacts`).
//!
//! Reduced scale for bench runtime (12 clients × 160 samples × 15 rounds —
//! the full-scale curve is `examples/noniid_convergence.rs`); the *shape*
//! targets are the paper's: FedPairing reaches the top accuracy band and FL
//! is competitive, with SplitFed lagging.

#[path = "common.rs"]
mod common;

use fedpairing::config::{Algorithm, ExperimentConfig};
use fedpairing::coordinator::run_experiment;

const ROUNDS: usize = 12;

fn cfg_for(algo: Algorithm) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("fig2").unwrap();
    cfg.algorithm = algo;
    cfg.n_clients = 8;
    cfg.samples_per_client = 96;
    cfg.noise_level = 2.5;
    cfg.rounds = ROUNDS;
    cfg.test_samples = 600;
    cfg.seed = 17;
    cfg
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    println!("== Fig. 2: IID convergence (8 clients x 96 samples, {ROUNDS} rounds) ==");
    let algos = [
        Algorithm::FedPairing,
        Algorithm::VanillaFL,
        Algorithm::VanillaSL,
        Algorithm::SplitFed,
    ];
    let mut results = Vec::new();
    for algo in algos {
        let t0 = std::time::Instant::now();
        let res = run_experiment(cfg_for(algo)).expect("run");
        println!(
            "  {:<12} final={:.4} best={:.4}  [{:.0}s wall, {} execs]",
            algo.name(),
            res.final_acc(),
            res.best_acc(),
            t0.elapsed().as_secs_f64(),
            res.total_execs
        );
        print!("    curve:");
        for (round, acc) in res.acc_curve() {
            if round % 3 == 0 || round == 1 || round == ROUNDS {
                print!(" {round}:{acc:.3}");
            }
        }
        println!();
        results.push((algo, res));
    }
    let acc = |a: Algorithm| {
        results
            .iter()
            .find(|(x, _)| *x == a)
            .map(|(_, r)| r.final_acc())
            .unwrap()
    };
    println!("-- paper deltas (FedPairing vs X, final round): FL +4.1pp SL +1.8pp SplitFed +10.8pp --");
    println!(
        "  measured: FL {:+.1}pp  SL {:+.1}pp  SplitFed {:+.1}pp",
        (acc(Algorithm::FedPairing) - acc(Algorithm::VanillaFL)) * 100.0,
        (acc(Algorithm::FedPairing) - acc(Algorithm::VanillaSL)) * 100.0,
        (acc(Algorithm::FedPairing) - acc(Algorithm::SplitFed)) * 100.0
    );
    common::check_shape(
        "fedpairing in top accuracy band (>= best - 2pp)",
        acc(Algorithm::FedPairing)
            >= results.iter().map(|(_, r)| r.final_acc()).fold(0.0, f64::max) - 0.02,
    );
    common::check_shape(
        "fedpairing >= splitfed - 1pp (paper: +10.8pp; sound implementations tie)",
        acc(Algorithm::FedPairing) >= acc(Algorithm::SplitFed) - 0.01,
    );
    common::check_shape(
        "all algorithms learn (>= 3x chance)",
        results.iter().all(|(_, r)| r.final_acc() > 0.3),
    );
}
