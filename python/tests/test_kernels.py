"""L1 correctness: Pallas kernels vs the pure-jnp oracles in `ref.py`.

Hypothesis sweeps shapes (and the relu/residual feature matrix) and asserts
allclose — the core signal that the HLO artifacts the Rust coordinator
executes compute the right numbers.
"""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.linear import fused_linear, _pick_block
from compile.kernels.linear_vjp import fused_linear_ad
from compile.kernels.softmax_xent import softmax_xent
from compile.kernels.ref import fused_linear_ref, softmax_xent_ref

import jax

RTOL = 2e-5
ATOL = 2e-5


def rand(rng, *shape):
    return rng.standard_normal(shape, dtype=np.float32)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    activation=st.sampled_from(["relu", "none"]),
    residual=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, activation, residual, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    res = rand(rng, m, n) if residual else None
    got = fused_linear(x, w, b, res, activation=activation)
    want = fused_linear_ref(x, w, b, activation, res)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(
    blocks=st.tuples(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64)),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_block_size_invariance(blocks, seed):
    """Any block configuration computes the same numbers (tiling is pure
    scheduling — the invariant behind the CPU-vs-TPU block-size choice)."""
    bm, bn, bk = blocks
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, 24, 36), rand(rng, 36, 20), rand(rng, 20)
    base = fused_linear(x, w, b, activation="relu")
    got = fused_linear(x, w, b, activation="relu", block_m=bm, block_n=bn, block_k=bk)
    # K-blocking changes f32 accumulation order → tiny representation noise.
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


def test_fused_linear_exact_paper_shapes():
    """The exact shapes the AOT model uses (3072→256, 256→256, 256→10)."""
    rng = np.random.default_rng(0)
    for (m, k, n) in [(32, 3072, 256), (32, 256, 256), (32, 256, 10)]:
        x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
        np.testing.assert_allclose(
            fused_linear(x, w, b, activation="none"),
            fused_linear_ref(x, w, b, "none"),
            rtol=RTOL,
            atol=ATOL,
        )


def test_fused_linear_residual_after_activation():
    """Residual must be added *after* relu: relu(0)+res == res exactly."""
    x = np.zeros((4, 8), np.float32)
    w = np.zeros((8, 8), np.float32)
    b = np.zeros(8, np.float32)
    res = np.full((4, 8), -3.0, np.float32)
    out = np.asarray(fused_linear(x, w, b, res, activation="relu"))
    np.testing.assert_array_equal(out, res)


def test_fused_linear_rejects_bad_shapes():
    rng = np.random.default_rng(1)
    with pytest.raises(ValueError):
        fused_linear(rand(rng, 4, 5), rand(rng, 6, 7), rand(rng, 7))
    with pytest.raises(ValueError):
        fused_linear(rand(rng, 4, 5), rand(rng, 5, 7), rand(rng, 8))
    with pytest.raises(ValueError):
        fused_linear(rand(rng, 4, 5), rand(rng, 5, 7), rand(rng, 7),
                     rand(rng, 3, 7))
    with pytest.raises(ValueError):
        fused_linear(rand(rng, 4, 5), rand(rng, 5, 7), rand(rng, 7),
                     activation="gelu")


def test_pick_block_divides():
    for dim in [1, 7, 32, 96, 3072]:
        for target in [1, 8, 128, 4096]:
            blk = _pick_block(dim, target)
            assert dim % blk == 0
            assert blk <= max(dim, target)


# ---------------------------------------------------------------------------
# fused_linear_ad (custom VJP)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 16),
    k=st.integers(2, 24),
    n=st.integers(2, 16),
    activation=st.sampled_from(["relu", "none"]),
    residual=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_vjp_matches_autodiff_of_ref(m, k, n, activation, residual, seed):
    """Gradients through the Pallas custom-vjp == jax.grad of the jnp ref."""
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    res = rand(rng, m, n) if residual else None

    def f_kernel(x, w, b, res):
        return jnp.sum(fused_linear_ad(x, w, b, res, activation) ** 2)

    def f_ref(x, w, b, res):
        return jnp.sum(fused_linear_ref(x, w, b, activation, res) ** 2)

    args = (x, w, b, res) if residual else (x, w, b, None)
    argnums = (0, 1, 2, 3) if residual else (0, 1, 2)
    g_kernel = jax.grad(f_kernel, argnums)(*args)
    g_ref = jax.grad(f_ref, argnums)(*args)
    for gk, gr in zip(g_kernel, g_ref):
        np.testing.assert_allclose(gk, gr, rtol=5e-4, atol=5e-4)


def test_vjp_relu_mask_at_zero():
    """Subgradient convention at relu(0): gradient must be 0, matching jnp."""
    x = np.zeros((2, 2), np.float32)
    w = np.zeros((2, 2), np.float32)
    b = np.zeros(2, np.float32)

    def f(x):
        return jnp.sum(fused_linear_ad(x, w, b, None, "relu"))

    g = jax.grad(f)(x)
    np.testing.assert_array_equal(np.asarray(g), np.zeros((2, 2), np.float32))


# ---------------------------------------------------------------------------
# softmax_xent
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    c=st.integers(2, 16),
    pad=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(m, c, pad, seed):
    rng = np.random.default_rng(seed)
    pad = min(pad, m)
    logits = rand(rng, m, c) * 5.0
    labels = rng.integers(0, c, m)
    y = np.eye(c, dtype=np.float32)[labels]
    y[m - pad :] = 0.0  # padding rows
    l1, g1 = softmax_xent(logits, y)
    l2, g2 = softmax_xent_ref(logits, y)
    np.testing.assert_allclose(l1, l2, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(g1, g2, rtol=RTOL, atol=ATOL)


def test_softmax_xent_padding_rows_zero():
    rng = np.random.default_rng(3)
    logits = rand(rng, 8, 10)
    y = np.zeros((8, 10), np.float32)
    y[0, 1] = 1.0  # single real row
    loss_rows, grad = softmax_xent(logits, y)
    assert float(loss_rows[0]) > 0.0
    np.testing.assert_array_equal(np.asarray(loss_rows)[1:], 0.0)
    np.testing.assert_allclose(np.asarray(grad)[1:], 0.0, atol=1e-7)


def test_softmax_xent_extreme_logits_stable():
    """Stability: ±1e4 logits must not overflow (the max-shift trick)."""
    logits = np.array([[1e4, -1e4, 0.0], [-1e4, 1e4, 0.0]], np.float32)
    y = np.eye(3, dtype=np.float32)[[0, 0]]
    loss_rows, grad = softmax_xent(logits, y)
    assert np.all(np.isfinite(np.asarray(loss_rows)))
    assert np.all(np.isfinite(np.asarray(grad)))
    assert float(loss_rows[0]) < 1e-3  # confident-correct ≈ 0 loss
    assert float(loss_rows[1]) > 1e3  # confident-wrong ≈ 2e4·ln e


def test_softmax_xent_grad_is_mean_scaled():
    """Gradient rows sum to (softmax − y)/M — scale must include M."""
    rng = np.random.default_rng(5)
    for m in (4, 32):
        logits = rand(rng, m, 10)
        y = np.eye(10, dtype=np.float32)[rng.integers(0, 10, m)]
        _, g = softmax_xent(logits, y)
        _, g_ref = softmax_xent_ref(logits, y)
        np.testing.assert_allclose(g, g_ref, rtol=1e-5, atol=1e-6)
        # each real row's gradient sums to ~0 (softmax sums 1, y sums 1)
        np.testing.assert_allclose(np.asarray(g).sum(axis=1), 0.0, atol=1e-6)


def test_softmax_xent_rejects_shape_mismatch():
    with pytest.raises(ValueError):
        softmax_xent(np.zeros((4, 10), np.float32), np.zeros((4, 9), np.float32))
