"""L2 correctness: the split-vs-full equivalence invariants of the
ResNet-MLP — the mathematical heart of FedPairing's split learning.

For every split point k:
    back_fwd_k ∘ front_fwd_k  ==  full_fwd
    front_bwd_k / back_bwd_k  ==  the corresponding slices of full grads
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M


def small_cfg(layers=4):
    return M.ModelConfig(input_dim=24, hidden=16, classes=6, layers=layers)


def batch(cfg, b, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b, cfg.input_dim), dtype=np.float32)
    y = np.eye(cfg.classes, dtype=np.float32)[rng.integers(0, cfg.classes, b)]
    return x, y


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


def test_config_layer_dims():
    cfg = small_cfg(5)
    dims = cfg.layer_dims()
    assert dims[0] == (24, 16)
    assert dims[1] == (16, 16) and dims[3] == (16, 16)
    assert dims[4] == (16, 6)
    assert len(dims) == 5


def test_config_param_count():
    cfg = small_cfg(3)
    expected = (24 * 16 + 16) + (16 * 16 + 16) + (16 * 6 + 6)
    assert cfg.n_params() == expected


def test_config_rejects_too_shallow():
    with pytest.raises(ValueError):
        M.ModelConfig(layers=1)


def test_flops_per_layer():
    cfg = small_cfg(3)
    f = cfg.flops_per_layer(2)
    assert f == [2 * 2 * 24 * 16, 2 * 2 * 16 * 16, 2 * 2 * 16 * 6]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def test_init_deterministic_and_seed_sensitive():
    cfg = small_cfg()
    a = M.init_params(cfg, 0)
    b = M.init_params(cfg, 0)
    c = M.init_params(cfg, 1)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_init_zero_head_gives_ln_c_loss():
    cfg = small_cfg()
    params = M.init_params(cfg, 3)
    x, y = batch(cfg, 8, 0)
    logits = M.full_fwd(cfg, params, x)
    np.testing.assert_allclose(np.asarray(logits), 0.0, atol=1e-6)
    loss, _ = M.loss_grad(logits, y)
    np.testing.assert_allclose(float(loss), np.log(cfg.classes), rtol=1e-5)


def test_init_shapes_match_config():
    cfg = small_cfg(6)
    params = M.init_params(cfg, 7)
    shapes = cfg.param_shapes()
    assert len(params) == 2 * cfg.layers
    for i, (w_shape, b_shape) in enumerate(shapes):
        assert params[2 * i].shape == w_shape
        assert params[2 * i + 1].shape == b_shape


# ---------------------------------------------------------------------------
# split equivalence (the core invariant)
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(layers=st.integers(2, 6), b=st.integers(1, 8), seed=st.integers(0, 1000))
def test_split_fwd_equals_full_fwd_all_k(layers, b, seed):
    cfg = small_cfg(layers)
    params = M.init_params(cfg, seed)
    # perturb head so logits are non-trivial
    params = list(params)
    rng = np.random.default_rng(seed)
    params[-2] = jnp.asarray(rng.standard_normal(params[-2].shape, dtype=np.float32) * 0.1)
    x, _ = batch(cfg, b, seed)
    full = M.full_fwd(cfg, params, x)
    for k in range(1, layers):
        act = M.front_fwd(cfg, k, params[: 2 * k], x)
        logits = M.back_fwd(cfg, k, params[2 * k :], act)
        np.testing.assert_allclose(logits, full, rtol=1e-5, atol=1e-5)


@settings(max_examples=5, deadline=None)
@given(layers=st.integers(2, 5), seed=st.integers(0, 1000))
def test_split_bwd_equals_full_grads_all_k(layers, seed):
    cfg = small_cfg(layers)
    params = list(M.init_params(cfg, seed))
    rng = np.random.default_rng(seed)
    params[-2] = jnp.asarray(rng.standard_normal(params[-2].shape, dtype=np.float32) * 0.1)
    x, y = batch(cfg, 4, seed)
    out = M.full_step(cfg, params, x, y)
    g_full, loss_full = out[:-1], out[-1]
    for k in range(1, layers):
        pf, pb = params[: 2 * k], params[2 * k :]
        act = M.front_fwd(cfg, k, pf, x)
        logits = M.back_fwd(cfg, k, pb, act)
        loss, g_logits = M.loss_grad(logits, y)
        np.testing.assert_allclose(float(loss), float(loss_full), rtol=1e-5)
        bb = M.back_bwd(cfg, k, pb, act, g_logits)
        g_back, g_act = bb[:-1], bb[-1]
        g_front = M.front_bwd(cfg, k, pf, x, g_act)
        assert len(g_front) == 2 * k
        assert len(g_back) == 2 * (layers - k)
        for got, want in zip(g_front, g_full[: 2 * k]):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        for got, want in zip(g_back, g_full[2 * k :]):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_full_step_grads_match_jax_grad():
    """full_step (vjp plumbing) == jax.grad of the composed loss.

    The reference loss uses the pure-jnp softmax (the Pallas loss kernel has
    no autodiff rule — full_step deliberately routes around it by feeding the
    kernel-produced logit-gradient into the forward VJP)."""
    from compile.kernels.ref import softmax_xent_ref

    cfg = small_cfg(3)
    params = list(M.init_params(cfg, 11))
    rng = np.random.default_rng(11)
    params[-2] = jnp.asarray(rng.standard_normal(params[-2].shape, dtype=np.float32) * 0.1)
    x, y = batch(cfg, 4, 11)

    def loss_fn(p):
        logits = M.full_fwd(cfg, p, x)
        loss_rows, _ = softmax_xent_ref(logits, y)
        return jnp.sum(loss_rows) / y.shape[0]

    g_ref = jax.grad(loss_fn)(params)
    out = M.full_step(cfg, params, x, y)
    g = out[:-1]
    for got, want in zip(g, g_ref):
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# loss / eval
# ---------------------------------------------------------------------------


def test_loss_grad_padding_invariance():
    """Padding rows must not change the loss (mean over labeled rows only)."""
    cfg = small_cfg()
    rng = np.random.default_rng(2)
    logits = rng.standard_normal((8, cfg.classes), dtype=np.float32)
    y = np.eye(cfg.classes, dtype=np.float32)[rng.integers(0, cfg.classes, 8)]
    loss_full, _ = M.loss_grad(logits, y)
    # same 8 rows + 8 padding rows
    logits_pad = np.concatenate([logits, rng.standard_normal((8, cfg.classes), dtype=np.float32)])
    y_pad = np.concatenate([y, np.zeros((8, cfg.classes), np.float32)])
    loss_pad, g_pad = M.loss_grad(logits_pad, y_pad)
    np.testing.assert_allclose(float(loss_pad), float(loss_full), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_pad)[8:], 0.0, atol=1e-7)


def test_eval_batch_counts():
    cfg = small_cfg()
    params = M.init_params(cfg, 1)
    x, y = batch(cfg, 10, 4)
    y[7:] = 0.0  # 3 padding rows
    loss_sum, n_correct, n_rows = M.eval_batch(cfg, params, x, y)
    assert float(n_rows) == 7.0
    assert 0.0 <= float(n_correct) <= 7.0
    assert float(loss_sum) >= 0.0


def test_eval_batch_perfect_predictions():
    """With a hand-built head that copies a one-hot input, accuracy is 1."""
    cfg = M.ModelConfig(input_dim=6, hidden=6, classes=6, layers=2)
    # layer0: identity-ish (relu passes positives), layer1: identity head
    params = [
        jnp.eye(6, dtype=jnp.float32),
        jnp.zeros(6, jnp.float32),
        jnp.eye(6, dtype=jnp.float32),
        jnp.zeros(6, jnp.float32),
    ]
    y = np.eye(6, dtype=np.float32)
    x = y * 10.0  # strongly one-hot inputs
    loss_sum, n_correct, n_rows = M.eval_batch(cfg, params, x, y)
    assert float(n_correct) == 6.0
    assert float(n_rows) == 6.0


def test_training_step_reduces_loss():
    """A few SGD steps on one batch must reduce its loss (sanity)."""
    cfg = small_cfg()
    params = list(M.init_params(cfg, 5))
    x, y = batch(cfg, 8, 5)
    losses = []
    for _ in range(5):
        out = M.full_step(cfg, params, x, y)
        grads, loss = out[:-1], out[-1]
        losses.append(float(loss))
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert losses[-1] < losses[0], losses
