"""AOT export pipeline: HLO-text round-trip and manifest integrity.

Exports a tiny model to a temp dir and checks (a) every artifact parses back
through the XLA client (the same parse the Rust `HloModuleProto::from_text_file`
performs), (b) the manifest signature matches the lowering, (c) executing the
HLO through the XLA client reproduces the eager JAX numbers — i.e. what the
Rust runtime will compute.
"""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def tiny_export(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    cfg = M.ModelConfig(input_dim=12, hidden=8, classes=4, layers=3)
    aot.export_all(cfg, train_batch=4, eval_batch_size=8, out_dir=out)
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    return out, cfg, manifest


def test_manifest_structure(tiny_export):
    out, cfg, m = tiny_export
    assert m["format"] == "hlo-text-v1"
    assert m["model"]["layers"] == 3
    assert m["model"]["n_params"] == cfg.n_params()
    assert m["train_batch"] == 4 and m["eval_batch"] == 8
    # 4 base entries + 4 per split × 2 splits
    assert len(m["entries"]) == 4 + 4 * (cfg.layers - 1)
    assert len(m["source_fingerprint"]) == 64


def test_all_artifacts_exist_and_parse(tiny_export):
    out, _, m = tiny_export
    for name, ent in m["entries"].items():
        path = os.path.join(out, ent["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} not HLO text"
        # Round-trip through the XLA text parser (what Rust does).
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_entry_signatures_match_model(tiny_export):
    _, cfg, m = tiny_export
    e = m["entries"]["front_fwd_1"]
    # inputs: w0 (12,8), b0 (8,), x (4,12)
    assert [s["shape"] for s in e["inputs"]] == [[12, 8], [8], [4, 12]]
    assert e["outputs"][0]["shape"] == [4, 8]
    e = m["entries"]["full_step"]
    assert len(e["inputs"]) == 2 * cfg.layers + 2
    assert len(e["outputs"]) == 2 * cfg.layers + 1  # grads + loss
    e = m["entries"]["back_bwd_2"]
    # params for layer 2 (w,b) + act + g_logits
    assert len(e["inputs"]) == 2 + 2
    assert len(e["outputs"]) == 2 + 1  # grads + g_act


def test_hlo_program_shapes_match_manifest(tiny_export):
    """Every artifact's ENTRY program shape (parameters + tuple result) must
    match the manifest signature exactly — this is the contract the Rust
    engine's buffer marshalling relies on. (Numeric equivalence of HLO
    execution vs eager JAX is covered by the Rust runtime tests, which run
    these artifacts through the PJRT CPU client.)"""
    out, cfg, m = tiny_export
    for name, ent in m["entries"].items():
        text = open(os.path.join(out, ent["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        ps = xc._xla.XlaComputation(mod.as_serialized_hlo_module_proto()).program_shape()
        assert len(ps.parameter_shapes()) == len(ent["inputs"]), name
        for shape, spec in zip(ps.parameter_shapes(), ent["inputs"]):
            assert list(shape.dimensions()) == spec["shape"], (name, spec)
        result = ps.result_shape()
        assert result.is_tuple(), name  # return_tuple=True contract
        assert len(result.tuple_shapes()) == len(ent["outputs"]), name
        for shape, spec in zip(result.tuple_shapes(), ent["outputs"]):
            assert list(shape.dimensions()) == spec["shape"], (name, spec)


def test_keep_unused_prevents_arg_pruning(tiny_export):
    """Regression for the 10-vs-9-buffers bug: XLA prunes arguments that are
    dead in the VJP (e.g. the head bias in back_bwd) unless lowered with
    keep_unused=True. The ENTRY program shape must keep every manifest input."""
    out, cfg, m = tiny_export
    for k in range(1, cfg.layers):
        name = f"back_bwd_{k}"
        text = open(os.path.join(out, m["entries"][name]["file"])).read()
        mod = xc._xla.hlo_module_from_text(text)
        ps = xc._xla.XlaComputation(mod.as_serialized_hlo_module_proto()).program_shape()
        n_inputs = len(m["entries"][name]["inputs"])
        assert len(ps.parameter_shapes()) == n_inputs, name


def test_fingerprint_changes_with_source(tiny_export, tmp_path):
    _, _, m = tiny_export
    # Exporting again from unchanged sources produces the same fingerprint.
    out2 = str(tmp_path / "a2")
    cfg = M.ModelConfig(input_dim=12, hidden=8, classes=4, layers=3)
    aot.export_all(cfg, 4, 8, out2)
    m2 = json.load(open(os.path.join(out2, "manifest.json")))
    assert m2["source_fingerprint"] == m["source_fingerprint"]
