"""AOT export: lower every L2 entry point to HLO *text* + write the manifest.

This is the only place Python touches the pipeline — ``make artifacts`` runs it
once; afterwards the Rust coordinator is self-contained (it loads
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file`` and executes
via the PJRT CPU client).

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md and aot_recipe).

Artifact set (per DESIGN.md §5), for a ``W``-layer model:

    init_params                      (seed:u32) -> (params…)
    full_step                        (params…, x, y) -> (grads…, loss)
    eval_batch                       (params…, x, y) -> (loss_sum, n_correct, n_rows)
    loss_grad                        (logits, y) -> (loss, g_logits)
    front_fwd_k / back_fwd_k         k = 1..W-1
    back_bwd_k / front_bwd_k         k = 1..W-1

``manifest.json`` describes the model config, per-layer parameter shapes and
every entry's input/output signature, so the Rust side never hardcodes shapes.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    ModelConfig,
    back_bwd,
    back_fwd,
    eval_batch,
    front_bwd,
    front_fwd,
    full_step,
    init_params,
    loss_grad,
)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec_dict(s) -> Dict[str, Any]:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def _flatten_specs(tree) -> List[Dict[str, Any]]:
    return [_spec_dict(leaf) for leaf in jax.tree_util.tree_leaves(tree)]


class Exporter:
    """Collects lowered entries and writes artifacts + manifest."""

    def __init__(self, cfg: ModelConfig, train_batch: int, eval_batch_size: int,
                 out_dir: str):
        self.cfg = cfg
        self.train_batch = train_batch
        self.eval_batch = eval_batch_size
        self.out_dir = out_dir
        self.entries: Dict[str, Dict[str, Any]] = {}

    def param_specs(self, lo: int = 0, hi: int | None = None):
        hi = self.cfg.layers if hi is None else hi
        shapes = self.cfg.param_shapes()[lo:hi]
        out = []
        for w_shape, b_shape in shapes:
            out.append(_spec(w_shape))
            out.append(_spec(b_shape))
        return out

    def export(self, name: str, fn, arg_specs) -> None:
        """jit → lower → HLO text → ``artifacts/<name>.hlo.txt`` + entry record.

        ``keep_unused=True`` because the manifest advertises the full input
        list: without it XLA prunes arguments that are dead in the VJP (e.g.
        the head bias in ``back_bwd_k``, whose primal output is discarded) and
        the Rust caller's buffer count no longer matches the executable.
        """
        lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.tree_util.tree_leaves(
            jax.eval_shape(fn, *arg_specs)
        )
        self.entries[name] = {
            "file": fname,
            "inputs": _flatten_specs(arg_specs),
            "outputs": [_spec_dict(s) for s in out_specs],
        }
        print(f"  exported {name}: {len(text)} chars, "
              f"{len(self.entries[name]['inputs'])} inputs, "
              f"{len(out_specs)} outputs")

    def manifest(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "format": "hlo-text-v1",
            "model": {
                "family": "resnet-mlp",
                "input_dim": cfg.input_dim,
                "hidden": cfg.hidden,
                "classes": cfg.classes,
                "layers": cfg.layers,
                "n_params": cfg.n_params(),
                "param_shapes": [
                    {"w": list(w), "b": list(b)} for w, b in cfg.param_shapes()
                ],
                "flops_per_layer_fwd_b1": cfg.flops_per_layer(1),
            },
            "train_batch": self.train_batch,
            "eval_batch": self.eval_batch,
            "entries": self.entries,
        }


def export_all(cfg: ModelConfig, train_batch: int, eval_batch_size: int,
               out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    ex = Exporter(cfg, train_batch, eval_batch_size, out_dir)
    W = cfg.layers
    Bt, Be = train_batch, eval_batch_size
    x_t = _spec((Bt, cfg.input_dim))
    y_t = _spec((Bt, cfg.classes))
    x_e = _spec((Be, cfg.input_dim))
    y_e = _spec((Be, cfg.classes))
    logits_t = _spec((Bt, cfg.classes))
    act_t = _spec((Bt, cfg.hidden))

    print(f"[aot] model: W={W} hidden={cfg.hidden} in={cfg.input_dim} "
          f"classes={cfg.classes} params={cfg.n_params()}")

    ex.export(
        "init_params",
        lambda seed: init_params(cfg, seed),
        [_spec((), jnp.uint32)],
    )
    ex.export(
        "full_step",
        lambda *a: full_step(cfg, a[:-2], a[-2], a[-1]),
        [*ex.param_specs(), x_t, y_t],
    )
    ex.export(
        "eval_batch",
        lambda *a: eval_batch(cfg, a[:-2], a[-2], a[-1]),
        [*ex.param_specs(), x_e, y_e],
    )
    ex.export("loss_grad", loss_grad, [logits_t, y_t])

    for k in range(1, W):
        ex.export(
            f"front_fwd_{k}",
            functools.partial(
                lambda k, *a: front_fwd(cfg, k, a[:-1], a[-1]), k
            ),
            [*ex.param_specs(0, k), x_t],
        )
        ex.export(
            f"back_fwd_{k}",
            functools.partial(
                lambda k, *a: back_fwd(cfg, k, a[:-1], a[-1]), k
            ),
            [*ex.param_specs(k, W), act_t],
        )
        ex.export(
            f"back_bwd_{k}",
            functools.partial(
                lambda k, *a: back_bwd(cfg, k, a[:-2], a[-2], a[-1]), k
            ),
            [*ex.param_specs(k, W), act_t, logits_t],
        )
        ex.export(
            f"front_bwd_{k}",
            functools.partial(
                lambda k, *a: front_bwd(cfg, k, a[:-2], a[-2], a[-1]), k
            ),
            [*ex.param_specs(0, k), x_t, act_t],
        )

    manifest = ex.manifest()
    # Fingerprint the compile inputs so `make artifacts` can skip cleanly.
    src_dir = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(src_dir)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    manifest["source_fingerprint"] = h.hexdigest()
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(ex.entries)} artifacts + manifest to {out_dir}")


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--layers", type=int, default=8, help="model depth W")
    p.add_argument("--hidden", type=int, default=256)
    p.add_argument("--input-dim", type=int, default=3072)
    p.add_argument("--classes", type=int, default=10)
    p.add_argument("--train-batch", type=int, default=32)
    p.add_argument("--eval-batch", type=int, default=256)
    args = p.parse_args()
    cfg = ModelConfig(
        input_dim=args.input_dim,
        hidden=args.hidden,
        classes=args.classes,
        layers=args.layers,
    )
    export_all(cfg, args.train_batch, args.eval_batch, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
