"""L2: the FedPairing ResNet-MLP model — full and split forward/backward in JAX.

The paper trains ResNet-18/10 on CIFAR-10; per DESIGN.md §2 we substitute a
**residual MLP** ("ResNet-MLP") whose depth ``W`` plays the paper's layer-count
role (the split point ``L_i = ⌊f_i/(f_i+f_j)·W⌋`` slices it anywhere), trained
on a synthetic CIFAR-like dataset generated on the Rust side. Layer structure:

    layer 0      : fused_linear(input_dim → hidden), relu            (stem)
    layers 1..W-2: h ← relu(h @ w_k + b_k) + h                       (residual)
    layer W-1    : fused_linear(hidden → classes), no activation     (head)

All dense math goes through the L1 Pallas kernel (`fused_linear_ad`, a
custom-vjp wrapper so the backward artifacts run the Pallas matmul too).

Split semantics (paper Sec. II-A.2): for a split point ``k ∈ {1..W-1}``,
the *front* is layers ``0..k-1`` (owned/computed by the data-owning client on
its own model) and the *back* is layers ``k..W-1`` (computed by the partner on
the partner's model). Because every interior activation has shape
``(B, hidden)``, one activation wire format covers every split point.

Every public function here is pure and shape-static so `aot.py` can lower it
to a standalone HLO artifact executed by the Rust coordinator.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import fused_linear_ad, softmax_xent


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture description (also serialized into the manifest)."""

    input_dim: int = 3072  # 3 x 32 x 32, flattened
    hidden: int = 256
    classes: int = 10
    layers: int = 8  # W — total depth, ≥ 2

    def __post_init__(self):
        if self.layers < 2:
            raise ValueError("ResNet-MLP needs at least stem + head (layers >= 2)")

    def layer_dims(self) -> List[Tuple[int, int]]:
        """Per-layer (fan_in, fan_out) for layers 0..W-1."""
        dims = [(self.input_dim, self.hidden)]
        dims += [(self.hidden, self.hidden)] * (self.layers - 2)
        dims.append((self.hidden, self.classes))
        return dims

    def param_shapes(self) -> List[Tuple[Tuple[int, ...], Tuple[int, ...]]]:
        """Per-layer ((w shape), (b shape))."""
        return [((fi, fo), (fo,)) for fi, fo in self.layer_dims()]

    def n_params(self) -> int:
        return sum(fi * fo + fo for fi, fo in self.layer_dims())

    def flops_per_layer(self, batch: int) -> List[int]:
        """Forward MACs×2 per layer for a ``batch``-row input (cost model hook)."""
        return [2 * batch * fi * fo for fi, fo in self.layer_dims()]


# A parameter list is a flat interleaving [w0, b0, w1, b1, ...]; slices of it
# (front = layers 0..k-1, back = layers k..W-1) are what the split artifacts
# take as inputs, so the Rust side can ship only the relevant tensors.
Params = Sequence[jax.Array]


def _layer(cfg: ModelConfig, idx: int, w, b, h):
    """Apply layer ``idx`` to activations ``h`` via the Pallas kernel."""
    if idx == 0:
        return fused_linear_ad(h, w, b, None, "relu")
    if idx == cfg.layers - 1:
        return fused_linear_ad(h, w, b, None, "none")
    # interior residual layer: relu(h @ w + b) + h, fused in one kernel call
    return fused_linear_ad(h, w, b, h, "relu")


def _apply_range(cfg: ModelConfig, params: Params, h, lo: int, hi: int):
    """Apply layers ``lo..hi-1``; ``params`` holds exactly those layers."""
    assert len(params) == 2 * (hi - lo), (len(params), lo, hi)
    for i, layer_idx in enumerate(range(lo, hi)):
        w, b = params[2 * i], params[2 * i + 1]
        h = _layer(cfg, layer_idx, w, b, h)
    return h


# --------------------------------------------------------------------------
# Forward entries
# --------------------------------------------------------------------------


def full_fwd(cfg: ModelConfig, params: Params, x):
    """Full-model logits: layers 0..W-1."""
    return _apply_range(cfg, params, x, 0, cfg.layers)


def front_fwd(cfg: ModelConfig, k: int, params_front: Params, x):
    """Front slice (layers 0..k-1): the data owner's half. Returns ``act``."""
    assert 1 <= k <= cfg.layers - 1
    return _apply_range(cfg, params_front, x, 0, k)


def back_fwd(cfg: ModelConfig, k: int, params_back: Params, act):
    """Back slice (layers k..W-1): the partner's half. Returns logits."""
    assert 1 <= k <= cfg.layers - 1
    return _apply_range(cfg, params_back, act, k, cfg.layers)


# --------------------------------------------------------------------------
# Loss
# --------------------------------------------------------------------------


def loss_grad(logits, y1hot):
    """Mean cross-entropy loss and its logit gradient, via the Pallas kernel.

    Returns ``(loss, g_logits)`` with ``loss`` a scalar mean over *labeled*
    rows (all-zero one-hot rows are padding) and ``g_logits`` already scaled
    for the mean, ready to feed ``back_bwd``.
    """
    loss_rows, grad = softmax_xent(logits, y1hot)
    n_rows = jnp.maximum(jnp.sum(y1hot), 1.0)  # number of labeled rows
    # softmax_xent scales grad by 1/M (padded batch size). Training always
    # uses full batches (see data::loader on the Rust side), so M == n_rows;
    # loss uses the true row count either way.
    loss = jnp.sum(loss_rows) / n_rows
    return loss, grad


# --------------------------------------------------------------------------
# Backward entries (the split-learning protocol's compute steps)
# --------------------------------------------------------------------------


def back_bwd(cfg: ModelConfig, k: int, params_back: Params, act, g_logits):
    """Partner-side backward: grads of back params + the activation cotangent.

    Returns ``(*g_params_back, g_act)`` — the gradient list matches the
    ``params_back`` layout, and ``g_act`` is shipped back to the data owner
    (the "gradient of the L_i+1-th layer" of paper Sec. II-A.2).
    """
    def f(pb, a):
        return back_fwd(cfg, k, pb, a)

    _, vjp = jax.vjp(f, list(params_back), act)
    g_params, g_act = vjp(g_logits)
    return (*g_params, g_act)


def front_bwd(cfg: ModelConfig, k: int, params_front: Params, x, g_act):
    """Data-owner backward: grads of front params given the activation cotangent."""
    def f(pf):
        return front_fwd(cfg, k, pf, x)

    _, vjp = jax.vjp(f, list(params_front))
    (g_params,) = vjp(g_act)
    return tuple(g_params)


def full_step(cfg: ModelConfig, params: Params, x, y1hot):
    """Vanilla-FL local step: grads of the mean loss for the whole model.

    Returns ``(*g_params, loss)``.
    """
    def fwd_only(p):
        return full_fwd(cfg, p, x)

    logits, vjp = jax.vjp(fwd_only, list(params))
    loss, g_logits = loss_grad(logits, y1hot)
    (g_params,) = vjp(g_logits)
    return (*g_params, loss)


# --------------------------------------------------------------------------
# Evaluation + init
# --------------------------------------------------------------------------


def eval_batch(cfg: ModelConfig, params: Params, x, y1hot):
    """Test-set batch metrics: ``(loss_sum, n_correct, n_rows)`` as f32 scalars.

    Padding rows (all-zero one-hot) are excluded from all three, so the Rust
    evaluator can pad the final partial batch and still aggregate exactly.
    """
    logits = full_fwd(cfg, params, x)
    loss_rows, _ = softmax_xent(logits, y1hot)
    has_label = jnp.sum(y1hot, axis=-1) > 0
    pred = jnp.argmax(logits, axis=-1)
    label = jnp.argmax(y1hot, axis=-1)
    correct = jnp.where(has_label, (pred == label).astype(jnp.float32), 0.0)
    return (
        jnp.sum(loss_rows),
        jnp.sum(correct),
        jnp.sum(has_label.astype(jnp.float32)),
    )


def init_params(cfg: ModelConfig, seed):
    """He-initialized parameter list from a scalar ``uint32`` seed.

    Exported as an artifact so the Rust coordinator can materialize the global
    model without reimplementing the init distribution.
    """
    key = jax.random.PRNGKey(seed)
    params = []
    dims = cfg.layer_dims()
    for idx, (fan_in, fan_out) in enumerate(dims):
        key, wk = jax.random.split(key)
        if idx == len(dims) - 1:
            # Zero-init the classifier head: with the residual stack growing
            # activation magnitude ~O(√W), a He-init head yields huge initial
            # logits (loss ≫ ln C); zero head starts at exactly ln(classes).
            w = jnp.zeros((fan_in, fan_out), jnp.float32)
        else:
            scale = jnp.sqrt(2.0 / fan_in).astype(jnp.float32)
            # Interior residual branches are additionally damped so the stem's
            # signal dominates at init (standard residual-scaling trick).
            if idx > 0:
                scale = scale / jnp.sqrt(jnp.float32(cfg.layers))
            w = jax.random.normal(wk, (fan_in, fan_out), jnp.float32) * scale
        params.append(w)
        params.append(jnp.zeros((fan_out,), jnp.float32))
    return tuple(params)
