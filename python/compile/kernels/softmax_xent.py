"""Pallas fused softmax cross-entropy kernel (loss + logit gradient in one pass).

In FedPairing's split backward (paper Sec. II-A.2, adapted for label privacy —
see DESIGN.md), the data-owning client computes the loss *and* the logit
gradient locally from the logits its partner returned, then ships only the
gradient back. This kernel produces both in a single row-blocked pass:

    loss_rows[i] = -log softmax(logits[i])[label_i]
    grad[i]      = (softmax(logits[i]) - y1hot[i]) / M      (mean-loss gradient)

Rows whose one-hot vector is all-zero (batch padding) contribute zero loss and
zero gradient, so the Rust coordinator can pad partial batches without
affecting the update — an invariant tested in python/tests/test_kernels.py.

TPU mapping: grid over row blocks only; each (bm, C) tile performs the
max/exp/sum reduction entirely in VMEM (C = #classes is tiny), one HBM read of
logits + labels, one write of loss + grad. For C=10 and bm=128 the working set
is < 20 KiB.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _softmax_xent_kernel(logits_ref, y_ref, loss_ref, grad_ref, *, n_total: int):
    """One row-block: stable softmax, per-row loss, mean-scaled gradient."""
    logits = logits_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    ex = jnp.exp(shifted)
    denom = jnp.sum(ex, axis=-1, keepdims=True)
    logp = shifted - jnp.log(denom)
    row_has_label = jnp.sum(y, axis=-1)  # 1.0 real row, 0.0 padding
    loss_ref[...] = -jnp.sum(y * logp, axis=-1)
    grad_ref[...] = (ex / denom * row_has_label[:, None] - y) / jnp.float32(n_total)


DEFAULT_BLOCK_M = int(os.environ.get("FEDPAIRING_BLOCK", "4096"))


@functools.partial(jax.jit, static_argnames=("block_m",))
def softmax_xent(logits, y1hot, *, block_m: int = DEFAULT_BLOCK_M):
    """Fused softmax cross-entropy: ``(loss_rows, grad)``.

    Args:
      logits: ``(M, C)`` raw scores (any float dtype; computed in f32).
      y1hot: ``(M, C)`` one-hot labels; all-zero rows are treated as padding.
      block_m: target row-block size (shrunk to a divisor of ``M``).

    Returns:
      ``loss_rows``: ``(M,)`` f32 per-row losses (0 for padding rows).
      ``grad``: ``(M, C)`` f32 gradient of the *mean* loss w.r.t. ``logits``.

    Matches :func:`ref.softmax_xent_ref`.
    """
    m, c = logits.shape
    if y1hot.shape != (m, c):
        raise ValueError(f"labels shape {y1hot.shape} != logits shape {logits.shape}")
    bm = m if m <= block_m else next(
        cand for cand in range(block_m, 0, -1) if m % cand == 0
    )
    grid = (m // bm,)
    kernel = functools.partial(_softmax_xent_kernel, n_total=m)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.float32),
            jax.ShapeDtypeStruct((m, c), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(logits, y1hot)
