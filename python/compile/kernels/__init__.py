"""L1: Pallas kernels for the FedPairing compute hot spot.

Exports:
  - :func:`linear.fused_linear` — fused ``act(x@w+b)(+res)`` matmul kernel.
  - :func:`linear_vjp.fused_linear_ad` — the same kernel wrapped in a
    ``custom_vjp`` whose backward pass is *also* expressed with the Pallas
    matmul kernel (so fwd and bwd artifacts both run the L1 hot path).
  - :func:`softmax_xent.softmax_xent` — fused loss + logit-gradient kernel.
  - :mod:`ref` — pure-jnp oracles for all of the above.
"""

from . import ref  # noqa: F401
from .linear import fused_linear  # noqa: F401
from .linear_vjp import fused_linear_ad  # noqa: F401
from .softmax_xent import softmax_xent  # noqa: F401
