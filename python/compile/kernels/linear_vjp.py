"""``custom_vjp`` wrapper around the Pallas fused-linear kernel.

``pallas_call`` has no general autodiff rule, so the L2 model cannot simply
``jax.vjp`` through :func:`linear.fused_linear`. This module supplies the
backward pass explicitly — and expresses it with the *same* Pallas matmul
kernel, so both the forward and backward HLO artifacts executed by the Rust
coordinator run the L1 hot path:

    forward:   y = act(x @ w + b) (+ res)
    backward:  dz = dy ⊙ 1[z > 0]          (relu mask; identity for "none")
               dx = dz @ wᵀ                (Pallas matmul)
               dw = xᵀ @ dz                (Pallas matmul)
               db = Σ_rows dz
               dres = dy                   (residual is a pass-through)

The relu mask is reconstructed from the saved output: ``relu(z) > 0 ⇔ z > 0``
and the residual is added *after* the activation, so ``mask = (y - res) > 0``.
This avoids saving the pre-activation (halves residency — the same trick the
flash-style TPU kernels use to stay inside VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .linear import fused_linear


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def fused_linear_ad(x, w, b, residual, activation: str = "relu"):
    """Differentiable fused linear layer.

    Same contract as :func:`linear.fused_linear` but ``residual`` is a
    positional argument (pass a ``(M, N)`` array or ``None``) so that
    ``jax.vjp`` can thread cotangents through it.
    """
    return fused_linear(x, w, b, residual, activation=activation)


def _fwd(x, w, b, residual, activation):
    y = fused_linear(x, w, b, residual, activation=activation)
    return y, (x, w, y, residual)


def _bwd(activation, saved, dy):
    x, w, y, residual = saved
    if activation == "relu":
        act_out = y if residual is None else y - residual
        mask = (act_out > 0).astype(dy.dtype)
        dz = dy * mask
    else:
        dz = dy
    zero_n = jnp.zeros((w.shape[0],), dy.dtype)
    zero_k = jnp.zeros((w.shape[1],), dy.dtype)
    # dx = dz @ wᵀ and dw = xᵀ @ dz, both through the Pallas kernel.
    dx = fused_linear_ad(dz, w.T, zero_n, None, "none")
    dw = fused_linear_ad(x.T, dz, zero_k, None, "none")
    db = jnp.sum(dz, axis=0)
    dres = dy if residual is not None else None
    return dx, dw, db, dres


fused_linear_ad.defvjp(_fwd, _bwd)
