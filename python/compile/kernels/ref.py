"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact functional twin here, written
with plain ``jax.numpy`` ops only. ``pytest python/tests`` asserts
``allclose(kernel(...), ref(...))`` over hypothesis-driven shape/dtype sweeps —
this is the core L1 correctness signal for the whole stack (the HLO artifacts
executed by the Rust coordinator embed the Pallas lowerings, so if the kernel
matches the ref here, the Rust hot path computes the right numbers).
"""

from __future__ import annotations

import jax.numpy as jnp


def fused_linear_ref(x, w, b, activation: str = "relu", residual=None):
    """Reference for ``fused_linear``: ``act(x @ w + b) (+ residual)``.

    Args:
      x: ``(M, K)`` input activations.
      w: ``(K, N)`` weight matrix.
      b: ``(N,)`` bias.
      activation: ``"relu"`` or ``"none"``.
      residual: optional ``(M, N)`` tensor added *after* the activation
        (pre-activation residual form used by the ResNet-MLP model).

    Returns:
      ``(M, N)`` output, same dtype as ``x``.
    """
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(x.dtype)


def softmax_xent_ref(logits, y1hot):
    """Reference for ``softmax_xent``: per-row loss and logit gradient.

    Numerically-stable softmax cross-entropy. The gradient is for the *mean*
    loss over the batch, i.e. ``(softmax(logits) - y1hot) / M`` — exactly what
    the split-learning backward pass feeds to ``back_bwd``.

    Args:
      logits: ``(M, C)`` raw scores.
      y1hot: ``(M, C)`` one-hot labels (rows may be all-zero for padding; such
        rows contribute zero loss and zero gradient).

    Returns:
      ``(loss_rows, grad)`` where ``loss_rows`` is ``(M,)`` per-row losses and
      ``grad`` is ``(M, C)``.
    """
    logits = logits.astype(jnp.float32)
    y1hot = y1hot.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))
    logp = shifted - lse
    row_has_label = jnp.sum(y1hot, axis=-1)  # 1.0 for real rows, 0.0 for pad
    loss_rows = -jnp.sum(y1hot * logp, axis=-1)
    n = logits.shape[0]
    grad = (jnp.exp(logp) * row_has_label[:, None] - y1hot) / jnp.float32(n)
    return loss_rows, grad


def relu_ref(x):
    """Reference ReLU."""
    return jnp.maximum(x, 0.0)
