"""Pallas fused-linear kernel: ``act(x @ w + b) (+ residual)`` — the L1 hot spot.

The ResNet-MLP's per-layer cost is one dense matmul; this kernel is the
training hot path of every artifact the Rust coordinator executes
(``front_fwd_k``/``back_fwd_k`` call it directly; the backward artifacts hit it
through JAX's VJP of this forward).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
``(M/bm, N/bn, K/bk)`` with an f32 VMEM accumulator tile; the MXU-shaped block
default is ``(128, 128, 128)`` → three f32 tiles ≈ 192 KiB of VMEM, far inside
the ~16 MiB budget, leaving room for double-buffered HBM→VMEM prefetch of the
next ``x``/``w`` blocks. Bias add, activation, and the residual add are fused
into the epilogue of the last K-step so the output tile makes a single trip to
HBM.

CPU execution uses ``interpret=True`` (mandatory here: real TPU lowering emits
a Mosaic custom-call the CPU PJRT plugin cannot run), which lowers the same
grid program to plain HLO.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# Default tile target. On a real TPU the MXU-shaped (128,128,128) tiling is
# the right choice (fits VMEM with double-buffering headroom — DESIGN.md
# §Perf); under interpret=True on CPU each grid step lowers to one iteration
# of an HLO while-loop, so larger tiles (fewer iterations) are strictly
# better: 128→4096 measured 43× faster on the 3072×256 layer. Overridable via
# FEDPAIRING_BLOCK for the TPU-mapping ablation.
DEFAULT_BLOCK = int(os.environ.get("FEDPAIRING_BLOCK", "4096"))


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``target`` (block shapes must tile)."""
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


def _fused_linear_kernel(x_ref, w_ref, b_ref, res_ref, o_ref, *,
                         activation: str, nsteps_k: int, has_residual: bool):
    """Grid program: one (bm, bn) output tile, iterating the K dimension.

    ``o_ref`` doubles as the f32 accumulator tile (the same output block is
    revisited across the K grid dimension); the epilogue (bias + activation +
    residual) runs only on the final K step.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_step == nsteps_k - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...].astype(jnp.float32)
        if activation == "relu":
            y = jnp.maximum(y, 0.0)
        if has_residual:
            y = y + res_ref[...].astype(jnp.float32)
        o_ref[...] = y


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def fused_linear(x, w, b, residual=None, *, activation: str = "relu",
                 block_m: int = DEFAULT_BLOCK, block_n: int = DEFAULT_BLOCK,
                 block_k: int = DEFAULT_BLOCK):
    """Fused ``act(x @ w + b) (+ residual)`` as a Pallas call.

    Args:
      x: ``(M, K)`` activations.
      w: ``(K, N)`` weights.
      b: ``(N,)`` bias.
      residual: optional ``(M, N)`` added after the activation.
      activation: ``"relu"`` or ``"none"``.
      block_m/n/k: target tile sizes; shrunk to divisors of the actual dims.

    Returns:
      ``(M, N)`` array with ``x``'s dtype.

    Matches :func:`ref.fused_linear_ref` bit-for-bit structure (f32 accumulate).
    """
    if activation not in ("relu", "none"):
        raise ValueError(f"unknown activation {activation!r}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"shape mismatch: x {x.shape} @ w {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")
    has_residual = residual is not None
    if has_residual and residual.shape != (m, n):
        raise ValueError(f"residual shape {residual.shape} != {(m, n)}")

    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    nsteps_k = grid[2]

    # bias is broadcast along M: give it a 2-D (1, bn) block so the kernel can
    # add it to the (bm, bn) accumulator tile.
    b2 = b.reshape(1, n)
    res = residual if has_residual else jnp.zeros((1, 1), x.dtype)

    kernel = functools.partial(
        _fused_linear_kernel,
        activation=activation,
        nsteps_k=nsteps_k,
        has_residual=has_residual,
    )
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),  # x: row-block × K-step
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),  # w: K-step × col-block
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),    # bias: col-block
        (pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))   # residual: out tile
         if has_residual else
         pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))),
    ]
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        # The output tile doubles as the f32 accumulator (revisited across the
        # K grid dimension); cast back to the input dtype at the end.
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls (see module doc)
    )(x, w, b2, res)
    return out.astype(x.dtype)
