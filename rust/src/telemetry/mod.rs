//! Telemetry: zero-cost-when-disabled observability for the simulator
//! (DESIGN.md §8).
//!
//! Three pillars:
//!
//! 1. **Metrics registry** ([`registry`]) — enum-keyed, lock-free counters /
//!    gauges / log2 histograms wired into the hot paths (memo cache, pair
//!    kernels, candidate generation, grid mobility, matching repair,
//!    `FixedPool` chunks). Disabled cost is one relaxed load + branch per
//!    hook.
//! 2. **Stage-attributed round breakdown** ([`breakdown`]) — every round's
//!    critical path decomposed into named split-protocol stages plus
//!    straggler attribution, carried on `RoundTime`/`RoundRecord` and
//!    exported to CSV/JSON. Computed unconditionally so observation can
//!    never perturb the simulation.
//! 3. **Exporters** ([`trace`], [`export`]) — a Chrome trace-event JSON
//!    writer (host phase spans + simulated pair lanes for the top-k slowest
//!    pairs), a Prometheus-style text snapshot, and a JSONL round-event
//!    stream, all driven by [`Telemetry`] from `TelemetryConfig`.
//! 4. **Distribution observatory** ([`sketch`], [`ledger`], [`report`]) —
//!    deterministic mergeable quantile sketches over unit makespans /
//!    stage durations / async staleness / fault recovery, a per-client
//!    fairness ledger with Jain index and straggler table, and an offline
//!    `fedpairing report` analyzer over the record streams (DESIGN.md §12).
//!
//! **Determinism invariant** (property-tested in `tests/telemetry.rs`):
//! with telemetry enabled — including trace export — every driver produces
//! `RoundRecord` traces bit-identical to the telemetry-off run at any thread
//! count. Hooks only read simulation state, never feed back into it.

pub mod breakdown;
pub mod export;
pub mod ledger;
pub mod registry;
pub mod report;
pub mod sketch;
pub mod trace;

pub use breakdown::{StageBreakdown, N_STAGES, STAGE_NAMES};
pub use ledger::{exact_lanes, ClientLedger, Observatory, RoundLanes};
pub use registry::{Counter, Gauge, Histo};
pub use sketch::QuantileSketch;

use crate::config::TelemetryConfig;
use crate::sim::latency::RoundTime;
use crate::util::json::{Json, JsonObj};
use std::io;
use std::time::Instant;

/// Chrome-trace pid for wall-clock simulator phase spans.
const PID_HOST: u64 = 0;
/// Chrome-trace pid for simulated-time pair lanes.
const PID_SIM: u64 = 1;

/// Per-run telemetry sink: owns the exporters and the phase-span clock.
/// Constructing one flips the global registry gate to the configured state.
/// All methods are cheap no-ops when telemetry is disabled or the round is
/// not sampled.
#[derive(Debug)]
pub struct Telemetry {
    cfg: TelemetryConfig,
    trace: Option<trace::TraceWriter>,
    events: Vec<Json>,
    run_t0: Instant,
    mark_t0: Instant,
    round: usize,
    sampling: bool,
    /// Aggregation events observed so far (event-driven mode only).
    events_seen: usize,
}

impl Telemetry {
    /// Build a sink and flip the global registry gate to `cfg.enabled`.
    pub fn new(cfg: &TelemetryConfig) -> Telemetry {
        registry::set_enabled(cfg.enabled);
        let trace = if cfg.enabled && cfg.trace_out.is_some() {
            let mut w = trace::TraceWriter::new();
            w.name_process(PID_HOST, "simulator (wall clock)");
            w.name_process(PID_SIM, "pair lanes (simulated time)");
            Some(w)
        } else {
            None
        };
        let now = Instant::now();
        Telemetry {
            cfg: cfg.clone(),
            trace,
            events: Vec::new(),
            run_t0: now,
            mark_t0: now,
            round: 0,
            sampling: false,
            events_seen: 0,
        }
    }

    /// Whether the registry gate is on for this run.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    fn exporting(&self) -> bool {
        self.trace.is_some()
    }

    /// Start a round. Rounds are 1-based; round `1` and every
    /// `sample_every`-th round after it are sampled for export.
    pub fn begin_round(&mut self, round: usize) {
        self.round = round;
        self.sampling =
            self.exporting() && (round.max(1) - 1) % self.cfg.sample_every.max(1) == 0;
        if self.sampling {
            self.mark_t0 = Instant::now();
        }
    }

    /// Start an aggregation event (event-driven mode). Unlike
    /// [`Telemetry::begin_round`], sampling counts *events*, not rounds —
    /// under buffered aggregation there is no fixed round cadence, and
    /// round-keyed sampling would alias against the merge stream (always-on
    /// or never-on depending on how merges happen to land). Event `1` and
    /// every `sample_every`-th event after it are sampled.
    pub fn begin_event(&mut self) {
        self.events_seen += 1;
        self.round = self.events_seen;
        self.sampling =
            self.exporting() && (self.events_seen - 1) % self.cfg.sample_every.max(1) == 0;
        if self.sampling {
            self.mark_t0 = Instant::now();
        }
    }

    /// Close the wall-clock span since the previous mark (or `begin_round`)
    /// under `name` — e.g. `dynamics`, `pairing`, `engine`, `train`.
    pub fn mark(&mut self, name: &str) {
        if !self.sampling {
            return;
        }
        let now = Instant::now();
        let ts_us = self.mark_t0.duration_since(self.run_t0).as_secs_f64() * 1e6;
        let dur_us = now.duration_since(self.mark_t0).as_secs_f64() * 1e6;
        let round = self.round;
        if let Some(tr) = self.trace.as_mut() {
            let mut args = JsonObj::new();
            args.insert("round", Json::Num(round as f64));
            tr.span_args(name, "phase", PID_HOST, 0, ts_us, dur_us, Some(args));
        }
        self.mark_t0 = now;
    }

    /// Record the finished round: one JSONL event plus trace lanes for the
    /// top-k slowest pairs. `lanes` holds `(a, b, total_s)` per pair (ids in
    /// whatever space the caller reports — remap before calling if needed);
    /// `sim_offset_s` is the round's simulated start time.
    pub fn end_round(
        &mut self,
        rt: &RoundTime,
        n_alive: usize,
        lanes: &[(usize, usize, f64)],
        sim_offset_s: f64,
    ) {
        if !self.sampling {
            return;
        }
        let mut o = JsonObj::new();
        o.insert("type", Json::str("round"));
        o.insert("round", Json::Num(self.round as f64));
        o.insert("n_alive", Json::Num(n_alive as f64));
        o.insert("sim_round_s", Json::Num(rt.total_s));
        o.insert("max_cpu_busy_s", Json::Num(rt.max_cpu_busy_s));
        o.insert("max_link_busy_s", Json::Num(rt.max_link_busy_s));
        o.insert("stages", rt.stages.to_json());
        self.events.push(Json::Obj(o));
        if let Some(tr) = self.trace.as_mut() {
            let mut top: Vec<(usize, usize, f64)> = lanes.to_vec();
            top.sort_by(|x, y| y.2.total_cmp(&x.2).then_with(|| (x.0, x.1).cmp(&(y.0, y.1))));
            top.truncate(self.cfg.top_k_pairs);
            for (lane, (a, b, t)) in top.iter().enumerate() {
                let mut args = JsonObj::new();
                args.insert("round", Json::Num(self.round as f64));
                tr.span_args(
                    &format!("pair {a}-{b}"),
                    "pair",
                    PID_SIM,
                    lane as u64,
                    sim_offset_s * 1e6,
                    t * 1e6,
                    Some(args),
                );
            }
        }
    }

    /// Record a buffered-aggregation merge: one JSONL `merge` event plus
    /// counter lanes (buffer occupancy, mean staleness) on the simulated-time
    /// pid. No-op when the current event is not sampled.
    pub fn end_merge(&mut self, e: &crate::asyncsim::AggregationEvent) {
        if !self.sampling {
            return;
        }
        let mut o = JsonObj::new();
        o.insert("type", Json::str("merge"));
        o.insert("seq", Json::Num(e.seq as f64));
        o.insert("t_wall_s", Json::Num(e.t_wall_s));
        o.insert("n_updates", Json::Num(e.n_updates as f64));
        o.insert("n_running", Json::Num(e.n_running as f64));
        o.insert("staleness_mean", Json::num(e.staleness_mean));
        o.insert("staleness_max", Json::Num(e.staleness_max as f64));
        o.insert("buffer_peak", Json::Num(e.buffer_peak as f64));
        o.insert("wait_eliminated_s", Json::Num(e.wait_eliminated_s));
        self.events.push(Json::Obj(o));
        if let Some(tr) = self.trace.as_mut() {
            let ts_us = e.t_wall_s * 1e6;
            tr.counter("buffer_occupancy", PID_SIM, ts_us, e.n_updates as f64);
            tr.counter("merge_staleness_mean", PID_SIM, ts_us, e.staleness_mean);
        }
    }

    /// Record this round's (or merge window's) fault events: one JSONL
    /// `fault` event each plus a lost-updates counter lane on the
    /// simulated-time pid. Event times are round-relative; `sim_offset_s`
    /// rebases them onto the run's simulated clock. No-op when the current
    /// round is not sampled or nothing fired.
    pub fn fault_events(&mut self, events: &[crate::faults::FaultEvent], sim_offset_s: f64) {
        if !self.sampling || events.is_empty() {
            return;
        }
        let mut total_lost = 0usize;
        for e in events {
            total_lost += e.lost;
            let mut o = JsonObj::new();
            o.insert("type", Json::str("fault"));
            o.insert("round", Json::Num(self.round as f64));
            o.insert("kind", Json::str(e.kind.name()));
            o.insert("a", Json::Num(e.a as f64));
            o.insert("b", Json::Num(e.b as f64));
            o.insert("t_s", Json::Num(sim_offset_s + e.t_s));
            o.insert("retries", Json::Num(e.retries as f64));
            o.insert("lost", Json::Num(e.lost as f64));
            self.events.push(Json::Obj(o));
        }
        if let Some(tr) = self.trace.as_mut() {
            tr.counter(
                "fault_lost_updates",
                PID_SIM,
                sim_offset_s * 1e6,
                total_lost as f64,
            );
        }
    }

    /// Flush the exporters. With `trace_out = Some(path)` this writes the
    /// Chrome trace to `path`, the Prometheus snapshot to `path.prom` and
    /// the JSONL round events to `path.events.jsonl`; returns the paths
    /// written (empty when exporting is off).
    pub fn finish(&mut self) -> io::Result<Vec<String>> {
        let mut written = Vec::new();
        let Some(path) = self.cfg.trace_out.clone() else {
            return Ok(written);
        };
        if let Some(tr) = self.trace.take() {
            std::fs::write(&path, tr.to_json().to_string_pretty(2))?;
            written.push(path.clone());
            let prom = format!("{path}.prom");
            std::fs::write(&prom, export::prometheus(&registry::snapshot()))?;
            written.push(prom);
            let ev = format!("{path}.events.jsonl");
            std::fs::write(&ev, export::jsonl(&self.events))?;
            written.push(ev);
        }
        Ok(written)
    }
}
