//! Deterministic, mergeable log-linear quantile sketches (DESIGN.md §12).
//!
//! An HDR-histogram-style sketch over a fixed bucket layout: values are
//! quantised to integer microsecond ticks, ticks below 2^SUB_BITS land in
//! exact unit-width buckets, and every power-of-two octave above that is
//! split into 2^SUB_BITS linear sub-buckets, bounding relative quantile
//! error by 2^-SUB_BITS ≈ 3.1 %. Because the layout is fixed and the state
//! is exact integer counts, merging is element-wise addition — commutative
//! and associative — so any partition of the same observation multiset
//! produces a bit-identical sketch regardless of feed or merge order. That
//! is the property that makes the distribution observatory safe at any
//! `--threads`: sharded feeds merge to the same bytes as a serial feed.
//!
//! Quantiles are nearest-rank over the bucket counts, reported at the
//! bucket's inclusive upper bound (clamped to the observed min/max), so a
//! quantile of a merged sketch is a deterministic function of the counts
//! alone.

/// Sub-bucket resolution: each power-of-two octave is split into
/// `2^SUB_BITS = 32` linear sub-buckets (relative error ≤ 1/32).
pub const SUB_BITS: u32 = 5;
/// Sub-buckets per octave.
const SUB: u64 = 1 << SUB_BITS;
/// Octaves above the exact range: values with msb ∈ [SUB_BITS, 63].
const N_OCTAVES: usize = 64 - SUB_BITS as usize;
/// Total buckets: `SUB` exact unit buckets + `SUB` per octave.
pub const N_BUCKETS: usize = SUB as usize * (1 + N_OCTAVES);

/// Sketches quantise seconds to integer microsecond ticks.
const TICKS_PER_S: f64 = 1e6;

/// Bucket index for a tick value. Total order: `bucket_of` is monotone
/// non-decreasing in `ticks` and covers the full `u64` domain.
pub fn bucket_of(ticks: u64) -> usize {
    if ticks < SUB {
        return ticks as usize;
    }
    let msb = 63 - ticks.leading_zeros(); // >= SUB_BITS
    let shift = msb - SUB_BITS;
    let sub = (ticks >> shift) - SUB; // in [0, SUB)
    (SUB + (msb as u64 - SUB_BITS as u64) * SUB + sub) as usize
}

/// Inclusive upper bound (in ticks) of bucket `idx`, saturating at
/// `u64::MAX` for the top bucket.
pub fn bucket_high(idx: usize) -> u64 {
    debug_assert!(idx < N_BUCKETS);
    if idx < SUB as usize {
        return idx as u64;
    }
    let oct = (idx - SUB as usize) as u64 / SUB;
    let sub = (idx - SUB as usize) as u64 % SUB;
    let next_low = ((SUB + sub + 1) as u128) << oct;
    (next_low - 1).min(u64::MAX as u128) as u64
}

/// A fixed-layout log-linear quantile sketch with exact integer state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuantileSketch {
    counts: Vec<u64>,
    count: u64,
    sum_ticks: u128,
    min_ticks: u64,
    max_ticks: u64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        QuantileSketch {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum_ticks: 0,
            min_ticks: u64::MAX,
            max_ticks: 0,
        }
    }
}

impl QuantileSketch {
    pub fn new() -> QuantileSketch {
        QuantileSketch::default()
    }

    /// Record one observation in seconds. Negative, NaN and infinite values
    /// are ignored; values above `u64::MAX` microseconds saturate.
    pub fn observe_secs(&mut self, v: f64) {
        if !v.is_finite() || v < 0.0 {
            return;
        }
        let t = v * TICKS_PER_S;
        let ticks = if t >= u64::MAX as f64 { u64::MAX } else { t.round() as u64 };
        self.observe_ticks(ticks);
    }

    /// Record one observation in ticks.
    pub fn observe_ticks(&mut self, ticks: u64) {
        self.counts[bucket_of(ticks)] += 1;
        self.count += 1;
        self.sum_ticks += ticks as u128;
        self.min_ticks = self.min_ticks.min(ticks);
        self.max_ticks = self.max_ticks.max(ticks);
    }

    /// Merge another sketch into this one: element-wise count addition.
    /// Commutative and associative, so any feed/merge order over the same
    /// observation multiset yields bit-identical state.
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_ticks += other.sum_ticks;
        self.min_ticks = self.min_ticks.min(other.min_ticks);
        self.max_ticks = self.max_ticks.max(other.max_ticks);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of the quantised observations, in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_ticks as f64 / TICKS_PER_S
    }

    /// Smallest observation, in seconds (NaN when empty).
    pub fn min_secs(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.min_ticks as f64 / TICKS_PER_S
    }

    /// Largest observation, in seconds (NaN when empty).
    pub fn max_secs(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.max_ticks as f64 / TICKS_PER_S
    }

    /// Raw bucket counts (length [`N_BUCKETS`]).
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Nearest-rank quantile estimate in seconds: the inclusive upper bound
    /// of the bucket holding rank `ceil(q·count)`, clamped to the observed
    /// min/max. NaN when empty.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                let est = bucket_high(i).clamp(self.min_ticks, self.max_ticks);
                return est as f64 / TICKS_PER_S;
            }
        }
        self.max_ticks as f64 / TICKS_PER_S
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_monotone_and_total() {
        let mut prev = 0usize;
        for msb in 0..64u32 {
            for probe in [1u64 << msb, (1u64 << msb) | ((1u64 << msb) - 1)] {
                let b = bucket_of(probe);
                assert!(b >= prev, "bucket_of not monotone at {probe}");
                assert!(b < N_BUCKETS);
                prev = b;
            }
        }
        assert_eq!(bucket_of(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn low_range_is_exact() {
        for t in 0..SUB {
            assert_eq!(bucket_of(t), t as usize);
            assert_eq!(bucket_high(t as usize), t);
        }
    }

    #[test]
    fn bucket_high_is_tight() {
        for idx in 0..N_BUCKETS - 1 {
            let hi = bucket_high(idx);
            assert_eq!(bucket_of(hi), idx, "high bound of {idx} maps back");
            assert_eq!(bucket_of(hi + 1), idx + 1, "bound+1 enters next bucket");
        }
        assert_eq!(bucket_high(N_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn relative_error_is_bounded() {
        let mut s = QuantileSketch::new();
        // Deterministic pseudo-random-ish spread over several octaves.
        let mut t = 7u64;
        let mut vals = Vec::new();
        for _ in 0..5000 {
            t = t.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let v = (t % 50_000_000) as f64 / TICKS_PER_S; // up to 50 s
            vals.push(v);
            s.observe_secs(v);
        }
        vals.sort_by(|a, b| a.total_cmp(b));
        for q in [0.5, 0.9, 0.99] {
            let rank = ((q * vals.len() as f64).ceil() as usize).clamp(1, vals.len());
            let exact = vals[rank - 1];
            let est = s.quantile_secs(q);
            assert!(
                (est - exact).abs() <= exact / 32.0 + 2e-6,
                "q={q}: est {est} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_is_order_independent_and_equals_serial() {
        let vals: Vec<f64> = (0..1000).map(|i| (i * i % 7919) as f64 * 1e-3).collect();
        let mut serial = QuantileSketch::new();
        for &v in &vals {
            serial.observe_secs(v);
        }
        // Shard into 4 interleaved chunks, merge in two different orders.
        let mut shards: Vec<QuantileSketch> = (0..4).map(|_| QuantileSketch::new()).collect();
        for (i, &v) in vals.iter().enumerate() {
            shards[i % 4].observe_secs(v);
        }
        let mut fwd = QuantileSketch::new();
        for sh in &shards {
            fwd.merge(sh);
        }
        let mut rev = QuantileSketch::new();
        for sh in shards.iter().rev() {
            rev.merge(sh);
        }
        assert_eq!(serial, fwd);
        assert_eq!(serial, rev);
        assert_eq!(serial.count(), 1000);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let mut s = QuantileSketch::new();
        assert!(s.quantile_secs(0.5).is_nan());
        assert!(s.min_secs().is_nan());
        s.observe_secs(f64::NAN);
        s.observe_secs(-1.0);
        s.observe_secs(f64::INFINITY);
        assert!(s.is_empty());
        s.observe_secs(0.0);
        assert_eq!(s.count(), 1);
        assert_eq!(s.quantile_secs(0.99), 0.0);
        s.observe_secs(1e30); // saturates the top bucket
        assert_eq!(s.count(), 2);
        assert!(s.quantile_secs(1.0) > 0.0);
    }

    #[test]
    fn sum_is_exact_in_ticks() {
        let mut s = QuantileSketch::new();
        s.observe_secs(1.5);
        s.observe_secs(2.25);
        assert!((s.sum_secs() - 3.75).abs() < 1e-9);
        assert_eq!(s.count(), 2);
    }
}
