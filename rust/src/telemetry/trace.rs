//! Chrome trace-event JSON writer (pillar 3 of the telemetry subsystem).
//!
//! Emits the `traceEvents` object format understood by `chrome://tracing`
//! and Perfetto. Only complete ("X") events are used — each span carries its
//! own start + duration, so the writer is a flat append buffer with no
//! begin/end pairing state.
//!
//! Two clock domains share one file, separated by pid: the host pid carries
//! wall-clock spans (simulator phase timings), while sim pids carry
//! *simulated*-time spans (pair lanes, where `ts`/`dur` are simulated
//! microseconds). Viewers render them as separate processes, so the domains
//! never visually interleave.

use crate::util::json::{Json, JsonObj};

/// Buffered trace-event writer.
#[derive(Debug, Default)]
pub struct TraceWriter {
    events: Vec<Json>,
}

impl TraceWriter {
    pub fn new() -> TraceWriter {
        TraceWriter::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Append a complete ("X") span. `ts_us`/`dur_us` are microseconds on
    /// the pid's clock domain.
    pub fn span(&mut self, name: &str, cat: &str, pid: u64, tid: u64, ts_us: f64, dur_us: f64) {
        self.span_args(name, cat, pid, tid, ts_us, dur_us, None);
    }

    /// [`TraceWriter::span`] with an optional `args` payload.
    #[allow(clippy::too_many_arguments)]
    pub fn span_args(
        &mut self,
        name: &str,
        cat: &str,
        pid: u64,
        tid: u64,
        ts_us: f64,
        dur_us: f64,
        args: Option<JsonObj>,
    ) {
        let mut e = JsonObj::new();
        e.insert("name", Json::str(name));
        e.insert("cat", Json::str(cat));
        e.insert("ph", Json::str("X"));
        e.insert("pid", Json::Num(pid as f64));
        e.insert("tid", Json::Num(tid as f64));
        e.insert("ts", Json::Num(ts_us));
        e.insert("dur", Json::Num(dur_us));
        if let Some(a) = args {
            e.insert("args", Json::Obj(a));
        }
        self.events.push(Json::Obj(e));
    }

    /// Append a counter ("C") sample: the viewer renders the series as a
    /// stacked area chart on the pid's clock domain. Used for async buffer
    /// occupancy / staleness lanes in simulated time.
    pub fn counter(&mut self, name: &str, pid: u64, ts_us: f64, value: f64) {
        let mut args = JsonObj::new();
        args.insert("value", Json::Num(value));
        let mut e = JsonObj::new();
        e.insert("name", Json::str(name));
        e.insert("ph", Json::str("C"));
        e.insert("pid", Json::Num(pid as f64));
        e.insert("tid", Json::Num(0.0));
        e.insert("ts", Json::Num(ts_us));
        e.insert("args", Json::Obj(args));
        self.events.push(Json::Obj(e));
    }

    /// Name a pid in the viewer's process list (metadata event).
    pub fn name_process(&mut self, pid: u64, name: &str) {
        let mut args = JsonObj::new();
        args.insert("name", Json::str(name));
        let mut e = JsonObj::new();
        e.insert("name", Json::str("process_name"));
        e.insert("ph", Json::str("M"));
        e.insert("pid", Json::Num(pid as f64));
        e.insert("tid", Json::Num(0.0));
        e.insert("args", Json::Obj(args));
        self.events.push(Json::Obj(e));
    }

    /// The full trace document: `{"traceEvents": [...], ...}`.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("traceEvents", Json::Arr(self.events.clone()));
        o.insert("displayTimeUnit", Json::str("ms"));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_round_trip_through_the_codec() {
        let mut w = TraceWriter::new();
        assert!(w.is_empty());
        w.name_process(0, "host");
        w.span("engine", "host", 0, 0, 10.0, 5.0);
        let mut args = JsonObj::new();
        args.insert("round", Json::Num(3.0));
        w.span_args("pairing", "host", 0, 0, 15.0, 2.0, Some(args));
        assert_eq!(w.len(), 3);
        let parsed = Json::parse(&w.to_json().to_string()).unwrap();
        let events = parsed.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(events.len(), 3);
        assert_eq!(events[1].get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(events[1].get("ts").and_then(Json::as_f64), Some(10.0));
        assert_eq!(
            events[2].get("args").and_then(|a| a.get("round")).and_then(Json::as_f64),
            Some(3.0)
        );
    }

    #[test]
    fn counters_emit_value_samples() {
        let mut w = TraceWriter::new();
        w.counter("buffer_occupancy", 1, 2_500_000.0, 7.0);
        let parsed = Json::parse(&w.to_json().to_string()).unwrap();
        let e = &parsed.get("traceEvents").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("C"));
        assert_eq!(e.get("ts").and_then(Json::as_f64), Some(2_500_000.0));
        assert_eq!(
            e.get("args").and_then(|a| a.get("value")).and_then(Json::as_f64),
            Some(7.0)
        );
    }
}
