//! Stage-attributed round breakdown (pillar 2 of the telemetry subsystem).
//!
//! Decomposes a round's critical path into the named stages of the split
//! protocol — the latency decomposition the paper's Fig. 4–5 argue from —
//! plus straggler attribution: which pair (or solo client) gated the round
//! and by how much slack over the median participant.
//!
//! The breakdown is computed **unconditionally** by every round evaluator,
//! with arithmetic that never reads telemetry state. That is what makes the
//! determinism invariant trivial: telemetry on vs. off cannot perturb
//! `RoundRecord`, because the record's fields are produced by the exact same
//! instructions either way. The telemetry gate only controls the *side
//! channels* (registry counters, trace/JSONL export).
//!
//! Stage seconds are *work attribution* along the critical flows — per-batch
//! stage duration × batch count — not a partition of wall time: the split
//! pipeline overlaps stages across the two directions, so the stage sum can
//! exceed (or, with idle gaps, undershoot) the critical path's wall clock.

use crate::util::json::{Json, JsonObj};

/// Number of named stages.
pub const N_STAGES: usize = 7;

/// Stage names, in `stage_s` index order:
/// - `front_fp` — front-model forward compute (client-side layers)
/// - `act_tx` — activation + logit-grad transfer, front → back
/// - `back_compute` — back-model forward + backward compute
/// - `grad_tx` — logits + activation-grad transfer, back → front
/// - `front_upd` — front-model backward/update compute
/// - `uplink` — trained-model upload to the central server
/// - `server_agg` — server-side aggregation / queueing (SplitFed queue wait;
///   zero for pair-local protocols, where aggregation is not modeled)
pub const STAGE_NAMES: [&str; N_STAGES] =
    ["front_fp", "act_tx", "back_compute", "grad_tx", "front_upd", "uplink", "server_agg"];

/// Per-round critical-path decomposition + straggler attribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageBreakdown {
    /// Seconds attributed to each stage (see [`STAGE_NAMES`]).
    pub stage_s: [f64; N_STAGES],
    /// Critical entity: client ids of the gating pair, or `(id, -1)` for a
    /// gating solo / FL / SL / SplitFed client, or `(-1, -1)` when the round
    /// had no attribution (empty round, or a path that does not produce one).
    pub crit_a: i64,
    pub crit_b: i64,
    /// Straggler slack: critical participant total minus the p50 participant
    /// total (0 when there are no participants).
    pub crit_slack_s: f64,
}

impl Default for StageBreakdown {
    fn default() -> Self {
        StageBreakdown {
            stage_s: [0.0; N_STAGES],
            crit_a: -1,
            crit_b: -1,
            crit_slack_s: 0.0,
        }
    }
}

impl StageBreakdown {
    /// Total attributed seconds across all stages.
    pub fn sum_s(&self) -> f64 {
        self.stage_s.iter().sum()
    }

    /// Remap critical ids from round-compact indices to universe ids
    /// (`members[compact] = universe`). Drivers that evaluate a round over a
    /// compact sub-fleet call this so exported ids match the fleet trace.
    pub fn remap_crit(&mut self, members: &[usize]) {
        if self.crit_a >= 0 {
            if let Some(&u) = members.get(self.crit_a as usize) {
                self.crit_a = u as i64;
            }
        }
        if self.crit_b >= 0 {
            if let Some(&u) = members.get(self.crit_b as usize) {
                self.crit_b = u as i64;
            }
        }
    }

    /// JSON object with named stage fields + attribution.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        for (name, s) in STAGE_NAMES.iter().zip(self.stage_s.iter()) {
            o.insert(*name, Json::Num(*s));
        }
        o.insert("crit_a", Json::Num(self.crit_a as f64));
        o.insert("crit_b", Json::Num(self.crit_b as f64));
        o.insert("crit_slack_s", Json::Num(self.crit_slack_s));
        Json::Obj(o)
    }
}

/// Stage attribution for a critical FedPairing pair: the two directions'
/// per-batch durations (`split_stage_durations` order: front-fwd, uplink,
/// back fwd+bwd, downlink, front-bwd) scaled by their batch counts, plus the
/// pair's model-upload time. Shared by the analytic engine and the DES path
/// so both produce bit-identical attribution.
pub fn pair_stages(
    d_i: &[f64; 5],
    nb_i: f64,
    d_j: &[f64; 5],
    nb_j: f64,
    upload_s: f64,
) -> [f64; N_STAGES] {
    [
        d_i[0] * nb_i + d_j[0] * nb_j,
        d_i[1] * nb_i + d_j[1] * nb_j,
        d_i[2] * nb_i + d_j[2] * nb_j,
        d_i[3] * nb_i + d_j[3] * nb_j,
        d_i[4] * nb_i + d_j[4] * nb_j,
        upload_s,
        0.0,
    ]
}

/// Stage attribution for a critical full-model participant (solo / FL
/// client): all compute is front compute, plus the model upload.
pub fn solo_stages(compute_s: f64, upload_s: f64) -> [f64; N_STAGES] {
    let mut s = [0.0; N_STAGES];
    s[0] = compute_s;
    s[5] = upload_s;
    s
}

/// Deterministic p50 of participant totals (`total_cmp` ordering; mutates
/// the slice via in-place selection; 0 for an empty round).
pub fn p50(totals: &mut [f64]) -> f64 {
    if totals.is_empty() {
        return 0.0;
    }
    let mid = (totals.len() - 1) / 2;
    let (_, v, _) = totals.select_nth_unstable_by(mid, |a, b| a.total_cmp(b));
    *v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_has_no_attribution() {
        let b = StageBreakdown::default();
        assert_eq!(b.crit_a, -1);
        assert_eq!(b.crit_b, -1);
        assert_eq!(b.sum_s(), 0.0);
    }

    #[test]
    fn pair_stages_scale_by_batches() {
        let d_i = [1.0, 2.0, 3.0, 4.0, 5.0];
        let d_j = [10.0, 20.0, 30.0, 40.0, 50.0];
        let s = pair_stages(&d_i, 2.0, &d_j, 1.0, 7.0);
        assert_eq!(s[0], 12.0);
        assert_eq!(s[1], 24.0);
        assert_eq!(s[4], 60.0);
        assert_eq!(s[5], 7.0);
        assert_eq!(s[6], 0.0);
    }

    #[test]
    fn p50_is_deterministic_median() {
        assert_eq!(p50(&mut []), 0.0);
        assert_eq!(p50(&mut [3.0]), 3.0);
        assert_eq!(p50(&mut [4.0, 1.0, 3.0, 2.0]), 2.0); // lower median
        assert_eq!(p50(&mut [5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn remap_translates_compact_ids() {
        let mut b = StageBreakdown { crit_a: 1, crit_b: 0, ..Default::default() };
        b.remap_crit(&[40, 70]);
        assert_eq!((b.crit_a, b.crit_b), (70, 40));
        let mut solo = StageBreakdown { crit_a: 0, crit_b: -1, ..Default::default() };
        solo.remap_crit(&[40, 70]);
        assert_eq!((solo.crit_a, solo.crit_b), (40, -1));
    }

    #[test]
    fn json_has_named_stages() {
        let b = StageBreakdown {
            stage_s: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
            ..Default::default()
        };
        let j = b.to_json();
        assert_eq!(j.get("front_fp").and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.get("server_agg").and_then(Json::as_f64), Some(7.0));
        assert_eq!(j.get("crit_a").and_then(Json::as_f64), Some(-1.0));
    }
}
