//! Offline run analyzer (pillar 4, DESIGN.md §12): replay a run's
//! `.stream.csv` / `.stream.jsonl` record stream and render tail evolution,
//! stage attribution, fault impact, and the fairness trajectory as text and
//! machine-readable JSON — without re-running the simulation.
//!
//! Both stream formats use shortest-exact float formatting (Rust's default
//! `{}`), so the per-round quantile lanes parsed here are bit-identical to
//! the values the run computed in memory; `tests/observatory.rs` pins that
//! round trip. The CSV loader resolves columns by header name, so streams
//! from older builds (fewer trailing columns) still load, with the missing
//! lanes as NaN.

use super::breakdown::{N_STAGES, STAGE_NAMES};
use super::ledger::RoundLanes;
use crate::util::json::{Json, JsonObj};
use std::collections::HashMap;
use std::fmt::Write as _;
use std::io;

/// One parsed stream record — the analyzer's projection of a
/// `RoundRecord` row.
#[derive(Clone, Debug)]
pub struct ReportRow {
    pub round: usize,
    pub n_alive: usize,
    /// Simulated seconds this round (sync) or merge window (async) took.
    pub sim_round_s: f64,
    /// Cumulative simulated seconds at this record's commit.
    pub t_wall_s: f64,
    /// Mean staleness of the merged updates (NaN on synchronous rounds).
    pub staleness_mean: f64,
    /// Critical-path stage seconds, indexed like [`STAGE_NAMES`].
    pub stage_s: [f64; N_STAGES],
    pub n_failed: u64,
    pub n_retries: u64,
    pub n_lost_updates: u64,
    pub recovery_s: f64,
    /// Exact per-round unit-makespan quantile lanes (NaN when the round
    /// recorded no units).
    pub lanes: RoundLanes,
    /// Cumulative Jain fairness index at this round (NaN until any client
    /// has attributed busy time).
    pub fairness: f64,
}

/// A fully parsed record stream plus the derived analyses.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub rows: Vec<ReportRow>,
}

/// Parse one float CSV field: empty (the NaN encoding) or absent columns
/// load as NaN; malformed tokens are an error, not a silent NaN.
fn csv_f64(fields: &[&str], idx: Option<&usize>) -> Result<f64, String> {
    match idx.and_then(|&i| fields.get(i)) {
        Some(s) if !s.is_empty() => s
            .parse::<f64>()
            .map_err(|e| format!("bad float field {s:?}: {e}")),
        _ => Ok(f64::NAN),
    }
}

/// A float JSON field: missing keys and `null` (the NaN encoding) load as
/// NaN.
fn json_f64(obj: &Json, key: &str) -> f64 {
    obj.get(key).and_then(Json::as_f64).unwrap_or(f64::NAN)
}

impl Report {
    /// Load a stream file, dispatching on extension: `.jsonl` parses as a
    /// JSON-lines stream, anything else as headered CSV.
    pub fn load(path: &str) -> io::Result<Report> {
        let text = std::fs::read_to_string(path)?;
        let parsed = if path.ends_with(".jsonl") {
            Report::from_jsonl(&text)
        } else {
            Report::from_csv(&text)
        };
        parsed.map_err(|e| io::Error::new(io::ErrorKind::InvalidData, format!("{path}: {e}")))
    }

    /// Parse a `.stream.csv` body (header + one row per record). Columns are
    /// resolved by header name, so trailing-column growth in either
    /// direction is tolerated.
    pub fn from_csv(text: &str) -> Result<Report, String> {
        let mut lines = text.lines();
        let header = lines.next().ok_or("empty stream: no header")?;
        let col: HashMap<&str, usize> =
            header.split(',').enumerate().map(|(i, n)| (n, i)).collect();
        if !col.contains_key("round") {
            return Err("not a record stream: header has no `round` column".into());
        }
        let mut rows = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split(',').collect();
            let at = |name: &str| csv_f64(&fields, col.get(name));
            let err = |e| format!("line {}: {e}", ln + 2);
            let round = at("round").map_err(err)?;
            if round.is_nan() {
                return Err(format!("line {}: missing round number", ln + 2));
            }
            let mut stage_s = [0.0; N_STAGES];
            for (k, name) in STAGE_NAMES.iter().enumerate() {
                let v = at(&format!("stage_{name}_s")).map_err(err)?;
                stage_s[k] = if v.is_nan() { 0.0 } else { v };
            }
            let count = |name: &str| -> Result<u64, String> {
                let v = at(name).map_err(err)?;
                Ok(if v.is_nan() { 0 } else { v as u64 })
            };
            rows.push(ReportRow {
                round: round as usize,
                n_alive: at("n_alive").map_err(err)?.max(0.0) as usize,
                sim_round_s: at("sim_round_s").map_err(err)?,
                t_wall_s: at("t_wall_s").map_err(err)?,
                staleness_mean: at("staleness_mean").map_err(err)?,
                stage_s,
                n_failed: count("n_failed")?,
                n_retries: count("n_retries")?,
                n_lost_updates: count("n_lost_updates")?,
                recovery_s: {
                    let v = at("recovery_s").map_err(err)?;
                    if v.is_nan() {
                        0.0
                    } else {
                        v
                    }
                },
                lanes: RoundLanes {
                    p50_s: at("mk_p50_s").map_err(err)?,
                    p90_s: at("mk_p90_s").map_err(err)?,
                    p99_s: at("mk_p99_s").map_err(err)?,
                },
                fairness: at("fairness").map_err(err)?,
            });
        }
        Ok(Report { rows })
    }

    /// Parse a `.stream.jsonl` body (one `RoundRecord` object per line).
    pub fn from_jsonl(text: &str) -> Result<Report, String> {
        let mut rows = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            if line.is_empty() {
                continue;
            }
            let o = Json::parse(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
            let round = o
                .get("round")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("line {}: missing round number", ln + 1))?;
            let mut stage_s = [0.0; N_STAGES];
            if let Some(st) = o.get("stages") {
                for (k, name) in STAGE_NAMES.iter().enumerate() {
                    stage_s[k] = st.get(name).and_then(Json::as_f64).unwrap_or(0.0);
                }
            }
            rows.push(ReportRow {
                round: round as usize,
                n_alive: json_f64(&o, "n_alive").max(0.0) as usize,
                sim_round_s: json_f64(&o, "sim_round_s"),
                t_wall_s: json_f64(&o, "t_wall_s"),
                staleness_mean: json_f64(&o, "staleness_mean"),
                stage_s,
                n_failed: o.get("n_failed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                n_retries: o.get("n_retries").and_then(Json::as_f64).unwrap_or(0.0) as u64,
                n_lost_updates: o.get("n_lost_updates").and_then(Json::as_f64).unwrap_or(0.0)
                    as u64,
                recovery_s: o.get("recovery_s").and_then(Json::as_f64).unwrap_or(0.0),
                lanes: RoundLanes {
                    p50_s: json_f64(&o, "mk_p50_s"),
                    p90_s: json_f64(&o, "mk_p90_s"),
                    p99_s: json_f64(&o, "mk_p99_s"),
                },
                fairness: json_f64(&o, "fairness"),
            });
        }
        Ok(Report { rows })
    }

    /// Total simulated seconds: the last record's wall-clock commit time.
    pub fn sim_total_s(&self) -> f64 {
        self.rows.last().map_or(0.0, |r| r.t_wall_s)
    }

    /// The row with the worst (largest finite) p99 makespan, if any round
    /// recorded units.
    pub fn worst_tail(&self) -> Option<&ReportRow> {
        self.rows
            .iter()
            .filter(|r| r.lanes.p99_s.is_finite())
            .max_by(|a, b| a.lanes.p99_s.total_cmp(&b.lanes.p99_s))
    }

    /// Critical-path seconds summed per stage across the run.
    pub fn stage_totals(&self) -> [f64; N_STAGES] {
        let mut t = [0.0; N_STAGES];
        for r in &self.rows {
            for (k, v) in r.stage_s.iter().enumerate() {
                t[k] += v;
            }
        }
        t
    }

    /// Run-total fault accounting:
    /// `(n_failed, n_retries, n_lost_updates, recovery_s)`.
    pub fn fault_totals(&self) -> (u64, u64, u64, f64) {
        self.rows.iter().fold((0, 0, 0, 0.0), |(f, r, l, s), row| {
            (
                f + row.n_failed,
                r + row.n_retries,
                l + row.n_lost_updates,
                s + row.recovery_s,
            )
        })
    }

    /// First and last finite fairness values — the run's fairness
    /// trajectory endpoints (`None` when no round carried a ledger value).
    pub fn fairness_span(&self) -> Option<(f64, f64)> {
        let first = self.rows.iter().find(|r| r.fairness.is_finite())?;
        let last = self.rows.iter().rev().find(|r| r.fairness.is_finite())?;
        Some((first.fairness, last.fairness))
    }

    /// Indices of up to `k` rows for the tail-evolution table: first, last,
    /// and evenly spaced rounds between them.
    fn sampled(&self, k: usize) -> Vec<usize> {
        let n = self.rows.len();
        if n <= k || k < 2 {
            return (0..n).collect();
        }
        let mut idx: Vec<usize> = (0..k)
            .map(|j| j * (n - 1) / (k - 1))
            .collect();
        idx.dedup();
        idx
    }

    /// Human-readable report.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "run: {} records, {:.3} simulated seconds",
            self.rows.len(),
            self.sim_total_s()
        );
        let fmt_lane = |v: f64| {
            if v.is_finite() {
                format!("{v:>9.4}")
            } else {
                format!("{:>9}", "-")
            }
        };
        let _ = writeln!(s, "\ntail evolution (unit makespan seconds):");
        let _ = writeln!(s, "  {:>6} {:>9} {:>9} {:>9}", "round", "p50", "p90", "p99");
        for i in self.sampled(12) {
            let r = &self.rows[i];
            let _ = writeln!(
                s,
                "  {:>6} {} {} {}",
                r.round,
                fmt_lane(r.lanes.p50_s),
                fmt_lane(r.lanes.p90_s),
                fmt_lane(r.lanes.p99_s)
            );
        }
        if let Some(w) = self.worst_tail() {
            let _ = writeln!(
                s,
                "  worst tail: round {} (p99 {:.4} s, p99/p50 x{:.2})",
                w.round,
                w.lanes.p99_s,
                w.lanes.p99_s / w.lanes.p50_s
            );
        }
        let totals = self.stage_totals();
        let grand: f64 = totals.iter().sum();
        let _ = writeln!(s, "\nstage attribution (critical-path seconds):");
        for (k, name) in STAGE_NAMES.iter().enumerate() {
            let share = if grand > 0.0 {
                100.0 * totals[k] / grand
            } else {
                0.0
            };
            let _ = writeln!(s, "  {:<14} {:>12.4}  ({share:>5.1}%)", name, totals[k]);
        }
        let (nf, nr, nl, rec) = self.fault_totals();
        let _ = writeln!(
            s,
            "\nfaults: {nf} failed, {nr} retries, {nl} lost updates, {rec:.3} s recovery"
        );
        match self.fairness_span() {
            Some((first, last)) => {
                let _ = writeln!(
                    s,
                    "fairness (Jain, cumulative busy time): {first:.4} -> {last:.4}"
                );
            }
            None => {
                let _ = writeln!(s, "fairness (Jain): no ledger data in stream");
            }
        }
        s
    }

    /// Machine-readable report. Per-round lanes are re-emitted with
    /// shortest-exact formatting, so a report of a report is idempotent.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("n_records", Json::num(self.rows.len() as f64));
        o.insert("sim_total_s", Json::num(self.sim_total_s()));
        if let Some(w) = self.worst_tail() {
            o.insert("worst_tail_round", Json::num(w.round as f64));
            o.insert("worst_tail_p99_s", Json::num(w.lanes.p99_s));
        }
        let totals = self.stage_totals();
        let mut st = JsonObj::new();
        for (k, name) in STAGE_NAMES.iter().enumerate() {
            st.insert(name, Json::num(totals[k]));
        }
        o.insert("stage_totals_s", Json::Obj(st));
        let (nf, nr, nl, rec) = self.fault_totals();
        let mut fo = JsonObj::new();
        fo.insert("n_failed", Json::num(nf as f64));
        fo.insert("n_retries", Json::num(nr as f64));
        fo.insert("n_lost_updates", Json::num(nl as f64));
        fo.insert("recovery_s", Json::num(rec));
        o.insert("faults", Json::Obj(fo));
        if let Some((first, last)) = self.fairness_span() {
            o.insert("fairness_first", Json::num(first));
            o.insert("fairness_last", Json::num(last));
        }
        let rounds: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut ro = JsonObj::new();
                ro.insert("round", Json::num(r.round as f64));
                ro.insert("mk_p50_s", Json::num(r.lanes.p50_s));
                ro.insert("mk_p90_s", Json::num(r.lanes.p90_s));
                ro.insert("mk_p99_s", Json::num(r.lanes.p99_s));
                ro.insert("fairness", Json::num(r.fairness));
                Json::Obj(ro)
            })
            .collect();
        o.insert("rounds", Json::Arr(rounds));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::RoundRecord;
    use crate::telemetry::breakdown::StageBreakdown;

    fn record(round: usize, lanes: [f64; 3], fairness: f64) -> RoundRecord {
        let mut stage_s = [0.0; N_STAGES];
        stage_s[0] = 1.5 * round as f64;
        stage_s[5] = 0.25;
        RoundRecord {
            round,
            n_alive: 10,
            train_loss: 1.0,
            test_acc: f64::NAN,
            test_loss: f64::NAN,
            sim_round_s: 0.1 + 0.2 * round as f64,
            sim_total_s: 10.0 * round as f64,
            mean_cut: 4.0,
            stages: StageBreakdown {
                stage_s,
                crit_a: 3,
                crit_b: -1,
                crit_slack_s: 0.5,
            },
            t_wall_s: 10.0 * round as f64,
            staleness_mean: f64::NAN,
            faults: crate::faults::FaultCounters {
                n_failed: round % 2,
                n_retries: round,
                n_lost_updates: 0,
                recovery_s: 0.5 * (round as f64 - 1.0),
            },
            mk_p50_s: lanes[0],
            mk_p90_s: lanes[1],
            mk_p99_s: lanes[2],
            fairness,
        }
    }

    fn stream() -> Vec<RoundRecord> {
        vec![
            record(1, [f64::NAN; 3], f64::NAN),
            record(2, [1.0 / 3.0, 0.7, 0.9], 0.875),
            record(3, [0.4, 0.8, 2.5], 0.9),
            record(4, [0.35, 0.75, 1.1], 0.97),
        ]
    }

    fn csv_of(recs: &[RoundRecord]) -> String {
        let mut s = RoundRecord::csv_header();
        s.push('\n');
        for r in recs {
            s.push_str(&r.csv_row());
            s.push('\n');
        }
        s
    }

    fn jsonl_of(recs: &[RoundRecord]) -> String {
        let mut s = String::new();
        for r in recs {
            s.push_str(&r.to_json_obj().to_string());
            s.push('\n');
        }
        s
    }

    /// NaN-aware bit equality for lane comparisons.
    fn same(a: f64, b: f64) -> bool {
        a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan())
    }

    #[test]
    fn csv_roundtrip_reproduces_lanes_bit_exactly() {
        let recs = stream();
        let rep = Report::from_csv(&csv_of(&recs)).unwrap();
        assert_eq!(rep.rows.len(), recs.len());
        for (row, rec) in rep.rows.iter().zip(&recs) {
            assert_eq!(row.round, rec.round);
            assert!(same(row.lanes.p50_s, rec.mk_p50_s));
            assert!(same(row.lanes.p90_s, rec.mk_p90_s));
            assert!(same(row.lanes.p99_s, rec.mk_p99_s));
            assert!(same(row.fairness, rec.fairness));
            assert!(same(row.sim_round_s, rec.sim_round_s));
            assert_eq!(row.stage_s[0].to_bits(), rec.stages.stage_s[0].to_bits());
        }
    }

    #[test]
    fn jsonl_roundtrip_matches_csv_roundtrip() {
        let recs = stream();
        let a = Report::from_csv(&csv_of(&recs)).unwrap();
        let b = Report::from_jsonl(&jsonl_of(&recs)).unwrap();
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.round, y.round);
            assert_eq!(x.n_alive, y.n_alive);
            assert!(same(x.lanes.p50_s, y.lanes.p50_s));
            assert!(same(x.lanes.p90_s, y.lanes.p90_s));
            assert!(same(x.lanes.p99_s, y.lanes.p99_s));
            assert!(same(x.fairness, y.fairness));
            assert_eq!(x.n_retries, y.n_retries);
            assert!(same(x.recovery_s, y.recovery_s));
        }
    }

    #[test]
    fn analyses_cover_tail_stages_faults_fairness() {
        let rep = Report::from_csv(&csv_of(&stream())).unwrap();
        assert_eq!(rep.worst_tail().unwrap().round, 3);
        assert_eq!(rep.sim_total_s(), 40.0);
        let totals = rep.stage_totals();
        assert!((totals[0] - 1.5 * (1.0 + 2.0 + 3.0 + 4.0)).abs() < 1e-12);
        assert!((totals[5] - 1.0).abs() < 1e-12);
        let (nf, nr, nl, rec) = rep.fault_totals();
        assert_eq!((nf, nr, nl), (2, 10, 0));
        assert!((rec - 3.0).abs() < 1e-12);
        assert_eq!(rep.fairness_span(), Some((0.875, 0.97)));
    }

    #[test]
    fn text_report_names_every_section() {
        let text = Report::from_csv(&csv_of(&stream())).unwrap().render_text();
        assert!(text.contains("tail evolution"));
        assert!(text.contains("worst tail: round 3"));
        assert!(text.contains("stage attribution"));
        assert!(text.contains("front_fp"));
        assert!(text.contains("faults: 2 failed, 10 retries"));
        assert!(text.contains("fairness (Jain, cumulative busy time): 0.8750 -> 0.9700"));
        // Rounds with no recorded units render dashes, not NaN.
        assert!(text.contains('-'));
        assert!(!text.contains("NaN"));
    }

    #[test]
    fn json_report_is_parseable_and_complete() {
        let j = Report::from_csv(&csv_of(&stream())).unwrap().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("n_records").and_then(Json::as_f64), Some(4.0));
        assert_eq!(
            parsed.get("worst_tail_round").and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(
            parsed
                .get("stage_totals_s")
                .and_then(|s| s.get("uplink"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(
            parsed
                .get("faults")
                .and_then(|f| f.get("n_retries"))
                .and_then(Json::as_f64),
            Some(10.0)
        );
        let rounds = parsed.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 4);
        // Round 1 had no units: lanes are null.
        assert!(rounds[0].get("mk_p50_s").unwrap().as_f64().is_none());
        assert_eq!(
            rounds[1].get("mk_p50_s").and_then(Json::as_f64),
            Some(1.0 / 3.0)
        );
    }

    #[test]
    fn sampling_keeps_first_and_last_rows() {
        let recs: Vec<RoundRecord> = (1..=40)
            .map(|r| record(r, [0.1, 0.2, 0.3], 0.9))
            .collect();
        let rep = Report::from_csv(&csv_of(&recs)).unwrap();
        let idx = rep.sampled(12);
        assert!(idx.len() <= 12);
        assert_eq!(*idx.first().unwrap(), 0);
        assert_eq!(*idx.last().unwrap(), 39);
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn loaders_reject_garbage() {
        assert!(Report::from_csv("").is_err());
        assert!(Report::from_csv("a,b,c\n1,2,3").is_err());
        let bad = format!("{}\nnot-a-number,1", RoundRecord::csv_header());
        assert!(Report::from_csv(&bad).is_err());
        assert!(Report::from_jsonl("{\"no_round\":1}").is_err());
        assert!(Report::from_jsonl("{not json").is_err());
    }
}
