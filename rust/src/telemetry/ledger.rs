//! Per-client fairness ledger + the distribution observatory (DESIGN.md
//! §12).
//!
//! [`ClientLedger`] is a compact SoA table over universe client ids:
//! cumulative compute / communication / barrier-wait seconds, rounds
//! participated, times on the round's critical path, times slower than the
//! round's p50 work unit, and updates lost to faults or deadlines. From it
//! derive the Jain fairness index over cumulative busy time and a top-k
//! straggler table.
//!
//! [`Observatory`] bundles the ledger with the [`QuantileSketch`] lanes the
//! drivers feed each round — work-unit makespans, per-stage durations,
//! async staleness / eliminated wait, and fault recovery time — plus the
//! exact per-round p50/p90/p99 makespan lanes carried on `RoundRecord`.
//!
//! Everything here follows the telemetry determinism contract
//! (`tests/observatory.rs`): feeds only *read* simulation state, arithmetic
//! is a deterministic function of the fed values in fed order, and merging
//! shards is element-wise, so ledger and sketches are bit-identical at any
//! `--threads` and the `RoundRecord` lanes are bit-identical whether the
//! telemetry gate is on or off.

use crate::telemetry::breakdown::{StageBreakdown, N_STAGES};
use crate::telemetry::sketch::QuantileSketch;
use crate::util::json::{Json, JsonObj};

/// Exact per-round makespan quantile lanes carried on `RoundRecord`
/// (nearest-rank over the round's work-unit times; NaN when the round
/// recorded no units, e.g. on the DES backend).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundLanes {
    pub p50_s: f64,
    pub p90_s: f64,
    pub p99_s: f64,
}

impl RoundLanes {
    pub fn nan() -> RoundLanes {
        RoundLanes { p50_s: f64::NAN, p90_s: f64::NAN, p99_s: f64::NAN }
    }
}

/// Exact nearest-rank p50/p90/p99 over `unit_times` (sorted on a scratch
/// copy with `total_cmp`, so the result is a pure function of the values).
pub fn exact_lanes(unit_times: &[f64]) -> RoundLanes {
    if unit_times.is_empty() {
        return RoundLanes::nan();
    }
    let mut v = unit_times.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    let pick = |q: f64| v[((q * n as f64).ceil() as usize).clamp(1, n) - 1];
    RoundLanes { p50_s: pick(0.5), p90_s: pick(0.9), p99_s: pick(0.99) }
}

/// One round work unit in universe ids: a split pair or a solo/full-model
/// participant. Aligned index-for-index with the engine's `unit_times` /
/// `unit_splits` arrays.
pub type UnitMembers = (usize, Option<usize>);

/// Compact SoA per-client accounting table, indexed by universe client id.
/// Grows on demand so `Default` is a valid empty ledger.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClientLedger {
    compute_s: Vec<f64>,
    comm_s: Vec<f64>,
    wait_s: Vec<f64>,
    rounds: Vec<u32>,
    crit: Vec<u32>,
    straggler: Vec<u32>,
    lost: Vec<u32>,
}

impl ClientLedger {
    pub fn new() -> ClientLedger {
        ClientLedger::default()
    }

    /// Number of client slots (highest id noted + 1).
    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    fn grow(&mut self, n: usize) {
        if self.rounds.len() < n {
            self.compute_s.resize(n, 0.0);
            self.comm_s.resize(n, 0.0);
            self.wait_s.resize(n, 0.0);
            self.rounds.resize(n, 0);
            self.crit.resize(n, 0);
            self.straggler.resize(n, 0);
            self.lost.resize(n, 0);
        }
    }

    /// Credit one round participation: attributed compute/comm seconds, the
    /// barrier wait behind the round's slowest unit, and whether this
    /// client's unit ran slower than the round's p50 unit.
    pub fn note_member(
        &mut self,
        id: usize,
        compute_s: f64,
        comm_s: f64,
        wait_s: f64,
        straggler: bool,
    ) {
        self.grow(id + 1);
        self.compute_s[id] += compute_s;
        self.comm_s[id] += comm_s;
        self.wait_s[id] += wait_s;
        self.rounds[id] += 1;
        if straggler {
            self.straggler[id] += 1;
        }
    }

    /// Credit one appearance on a round's critical path.
    pub fn note_crit(&mut self, id: usize) {
        self.grow(id + 1);
        self.crit[id] += 1;
    }

    /// Credit one lost update (fault or deadline cutoff).
    pub fn note_lost(&mut self, id: usize) {
        self.grow(id + 1);
        self.lost[id] += 1;
    }

    /// Cumulative busy seconds (compute + communication) for `id`.
    pub fn busy_s(&self, id: usize) -> f64 {
        if id < self.rounds.len() {
            self.compute_s[id] + self.comm_s[id]
        } else {
            0.0
        }
    }

    pub fn wait_of(&self, id: usize) -> f64 {
        self.wait_s.get(id).copied().unwrap_or(0.0)
    }

    pub fn rounds_of(&self, id: usize) -> u32 {
        self.rounds.get(id).copied().unwrap_or(0)
    }

    pub fn crit_of(&self, id: usize) -> u32 {
        self.crit.get(id).copied().unwrap_or(0)
    }

    pub fn straggler_of(&self, id: usize) -> u32 {
        self.straggler.get(id).copied().unwrap_or(0)
    }

    pub fn lost_of(&self, id: usize) -> u32 {
        self.lost.get(id).copied().unwrap_or(0)
    }

    /// Jain fairness index over cumulative busy time of the clients that
    /// participated at least once: `(Σx)² / (n·Σx²)` ∈ (0, 1], 1 = perfectly
    /// even load. NaN when no client has participated (or all busy time is
    /// zero, as on the DES backend, which attributes no per-unit splits).
    pub fn jain(&self) -> f64 {
        let mut n = 0usize;
        let mut s = 0.0f64;
        let mut s2 = 0.0f64;
        for id in 0..self.rounds.len() {
            if self.rounds[id] == 0 {
                continue;
            }
            let x = self.compute_s[id] + self.comm_s[id];
            n += 1;
            s += x;
            s2 += x * x;
        }
        if n == 0 || s2 <= 0.0 {
            return f64::NAN;
        }
        (s * s) / (n as f64 * s2)
    }

    /// Top-k straggler table: `(client id, times slower than round p50)`,
    /// most frequent first, ties broken by ascending id; clients that never
    /// straggled are excluded.
    pub fn top_stragglers(&self, k: usize) -> Vec<(usize, u32)> {
        let mut v: Vec<(usize, u32)> = self
            .straggler
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(id, &c)| (id, c))
            .collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
        v.truncate(k);
        v
    }

    /// Element-wise merge of another ledger shard into this one.
    pub fn merge(&mut self, other: &ClientLedger) {
        self.grow(other.rounds.len());
        for id in 0..other.rounds.len() {
            self.compute_s[id] += other.compute_s[id];
            self.comm_s[id] += other.comm_s[id];
            self.wait_s[id] += other.wait_s[id];
            self.rounds[id] += other.rounds[id];
            self.crit[id] += other.crit[id];
            self.straggler[id] += other.straggler[id];
            self.lost[id] += other.lost[id];
        }
    }

    /// JSON summary: fairness index plus the top-k straggler table.
    pub fn to_json(&self, top_k: usize) -> Json {
        let mut o = JsonObj::new();
        o.insert("n_clients", Json::Num(self.len() as f64));
        o.insert("fairness_jain", Json::num(self.jain()));
        let mut rows = Vec::new();
        for (id, count) in self.top_stragglers(top_k) {
            let mut r = JsonObj::new();
            r.insert("client", Json::Num(id as f64));
            r.insert("straggled", Json::Num(count as f64));
            r.insert("on_critical_path", Json::Num(self.crit[id] as f64));
            r.insert("busy_s", Json::num(self.busy_s(id)));
            r.insert("lost_updates", Json::Num(self.lost[id] as f64));
            rows.push(Json::Obj(r));
        }
        o.insert("top_stragglers", Json::Arr(rows));
        Json::Obj(o)
    }
}

/// The distribution observatory: quantile-sketch lanes + per-client ledger,
/// owned by a driver for the duration of a run and carried on `RunResult`
/// so the CLI can export/print it after the run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Observatory {
    /// Work-unit makespans (pair/solo totals, every round).
    pub unit_makespan: QuantileSketch,
    /// Per-stage critical-path seconds, one observation per round per stage
    /// with non-zero attribution (`STAGE_NAMES` order).
    pub stage: [QuantileSketch; N_STAGES],
    /// Async merge staleness (mean rounds per aggregation event).
    pub staleness: QuantileSketch,
    /// Async wait eliminated vs a synchronous barrier, seconds per event.
    pub wait: QuantileSketch,
    /// Fault recovery seconds, one observation per round that paid any.
    pub recovery: QuantileSketch,
    /// Per-client accounting.
    pub ledger: ClientLedger,
}

impl Observatory {
    pub fn new() -> Observatory {
        Observatory::default()
    }

    /// Feed one synchronous round: every work unit's makespan goes to the
    /// sketch, and every member is credited with its attributed
    /// compute/comm split, the barrier wait behind the round total, and a
    /// straggler mark when its unit exceeded the round's p50 unit. Returns
    /// the exact quantile lanes for the `RoundRecord`.
    ///
    /// `units`, `unit_times` and `unit_splits` are aligned index-for-index;
    /// when the engine recorded no per-unit state (DES backend) all three
    /// are empty and only NaN lanes come back.
    pub fn note_sync_round(
        &mut self,
        units: &[UnitMembers],
        unit_times: &[f64],
        unit_splits: &[[f64; 4]],
        round_total_s: f64,
        lost: &[usize],
    ) -> RoundLanes {
        self.note_units(units, None, unit_times, unit_splits, round_total_s, lost)
    }

    /// Feed one asynchronous merge window. Identical to
    /// [`Observatory::note_sync_round`] except: there is no barrier, so wait
    /// is 0, and the ledger only credits units in `started` (repriced
    /// in-flight units re-enter every window and would be double-counted).
    /// All unit times still feed the makespan sketch and the lanes.
    pub fn note_async_window(
        &mut self,
        units: &[UnitMembers],
        started: &[bool],
        unit_times: &[f64],
        unit_splits: &[[f64; 4]],
        lost: &[usize],
    ) -> RoundLanes {
        self.note_units(units, Some(started), unit_times, unit_splits, 0.0, lost)
    }

    fn note_units(
        &mut self,
        units: &[UnitMembers],
        started: Option<&[bool]>,
        unit_times: &[f64],
        unit_splits: &[[f64; 4]],
        round_total_s: f64,
        lost: &[usize],
    ) -> RoundLanes {
        let lanes = exact_lanes(unit_times);
        for &t in unit_times {
            self.unit_makespan.observe_secs(t);
        }
        let aligned = units.len() == unit_times.len() && units.len() == unit_splits.len();
        if aligned {
            for (u, &(a, b)) in units.iter().enumerate() {
                if let Some(mask) = started {
                    if !mask.get(u).copied().unwrap_or(false) {
                        continue;
                    }
                }
                let s = unit_splits[u];
                let t = unit_times[u];
                let wait = (round_total_s - t).max(0.0);
                let strag = lanes.p50_s.is_finite() && t > lanes.p50_s;
                self.ledger.note_member(a, s[0], s[1], wait, strag);
                if let Some(b) = b {
                    self.ledger.note_member(b, s[2], s[3], wait, strag);
                }
            }
        }
        for &id in lost {
            self.ledger.note_lost(id);
        }
        lanes
    }

    /// Feed the round's stage attribution (post-`remap_crit`): each stage
    /// with non-zero seconds gets one observation, and the critical
    /// participant(s) are credited in the ledger.
    pub fn note_stages(&mut self, stages: &StageBreakdown) {
        for (i, &s) in stages.stage_s.iter().enumerate() {
            if s > 0.0 {
                self.stage[i].observe_secs(s);
            }
        }
        if stages.crit_a >= 0 {
            self.ledger.note_crit(stages.crit_a as usize);
        }
        if stages.crit_b >= 0 {
            self.ledger.note_crit(stages.crit_b as usize);
        }
    }

    /// Feed a round's fault recovery cost (skipped when zero: fault-free
    /// rounds carry no recovery observation).
    pub fn note_fault_recovery(&mut self, recovery_s: f64) {
        if recovery_s > 0.0 {
            self.recovery.observe_secs(recovery_s);
        }
    }

    /// Feed one buffered-aggregation event's staleness / eliminated-wait.
    pub fn note_async_event(&mut self, staleness_mean: f64, wait_eliminated_s: f64) {
        if staleness_mean.is_finite() && staleness_mean >= 0.0 {
            self.staleness.observe_secs(staleness_mean);
        }
        if wait_eliminated_s > 0.0 {
            self.wait.observe_secs(wait_eliminated_s);
        }
    }

    /// Element-wise merge of another observatory shard.
    pub fn merge(&mut self, other: &Observatory) {
        self.unit_makespan.merge(&other.unit_makespan);
        for (a, b) in self.stage.iter_mut().zip(other.stage.iter()) {
            a.merge(b);
        }
        self.staleness.merge(&other.staleness);
        self.wait.merge(&other.wait);
        self.recovery.merge(&other.recovery);
        self.ledger.merge(&other.ledger);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_lanes_nearest_rank() {
        let l = exact_lanes(&[]);
        assert!(l.p50_s.is_nan() && l.p90_s.is_nan() && l.p99_s.is_nan());
        let l = exact_lanes(&[5.0]);
        assert_eq!((l.p50_s, l.p90_s, l.p99_s), (5.0, 5.0, 5.0));
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let l = exact_lanes(&v);
        assert_eq!((l.p50_s, l.p90_s, l.p99_s), (50.0, 90.0, 99.0));
        // Order independence: lanes are a pure function of the multiset.
        let mut rev = v.clone();
        rev.reverse();
        let lr = exact_lanes(&rev);
        assert_eq!((l.p50_s, l.p90_s, l.p99_s), (lr.p50_s, lr.p90_s, lr.p99_s));
    }

    #[test]
    fn ledger_attribution_and_jain() {
        let mut led = ClientLedger::new();
        led.note_member(0, 1.0, 0.5, 0.0, false);
        led.note_member(3, 1.0, 0.5, 2.0, true);
        assert_eq!(led.len(), 4);
        assert_eq!(led.rounds_of(0), 1);
        assert_eq!(led.rounds_of(1), 0);
        assert_eq!(led.straggler_of(3), 1);
        assert_eq!(led.wait_of(3), 2.0);
        // Equal busy → Jain = 1.
        assert!((led.jain() - 1.0).abs() < 1e-12);
        led.note_member(0, 3.0, 0.0, 0.0, true);
        assert!(led.jain() < 1.0);
        led.note_crit(3);
        led.note_lost(7);
        assert_eq!(led.crit_of(3), 1);
        assert_eq!(led.lost_of(7), 1);
        assert_eq!(led.len(), 8);
    }

    #[test]
    fn empty_ledger_jain_is_nan() {
        assert!(ClientLedger::new().jain().is_nan());
    }

    #[test]
    fn stragglers_rank_by_count_then_id() {
        let mut led = ClientLedger::new();
        for _ in 0..3 {
            led.note_member(5, 1.0, 0.0, 0.0, true);
        }
        led.note_member(2, 1.0, 0.0, 0.0, true);
        led.note_member(9, 1.0, 0.0, 0.0, true);
        led.note_member(1, 1.0, 0.0, 0.0, false);
        assert_eq!(led.top_stragglers(2), vec![(5, 3), (2, 1)]);
        assert_eq!(led.top_stragglers(10), vec![(5, 3), (2, 1), (9, 1)]);
    }

    #[test]
    fn ledger_merge_matches_serial() {
        let mut serial = ClientLedger::new();
        let mut a = ClientLedger::new();
        let mut b = ClientLedger::new();
        for i in 0..20usize {
            let (c, m, w) = (i as f64, 0.5 * i as f64, 0.1);
            serial.note_member(i % 7, c, m, w, i % 3 == 0);
            if i % 2 == 0 {
                a.note_member(i % 7, c, m, w, i % 3 == 0);
            } else {
                b.note_member(i % 7, c, m, w, i % 3 == 0);
            }
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(serial, merged);
    }

    #[test]
    fn sync_round_feeds_sketch_and_ledger() {
        let mut obs = Observatory::new();
        let units: Vec<UnitMembers> = vec![(0, Some(1)), (2, None)];
        let times = [4.0, 2.0];
        let splits = [[1.0, 0.5, 2.0, 1.5], [1.5, 0.5, 0.0, 0.0]];
        let lanes = obs.note_sync_round(&units, &times, &splits, 4.0, &[2]);
        assert_eq!(lanes.p50_s, 2.0);
        assert_eq!(lanes.p99_s, 4.0);
        assert_eq!(obs.unit_makespan.count(), 2);
        // Pair members straggle (4.0 > p50=2.0), solo does not.
        assert_eq!(obs.ledger.straggler_of(0), 1);
        assert_eq!(obs.ledger.straggler_of(1), 1);
        assert_eq!(obs.ledger.straggler_of(2), 0);
        // Solo waits behind the pair at the barrier.
        assert_eq!(obs.ledger.wait_of(2), 2.0);
        assert_eq!(obs.ledger.lost_of(2), 1);
        assert!((obs.ledger.busy_s(0) - 1.5).abs() < 1e-12);
        assert!((obs.ledger.busy_s(1) - 3.5).abs() < 1e-12);
    }

    #[test]
    fn async_window_credits_started_units_only() {
        let mut obs = Observatory::new();
        let units: Vec<UnitMembers> = vec![(0, None), (1, None)];
        let times = [1.0, 3.0];
        let splits = [[1.0, 0.0, 0.0, 0.0], [2.0, 1.0, 0.0, 0.0]];
        let lanes = obs.note_async_window(&units, &[true, false], &times, &splits, &[]);
        assert_eq!(lanes.p99_s, 3.0);
        assert_eq!(obs.unit_makespan.count(), 2); // sketch sees every unit
        assert_eq!(obs.ledger.rounds_of(0), 1);
        assert_eq!(obs.ledger.rounds_of(1), 0); // repriced unit not credited
        assert_eq!(obs.ledger.wait_of(0), 0.0); // no barrier in async mode
    }

    #[test]
    fn stage_feed_skips_zero_stages_and_credits_crit() {
        let mut obs = Observatory::new();
        let mut stage_s = [0.0; N_STAGES];
        stage_s[0] = 1.0;
        let br = StageBreakdown { stage_s, crit_a: 4, ..Default::default() };
        obs.note_stages(&br);
        assert_eq!(obs.stage[0].count(), 1);
        assert_eq!(obs.stage[1].count(), 0);
        assert_eq!(obs.ledger.crit_of(4), 1);
    }

    #[test]
    fn observatory_merge_matches_serial() {
        let units: Vec<UnitMembers> = (0..10).map(|i| (i, None)).collect();
        let times: Vec<f64> = (0..10).map(|i| 1.0 + i as f64).collect();
        let splits: Vec<[f64; 4]> = times.iter().map(|&t| [t * 0.7, t * 0.3, 0.0, 0.0]).collect();
        let mut serial = Observatory::new();
        serial.note_sync_round(&units, &times, &splits, 10.0, &[]);
        serial.note_fault_recovery(0.5);
        let mut a = Observatory::new();
        a.note_sync_round(&units[..5], &times[..5], &splits[..5], 10.0, &[]);
        a.note_fault_recovery(0.5);
        let mut b = Observatory::new();
        b.note_sync_round(&units[5..], &times[5..], &splits[5..], 10.0, &[]);
        let mut merged = a.clone();
        merged.merge(&b);
        // Sketch + per-client sums agree; straggler marks differ because the
        // shards see different p50s, so compare the sketch and busy fields.
        assert_eq!(serial.unit_makespan, merged.unit_makespan);
        assert_eq!(serial.recovery, merged.recovery);
        for id in 0..10 {
            assert_eq!(serial.ledger.busy_s(id), merged.ledger.busy_s(id));
            assert_eq!(serial.ledger.rounds_of(id), merged.ledger.rounds_of(id));
        }
    }
}
