//! Prometheus-style text exposition + JSONL event-stream renderers
//! (pillar 3 of the telemetry subsystem).

use super::ledger::Observatory;
use super::registry::Snapshot;
use super::sketch::{bucket_high, QuantileSketch};
use crate::telemetry::breakdown::STAGE_NAMES;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Metric-name prefix for every exposed series.
const PREFIX: &str = "fedpairing";

/// Render a registry snapshot in the Prometheus text exposition format:
/// counters, gauges, the derived memo hit-rate, log2 histograms as
/// cumulative `_bucket{le="..."}` series (trailing all-zero buckets elided),
/// and per-histogram top-bucket overflow counters.
pub fn prometheus(snap: &Snapshot) -> String {
    let mut s = String::new();
    for (name, v) in &snap.counters {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} counter\n{PREFIX}_{name} {v}");
    }
    for (name, v) in &snap.gauges {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} gauge\n{PREFIX}_{name} {v}");
    }
    let rate = snap.memo_hit_rate();
    let _ = writeln!(s, "# TYPE {PREFIX}_memo_hit_rate gauge\n{PREFIX}_memo_hit_rate {rate}");
    for (name, buckets) in &snap.histos {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} histogram");
        let last = buckets.iter().rposition(|&b| b > 0);
        let mut cum = 0u64;
        if let Some(last) = last {
            for (k, &b) in buckets.iter().enumerate().take(last + 1) {
                cum += b;
                let le = super::registry::bucket_bound(k);
                let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"{le}\"}} {cum}");
            }
        }
        let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(s, "{PREFIX}_{name}_count {cum}");
    }
    for (name, v) in &snap.histo_overflows {
        let _ = writeln!(
            s,
            "# TYPE {PREFIX}_{name}_overflow_total counter\n{PREFIX}_{name}_overflow_total {v}"
        );
    }
    s
}

/// Render one quantile sketch as a conformant Prometheus histogram in
/// seconds: cumulative `_bucket{le="..."}` at each non-empty bucket's upper
/// bound, a `+Inf` bucket, exact `_sum` and `_count`.
fn sketch_histogram(s: &mut String, name: &str, sk: &QuantileSketch) {
    let _ = writeln!(s, "# TYPE {PREFIX}_{name} histogram");
    let mut cum = 0u64;
    for (i, &c) in sk.counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        cum += c;
        let le = bucket_high(i) as f64 / 1e6;
        let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"+Inf\"}} {cum}");
    let _ = writeln!(s, "{PREFIX}_{name}_sum {}", sk.sum_secs());
    let _ = writeln!(s, "{PREFIX}_{name}_count {}", sk.count());
}

/// Render the distribution observatory: every sketch lane as a Prometheus
/// histogram (empty lanes elided), plus the ledger's Jain fairness gauge and
/// per-client straggler/critical-path counts for the top-k stragglers.
pub fn observatory(obs: &Observatory, top_k: usize) -> String {
    let mut s = String::new();
    let lanes: Vec<(String, &QuantileSketch)> = std::iter::once(
        ("unit_makespan_seconds".to_string(), &obs.unit_makespan),
    )
    .chain(
        STAGE_NAMES
            .iter()
            .zip(obs.stage.iter())
            .map(|(n, sk)| (format!("stage_{n}_seconds"), sk)),
    )
    .chain([
        ("async_staleness_rounds".to_string(), &obs.staleness),
        ("async_wait_eliminated_seconds".to_string(), &obs.wait),
        ("fault_recovery_seconds".to_string(), &obs.recovery),
    ])
    .collect();
    for (name, sk) in &lanes {
        if !sk.is_empty() {
            sketch_histogram(&mut s, name, sk);
        }
    }
    let jain = obs.ledger.jain();
    if !jain.is_nan() {
        let _ = writeln!(
            s,
            "# TYPE {PREFIX}_fairness_jain gauge\n{PREFIX}_fairness_jain {jain}"
        );
    }
    for (id, count) in obs.ledger.top_stragglers(top_k) {
        let _ = writeln!(
            s,
            "{PREFIX}_client_straggler_total{{client=\"{id}\"}} {count}"
        );
        let _ = writeln!(
            s,
            "{PREFIX}_client_critical_path_total{{client=\"{id}\"}} {}",
            obs.ledger.crit_of(id)
        );
    }
    s
}

/// Render an event stream as JSON Lines (one compact object per line).
pub fn jsonl(events: &[Json]) -> String {
    let mut s = String::new();
    for e in events {
        s.push_str(&e.to_string());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::HISTO_BUCKETS;
    use crate::util::json::JsonObj;

    #[test]
    fn prometheus_renders_all_metric_kinds() {
        let mut buckets = [0u64; HISTO_BUCKETS];
        buckets[0] = 1; // one zero-valued observation
        buckets[3] = 2; // two observations in [4, 8)
        let snap = Snapshot {
            counters: vec![("memo_hits_total", 3), ("memo_misses_total", 1)],
            gauges: vec![("fleet_alive", 42)],
            histos: vec![("pool_chunk_nanos", buckets)],
            histo_overflows: vec![("pool_chunk_nanos", 5)],
        };
        let text = prometheus(&snap);
        assert!(text.contains("fedpairing_memo_hits_total 3"));
        assert!(text.contains("# TYPE fedpairing_fleet_alive gauge"));
        assert!(text.contains("fedpairing_memo_hit_rate 0.75"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"0\"} 1"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"7\"} 3"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_count 3"));
        assert!(text.contains("# TYPE fedpairing_pool_chunk_nanos_overflow_total counter"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_overflow_total 5"));
    }

    #[test]
    fn observatory_renders_sketches_with_sum_and_count() {
        let mut obs = Observatory::new();
        obs.unit_makespan.observe_secs(1.5);
        obs.unit_makespan.observe_secs(2.25);
        obs.ledger.note_member(3, 1.0, 0.5, 0.0, true);
        obs.ledger.note_member(4, 1.0, 0.5, 0.0, false);
        obs.ledger.note_crit(3);
        let text = observatory(&obs, 5);
        assert!(text.contains("# TYPE fedpairing_unit_makespan_seconds histogram"));
        assert!(text.contains("fedpairing_unit_makespan_seconds_count 2"));
        assert!(text.contains("fedpairing_unit_makespan_seconds_sum 3.75"));
        assert!(text.contains("fedpairing_unit_makespan_seconds_bucket{le=\"+Inf\"} 2"));
        // Cumulative buckets are monotone and end at the count.
        let mut last = 0u64;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("fedpairing_unit_makespan_seconds_bucket") {
                let v: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= last);
                last = v;
            }
        }
        assert_eq!(last, 2);
        // Empty lanes (e.g. async staleness) are elided entirely.
        assert!(!text.contains("async_staleness"));
        // Ledger series: fairness gauge + top-k straggler labels.
        assert!(text.contains("fedpairing_fairness_jain 1"));
        assert!(text.contains("fedpairing_client_straggler_total{client=\"3\"} 1"));
        assert!(text.contains("fedpairing_client_critical_path_total{client=\"3\"} 1"));
    }

    #[test]
    fn jsonl_emits_one_line_per_event() {
        let mut a = JsonObj::new();
        a.insert("round", Json::Num(1.0));
        let text = jsonl(&[Json::Obj(a.clone()), Json::Obj(a)]);
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(Json::parse(line).is_ok());
        }
    }
}
