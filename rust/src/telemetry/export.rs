//! Prometheus-style text exposition + JSONL event-stream renderers
//! (pillar 3 of the telemetry subsystem).

use super::registry::Snapshot;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Metric-name prefix for every exposed series.
const PREFIX: &str = "fedpairing";

/// Render a registry snapshot in the Prometheus text exposition format:
/// counters, gauges, the derived memo hit-rate, and log2 histograms as
/// cumulative `_bucket{le="..."}` series (trailing all-zero buckets elided).
pub fn prometheus(snap: &Snapshot) -> String {
    let mut s = String::new();
    for (name, v) in &snap.counters {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} counter\n{PREFIX}_{name} {v}");
    }
    for (name, v) in &snap.gauges {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} gauge\n{PREFIX}_{name} {v}");
    }
    let rate = snap.memo_hit_rate();
    let _ = writeln!(s, "# TYPE {PREFIX}_memo_hit_rate gauge\n{PREFIX}_memo_hit_rate {rate}");
    for (name, buckets) in &snap.histos {
        let _ = writeln!(s, "# TYPE {PREFIX}_{name} histogram");
        let last = buckets.iter().rposition(|&b| b > 0);
        let mut cum = 0u64;
        if let Some(last) = last {
            for (k, &b) in buckets.iter().enumerate().take(last + 1) {
                cum += b;
                let le = super::registry::bucket_bound(k);
                let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"{le}\"}} {cum}");
            }
        }
        let _ = writeln!(s, "{PREFIX}_{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(s, "{PREFIX}_{name}_count {cum}");
    }
    s
}

/// Render an event stream as JSON Lines (one compact object per line).
pub fn jsonl(events: &[Json]) -> String {
    let mut s = String::new();
    for e in events {
        s.push_str(&e.to_string());
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::registry::HISTO_BUCKETS;
    use crate::util::json::JsonObj;

    #[test]
    fn prometheus_renders_all_metric_kinds() {
        let mut buckets = [0u64; HISTO_BUCKETS];
        buckets[0] = 1; // one zero-valued observation
        buckets[3] = 2; // two observations in [4, 8)
        let snap = Snapshot {
            counters: vec![("memo_hits_total", 3), ("memo_misses_total", 1)],
            gauges: vec![("fleet_alive", 42)],
            histos: vec![("pool_chunk_nanos", buckets)],
        };
        let text = prometheus(&snap);
        assert!(text.contains("fedpairing_memo_hits_total 3"));
        assert!(text.contains("# TYPE fedpairing_fleet_alive gauge"));
        assert!(text.contains("fedpairing_memo_hit_rate 0.75"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"0\"} 1"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"7\"} 3"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("fedpairing_pool_chunk_nanos_count 3"));
    }

    #[test]
    fn jsonl_emits_one_line_per_event() {
        let mut a = JsonObj::new();
        a.insert("round", Json::Num(1.0));
        let text = jsonl(&[Json::Obj(a.clone()), Json::Obj(a)]);
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(Json::parse(line).is_ok());
        }
    }
}
