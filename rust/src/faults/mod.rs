//! Mid-round fault injection and recovery (DESIGN.md §11).
//!
//! The simulator's round kernels assume every client that starts a round
//! finishes it. This module breaks that assumption deterministically: a
//! [`FaultModel`] samples per-stage failure events — client crash during
//! local compute, pair-link drop during activation/gradient transfer,
//! uplink loss during model upload — from configurable hazards on a
//! dedicated seeded RNG stream, and prices what the configured recovery
//! policy ([`crate::config::RecoveryConfig`]) costs in round time and lost
//! updates:
//!
//! * **Bounded retry with exponential backoff + jitter** for transmission
//!   failures (pair link and uplink).
//! * **Survivor-goes-solo re-pairing** when a split partner dies mid-pair:
//!   the survivor finishes the *full* model from the crash point at its own
//!   solo rate, and its update still counts.
//! * **Deadline-based partial aggregation**: a server-side round deadline
//!   truncates the round, merges whatever arrived in time, and counts the
//!   rest as lost — instead of waiting on doomed stragglers.
//!
//! **Determinism contract** (property-tested in `tests/faults.rs`): every
//! work unit draws from its own self-contained RNG stream keyed on
//! `(seed, round, unit member ids)`, so the number of draws one unit makes
//! can never perturb another unit's outcome and the whole pass is
//! independent of evaluation order and `--threads`. With all hazards zero
//! the pass is skipped entirely and traces are bit-for-bit identical to a
//! fault-free run; hazard draws are also deadline-independent, so a tighter
//! deadline can only truncate the round earlier and lose more updates —
//! never change *which* faults fire (the monotonicity the property suite
//! asserts).
//!
//! The model is applied as a post-kernel pass over the engine's recorded
//! per-unit times (`RoundEngine::unit_times`), which is why the DES backend
//! (which records none) rejects fault configs at validation time. In async
//! mode the decision is made once when a unit starts on the `Timeline`
//! ([`FaultModel::plan_unit`] + [`AsyncFaults`]) and replayed as an additive
//! duration delta across reprices; doomed units run to their death time,
//! deliver nothing at merge, and their members re-enter the queue at the
//! next window.

use crate::config::{Algorithm, ComputeConfig, FaultConfig};
use crate::sim::channel::Channel;
use crate::sim::latency::{full_local_time, ClientSet, Schedule};
use crate::sim::profile::ModelProfile;
use crate::telemetry::registry::{self, Counter, Histo};
use crate::util::rng::{splitmix64, Rng};
use std::collections::HashMap;

/// Stream tag for the fault RNG: decorrelated from the pairing
/// (`seed ^ 0x9A1F`) and loader (`seed ^ 0xC11E47`) streams.
pub const FAULT_STREAM: u64 = 0xFA17;

/// Per-round fault accounting, carried on `RoundTime` → `RoundRecord`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultCounters {
    /// Clients that suffered a terminal failure this round (crash, or a
    /// transfer whose retries were exhausted). Never exceeds the round's
    /// participant count.
    pub n_failed: usize,
    /// Retry attempts spent on transmission failures.
    pub n_retries: usize,
    /// Client updates that never reached the aggregator (failures plus
    /// deadline cutoffs).
    pub n_lost_updates: usize,
    /// Extra simulated seconds spent on recovery (backoff waits, solo
    /// finishes) relative to the fault-free round.
    pub recovery_s: f64,
}

/// What failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// A client died during local compute.
    Crash,
    /// The pair (or client↔server split) link dropped mid-transfer.
    LinkDrop,
    /// The model upload to the aggregator was lost.
    UplinkLoss,
    /// The server's round deadline fired before every update arrived.
    Deadline,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Crash => "crash",
            FaultKind::LinkDrop => "link_drop",
            FaultKind::UplinkLoss => "uplink_loss",
            FaultKind::Deadline => "deadline",
        }
    }
}

/// One injected fault incident (exported as a JSONL `fault` event).
#[derive(Clone, Debug)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// Universe id of the primary affected client (`-1` for deadline).
    pub a: i64,
    /// Universe id of the partner, `-1` when the unit has none.
    pub b: i64,
    /// Simulated seconds into the round at which the incident fired.
    pub t_s: f64,
    /// Retry attempts spent recovering from this incident.
    pub retries: usize,
    /// Updates lost to this incident.
    pub lost: usize,
}

impl FaultEvent {
    fn new(kind: FaultKind, a: i64, b: i64, t_s: f64, retries: usize, lost: usize) -> FaultEvent {
        FaultEvent { kind, a, b, t_s, retries, lost }
    }
}

/// One work unit of a round, in universe ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultUnit {
    /// A split-training pair (FedPairing).
    Pair(usize, usize),
    /// A lone client training against the server (FedPairing leftover,
    /// VanillaFL, SplitFed).
    Solo(usize),
    /// One sequential split-learning session (VanillaSL).
    Session(usize),
}

impl FaultUnit {
    /// Universe ids participating in this unit.
    pub fn members(self) -> Vec<usize> {
        match self {
            FaultUnit::Pair(a, b) => vec![a, b],
            FaultUnit::Solo(s) | FaultUnit::Session(s) => vec![s],
        }
    }

    fn ids(self) -> (i64, i64) {
        match self {
            FaultUnit::Pair(a, b) => (a as i64, b as i64),
            FaultUnit::Solo(s) | FaultUnit::Session(s) => (s as i64, -1),
        }
    }

    fn stream_key(self) -> (u64, u64) {
        match self {
            FaultUnit::Pair(a, b) => (a as u64, b as u64),
            FaultUnit::Solo(s) => (s as u64, u64::MAX),
            FaultUnit::Session(s) => (s as u64, u64::MAX - 1),
        }
    }
}

/// A unit plus its fault-free price and recovery fallbacks.
#[derive(Clone, Copy, Debug)]
pub struct UnitSpec {
    pub unit: FaultUnit,
    /// Fault-free duration of this unit (the engine's `unit_times()` entry).
    pub t0: f64,
    /// Full-model solo finish time for the first pair member (unused for
    /// solos/sessions).
    pub solo_a: f64,
    /// Full-model solo finish time for the second pair member.
    pub solo_b: f64,
}

/// The folded result of one round's fault pass.
#[derive(Clone, Debug)]
pub struct FaultOutcome {
    pub counters: FaultCounters,
    /// The round's total after faults and deadline (equals the fault-free
    /// total when `changed` is false).
    pub total_s: f64,
    /// Whether anything fired. When false the caller must leave the
    /// fault-free trace untouched — this is the bit-identity gate.
    pub changed: bool,
    /// Universe ids whose updates must be excluded from aggregation, sorted.
    pub lost: Vec<usize>,
    pub events: Vec<FaultEvent>,
}

/// What an exhausted pair/split-link retry budget falls back to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum LinkFail {
    /// Unit has no mid-round transfer link (plain FL uploads only).
    None,
    /// Pair members fall back to solo full-model training and still deliver.
    SoloFinish,
    /// No partner to fall back on (split pipeline vs. the server): lost.
    Lost,
}

/// How one unit actually ran under injected faults.
#[derive(Clone, Debug)]
struct UnitRun {
    unit: FaultUnit,
    /// Seconds the unit holds the round open (death or delivery, before any
    /// shared post-pipeline overhead).
    occupied_s: f64,
    /// Whether the surviving members still deliver an update.
    delivers: bool,
    /// Universe ids lost to fault events (deadline losses come later).
    lost: Vec<usize>,
    failed: usize,
    retries: usize,
    recovery_s: f64,
    events: Vec<FaultEvent>,
}

/// Samples per-stage failures and prices the configured recovery policy.
pub struct FaultModel<'a> {
    cfg: &'a FaultConfig,
    algo: Algorithm,
    seed: u64,
}

impl<'a> FaultModel<'a> {
    pub fn new(cfg: &'a FaultConfig, algo: Algorithm, seed: u64) -> FaultModel<'a> {
        FaultModel { cfg, algo, seed }
    }

    /// Whether any hazard or the deadline is armed.
    pub fn active(&self) -> bool {
        self.cfg.active()
    }

    /// Run the fault pass over one synchronous round.
    ///
    /// `units` lists the round's work units with their fault-free prices (in
    /// the engine's `unit_times()` order); `shared_delivery_s` is overhead
    /// added to every delivering unit's arrival time (SplitFed's FedAvg
    /// upload, zero elsewhere); `fault_free_total_s` is the kernel's round
    /// total, returned untouched when nothing fires.
    pub fn inject_round(
        &self,
        round: usize,
        units: &[UnitSpec],
        shared_delivery_s: f64,
        fault_free_total_s: f64,
    ) -> FaultOutcome {
        let mut runs: Vec<UnitRun> = Vec::with_capacity(units.len());
        for spec in units {
            let mut rng = self.unit_rng(round, spec.unit);
            runs.push(self.eval_unit(spec, &mut rng));
        }
        self.fold_round(&runs, shared_delivery_s, fault_free_total_s)
    }

    /// Decide the fault outcome for a unit starting in async merge window
    /// `window`. The decision is final for the unit's lifetime; reprices
    /// replay it through [`AsyncFaults::reprice`].
    pub fn plan_unit(&self, window: usize, spec: &UnitSpec) -> PlannedUnit {
        let mut rng = self.unit_rng(window, spec.unit);
        let run = self.eval_unit(spec, &mut rng);
        PlannedUnit { dur_s: run.occupied_s, t0: spec.t0, run }
    }

    /// Self-contained per-unit stream: a SplitMix64 chain over
    /// `(round, member ids)` picks the stream, so one unit's draw count can
    /// never shift another unit's sequence.
    fn unit_rng(&self, round: usize, unit: FaultUnit) -> Rng {
        let (a, b) = unit.stream_key();
        let mut state = (round as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut acc = splitmix64(&mut state);
        state = acc ^ a;
        acc = splitmix64(&mut state);
        state = acc ^ b;
        let stream = splitmix64(&mut state);
        Rng::with_stream(self.seed ^ FAULT_STREAM, stream)
    }

    /// Which failure stages apply to this unit under this algorithm.
    fn stage_plan(&self, unit: FaultUnit) -> (LinkFail, bool) {
        match (self.algo, unit) {
            (_, FaultUnit::Pair(..)) => (LinkFail::SoloFinish, true),
            (Algorithm::SplitFed, FaultUnit::Solo(_)) => (LinkFail::Lost, true),
            (_, FaultUnit::Session(_)) => (LinkFail::Lost, false),
            (_, FaultUnit::Solo(_)) => (LinkFail::None, true),
        }
    }

    fn eval_unit(&self, spec: &UnitSpec, rng: &mut Rng) -> UnitRun {
        let mut run = UnitRun {
            unit: spec.unit,
            occupied_s: spec.t0,
            delivers: true,
            lost: Vec::new(),
            failed: 0,
            retries: 0,
            recovery_s: 0.0,
            events: Vec::new(),
        };
        self.eval_stages(spec, rng, &mut run);
        run.recovery_s = (run.occupied_s - spec.t0).max(0.0);
        run
    }

    fn eval_stages(&self, spec: &UnitSpec, rng: &mut Rng, run: &mut UnitRun) {
        let h = self.cfg;
        let rc = &h.recovery;
        let t0 = spec.t0;
        let (link_fail, has_uplink) = self.stage_plan(spec.unit);

        // Stage 1: client crash during local compute; stage 2: mid-round
        // transfer-link drop (only units that survive stage 1 intact).
        match spec.unit {
            FaultUnit::Pair(a, b) => {
                let (ca, ua) = crash_draw(h.crash_per_round, rng);
                let (cb, ub) = crash_draw(h.crash_per_round, rng);
                match (ca, cb) {
                    (true, true) => {
                        run.occupied_s = ua.max(ub) * t0;
                        run.delivers = false;
                        run.lost = vec![a, b];
                        run.failed = 2;
                        let (ea, eb) = (a as i64, b as i64);
                        run.events.push(FaultEvent::new(FaultKind::Crash, ea, eb, ua * t0, 0, 1));
                        run.events.push(FaultEvent::new(FaultKind::Crash, eb, ea, ub * t0, 0, 1));
                        return;
                    }
                    (true, false) => {
                        // Partner a dies: survivor b goes solo and finishes
                        // the full model from the crash point.
                        run.occupied_s = ua * t0 + (1.0 - ua) * spec.solo_b;
                        run.lost.push(a);
                        run.failed = 1;
                        let ev =
                            FaultEvent::new(FaultKind::Crash, a as i64, b as i64, ua * t0, 0, 1);
                        run.events.push(ev);
                    }
                    (false, true) => {
                        run.occupied_s = ub * t0 + (1.0 - ub) * spec.solo_a;
                        run.lost.push(b);
                        run.failed = 1;
                        let ev =
                            FaultEvent::new(FaultKind::Crash, b as i64, a as i64, ub * t0, 0, 1);
                        run.events.push(ev);
                    }
                    (false, false) => {
                        if h.link_drop > 0.0 && rng.f64() < h.link_drop {
                            let ud = rng.f64();
                            let (backoff, n, ok) = retry_transmission(h.link_drop, rc, rng);
                            run.retries = n;
                            if ok {
                                run.occupied_s = t0 + backoff;
                            } else {
                                // Retries exhausted: both members fall back
                                // to solo full-model training from the drop
                                // point; their updates still arrive.
                                let solo = spec.solo_a.max(spec.solo_b);
                                run.occupied_s = ud * t0 + backoff + (1.0 - ud) * solo;
                            }
                            let ev = FaultEvent::new(
                                FaultKind::LinkDrop,
                                a as i64,
                                b as i64,
                                ud * t0,
                                n,
                                0,
                            );
                            run.events.push(ev);
                        }
                    }
                }
            }
            FaultUnit::Solo(s) | FaultUnit::Session(s) => {
                let (c, u) = crash_draw(h.crash_per_round, rng);
                if c {
                    run.occupied_s = u * t0;
                    run.delivers = false;
                    run.lost = vec![s];
                    run.failed = 1;
                    run.events.push(FaultEvent::new(FaultKind::Crash, s as i64, -1, u * t0, 0, 1));
                    return;
                }
                if link_fail != LinkFail::None && h.link_drop > 0.0 && rng.f64() < h.link_drop {
                    let ud = rng.f64();
                    let (backoff, n, ok) = retry_transmission(h.link_drop, rc, rng);
                    run.retries = n;
                    let mut lost_here = 0;
                    if ok {
                        run.occupied_s = t0 + backoff;
                    } else {
                        // Split pipeline against the server: no partner to
                        // fall back on, the session dies at the drop point.
                        run.occupied_s = ud * t0 + backoff;
                        run.delivers = false;
                        run.lost = vec![s];
                        run.failed = 1;
                        lost_here = 1;
                    }
                    let ev = FaultEvent::new(
                        FaultKind::LinkDrop,
                        s as i64,
                        -1,
                        ud * t0,
                        n,
                        lost_here,
                    );
                    run.events.push(ev);
                }
            }
        }

        // Stage 3: uplink loss during the model upload.
        if has_uplink && run.delivers && h.uplink_loss > 0.0 && rng.f64() < h.uplink_loss {
            let (backoff, n, ok) = retry_transmission(h.uplink_loss, rc, rng);
            run.retries += n;
            run.occupied_s += backoff;
            let (ea, eb) = spec.unit.ids();
            if ok {
                run.events.push(FaultEvent::new(FaultKind::UplinkLoss, ea, eb, t0, n, 0));
            } else {
                let survivors: Vec<usize> =
                    spec.unit.members().into_iter().filter(|m| !run.lost.contains(m)).collect();
                run.delivers = false;
                run.failed += survivors.len();
                let ev = FaultEvent::new(FaultKind::UplinkLoss, ea, eb, t0, n, survivors.len());
                run.events.push(ev);
                run.lost.extend(survivors);
            }
        }
    }

    /// Fold per-unit runs into the round total, applying the deadline.
    /// Hazard outcomes are deadline-independent, so `total = min(deadline,
    /// raw_total)` and the deadline-lost set can only grow as the deadline
    /// tightens — the monotonicity contract.
    fn fold_round(
        &self,
        runs: &[UnitRun],
        shared_delivery_s: f64,
        fault_free_total_s: f64,
    ) -> FaultOutcome {
        let deadline = self.cfg.deadline_s;
        let mut counters = FaultCounters::default();
        let mut lost: Vec<usize> = Vec::new();
        let mut events: Vec<FaultEvent> = Vec::new();
        let mut any = false;
        for run in runs {
            any |= !run.events.is_empty();
            counters.n_failed += run.failed;
            counters.n_retries += run.retries;
            counters.n_lost_updates += run.lost.len();
            counters.recovery_s += run.recovery_s;
            lost.extend_from_slice(&run.lost);
            events.extend(run.events.iter().cloned());
        }

        let sequential = self.algo == Algorithm::VanillaSL;
        let mut n_deadline_lost = 0usize;
        let raw_total = if sequential {
            // Sessions run back to back; a session delivers only if the
            // running sum reaches the server before the deadline.
            let mut sum = 0.0;
            for run in runs {
                sum += run.occupied_s;
                if deadline > 0.0 && run.delivers && sum > deadline {
                    for m in run.unit.members() {
                        if !run.lost.contains(&m) {
                            lost.push(m);
                            n_deadline_lost += 1;
                        }
                    }
                }
            }
            sum
        } else {
            // Parallel units: the round holds open for the slowest delivery
            // (or death), and a unit delivers only if it arrives in time.
            let mut t_all = 0.0f64;
            for run in runs {
                let arrive =
                    if run.delivers { run.occupied_s + shared_delivery_s } else { run.occupied_s };
                t_all = t_all.max(arrive);
                if deadline > 0.0 && run.delivers && run.occupied_s + shared_delivery_s > deadline
                {
                    for m in run.unit.members() {
                        if !run.lost.contains(&m) {
                            lost.push(m);
                            n_deadline_lost += 1;
                        }
                    }
                }
            }
            t_all
        };

        let deadline_binds = deadline > 0.0 && (n_deadline_lost > 0 || deadline < raw_total);
        if deadline_binds {
            counters.n_lost_updates += n_deadline_lost;
            let ev = FaultEvent::new(FaultKind::Deadline, -1, -1, deadline, 0, n_deadline_lost);
            events.push(ev);
        }
        let changed = any || deadline_binds;
        let total_s = if !changed {
            fault_free_total_s
        } else if deadline > 0.0 {
            raw_total.min(deadline)
        } else {
            raw_total
        };
        lost.sort_unstable();
        FaultOutcome { counters, total_s, changed, lost, events }
    }
}

/// Retry loop for one already-failed transmission: waits an exponentially
/// growing, jittered backoff before each attempt. Returns `(total backoff
/// seconds, retries spent, succeeded)`.
fn retry_transmission(
    hazard: f64,
    rc: &crate::config::RecoveryConfig,
    rng: &mut Rng,
) -> (f64, usize, bool) {
    let mut backoff = 0.0f64;
    for k in 0..rc.retry_max {
        backoff +=
            rc.backoff_base_s * 2.0f64.powi(k as i32) * (1.0 + rc.backoff_jitter * rng.f64());
        if rng.f64() >= hazard {
            return (backoff, k + 1, true);
        }
    }
    (backoff, rc.retry_max, false)
}

/// Draw `(crashed, crash fraction)` for one client. Skips the draws when the
/// hazard is disarmed so a crash-free config costs nothing.
fn crash_draw(hazard: f64, rng: &mut Rng) -> (bool, f64) {
    if hazard <= 0.0 {
        return (false, 0.0);
    }
    let c = rng.f64() < hazard;
    let u = rng.f64();
    (c, u)
}

/// Feed one round's fault outcome into the metrics registry. Cheap no-op
/// when telemetry is disabled or nothing fired.
pub fn note_outcome(counters: &FaultCounters, events: &[FaultEvent]) {
    if !registry::enabled() {
        return;
    }
    let injected = events.iter().filter(|e| e.kind != FaultKind::Deadline).count();
    if injected > 0 {
        registry::count(Counter::FaultsInjected, injected as u64);
    }
    if counters.n_retries > 0 {
        registry::count(Counter::FaultRetries, counters.n_retries as u64);
    }
    if counters.n_lost_updates > 0 {
        registry::count(Counter::FaultLostUpdates, counters.n_lost_updates as u64);
    }
    if counters.recovery_s > 0.0 {
        registry::observe(Histo::FaultRecoveryUs, (counters.recovery_s * 1e6) as u64);
    }
}

/// Per-unit fault plan for the async `Timeline`, decided once at unit start.
#[derive(Clone, Debug)]
pub struct PlannedUnit {
    /// Faulted duration to start the unit with.
    pub dur_s: f64,
    t0: f64,
    run: UnitRun,
}

/// Bookkeeping for faulted units in flight on the async `Timeline`: maps
/// Timeline unit ids to their fault plan so reprices preserve the decided
/// delta and merges know which payloads are doomed.
#[derive(Debug, Default)]
pub struct AsyncFaults {
    window: FaultCounters,
    window_events: Vec<FaultEvent>,
    extra: HashMap<u64, f64>,
    lost: HashMap<u64, Vec<usize>>,
}

impl AsyncFaults {
    pub fn new() -> AsyncFaults {
        AsyncFaults::default()
    }

    /// Record a started unit's plan under its Timeline id.
    pub fn register(&mut self, id: u64, p: &PlannedUnit) {
        self.window.n_failed += p.run.failed;
        self.window.n_retries += p.run.retries;
        self.window.n_lost_updates += p.run.lost.len();
        self.window.recovery_s += p.run.recovery_s;
        self.window_events.extend(p.run.events.iter().cloned());
        let extra = p.dur_s - p.t0;
        if extra != 0.0 {
            self.extra.insert(id, extra);
        }
        if !p.run.lost.is_empty() {
            self.lost.insert(id, p.run.lost.clone());
        }
    }

    /// Faulted duration for a reprice of unit `id` whose fault-free price is
    /// now `t0`: the additive delta decided at start is preserved, and a
    /// fault-free unit reprices to exactly `t0`.
    pub fn reprice(&self, id: u64, t0: f64) -> f64 {
        match self.extra.get(&id) {
            Some(e) => (t0 + e).max(0.0),
            None => t0,
        }
    }

    /// Universe ids whose updates unit `id` lost to a fault.
    pub fn lost_of(&self, id: u64) -> &[usize] {
        self.lost.get(&id).map_or(&[], |v| v.as_slice())
    }

    /// Drop bookkeeping for a merged or cancelled unit.
    pub fn forget(&mut self, id: u64) {
        self.extra.remove(&id);
        self.lost.remove(&id);
    }

    /// Drain the counters/events accumulated since the last merge window.
    pub fn take_window(&mut self) -> (FaultCounters, Vec<FaultEvent>) {
        (std::mem::take(&mut self.window), std::mem::take(&mut self.window_events))
    }
}

/// Build FedPairing's round units in the engine's evaluation order — pairs
/// (call order) then solos — priced with the engine's recorded
/// `unit_times()`. `cpairs`/`csolos` are round-compact ids into `view`;
/// `members` maps them back to universe ids. Pair members carry
/// survivor-solo fallback prices from the same
/// [`crate::sim::latency::full_local_time`] kernel the analytic engine
/// charges, so a recovery costs exactly what a solo participant would.
#[allow(clippy::too_many_arguments)]
pub fn fedpairing_unit_specs<C: ClientSet>(
    unit_times: &[f64],
    cpairs: &[(usize, usize)],
    csolos: &[usize],
    members: &[usize],
    view: &C,
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
) -> Vec<UnitSpec> {
    debug_assert_eq!(unit_times.len(), cpairs.len() + csolos.len());
    let mut specs = Vec::with_capacity(unit_times.len());
    for (k, &(ca, cb)) in cpairs.iter().enumerate() {
        let solo_a = full_local_time(view, ca, profile, sched, channel, comp, true).1;
        let solo_b = full_local_time(view, cb, profile, sched, channel, comp, true).1;
        specs.push(UnitSpec {
            unit: FaultUnit::Pair(members[ca], members[cb]),
            t0: unit_times[k],
            solo_a,
            solo_b,
        });
    }
    for (k, &cs) in csolos.iter().enumerate() {
        specs.push(UnitSpec {
            unit: FaultUnit::Solo(members[cs]),
            t0: unit_times[cpairs.len() + k],
            solo_a: 0.0,
            solo_b: 0.0,
        });
    }
    specs
}

/// Build a solo-algorithm round's units (one per client, fleet order) from
/// the engine's recorded `unit_times()`: vanilla-FL and SplitFed clients are
/// parallel [`FaultUnit::Solo`] units, vanilla-SL clients sequential
/// [`FaultUnit::Session`]s.
pub fn solo_unit_specs(algo: Algorithm, unit_times: &[f64], members: &[usize]) -> Vec<UnitSpec> {
    debug_assert_eq!(unit_times.len(), members.len());
    members
        .iter()
        .zip(unit_times)
        .map(|(&m, &t0)| UnitSpec {
            unit: if algo == Algorithm::VanillaSL {
                FaultUnit::Session(m)
            } else {
                FaultUnit::Solo(m)
            },
            t0,
            solo_a: 0.0,
            solo_b: 0.0,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{FaultConfig, RecoveryConfig};

    fn hazards(crash: f64, link: f64, uplink: f64, deadline: f64) -> FaultConfig {
        FaultConfig {
            crash_per_round: crash,
            link_drop: link,
            uplink_loss: uplink,
            deadline_s: deadline,
            recovery: RecoveryConfig::default(),
        }
    }

    fn pair_units() -> Vec<UnitSpec> {
        vec![
            UnitSpec { unit: FaultUnit::Pair(0, 1), t0: 10.0, solo_a: 14.0, solo_b: 18.0 },
            UnitSpec { unit: FaultUnit::Pair(2, 3), t0: 12.0, solo_a: 13.0, solo_b: 15.0 },
            UnitSpec { unit: FaultUnit::Solo(4), t0: 9.0, solo_a: 0.0, solo_b: 0.0 },
        ]
    }

    #[test]
    fn zero_hazards_change_nothing() {
        let cfg = hazards(0.0, 0.0, 0.0, 0.0);
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 7);
        let out = model.inject_round(3, &pair_units(), 0.0, 12.0);
        assert!(!out.changed);
        assert_eq!(out.total_s.to_bits(), 12.0f64.to_bits());
        assert_eq!(out.counters, FaultCounters::default());
        assert!(out.lost.is_empty());
        assert!(out.events.is_empty());
    }

    #[test]
    fn fixed_seed_is_reproducible() {
        let cfg = hazards(0.3, 0.3, 0.3, 0.0);
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 42);
        let a = model.inject_round(5, &pair_units(), 0.0, 12.0);
        let b = model.inject_round(5, &pair_units(), 0.0, 12.0);
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
        assert_eq!(a.counters, b.counters);
        assert_eq!(a.lost, b.lost);
        assert_eq!(a.events.len(), b.events.len());
        // A different round draws a different trace for at least one seed in
        // this config (hazards are high enough that rounds rarely match).
        let c = model.inject_round(6, &pair_units(), 0.0, 12.0);
        let _ = c; // determinism, not divergence, is the contract under test
    }

    #[test]
    fn certain_crash_loses_every_member() {
        let cfg = hazards(1.0, 0.0, 0.0, 0.0);
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 1);
        let out = model.inject_round(0, &pair_units(), 0.0, 12.0);
        assert!(out.changed);
        assert_eq!(out.counters.n_failed, 5);
        assert_eq!(out.counters.n_lost_updates, 5);
        assert_eq!(out.lost, vec![0, 1, 2, 3, 4]);
        // Everyone died mid-compute, so the round can only get shorter.
        assert!(out.total_s <= 12.0);
    }

    #[test]
    fn exhausted_pair_link_still_delivers_solo() {
        let cfg = FaultConfig {
            crash_per_round: 0.0,
            link_drop: 1.0,
            uplink_loss: 0.0,
            deadline_s: 0.0,
            recovery: RecoveryConfig { retry_max: 3, backoff_base_s: 0.5, backoff_jitter: 0.0 },
        };
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 9);
        let out = model.inject_round(0, &pair_units(), 0.0, 12.0);
        assert!(out.changed);
        // Both pairs drop and exhaust 3 retries each; the FedPairing solo
        // has no mid-round link so it is untouched.
        assert_eq!(out.counters.n_retries, 6);
        assert_eq!(out.counters.n_failed, 0);
        assert!(out.lost.is_empty());
        assert!(out.counters.recovery_s > 0.0);
        assert!(out.total_s > 12.0);
    }

    #[test]
    fn uplink_exhaustion_with_no_retries_loses_units() {
        let cfg = FaultConfig {
            crash_per_round: 0.0,
            link_drop: 0.0,
            uplink_loss: 1.0,
            deadline_s: 0.0,
            recovery: RecoveryConfig { retry_max: 0, backoff_base_s: 0.5, backoff_jitter: 0.0 },
        };
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 9);
        let out = model.inject_round(0, &pair_units(), 0.0, 12.0);
        assert!(out.changed);
        assert_eq!(out.counters.n_retries, 0);
        assert_eq!(out.counters.n_failed, 5);
        assert_eq!(out.lost, vec![0, 1, 2, 3, 4]);
        // Zero backoff: occupation times are unchanged, so the total is the
        // fault-free makespan even though every update was lost.
        assert_eq!(out.total_s.to_bits(), 12.0f64.to_bits());
    }

    #[test]
    fn deadline_truncates_and_loses_late_units() {
        let cfg = hazards(0.0, 0.0, 0.0, 9.5);
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 3);
        let out = model.inject_round(0, &pair_units(), 0.0, 12.0);
        assert!(out.changed);
        assert_eq!(out.total_s, 9.5);
        assert_eq!(out.counters.n_lost_updates, 4);
        assert_eq!(out.counters.n_failed, 0);
        assert_eq!(out.lost, vec![0, 1, 2, 3]);
        assert_eq!(out.events.len(), 1);
        assert_eq!(out.events[0].kind, FaultKind::Deadline);

        // A looser deadline loses fewer updates and never shortens further.
        let cfg2 = hazards(0.0, 0.0, 0.0, 11.0);
        let out2 = FaultModel::new(&cfg2, Algorithm::FedPairing, 3)
            .inject_round(0, &pair_units(), 0.0, 12.0);
        assert_eq!(out2.total_s, 11.0);
        assert_eq!(out2.counters.n_lost_updates, 2);
        assert!(out2.total_s >= out.total_s);

        // A non-binding deadline leaves the fault-free trace untouched.
        let cfg3 = hazards(0.0, 0.0, 0.0, 13.0);
        let out3 = FaultModel::new(&cfg3, Algorithm::FedPairing, 3)
            .inject_round(0, &pair_units(), 0.0, 12.0);
        assert!(!out3.changed);
        assert_eq!(out3.total_s.to_bits(), 12.0f64.to_bits());
        assert_eq!(out3.counters.n_lost_updates, 0);
    }

    #[test]
    fn sequential_deadline_cuts_the_session_tail() {
        let cfg = hazards(0.0, 0.0, 0.0, 10.0);
        let model = FaultModel::new(&cfg, Algorithm::VanillaSL, 3);
        let units = vec![
            UnitSpec { unit: FaultUnit::Session(0), t0: 4.0, solo_a: 0.0, solo_b: 0.0 },
            UnitSpec { unit: FaultUnit::Session(1), t0: 5.0, solo_a: 0.0, solo_b: 0.0 },
            UnitSpec { unit: FaultUnit::Session(2), t0: 6.0, solo_a: 0.0, solo_b: 0.0 },
        ];
        let out = model.inject_round(0, &units, 0.0, 15.0);
        assert!(out.changed);
        assert_eq!(out.total_s, 10.0);
        assert_eq!(out.lost, vec![2]);
        assert_eq!(out.counters.n_lost_updates, 1);
    }

    #[test]
    fn async_reprice_preserves_the_fault_delta() {
        let cfg = FaultConfig {
            crash_per_round: 0.0,
            link_drop: 1.0,
            uplink_loss: 0.0,
            deadline_s: 0.0,
            recovery: RecoveryConfig { retry_max: 2, backoff_base_s: 0.5, backoff_jitter: 0.0 },
        };
        let model = FaultModel::new(&cfg, Algorithm::FedPairing, 11);
        let spec = UnitSpec { unit: FaultUnit::Pair(3, 8), t0: 10.0, solo_a: 12.0, solo_b: 16.0 };
        let plan = model.plan_unit(2, &spec);
        let delta = plan.dur_s - 10.0;
        assert!(delta > 0.0);

        let mut af = AsyncFaults::new();
        af.register(7, &plan);
        let repriced = af.reprice(7, 20.0);
        assert!((repriced - (20.0 + delta)).abs() < 1e-12);
        // Unknown ids reprice to exactly the fault-free duration.
        assert_eq!(af.reprice(99, 20.0).to_bits(), 20.0f64.to_bits());
        let (w, ev) = af.take_window();
        assert_eq!(w.n_retries, 2);
        assert_eq!(ev.len(), 1);
        af.forget(7);
        assert_eq!(af.reprice(7, 20.0).to_bits(), 20.0f64.to_bits());
        assert!(af.lost_of(7).is_empty());
    }
}
