//! Host-side parameter math: the L3 pieces of the training algebra that
//! rightly belong to the coordinator (everything batch-shaped runs inside the
//! AOT artifacts instead).
//!
//! Covers the paper's update equations:
//! * eq. (1)/(2) — paired split update `ω ← ω − η(a_own·g_front + a_peer·g_back)`,
//! * eq. (7) — the 2× step on overlapping layers,
//! * FedAvg aggregation (Sec. II-A.3), in two flavors: the classic weighted
//!   average (for vanilla FL, whose local grads are unweighted) and delta-sum
//!   aggregation for FedPairing (whose local grads arrive pre-scaled by `a_i`;
//!   the paper's plain `Σω^i` would multiply the base model by N — see
//!   DESIGN.md §2 on this paper inconsistency).
//!
//! A parameter set is a flat tensor list `[w0, b0, w1, b1, …]` matching the
//! AOT manifest layout; layer `k` owns tensors `2k` and `2k+1`.

/// Flat tensor list (manifest order).
pub type Params = Vec<Vec<f32>>;

/// Tensors per layer in the flat layout.
pub const TENSORS_PER_LAYER: usize = 2;

/// Zero-filled clone of a shape.
pub fn zeros_like(p: &Params) -> Params {
    p.iter().map(|t| vec![0.0; t.len()]).collect()
}

/// `dst += s · src`, elementwise across the whole tensor list.
pub fn add_scaled(dst: &mut Params, src: &Params, s: f32) {
    assert_eq!(dst.len(), src.len(), "tensor-count mismatch");
    for (d, a) in dst.iter_mut().zip(src) {
        assert_eq!(d.len(), a.len(), "tensor-shape mismatch");
        for (x, y) in d.iter_mut().zip(a) {
            *x += s * y;
        }
    }
}

/// Global L2 norm across all tensors.
pub fn l2_norm(p: &Params) -> f64 {
    p.iter()
        .flat_map(|t| t.iter())
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt()
}

/// Plain SGD: `p ← p − lr · g`.
pub fn sgd_apply(params: &mut Params, grads: &Params, lr: f32) {
    add_scaled(params, grads, -lr);
}

/// The paired split update for one client's model (eqs. 1–2 + eq. 7).
///
/// * `g_front` — grads from the client's *own-data* flow, covering layers
///   `[0, l_own)` (tensor list of length `2·l_own`).
/// * `g_back` — grads from the *partner's-data* flow through this model's
///   back part, covering layers `[l_partner, w)` (length `2·(w−l_partner)`).
/// * `a_own`/`a_peer` — FedAvg weights of the data owners of each flow.
/// * `overlap_boost` — apply eq. (7)'s 2× step where both flows hit a layer
///   (`l_partner ≤ k < l_own`, possible only when `l_own > l_partner`).
///
/// Layers in the *gap* `[l_own, l_partner)` (smaller-`L` client) receive no
/// gradient this step — exactly the propagation-flow geometry of paper Fig. 1.
#[allow(clippy::too_many_arguments)]
pub fn apply_split_update(
    params: &mut Params,
    w: usize,
    l_own: usize,
    l_partner: usize,
    g_front: &[Vec<f32>],
    g_back: &[Vec<f32>],
    a_own: f32,
    a_peer: f32,
    lr: f32,
    overlap_boost: bool,
) {
    assert_eq!(params.len(), TENSORS_PER_LAYER * w, "params/layer mismatch");
    assert!(l_own >= 1 && l_own <= w);
    assert!(l_partner >= 1 && l_partner <= w);
    assert_eq!(g_front.len(), TENSORS_PER_LAYER * l_own, "front grads");
    assert_eq!(
        g_back.len(),
        TENSORS_PER_LAYER * (w - l_partner),
        "back grads"
    );
    for k in 0..w {
        let in_front = k < l_own;
        let in_back = k >= l_partner;
        let boost = if overlap_boost && in_front && in_back {
            2.0
        } else {
            1.0
        };
        for t in 0..TENSORS_PER_LAYER {
            let pi = TENSORS_PER_LAYER * k + t;
            if in_front {
                let g = &g_front[pi];
                assert_eq!(g.len(), params[pi].len());
                for (p, &gv) in params[pi].iter_mut().zip(g) {
                    *p -= lr * boost * a_own * gv;
                }
            }
            if in_back {
                let g = &g_back[TENSORS_PER_LAYER * (k - l_partner) + t];
                assert_eq!(g.len(), params[pi].len());
                for (p, &gv) in params[pi].iter_mut().zip(g) {
                    *p -= lr * boost * a_peer * gv;
                }
            }
        }
    }
}

/// Classic weighted FedAvg: `ω_g = Σ a_i · ω^i` (vanilla FL; `Σ a_i = 1`).
pub fn fedavg_weighted(models: &[Params], weights: &[f64]) -> Params {
    assert_eq!(models.len(), weights.len());
    assert!(!models.is_empty());
    let wsum: f64 = weights.iter().sum();
    assert!((wsum - 1.0).abs() < 1e-6, "weights must sum to 1, got {wsum}");
    let mut out = zeros_like(&models[0]);
    for (m, &a) in models.iter().zip(weights) {
        add_scaled(&mut out, m, a as f32);
    }
    out
}

/// Delta-sum aggregation for pre-weighted local updates:
/// `ω_g ← ω_g + Σ_i (ω^i − ω_g)`.
///
/// Because FedPairing scales every local gradient by `a_i` before it is
/// applied (eqs. 1–2) and `Σ a_i = 1`, summing raw deltas yields exactly the
/// data-weighted average update — the consistent reading of the paper's
/// Sec. II-A.3 "directly perform averaging".
pub fn aggregate_deltas(global: &mut Params, locals: &[Params]) {
    for local in locals {
        assert_eq!(local.len(), global.len());
    }
    // Accumulate Σ(local − global) against a snapshot so the result is exact
    // regardless of accumulation order.
    let snapshot = global.clone();
    for local in locals {
        for (ti, t) in local.iter().enumerate() {
            for (vi, &v) in t.iter().enumerate() {
                global[ti][vi] += v - snapshot[ti][vi];
            }
        }
    }
}

/// Numerical-health check used by the coordinator each round.
pub fn all_finite(p: &Params) -> bool {
    p.iter().all(|t| t.iter().all(|x| x.is_finite()))
}

/// Aggregation payload guard: drop every model carrying a NaN/±inf tensor
/// (and its paired weight) in place, returning how many were rejected.
///
/// Callers renormalize the surviving weights exactly as they already do for
/// churned-out clients, so one poisoned update can never corrupt the merged
/// global model. When nothing is rejected the vectors are untouched —
/// healthy runs keep their bit-for-bit traces.
pub fn reject_nonfinite(models: &mut Vec<Params>, weights: &mut Vec<f64>) -> usize {
    assert_eq!(models.len(), weights.len());
    if models.iter().all(all_finite) {
        return 0;
    }
    let keep: Vec<bool> = models.iter().map(all_finite).collect();
    let mut it = keep.iter();
    models.retain(|_| *it.next().unwrap());
    let mut it = keep.iter();
    weights.retain(|_| *it.next().unwrap());
    keep.iter().filter(|&&k| !k).count()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params3(w: usize, fill: f32) -> Params {
        (0..TENSORS_PER_LAYER * w).map(|_| vec![fill; 4]).collect()
    }

    #[test]
    fn add_scaled_and_norm() {
        let mut a = params3(2, 1.0);
        let b = params3(2, 2.0);
        add_scaled(&mut a, &b, 0.5);
        assert!(a.iter().all(|t| t.iter().all(|&x| x == 2.0)));
        let n = l2_norm(&a);
        assert!((n - (16.0f64 * 4.0).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn sgd_moves_against_gradient() {
        let mut p = params3(1, 0.0);
        let g = params3(1, 1.0);
        sgd_apply(&mut p, &g, 0.1);
        assert!(p.iter().all(|t| t.iter().all(|&x| (x + 0.1).abs() < 1e-7)));
    }

    #[test]
    fn split_update_full_coverage_equal_split() {
        // w=4, l_own=2, l_partner=2: front covers 0..2, back covers 2..4 — no
        // overlap, no gap; everything moves by its own flow's grad.
        let w = 4;
        let mut p = params3(w, 0.0);
        let g_front: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect();
        let g_back: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect();
        apply_split_update(&mut p, w, 2, 2, &g_front, &g_back, 0.5, 0.5, 0.1, true);
        for t in &p {
            for &x in t {
                assert!((x + 0.1 * 0.5).abs() < 1e-7, "{x}");
            }
        }
    }

    #[test]
    fn split_update_overlap_double_steps() {
        // w=3, l_own=2, l_partner=1 (the larger-L client from paper Fig. 1):
        // layer 0: front only; layer 1: BOTH (overlap); layer 2: back only.
        let w = 3;
        let mut p = params3(w, 0.0);
        let g_front: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect(); // layers 0..2
        let g_back: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect(); // layers 1..3
        apply_split_update(&mut p, w, 2, 1, &g_front, &g_back, 0.5, 0.5, 0.1, true);
        let eta_a = 0.1 * 0.5;
        assert!((p[0][0] + eta_a).abs() < 1e-7, "layer0 {:?}", p[0][0]);
        // overlap layer: 2η(a_own·g + a_peer·g) = 2·(0.05+0.05) = 0.2
        assert!(
            (p[2][0] + 2.0 * 2.0 * eta_a).abs() < 1e-7,
            "layer1 {:?}",
            p[2][0]
        );
        assert!((p[4][0] + eta_a).abs() < 1e-7, "layer2 {:?}", p[4][0]);
    }

    #[test]
    fn split_update_no_boost_single_steps_overlap() {
        let w = 3;
        let mut p = params3(w, 0.0);
        let g_front: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect();
        let g_back: Vec<Vec<f32>> = (0..4).map(|_| vec![1.0; 4]).collect();
        apply_split_update(&mut p, w, 2, 1, &g_front, &g_back, 0.5, 0.5, 0.1, false);
        // overlap layer without boost: η(a_own + a_peer)·g = 0.1·1.0
        assert!((p[2][0] + 0.1).abs() < 1e-7);
    }

    #[test]
    fn split_update_gap_untouched() {
        // Smaller-L client: w=3, l_own=1, l_partner=2 → layer 1 is a gap.
        let w = 3;
        let mut p = params3(w, 7.0);
        let g_front: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0; 4]).collect(); // layer 0
        let g_back: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0; 4]).collect(); // layer 2
        apply_split_update(&mut p, w, 1, 2, &g_front, &g_back, 0.5, 0.5, 0.1, true);
        assert!(p[2].iter().all(|&x| x == 7.0), "gap layer must not move");
        assert!(p[0].iter().all(|&x| x < 7.0));
        assert!(p[4].iter().all(|&x| x < 7.0));
    }

    #[test]
    fn fedavg_weighted_average() {
        let a = params3(1, 0.0);
        let b = params3(1, 10.0);
        let avg = fedavg_weighted(&[a, b], &[0.25, 0.75]);
        assert!(avg.iter().all(|t| t.iter().all(|&x| (x - 7.5).abs() < 1e-6)));
    }

    #[test]
    #[should_panic(expected = "sum to 1")]
    fn fedavg_rejects_unnormalized_weights() {
        let a = params3(1, 0.0);
        fedavg_weighted(&[a.clone(), a], &[0.5, 0.9]);
    }

    #[test]
    fn aggregate_deltas_sums_updates() {
        let global = params3(1, 1.0);
        // Two locals, each moved by ±δ from global.
        let mut l1 = global.clone();
        add_scaled(&mut l1, &params3(1, 1.0), 0.3); // +0.3
        let mut l2 = global.clone();
        add_scaled(&mut l2, &params3(1, 1.0), -0.1); // −0.1
        let mut g = global.clone();
        aggregate_deltas(&mut g, &[l1, l2]);
        // 1.0 + 0.3 − 0.1 = 1.2
        assert!(g.iter().all(|t| t.iter().all(|&x| (x - 1.2).abs() < 1e-6)));
    }

    #[test]
    fn aggregate_deltas_identity_when_no_change() {
        let global = params3(2, 3.0);
        let mut g = global.clone();
        aggregate_deltas(&mut g, &[global.clone(), global.clone()]);
        assert_eq!(g, global);
    }

    #[test]
    fn all_finite_detects_nan() {
        let mut p = params3(1, 0.0);
        assert!(all_finite(&p));
        p[0][2] = f32::NAN;
        assert!(!all_finite(&p));
    }

    #[test]
    fn reject_nonfinite_drops_poisoned_updates_only() {
        // One NaN client among three must not corrupt the merge: the guard
        // drops it, the caller renormalizes, and FedAvg stays finite.
        let mut models = vec![params3(1, 1.0), params3(1, 4.0), params3(1, 7.0)];
        models[1][0][2] = f32::NAN;
        let mut weights = vec![0.25, 0.25, 0.5];
        let dropped = reject_nonfinite(&mut models, &mut weights);
        assert_eq!(dropped, 1);
        assert_eq!(models.len(), 2);
        assert_eq!(weights, vec![0.25, 0.5]);
        let wsum: f64 = weights.iter().sum();
        let renorm: Vec<f64> = weights.iter().map(|w| w / wsum).collect();
        let avg = fedavg_weighted(&models, &renorm);
        assert!(all_finite(&avg));
        // 1·(1/3) + 7·(2/3) = 5
        assert!(avg.iter().all(|t| t.iter().all(|&x| (x - 5.0).abs() < 1e-6)));
    }

    #[test]
    fn reject_nonfinite_is_a_no_op_on_healthy_payloads() {
        let mut models = vec![params3(1, 1.0), params3(1, 2.0)];
        let mut weights = vec![0.5, 0.5];
        let before = models.clone();
        assert_eq!(reject_nonfinite(&mut models, &mut weights), 0);
        assert_eq!(models, before);
        assert_eq!(weights, vec![0.5, 0.5]);
    }

    #[test]
    #[should_panic]
    fn split_update_shape_mismatch_panics() {
        let mut p = params3(3, 0.0);
        let g_front: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0; 4]).collect();
        let g_back: Vec<Vec<f32>> = (0..2).map(|_| vec![1.0; 4]).collect();
        // l_own=2 needs 4 front tensors, only 2 given.
        apply_split_update(&mut p, 3, 2, 2, &g_front, &g_back, 0.5, 0.5, 0.1, true);
    }
}
