//! Host-side model metadata: parses the AOT `manifest.json` so the Rust
//! coordinator never hardcodes shapes, entry names or parameter layouts.
//!
//! The manifest is produced by `python/compile/aot.py` alongside the HLO
//! artifacts; it describes the ResNet-MLP architecture (depth `W`, widths),
//! the per-layer parameter shapes (flat `[w0, b0, w1, b1, …]` layout), the
//! train/eval batch sizes the artifacts were lowered for, and every entry
//! point's input/output signature.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub input_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    /// Depth `W` (split points are `1..W-1`).
    pub layers: usize,
    pub n_params: usize,
    /// Per-layer `(w_shape, b_shape)`.
    pub param_shapes: Vec<(Vec<usize>, Vec<usize>)>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub entries: BTreeMap<String, EntrySpec>,
}

/// Manifest parse failure.
#[derive(Debug)]
pub struct MetaError(pub String);

impl fmt::Display for MetaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}
impl std::error::Error for MetaError {}

macro_rules! field {
    ($obj:expr, $key:literal, $conv:ident) => {
        $obj.get($key)
            .and_then(|v| v.$conv())
            .ok_or_else(|| MetaError(format!("missing/invalid field {:?}", $key)))?
    };
}

impl ModelMeta {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<ModelMeta, Box<dyn std::error::Error>> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| MetaError(format!("cannot read {path}: {e}")))?;
        let j = Json::parse(&text)?;
        Ok(Self::from_json(&j)?)
    }

    pub fn from_json(j: &Json) -> Result<ModelMeta, MetaError> {
        let model = j
            .get("model")
            .ok_or_else(|| MetaError("missing model section".into()))?;
        let param_shapes_j = model
            .get("param_shapes")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| MetaError("missing param_shapes".into()))?;
        let mut param_shapes = Vec::with_capacity(param_shapes_j.len());
        for ps in param_shapes_j {
            let w = ps
                .get("w")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| MetaError("param_shapes entry missing w".into()))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| MetaError("bad dim".into())))
                .collect::<Result<Vec<_>, _>>()?;
            let b = ps
                .get("b")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| MetaError("param_shapes entry missing b".into()))?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| MetaError("bad dim".into())))
                .collect::<Result<Vec<_>, _>>()?;
            param_shapes.push((w, b));
        }
        let entries_j = j
            .get("entries")
            .and_then(|v| v.as_obj())
            .ok_or_else(|| MetaError("missing entries".into()))?;
        let mut entries = BTreeMap::new();
        for (name, ent) in entries_j.iter() {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>, MetaError> {
                ent.get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| MetaError(format!("entry {name} missing {key}")))?
                    .iter()
                    .map(|s| {
                        let shape = s
                            .get("shape")
                            .and_then(|v| v.as_arr())
                            .ok_or_else(|| MetaError("spec missing shape".into()))?
                            .iter()
                            .map(|x| x.as_usize().ok_or_else(|| MetaError("bad dim".into())))
                            .collect::<Result<Vec<_>, _>>()?;
                        let dtype = s
                            .get("dtype")
                            .and_then(|v| v.as_str())
                            .ok_or_else(|| MetaError("spec missing dtype".into()))?
                            .to_string();
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: ent
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| MetaError(format!("entry {name} missing file")))?
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        let meta = ModelMeta {
            input_dim: field!(model, "input_dim", as_usize),
            hidden: field!(model, "hidden", as_usize),
            classes: field!(model, "classes", as_usize),
            layers: field!(model, "layers", as_usize),
            n_params: field!(model, "n_params", as_usize),
            param_shapes,
            train_batch: field!(j, "train_batch", as_usize),
            eval_batch: field!(j, "eval_batch", as_usize),
            entries,
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<(), MetaError> {
        if self.param_shapes.len() != self.layers {
            return Err(MetaError(format!(
                "param_shapes has {} layers, expected {}",
                self.param_shapes.len(),
                self.layers
            )));
        }
        let computed: usize = self
            .param_shapes
            .iter()
            .map(|(w, b)| w.iter().product::<usize>() + b.iter().product::<usize>())
            .sum();
        if computed != self.n_params {
            return Err(MetaError(format!(
                "n_params {} != computed {}",
                self.n_params, computed
            )));
        }
        // Every entry the protocol needs must exist.
        for base in ["init_params", "full_step", "eval_batch", "loss_grad"] {
            if !self.entries.contains_key(base) {
                return Err(MetaError(format!("missing entry {base}")));
            }
        }
        for k in 1..self.layers {
            for prefix in ["front_fwd", "back_fwd", "back_bwd", "front_bwd"] {
                let name = format!("{prefix}_{k}");
                if !self.entries.contains_key(&name) {
                    return Err(MetaError(format!("missing entry {name}")));
                }
            }
        }
        Ok(())
    }

    /// Flat-layout tensor count for the whole model (`2·W`).
    pub fn n_tensors(&self) -> usize {
        2 * self.layers
    }

    /// Element count of flat tensor `idx`.
    pub fn tensor_elems(&self, idx: usize) -> usize {
        let (w, b) = &self.param_shapes[idx / 2];
        if idx % 2 == 0 {
            w.iter().product()
        } else {
            b.iter().product()
        }
    }

    /// Flat tensor range `[lo, hi)` for layers `[layer_lo, layer_hi)`.
    pub fn tensor_range(&self, layer_lo: usize, layer_hi: usize) -> std::ops::Range<usize> {
        2 * layer_lo..2 * layer_hi
    }

    /// Cost profile of this architecture for the latency simulator.
    pub fn profile(&self) -> crate::sim::profile::ModelProfile {
        crate::sim::profile::ModelProfile::mlp(
            self.input_dim,
            self.hidden,
            self.classes,
            self.layers,
        )
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec, MetaError> {
        self.entries
            .get(name)
            .ok_or_else(|| MetaError(format!("unknown entry {name:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal synthetic manifest for parser tests (W=2).
    fn manifest_json() -> String {
        let mut entries = String::new();
        let mut add = |name: &str| {
            if !entries.is_empty() {
                entries.push(',');
            }
            entries.push_str(&format!(
                r#""{name}": {{"file": "{name}.hlo.txt",
                   "inputs": [{{"shape": [4, 3], "dtype": "float32"}}],
                   "outputs": [{{"shape": [4, 2], "dtype": "float32"}}]}}"#
            ));
        };
        for n in [
            "init_params",
            "full_step",
            "eval_batch",
            "loss_grad",
            "front_fwd_1",
            "back_fwd_1",
            "back_bwd_1",
            "front_bwd_1",
        ] {
            add(n);
        }
        format!(
            r#"{{
            "format": "hlo-text-v1",
            "model": {{
                "family": "resnet-mlp", "input_dim": 3, "hidden": 4,
                "classes": 2, "layers": 2, "n_params": 26,
                "param_shapes": [{{"w": [3, 4], "b": [4]}}, {{"w": [4, 2], "b": [2]}}]
            }},
            "train_batch": 4, "eval_batch": 8,
            "entries": {{{entries}}}
        }}"#
        )
    }

    #[test]
    fn parses_synthetic_manifest() {
        let j = Json::parse(&manifest_json()).unwrap();
        let m = ModelMeta::from_json(&j).unwrap();
        assert_eq!(m.layers, 2);
        assert_eq!(m.input_dim, 3);
        assert_eq!(m.n_params, 26);
        assert_eq!(m.train_batch, 4);
        assert_eq!(m.n_tensors(), 4);
        assert_eq!(m.tensor_elems(0), 12);
        assert_eq!(m.tensor_elems(1), 4);
        assert_eq!(m.tensor_elems(2), 8);
        assert_eq!(m.tensor_elems(3), 2);
        assert_eq!(m.tensor_range(0, 1), 0..2);
        assert_eq!(m.tensor_range(1, 2), 2..4);
        let e = m.entry("front_fwd_1").unwrap();
        assert_eq!(e.file, "front_fwd_1.hlo.txt");
        assert_eq!(e.inputs[0].shape, vec![4, 3]);
        assert_eq!(e.inputs[0].elems(), 12);
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let bad = manifest_json().replace("\"n_params\": 26", "\"n_params\": 27");
        let j = Json::parse(&bad).unwrap();
        assert!(ModelMeta::from_json(&j).is_err());
    }

    #[test]
    fn rejects_missing_entry() {
        let bad = manifest_json().replace("front_bwd_1", "front_bwd_9");
        let j = Json::parse(&bad).unwrap();
        assert!(ModelMeta::from_json(&j).is_err());
    }

    #[test]
    fn profile_matches_architecture() {
        let j = Json::parse(&manifest_json()).unwrap();
        let m = ModelMeta::from_json(&j).unwrap();
        let p = m.profile();
        assert_eq!(p.w(), 2);
        assert_eq!(p.params(0, 2), 26);
    }

    #[test]
    fn unknown_entry_lookup_errors() {
        let j = Json::parse(&manifest_json()).unwrap();
        let m = ModelMeta::from_json(&j).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-ish: when `make artifacts` has run, the real manifest
        // must parse and describe a consistent W-layer model.
        if let Ok(m) = ModelMeta::load("artifacts") {
            assert!(m.layers >= 2);
            assert_eq!(m.param_shapes.len(), m.layers);
            assert_eq!(m.entries.len(), 4 + 4 * (m.layers - 1));
        }
    }
}
