//! The PJRT execution engine: loads the AOT HLO-text artifacts and runs them
//! on the XLA CPU client — the only place the crate touches `xla`.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → execute. Artifacts are
//! compiled lazily on first use and cached for the lifetime of the engine
//! (one compile per entry per process; the training loop then only executes).
//!
//! **Buffer discipline.** Inputs travel host→device via
//! `buffer_from_host_buffer` and execution uses `execute_b` (caller-owned
//! buffers). The crate's literal-based `execute` leaks its transient input
//! device buffers (`BufferFromHostLiteral(..).release()` with no owner —
//! ≈5 MB/step measured), so it is deliberately not used; `execute_b` inputs
//! stay owned by [`DeviceTensors`]/[`PjRtBuffer`] RAII handles and are freed
//! on drop. This also lets the split trainer upload a parameter slice once
//! and reuse it across the forward and backward calls of a batch (§Perf).
//!
//! The typed wrappers ([`Engine::front_fwd`], [`Engine::back_bwd`], …) mirror
//! the split-learning protocol steps and validate shapes against the manifest
//! before every call, so a stale `artifacts/` directory fails loudly rather
//! than numerically.

use crate::model::ModelMeta;
use crate::nn::Params;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// A set of device-resident tensors (e.g. one model slice), freed on drop.
pub struct DeviceTensors {
    bufs: Vec<xla::PjRtBuffer>,
    /// First layer this slice covers (for shape validation).
    pub layer_lo: usize,
}

impl DeviceTensors {
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// Lazily-compiled artifact engine.
pub struct Engine {
    dir: String,
    meta: ModelMeta,
    client: xla::PjRtClient,
    exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    /// Execution counter per entry (perf diagnostics).
    exec_counts: BTreeMap<String, u64>,
}

impl Engine {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn load(dir: &str) -> Result<Engine> {
        let meta = ModelMeta::load(dir)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| format!("loading manifest from {dir}"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            dir: dir.to_string(),
            meta,
            client,
            exes: BTreeMap::new(),
            exec_counts: BTreeMap::new(),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Total artifact executions so far (all entries).
    pub fn total_execs(&self) -> u64 {
        self.exec_counts.values().sum()
    }

    /// Per-entry execution counts.
    pub fn exec_counts(&self) -> &BTreeMap<String, u64> {
        &self.exec_counts
    }

    /// Compile (or fetch cached) an entry's executable.
    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let entry = self.meta.entry(name).map_err(|e| anyhow::anyhow!("{e}"))?;
            let path = format!("{}/{}", self.dir, entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// Pre-compile every artifact (useful before timed runs).
    pub fn warmup(&mut self) -> Result<()> {
        let names: Vec<String> = self.meta.entries.keys().cloned().collect();
        for n in names {
            self.exe(&n)?;
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Host→device upload helpers
    // ------------------------------------------------------------------

    /// Upload a flat f32 tensor.
    pub fn upload_f32(&self, shape: &[usize], data: &[f32]) -> Result<xla::PjRtBuffer> {
        let elems: usize = shape.iter().product();
        if elems != data.len() {
            bail!("upload shape {shape:?} wants {elems} elems, got {}", data.len());
        }
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .context("uploading f32 buffer")
    }

    /// Upload a scalar u32 (artifact RNG seeds).
    pub fn upload_u32(&self, v: u32) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .context("uploading u32 scalar")
    }

    /// Upload a parameter slice starting at `layer_lo`, validated against the
    /// manifest layout. The returned [`DeviceTensors`] can be reused across
    /// every artifact call of a batch (fwd + bwd), halving param uploads.
    pub fn upload_params(&self, params: &[Vec<f32>], layer_lo: usize) -> Result<DeviceTensors> {
        let mut bufs = Vec::with_capacity(params.len());
        for (off, t) in params.iter().enumerate() {
            let idx = 2 * layer_lo + off;
            let (w, b) = &self.meta.param_shapes[idx / 2];
            let shape: &[usize] = if idx % 2 == 0 { w } else { b };
            bufs.push(self.upload_f32(shape, t)?);
        }
        Ok(DeviceTensors {
            bufs,
            layer_lo,
        })
    }

    // ------------------------------------------------------------------
    // Execution
    // ------------------------------------------------------------------

    /// Raw buffer call: validate arity, execute, unpack the output tuple into
    /// flat f32 vectors.
    pub fn run(&mut self, name: &str, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<Vec<f32>>> {
        let entry = self.meta.entry(name).map_err(|e| anyhow::anyhow!("{e}"))?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: got {} inputs, artifact expects {}",
                inputs.len(),
                entry.inputs.len()
            );
        }
        let n_outputs = entry.outputs.len();
        let exe = self.exe(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {name} result"))?;
        // aot.py lowers with return_tuple=True: always a tuple, even arity 1.
        let parts = lit.to_tuple().context("untupling result")?;
        if parts.len() != n_outputs {
            bail!(
                "{name}: artifact returned {} outputs, manifest says {}",
                parts.len(),
                n_outputs
            );
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.iter().enumerate() {
            let v: Vec<f32> = p
                .to_vec()
                .with_context(|| format!("{name}: output {i} to_vec"))?;
            out.push(v);
        }
        Ok(out)
    }

    /// Assemble `params (device) + extra host tensors`, then run.
    fn run_with_params(
        &mut self,
        name: &str,
        params: &DeviceTensors,
        extra: &[(&[usize], &[f32])],
    ) -> Result<Vec<Vec<f32>>> {
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(extra.len());
        for (shape, data) in extra {
            owned.push(self.upload_f32(shape, data)?);
        }
        let mut inputs: Vec<&xla::PjRtBuffer> = params.bufs.iter().collect();
        inputs.extend(owned.iter());
        self.run(name, &inputs)
    }

    // ------------------------------------------------------------------
    // Protocol-step wrappers (host-slice convenience forms)
    // ------------------------------------------------------------------

    /// Materialize the initial global model from a seed.
    pub fn init_params(&mut self, seed: u32) -> Result<Params> {
        let seed_buf = self.upload_u32(seed)?;
        self.run("init_params", &[&seed_buf])
    }

    /// Vanilla-FL local step: `(grads, loss)`.
    pub fn full_step(&mut self, params: &Params, x: &[f32], y1hot: &[f32]) -> Result<(Params, f32)> {
        let dev = self.upload_params(params, 0)?;
        self.full_step_b(&dev, x, y1hot)
    }

    /// `full_step` with pre-uploaded params.
    pub fn full_step_b(
        &mut self,
        params: &DeviceTensors,
        x: &[f32],
        y1hot: &[f32],
    ) -> Result<(Params, f32)> {
        let b = self.meta.train_batch;
        let (di, dc) = (self.meta.input_dim, self.meta.classes);
        let mut out = self.run_with_params(
            "full_step",
            params,
            &[(&[b, di], x), (&[b, dc], y1hot)],
        )?;
        let loss = out.pop().expect("full_step outputs")[0];
        Ok((out, loss))
    }

    /// Evaluation batch: `(loss_sum, n_correct, n_rows)`.
    pub fn eval_batch(&mut self, params: &Params, x: &[f32], y1hot: &[f32]) -> Result<(f32, f32, f32)> {
        let dev = self.upload_params(params, 0)?;
        self.eval_batch_b(&dev, x, y1hot)
    }

    /// `eval_batch` with pre-uploaded params (reused across test batches).
    pub fn eval_batch_b(
        &mut self,
        params: &DeviceTensors,
        x: &[f32],
        y1hot: &[f32],
    ) -> Result<(f32, f32, f32)> {
        let b = self.meta.eval_batch;
        let (di, dc) = (self.meta.input_dim, self.meta.classes);
        let out = self.run_with_params(
            "eval_batch",
            params,
            &[(&[b, di], x), (&[b, dc], y1hot)],
        )?;
        Ok((out[0][0], out[1][0], out[2][0]))
    }

    /// Front forward at split `k`: activation of shape `[train_batch, hidden]`.
    pub fn front_fwd(&mut self, k: usize, params_front: &[Vec<f32>], x: &[f32]) -> Result<Vec<f32>> {
        let dev = self.upload_params(params_front, 0)?;
        let xb = self.upload_f32(&[self.meta.train_batch, self.meta.input_dim], x)?;
        self.front_fwd_b(k, &dev, &xb)
    }

    /// `front_fwd` with device-resident params + input.
    pub fn front_fwd_b(
        &mut self,
        k: usize,
        params_front: &DeviceTensors,
        x: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(params_front.layer_lo == 0, "front params must start at layer 0");
        let mut inputs: Vec<&xla::PjRtBuffer> = params_front.bufs.iter().collect();
        inputs.push(x);
        let mut out = self.run(&format!("front_fwd_{k}"), &inputs)?;
        Ok(out.pop().expect("front_fwd output"))
    }

    /// Back forward at split `k`: logits.
    pub fn back_fwd(&mut self, k: usize, params_back: &[Vec<f32>], act: &[f32]) -> Result<Vec<f32>> {
        let dev = self.upload_params(params_back, k)?;
        let ab = self.upload_f32(&[self.meta.train_batch, self.meta.hidden], act)?;
        self.back_fwd_b(k, &dev, &ab)
    }

    /// `back_fwd` with device-resident params + activation.
    pub fn back_fwd_b(
        &mut self,
        k: usize,
        params_back: &DeviceTensors,
        act: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(params_back.layer_lo == k, "back params must start at layer k");
        let mut inputs: Vec<&xla::PjRtBuffer> = params_back.bufs.iter().collect();
        inputs.push(act);
        let mut out = self.run(&format!("back_fwd_{k}"), &inputs)?;
        Ok(out.pop().expect("back_fwd output"))
    }

    /// Loss + logit gradient (computed by the data owner; labels stay local).
    pub fn loss_grad(&mut self, logits: &[f32], y1hot: &[f32]) -> Result<(f32, Vec<f32>)> {
        let b = self.meta.train_batch;
        let dc = self.meta.classes;
        let lb = self.upload_f32(&[b, dc], logits)?;
        let yb = self.upload_f32(&[b, dc], y1hot)?;
        let mut out = self.run("loss_grad", &[&lb, &yb])?;
        let g = out.pop().expect("loss_grad grad");
        let loss = out.pop().expect("loss_grad loss")[0];
        Ok((loss, g))
    }

    /// Back backward at split `k`: `(grads for layers k..W, g_act)`.
    pub fn back_bwd(
        &mut self,
        k: usize,
        params_back: &[Vec<f32>],
        act: &[f32],
        g_logits: &[f32],
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        let dev = self.upload_params(params_back, k)?;
        let ab = self.upload_f32(&[self.meta.train_batch, self.meta.hidden], act)?;
        self.back_bwd_b(k, &dev, &ab, g_logits)
    }

    /// `back_bwd` with device-resident params + activation.
    pub fn back_bwd_b(
        &mut self,
        k: usize,
        params_back: &DeviceTensors,
        act: &xla::PjRtBuffer,
        g_logits: &[f32],
    ) -> Result<(Vec<Vec<f32>>, Vec<f32>)> {
        anyhow::ensure!(params_back.layer_lo == k, "back params must start at layer k");
        let b = self.meta.train_batch;
        let gb = self.upload_f32(&[b, self.meta.classes], g_logits)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = params_back.bufs.iter().collect();
        inputs.push(act);
        inputs.push(&gb);
        let mut out = self.run(&format!("back_bwd_{k}"), &inputs)?;
        let g_act = out.pop().expect("back_bwd g_act");
        Ok((out, g_act))
    }

    /// Front backward at split `k`: grads for layers `0..k`.
    pub fn front_bwd(
        &mut self,
        k: usize,
        params_front: &[Vec<f32>],
        x: &[f32],
        g_act: &[f32],
    ) -> Result<Vec<Vec<f32>>> {
        let dev = self.upload_params(params_front, 0)?;
        let xb = self.upload_f32(&[self.meta.train_batch, self.meta.input_dim], x)?;
        self.front_bwd_b(k, &dev, &xb, g_act)
    }

    /// `front_bwd` with device-resident params + input.
    pub fn front_bwd_b(
        &mut self,
        k: usize,
        params_front: &DeviceTensors,
        x: &xla::PjRtBuffer,
        g_act: &[f32],
    ) -> Result<Vec<Vec<f32>>> {
        anyhow::ensure!(params_front.layer_lo == 0, "front params must start at layer 0");
        let b = self.meta.train_batch;
        let gb = self.upload_f32(&[b, self.meta.hidden], g_act)?;
        let mut inputs: Vec<&xla::PjRtBuffer> = params_front.bufs.iter().collect();
        inputs.push(x);
        inputs.push(&gb);
        self.run(&format!("front_bwd_{k}"), &inputs)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have produced `artifacts/`;
    //! they are skipped (cleanly) otherwise so `cargo test` works pre-AOT.
    use super::*;

    fn engine() -> Option<Engine> {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            Some(Engine::load("artifacts").expect("engine"))
        } else {
            crate::log_warn!("skipping runtime test: artifacts/ not built");
            None
        }
    }

    #[test]
    fn init_params_shapes_match_manifest() {
        let Some(mut e) = engine() else { return };
        let p = e.init_params(7).unwrap();
        assert_eq!(p.len(), e.meta().n_tensors());
        for (i, t) in p.iter().enumerate() {
            assert_eq!(t.len(), e.meta().tensor_elems(i), "tensor {i}");
        }
        // deterministic in the seed
        let p2 = e.init_params(7).unwrap();
        assert_eq!(p[0], p2[0]);
        let p3 = e.init_params(8).unwrap();
        assert_ne!(p[0], p3[0]);
    }

    #[test]
    fn split_fwd_equals_full_fwd_loss() {
        // front_fwd ∘ back_fwd must reproduce full_step's loss for every k.
        let Some(mut e) = engine() else { return };
        let meta = e.meta().clone();
        let params = e.init_params(1).unwrap();
        let b = meta.train_batch;
        let x: Vec<f32> = (0..b * meta.input_dim)
            .map(|i| ((i * 2654435761usize) % 1000) as f32 / 500.0 - 1.0)
            .collect();
        let mut y = vec![0f32; b * meta.classes];
        for r in 0..b {
            y[r * meta.classes + r % meta.classes] = 1.0;
        }
        let (_, loss_full) = e.full_step(&params, &x, &y).unwrap();
        for k in 1..meta.layers {
            let pf = params[..2 * k].to_vec();
            let pb = params[2 * k..].to_vec();
            let act = e.front_fwd(k, &pf, &x).unwrap();
            let logits = e.back_fwd(k, &pb, &act).unwrap();
            let (loss_split, _) = e.loss_grad(&logits, &y).unwrap();
            assert!(
                (loss_full - loss_split).abs() < 1e-4,
                "k={k}: {loss_full} vs {loss_split}"
            );
        }
    }

    #[test]
    fn split_grads_equal_full_grads() {
        let Some(mut e) = engine() else { return };
        let meta = e.meta().clone();
        let params = e.init_params(2).unwrap();
        let b = meta.train_batch;
        let x: Vec<f32> = (0..b * meta.input_dim)
            .map(|i| (((i * 131) % 97) as f32 / 48.5) - 1.0)
            .collect();
        let mut y = vec![0f32; b * meta.classes];
        for r in 0..b {
            y[r * meta.classes + (r * 3) % meta.classes] = 1.0;
        }
        let (g_full, _) = e.full_step(&params, &x, &y).unwrap();
        let k = meta.layers / 2;
        let pf = params[..2 * k].to_vec();
        let pb = params[2 * k..].to_vec();
        let act = e.front_fwd(k, &pf, &x).unwrap();
        let logits = e.back_fwd(k, &pb, &act).unwrap();
        let (_, g_logits) = e.loss_grad(&logits, &y).unwrap();
        let (g_back, g_act) = e.back_bwd(k, &pb, &act, &g_logits).unwrap();
        let g_front = e.front_bwd(k, &pf, &x, &g_act).unwrap();
        assert_eq!(g_front.len(), 2 * k);
        assert_eq!(g_back.len(), 2 * (meta.layers - k));
        let check = |a: &[f32], b: &[f32], what: &str| {
            let max_err = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0f32, f32::max);
            assert!(max_err < 1e-3, "{what}: max err {max_err}");
        };
        for (i, g) in g_front.iter().enumerate() {
            check(g, &g_full[i], &format!("front tensor {i}"));
        }
        for (i, g) in g_back.iter().enumerate() {
            check(g, &g_full[2 * k + i], &format!("back tensor {i}"));
        }
    }

    #[test]
    fn buffer_reuse_matches_fresh_uploads() {
        // The *_b fast path (shared device params/input) must compute exactly
        // the same numbers as the slice-based convenience path.
        let Some(mut e) = engine() else { return };
        let meta = e.meta().clone();
        let params = e.init_params(4).unwrap();
        let k = 2;
        let pf = params[..2 * k].to_vec();
        let b = meta.train_batch;
        let x = vec![0.25f32; b * meta.input_dim];
        let slow = e.front_fwd(k, &pf, &x).unwrap();
        let dev = e.upload_params(&pf, 0).unwrap();
        let xb = e.upload_f32(&[b, meta.input_dim], &x).unwrap();
        let fast = e.front_fwd_b(k, &dev, &xb).unwrap();
        assert_eq!(slow, fast);
        // reuse the same buffers a second time
        let fast2 = e.front_fwd_b(k, &dev, &xb).unwrap();
        assert_eq!(fast, fast2);
    }

    #[test]
    fn no_memory_leak_in_exec_loop() {
        // Regression for the crate's literal-execute leak (~5 MB/step): 120
        // full_steps must not grow RSS by more than ~80 MB.
        let Some(mut e) = engine() else { return };
        let meta = e.meta().clone();
        let params = e.init_params(1).unwrap();
        let b = meta.train_batch;
        let x = vec![0.1f32; b * meta.input_dim];
        let y = vec![0f32; b * meta.classes];
        let rss = || -> f64 {
            let s = std::fs::read_to_string("/proc/self/status").unwrap_or_default();
            s.lines()
                .find(|l| l.starts_with("VmRSS"))
                .and_then(|l| l.split_whitespace().nth(1))
                .and_then(|v| v.parse::<f64>().ok())
                .unwrap_or(0.0)
                / 1024.0
        };
        // warm (first exec compiles + allocates arenas)
        for _ in 0..10 {
            let _ = e.full_step(&params, &x, &y).unwrap();
        }
        let before = rss();
        for _ in 0..120 {
            let _ = e.full_step(&params, &x, &y).unwrap();
        }
        let grown = rss() - before;
        assert!(grown < 80.0, "RSS grew {grown:.0} MB over 120 steps — leak?");
    }

    #[test]
    fn eval_batch_counts_plausible() {
        let Some(mut e) = engine() else { return };
        let meta = e.meta().clone();
        let params = e.init_params(3).unwrap();
        let b = meta.eval_batch;
        let x = vec![0.1f32; b * meta.input_dim];
        let mut y = vec![0f32; b * meta.classes];
        for r in 0..b / 2 {
            // half the rows labeled, half padding
            y[r * meta.classes] = 1.0;
        }
        let (loss_sum, n_correct, n_rows) = e.eval_batch(&params, &x, &y).unwrap();
        assert_eq!(n_rows, (b / 2) as f32);
        assert!(n_correct <= n_rows);
        assert!(loss_sum.is_finite() && loss_sum >= 0.0);
    }

    #[test]
    fn run_rejects_wrong_arity() {
        let Some(mut e) = engine() else { return };
        assert!(e.run("loss_grad", &[]).is_err());
    }

    #[test]
    fn upload_f32_shape_mismatch_errors() {
        let Some(e) = engine() else { return };
        assert!(e.upload_f32(&[2, 3], &[0.0; 5]).is_err());
        assert!(e.upload_f32(&[2, 3], &[0.0; 6]).is_ok());
    }

    #[test]
    fn exec_counts_track() {
        let Some(mut e) = engine() else { return };
        let before = e.total_execs();
        let _ = e.init_params(9).unwrap();
        assert_eq!(e.total_execs(), before + 1);
        assert_eq!(e.exec_counts()["init_params"], 1);
    }
}
