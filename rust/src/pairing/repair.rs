//! Incremental matching repair — the fleet-dynamics extension of Sec. III.
//!
//! When churn removes or adds clients mid-run, recomputing the full eq. (5)
//! graph and re-matching everyone both wastes work (O(n²) edges for a
//! handful of affected clients) and needlessly re-shuffles healthy pairs,
//! which invalidates their split state. [`repair_matching`] instead touches
//! only the *affected* clients: pairs whose endpoints both survive are kept
//! verbatim; widowed partners, returning solos and newcomers form a small
//! pool that is greedily re-matched on fresh edge weights. Any leftover
//! client (odd pool) becomes a **solo** and trains the full model locally —
//! the same fallback that removes the even-`n` assumption from the static
//! pairing path.

use super::graph::uncovered;
use crate::config::{PairingBackendConfig, PairingStrategy};
use crate::sim::channel::Channel;
use crate::sim::latency::Fleet;
use crate::telemetry::registry::{Counter, Gauge, Histo};
use crate::util::bitset::BitSet;
use crate::util::rng::Rng;

/// A near-perfect matching with explicit solo clients. Indices are *universe*
/// client ids (stable across churn), not compact per-round ids.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Matching {
    pub pairs: Vec<(usize, usize)>,
    pub solos: Vec<usize>,
}

impl Matching {
    /// Every client covered by the matching (pairs then solos).
    pub fn members(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .pairs
            .iter()
            .flat_map(|&(a, b)| [a, b])
            .chain(self.solos.iter().copied())
            .collect();
        out.sort_unstable();
        out
    }

    /// True when the matching covers exactly `members`, each client once.
    pub fn is_valid_over(&self, members: &[usize]) -> bool {
        let mut expect: Vec<usize> = members.to_vec();
        expect.sort_unstable();
        expect.dedup();
        let got = self.members();
        // members() sorts but does not dedup, so duplicates break equality.
        got == expect
    }

    /// Restrict to the clients in `present` for one round: pairs with both
    /// endpoints present survive; a pair with one transient endpoint demotes
    /// the survivor to solo *for this round only* (the stored matching is
    /// untouched); absent solos are dropped.
    pub fn restricted_to(&self, present: &[usize]) -> Matching {
        // Packed membership bits instead of a HashSet: ids out of range are
        // simply absent, and the probe is a shift+mask instead of a hash.
        let cap = present.iter().max().map_or(0, |&m| m + 1);
        let set = BitSet::from_ids(cap, present.iter().copied());
        let mut out = Matching::default();
        for &(a, b) in &self.pairs {
            match (set.contains(a), set.contains(b)) {
                (true, true) => out.pairs.push((a, b)),
                (true, false) => out.solos.push(a),
                (false, true) => out.solos.push(b),
                (false, false) => {}
            }
        }
        for &s in &self.solos {
            if set.contains(s) {
                out.solos.push(s);
            }
        }
        out
    }
}

/// What a repair operation did (for logging and tests).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RepairReport {
    /// Pairs removed because at least one endpoint left the fleet.
    pub dropped_pairs: Vec<(usize, usize)>,
    /// Pairs formed from the affected pool.
    pub new_pairs: Vec<(usize, usize)>,
    /// Clients left solo after the repair.
    pub new_solos: Vec<usize>,
    /// Healthy pairs carried over untouched.
    pub kept_pairs: usize,
}

impl RepairReport {
    pub fn changed(&self) -> bool {
        !self.dropped_pairs.is_empty() || !self.new_pairs.is_empty()
    }
}

/// The kept/affected split a repair operates on (see [`repair_matching`]).
struct RepairPartition {
    /// Pairs whose endpoints both survive — carried over untouched.
    kept: Vec<(usize, usize)>,
    /// Affected clients to re-match: widows, surviving solos, newcomers
    /// (sorted, deduped).
    pool: Vec<usize>,
    /// Pairs that lost at least one endpoint.
    dropped: Vec<(usize, usize)>,
}

/// Split `m` against the alive set: healthy pairs are kept, everyone else
/// lands in the re-match pool.
fn partition_for_repair(m: &Matching, members: &[usize]) -> RepairPartition {
    let cap = members.iter().max().map_or(0, |&m| m + 1);
    let set = BitSet::from_ids(cap, members.iter().copied());
    let mut kept: Vec<(usize, usize)> = Vec::with_capacity(m.pairs.len());
    let mut dropped: Vec<(usize, usize)> = Vec::new();
    let mut pool: Vec<usize> = Vec::new();
    for &(a, b) in &m.pairs {
        match (set.contains(a), set.contains(b)) {
            (true, true) => kept.push((a, b)),
            (true, false) => {
                dropped.push((a, b));
                pool.push(a);
            }
            (false, true) => {
                dropped.push((a, b));
                pool.push(b);
            }
            (false, false) => dropped.push((a, b)),
        }
    }
    // Surviving solos rejoin the pool — a repair may finally pair them up.
    for &s in &m.solos {
        if set.contains(s) {
            pool.push(s);
        }
    }
    // Newcomers: alive clients covered by neither kept pairs nor the pool.
    let mut covered = BitSet::new(cap);
    for id in kept.iter().flat_map(|&(a, b)| [a, b]).chain(pool.iter().copied()) {
        covered.insert(id);
    }
    for &c in members {
        if !covered.contains(c) {
            pool.push(c);
        }
    }
    pool.sort_unstable();
    pool.dedup();
    RepairPartition { kept, pool, dropped }
}

/// Dense greedy max-weight matching of a (small) pool on fresh weights —
/// O(pool²) edges, which is exactly right for the handful of clients a
/// typical churn round touches.
pub fn dense_pool_matching<W: Fn(usize, usize) -> f64>(pool: &[usize], weight: &W) -> Matching {
    let mut edges: Vec<(f64, usize, usize)> = Vec::with_capacity(pool.len() * pool.len() / 2);
    for (x, &a) in pool.iter().enumerate() {
        for &b in &pool[x + 1..] {
            edges.push((weight(a, b), a, b));
        }
    }
    // total_cmp: total order without the NaN-driven unwrap/Equal escape
    // hatch (identical ordering on the non-NaN weights we actually see).
    edges.sort_by(|p, q| {
        q.0.total_cmp(&p.0).then_with(|| (p.1, p.2).cmp(&(q.1, q.2)))
    });
    let cap = pool.iter().max().map_or(0, |&m| m + 1);
    let mut taken = BitSet::new(cap);
    let mut pairs = Vec::new();
    for &(_, a, b) in &edges {
        if !taken.contains(a) && !taken.contains(b) {
            taken.insert(a);
            taken.insert(b);
            pairs.push((a, b));
        }
    }
    let solos = pool.iter().copied().filter(|&c| !taken.contains(c)).collect();
    Matching { pairs, solos }
}

/// Repair `m` in place so it covers exactly `members`, re-matching only the
/// affected pool through `pair_pool` (which receives the sorted pool and must
/// return a matching covering it). This is the backend-agnostic core: the
/// fleet layer passes a grid-local sparse matcher for metro-scale pools and
/// the dense matcher otherwise.
pub fn repair_matching_pooled(
    m: &mut Matching,
    members: &[usize],
    pair_pool: impl FnOnce(&[usize]) -> Matching,
) -> RepairReport {
    let part = partition_for_repair(m, members);
    crate::tm_gauge!(Gauge::RepairPoolSize, part.pool.len() as u64);
    crate::tm_observe!(Histo::RepairPoolSizes, part.pool.len() as u64);
    let pooled = pair_pool(&part.pool);
    debug_assert!(pooled.is_valid_over(&part.pool), "pool matcher broke coverage");
    crate::tm_count!(Counter::RepairDroppedPairs, part.dropped.len() as u64);
    crate::tm_count!(Counter::RepairNewPairs, pooled.pairs.len() as u64);
    let report = RepairReport {
        dropped_pairs: part.dropped,
        new_pairs: pooled.pairs.clone(),
        new_solos: pooled.solos.clone(),
        kept_pairs: part.kept.len(),
    };
    m.pairs = part.kept;
    m.pairs.extend(pooled.pairs);
    m.solos = pooled.solos;
    report
}

/// Cross-epoch memo for [`repair_matching_pooled_memo`]: remembers the last
/// affected pool, the weight-state generation stamp it was matched under, and
/// the matching the pool matcher produced.
#[derive(Clone, Debug, Default)]
pub struct RepairMemo {
    pool: Vec<usize>,
    stamp: u64,
    result: Option<Matching>,
    /// Epochs where the cached pool matching was reused (for tests/telemetry).
    pub hits: u64,
}

/// [`repair_matching_pooled`] with a generation stamp: when the affected pool
/// is identical to the previous epoch's *and* `stamp` (the caller's
/// fingerprint of everything the pool matcher reads — channel state, fleet
/// positions/frequencies, weight spec, shuffle nonce) is unchanged, the pool
/// matcher is a pure function re-applied to identical inputs, so the cached
/// matching is reused and the O(pool² log pool) re-sort is skipped entirely.
pub fn repair_matching_pooled_memo(
    m: &mut Matching,
    members: &[usize],
    stamp: u64,
    memo: &mut RepairMemo,
    pair_pool: impl FnOnce(&[usize]) -> Matching,
) -> RepairReport {
    let part = partition_for_repair(m, members);
    crate::tm_gauge!(Gauge::RepairPoolSize, part.pool.len() as u64);
    crate::tm_observe!(Histo::RepairPoolSizes, part.pool.len() as u64);
    let pooled = match &memo.result {
        Some(cached) if memo.stamp == stamp && memo.pool == part.pool => {
            memo.hits += 1;
            cached.clone()
        }
        _ => {
            let fresh = pair_pool(&part.pool);
            memo.pool = part.pool.clone();
            memo.stamp = stamp;
            memo.result = Some(fresh.clone());
            fresh
        }
    };
    debug_assert!(pooled.is_valid_over(&part.pool), "pool matcher broke coverage");
    crate::tm_count!(Counter::RepairDroppedPairs, part.dropped.len() as u64);
    crate::tm_count!(Counter::RepairNewPairs, pooled.pairs.len() as u64);
    let report = RepairReport {
        dropped_pairs: part.dropped,
        new_pairs: pooled.pairs.clone(),
        new_solos: pooled.solos.clone(),
        kept_pairs: part.kept.len(),
    };
    m.pairs = part.kept;
    m.pairs.extend(pooled.pairs);
    m.solos = pooled.solos;
    report
}

/// Repair `m` in place so it covers exactly `members` (the currently-alive
/// universe ids), re-matching only the affected clients.
///
/// `weight` supplies *fresh* eq. (5) edge weights — pairing weights go stale
/// under time-varying channels, so the repair pool is matched on current
/// rates, not the ones the original matching saw.
pub fn repair_matching<W: Fn(usize, usize) -> f64>(
    m: &mut Matching,
    members: &[usize],
    weight: W,
) -> RepairReport {
    repair_matching_pooled(m, members, |pool| dense_pool_matching(pool, &weight))
}

/// Full (re-)pairing of an arbitrary subset of the fleet: maps `members` to a
/// compact sub-fleet, runs the configured strategy, and maps back — recording
/// the odd-one-out as a solo. Uses the default (`Auto`) candidate backend;
/// see [`pair_members_with`] to pin one.
pub fn pair_members(
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    rng: &mut Rng,
    members: &[usize],
) -> Matching {
    pair_members_with(
        &PairingBackendConfig::default(),
        strategy,
        fleet,
        channel,
        alpha,
        beta,
        None,
        rng,
        members,
    )
}

/// [`pair_members`] with an explicit candidate-graph backend and an optional
/// split-cost model (co-designed Greedy/Exact weights — see
/// [`super::pair_clients_with`]).
#[allow(clippy::too_many_arguments)]
pub fn pair_members_with(
    backend: &PairingBackendConfig,
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    cost: Option<&crate::split::SplitCostModel>,
    rng: &mut Rng,
    members: &[usize],
) -> Matching {
    let mut ms: Vec<usize> = members.to_vec();
    ms.sort_unstable();
    ms.dedup();
    if ms.is_empty() {
        return Matching::default();
    }
    if ms.len() == 1 {
        return Matching {
            pairs: Vec::new(),
            solos: ms,
        };
    }
    let sub = fleet.subset(&ms);
    let compact =
        super::pair_clients_with(backend, strategy, &sub, channel, alpha, beta, cost, rng);
    let pairs: Vec<(usize, usize)> = compact.iter().map(|&(a, b)| (ms[a], ms[b])).collect();
    let solos: Vec<usize> = uncovered(ms.len(), &compact)
        .into_iter()
        .map(|c| ms[c])
        .collect();
    Matching { pairs, solos }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};

    fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        (
            Fleet::sample(&cfg, &mut Rng::new(seed)),
            Channel::new(ChannelConfig::default()),
        )
    }

    fn weight_of(fleet: &Fleet, channel: &Channel) -> impl Fn(usize, usize) -> f64 {
        let freqs = fleet.freqs_hz.clone();
        let pos = fleet.positions.clone();
        let ch = channel.clone();
        move |a, b| {
            let df = (freqs[a] - freqs[b]) / 1e9;
            df * df + 2e-9 * ch.rate(&pos[a], &pos[b])
        }
    }

    #[test]
    fn pair_members_even_and_odd() {
        let (f, ch) = fleet(8, 1);
        let mut rng = Rng::new(2);
        let all: Vec<usize> = (0..8).collect();
        let m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &all);
        assert_eq!(m.pairs.len(), 4);
        assert!(m.solos.is_empty());
        assert!(m.is_valid_over(&all));
        // odd subset → one solo
        let odd: Vec<usize> = vec![0, 2, 3, 5, 7];
        let m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &odd);
        assert_eq!(m.pairs.len(), 2);
        assert_eq!(m.solos.len(), 1);
        assert!(m.is_valid_over(&odd));
    }

    #[test]
    fn pair_members_n7_all_strategies() {
        // Regression: n_clients = 7 must work for every strategy.
        let (f, ch) = fleet(7, 3);
        let all: Vec<usize> = (0..7).collect();
        for s in [
            PairingStrategy::Greedy,
            PairingStrategy::Random,
            PairingStrategy::Location,
            PairingStrategy::Compute,
            PairingStrategy::Exact,
        ] {
            let mut rng = Rng::new(4);
            let m = pair_members(s, &f, &ch, 1.0, 2e-9, &mut rng, &all);
            assert_eq!(m.pairs.len(), 3, "{s:?}");
            assert_eq!(m.solos.len(), 1, "{s:?}");
            assert!(m.is_valid_over(&all), "{s:?}: {m:?}");
        }
    }

    #[test]
    fn repair_after_single_departure_keeps_healthy_pairs() {
        let (f, ch) = fleet(10, 5);
        let all: Vec<usize> = (0..10).collect();
        let mut rng = Rng::new(6);
        let mut m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &all);
        let before = m.pairs.clone();
        // Client 3 departs: only its pair may change; its widow goes solo.
        let members: Vec<usize> = all.iter().copied().filter(|&c| c != 3).collect();
        let rep = repair_matching(&mut m, &members, weight_of(&f, &ch));
        assert!(rep.changed());
        assert_eq!(rep.dropped_pairs.len(), 1);
        assert_eq!(rep.kept_pairs, 4);
        assert_eq!(rep.new_solos.len(), 1);
        assert!(m.is_valid_over(&members), "{m:?}");
        // Healthy pairs untouched.
        for p in &before {
            if p.0 != 3 && p.1 != 3 {
                assert!(m.pairs.contains(p), "healthy pair {p:?} was disturbed");
            }
        }
    }

    #[test]
    fn repair_pairs_widow_with_newcomer() {
        let (f, ch) = fleet(10, 7);
        let mut rng = Rng::new(8);
        // Start with clients 0..8 matched; 8 and 9 unknown to the matching.
        let initial: Vec<usize> = (0..8).collect();
        let mut m =
            pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &initial);
        // Client 0 departs, clients 8 and 9 join: widow + 2 newcomers = pool
        // of 3 → one new pair + one solo.
        let members: Vec<usize> = (1..10).collect();
        let rep = repair_matching(&mut m, &members, weight_of(&f, &ch));
        assert_eq!(rep.dropped_pairs.len(), 1);
        assert_eq!(rep.new_pairs.len(), 1);
        assert_eq!(rep.new_solos.len(), 1);
        assert!(m.is_valid_over(&members), "{m:?}");
    }

    #[test]
    fn repair_on_empty_change_is_noop() {
        let (f, ch) = fleet(6, 9);
        let all: Vec<usize> = (0..6).collect();
        let mut rng = Rng::new(10);
        let mut m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &all);
        let snapshot = m.clone();
        let rep = repair_matching(&mut m, &all, weight_of(&f, &ch));
        assert!(!rep.changed());
        assert_eq!(m, snapshot);
    }

    #[test]
    fn restricted_to_demotes_transient_partners() {
        let m = Matching {
            pairs: vec![(0, 1), (2, 3)],
            solos: vec![4],
        };
        // 1 and 4 transiently out this round.
        let eff = m.restricted_to(&[0, 2, 3]);
        assert_eq!(eff.pairs, vec![(2, 3)]);
        assert_eq!(eff.solos, vec![0]);
        // Stored matching untouched.
        assert_eq!(m.pairs.len(), 2);
        assert_eq!(m.solos, vec![4]);
    }

    #[test]
    fn memo_skips_pool_matcher_when_pool_and_stamp_unchanged() {
        let (f, ch) = fleet(10, 13);
        let all: Vec<usize> = (0..10).collect();
        let mut rng = Rng::new(14);
        let mut m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &all);
        let members: Vec<usize> = all.iter().copied().filter(|&c| c != 3).collect();
        let w = weight_of(&f, &ch);
        let mut memo = RepairMemo::default();
        let mut calls = 0;
        // Epoch 1: client 3 departed → pool matcher runs.
        repair_matching_pooled_memo(&mut m, &members, 7, &mut memo, |pool| {
            calls += 1;
            dense_pool_matching(pool, &w)
        });
        assert_eq!(calls, 1);
        let snapshot = m.clone();
        // Epoch 2: identical pool (the surviving solo), identical stamp →
        // the cached pool matching is reused, the matcher is NOT re-run.
        repair_matching_pooled_memo(&mut m, &members, 7, &mut memo, |pool| {
            calls += 1;
            dense_pool_matching(pool, &w)
        });
        assert_eq!(calls, 1, "unchanged pool+stamp must skip the matcher");
        assert_eq!(memo.hits, 1);
        assert_eq!(m, snapshot);
        assert!(m.is_valid_over(&members));
        // Epoch 3: stamp bump (weight state changed) → must re-run.
        repair_matching_pooled_memo(&mut m, &members, 8, &mut memo, |pool| {
            calls += 1;
            dense_pool_matching(pool, &w)
        });
        assert_eq!(calls, 2, "a stamp change must invalidate the memo");
        assert!(m.is_valid_over(&members));
    }

    #[test]
    fn repair_down_to_one_client() {
        let (f, ch) = fleet(4, 11);
        let all: Vec<usize> = (0..4).collect();
        let mut rng = Rng::new(12);
        let mut m = pair_members(PairingStrategy::Greedy, &f, &ch, 1.0, 2e-9, &mut rng, &all);
        let rep = repair_matching(&mut m, &[2], weight_of(&f, &ch));
        assert_eq!(rep.dropped_pairs.len(), 2);
        assert_eq!(m.pairs.len(), 0);
        assert_eq!(m.solos, vec![2]);
        assert!(m.is_valid_over(&[2]));
    }
}
