//! The client graph of paper Sec. III-A: vertices are clients, edge weights
//! follow eq. (5):
//!
//! ```text
//!     ε_ij = α · (f_i − f_j)² + β · r_ij
//! ```
//!
//! Frequencies enter in **GHz** so the two terms are commensurable with the
//! default weights (α=1, β=2e-9 · bits/s): a full-range frequency gap
//! contributes ≈ 3.6 while a strong link contributes ≈ 1.6.

use crate::sim::channel::Channel;
use crate::sim::latency::Fleet;

/// A weighted undirected edge `(i, j, ε_ij)` with `i < j`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    pub i: usize,
    pub j: usize,
    pub weight: f64,
}

/// Eq. (5) edge weight from raw client state. The **single** implementation
/// shared by the dense and sparse backends, so the two are bit-identical
/// whenever they evaluate the same edge.
#[inline]
pub fn eq5_weight(alpha: f64, beta: f64, f_i_hz: f64, f_j_hz: f64, rate_bps: f64) -> f64 {
    let df_ghz = (f_i_hz - f_j_hz) / 1e9;
    alpha * df_ghz * df_ghz + beta * rate_bps
}

/// A source of candidate edges for the matching algorithms.
///
/// The dense backend ([`ClientGraph`]) yields all `n(n−1)/2` edges with
/// precomputed weights — exactly the paper's complete graph. The sparse
/// backend ([`crate::pairing::candidates::SparseCandidateGraph`]) yields
/// O(n·k) grid-local + frequency-band edges with weights evaluated lazily.
/// `greedy_matching` consumes either through this trait.
pub trait CandidateGraph {
    /// Upper bound (exclusive) on vertex ids appearing in the edges.
    fn n(&self) -> usize;

    /// Weight of the `(a, b)` edge. May panic if the edge is not represented
    /// (dense graphs represent every edge; sparse ones evaluate on demand).
    fn weight(&self, a: usize, b: usize) -> f64;

    /// The candidate edge list (each undirected edge once, `i < j`).
    /// Borrowed — the matchers sort an index permutation over it, so no
    /// O(edges) copy happens per pairing round.
    fn candidate_edges(&self) -> &[Edge];
}

/// Complete weighted client graph.
#[derive(Clone, Debug)]
pub struct ClientGraph {
    pub n: usize,
    pub edges: Vec<Edge>,
}

impl ClientGraph {
    /// Build the complete graph from fleet state per eq. (5).
    pub fn build(fleet: &Fleet, channel: &Channel, alpha: f64, beta: f64) -> ClientGraph {
        Self::build_spec(
            fleet,
            channel,
            crate::pairing::EdgeWeightSpec::Eq5 { alpha, beta },
        )
    }

    /// Build the complete graph under an arbitrary
    /// [`EdgeWeightSpec`](crate::pairing::EdgeWeightSpec) — e.g. the
    /// split-planner's predicted pair latency, so the dense matchers (greedy
    /// *and* the exact DP) can optimize the co-designed objective. With the
    /// `Eq5` spec this is [`ClientGraph::build`] bit-for-bit.
    pub fn build_spec(
        fleet: &Fleet,
        channel: &Channel,
        spec: crate::pairing::EdgeWeightSpec<'_>,
    ) -> ClientGraph {
        let n = fleet.n();
        let mut edges = Vec::with_capacity(n * (n - 1) / 2);
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push(Edge {
                    i,
                    j,
                    weight: spec.weight(fleet, channel, i, j),
                });
            }
        }
        ClientGraph { n, edges }
    }

    /// Weight lookup (O(1) arithmetic index into the triangular edge list).
    pub fn weight(&self, a: usize, b: usize) -> f64 {
        assert!(a != b && a < self.n && b < self.n);
        let (i, j) = if a < b { (a, b) } else { (b, a) };
        // index of (i,j) in the row-major upper triangle
        let idx = i * self.n - i * (i + 1) / 2 + (j - i - 1);
        let e = self.edges[idx];
        debug_assert_eq!((e.i, e.j), (i, j));
        e.weight
    }

    /// Total weight of a matching.
    pub fn matching_weight(&self, pairs: &[(usize, usize)]) -> f64 {
        pairs.iter().map(|&(a, b)| self.weight(a, b)).sum()
    }
}

impl CandidateGraph for ClientGraph {
    fn n(&self) -> usize {
        self.n
    }

    fn weight(&self, a: usize, b: usize) -> f64 {
        ClientGraph::weight(self, a, b)
    }

    fn candidate_edges(&self) -> &[Edge] {
        &self.edges
    }
}

/// Check a pairing is a valid *near-perfect* matching on `n` vertices:
/// `⌊n/2⌋` pairs, every vertex in at most one pair, no self-loops — so for
/// even `n` everyone is covered (constraints (4a)/(4b)/(6a)/(6b)) and for odd
/// `n` exactly one client is left solo (the fleet-dynamics extension; the
/// solo client trains the full model locally).
pub fn is_perfect_matching(n: usize, pairs: &[(usize, usize)]) -> bool {
    if pairs.len() != n / 2 {
        return false;
    }
    let mut seen = vec![false; n];
    for &(a, b) in pairs {
        if a == b || a >= n || b >= n || seen[a] || seen[b] {
            return false;
        }
        seen[a] = true;
        seen[b] = true;
    }
    true
}

/// The vertices of `[0, n)` not covered by `pairs` (the solo clients of a
/// near-perfect matching; empty for a perfect one).
pub fn uncovered(n: usize, pairs: &[(usize, usize)]) -> Vec<usize> {
    let mut seen = vec![false; n];
    for &(a, b) in pairs {
        if a < n {
            seen[a] = true;
        }
        if b < n {
            seen[b] = true;
        }
    }
    (0..n).filter(|&v| !seen[v]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::util::rng::Rng;

    fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        let mut rng = Rng::new(seed);
        (
            Fleet::sample(&cfg, &mut rng),
            Channel::new(ChannelConfig::default()),
        )
    }

    #[test]
    fn complete_graph_edge_count() {
        let (f, ch) = fleet(20, 1);
        let g = ClientGraph::build(&f, &ch, 1.0, 2e-9);
        assert_eq!(g.edges.len(), 20 * 19 / 2);
        assert!(g.edges.iter().all(|e| e.i < e.j && e.weight >= 0.0));
    }

    #[test]
    fn weight_lookup_matches_edge_list() {
        let (f, ch) = fleet(8, 2);
        let g = ClientGraph::build(&f, &ch, 1.0, 2e-9);
        for e in &g.edges {
            assert_eq!(g.weight(e.i, e.j), e.weight);
            assert_eq!(g.weight(e.j, e.i), e.weight); // symmetric
        }
    }

    #[test]
    fn eq5_terms_behave() {
        let (f, ch) = fleet(4, 3);
        // α-only: weight grows with frequency gap.
        let g_alpha = ClientGraph::build(&f, &ch, 1.0, 0.0);
        let mut max_gap_pair = (0, 1);
        let mut max_gap = 0.0;
        for i in 0..4 {
            for j in (i + 1)..4 {
                let gap = ((f.freqs_hz[i] - f.freqs_hz[j]) / 1e9).powi(2);
                if gap > max_gap {
                    max_gap = gap;
                    max_gap_pair = (i, j);
                }
            }
        }
        let best = g_alpha
            .edges
            .iter()
            .max_by(|a, b| a.weight.partial_cmp(&b.weight).unwrap())
            .unwrap();
        assert_eq!((best.i, best.j), max_gap_pair);
        // β-only: nearest pair (highest rate) wins.
        let g_beta = ClientGraph::build(&f, &ch, 0.0, 1.0);
        let best = g_beta
            .edges
            .iter()
            .max_by(|a, b| a.weight.partial_cmp(&b.weight).unwrap())
            .unwrap();
        let mut min_d = f64::INFINITY;
        let mut min_pair = (0, 1);
        for i in 0..4 {
            for j in (i + 1)..4 {
                let d = f.positions[i].dist(&f.positions[j]);
                if d < min_d {
                    min_d = d;
                    min_pair = (i, j);
                }
            }
        }
        assert_eq!((best.i, best.j), min_pair);
    }

    #[test]
    fn perfect_matching_validation() {
        assert!(is_perfect_matching(4, &[(0, 1), (2, 3)]));
        assert!(is_perfect_matching(4, &[(3, 0), (1, 2)]));
        assert!(!is_perfect_matching(4, &[(0, 1)])); // incomplete
        assert!(!is_perfect_matching(4, &[(0, 1), (1, 2)])); // vertex reuse
        assert!(!is_perfect_matching(4, &[(0, 0), (2, 3)])); // self loop
        assert!(!is_perfect_matching(4, &[(0, 1), (2, 5)])); // out of range
        // Odd n: near-perfect — ⌊n/2⌋ pairs, exactly one vertex solo.
        assert!(is_perfect_matching(5, &[(0, 1), (2, 3)]));
        assert!(is_perfect_matching(3, &[(0, 2)]));
        assert!(!is_perfect_matching(3, &[])); // needs one pair
        assert!(!is_perfect_matching(5, &[(0, 1)])); // needs two pairs
    }

    #[test]
    fn uncovered_lists_solo_vertices() {
        assert_eq!(uncovered(5, &[(0, 1), (2, 3)]), vec![4]);
        assert_eq!(uncovered(4, &[(0, 3), (1, 2)]), Vec::<usize>::new());
        assert_eq!(uncovered(3, &[(0, 2)]), vec![1]);
    }

    #[test]
    fn matching_weight_sums() {
        let (f, ch) = fleet(4, 5);
        let g = ClientGraph::build(&f, &ch, 1.0, 2e-9);
        let m = [(0usize, 1usize), (2usize, 3usize)];
        let expect = g.weight(0, 1) + g.weight(2, 3);
        assert!((g.matching_weight(&m) - expect).abs() < 1e-12);
    }
}
