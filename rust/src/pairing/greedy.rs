//! Algorithm 1 of the paper: greedy max-weight matching.
//!
//! 1. Sort all edges by weight, descending (the paper's pseudocode says
//!    "ascending" but its step text — "iteratively pick the edge with the
//!    largest weight" — and the objective (6) require descending; we follow
//!    the objective).
//! 2. Walk the sorted list, taking every edge whose endpoints are both
//!    uncovered.
//!
//! This is the classic ½-approximation for maximum-weight matching: the
//! result is vertex-disjoint, covers all vertices of a complete even-order
//! graph, and its weight is ≥ ½ of the optimum (property-tested against the
//! exact DP in `exact.rs`).
//!
//! The matcher is generic over [`CandidateGraph`]: on the dense complete
//! graph it is the paper's Algorithm 1 verbatim (O(n² log n)); on the sparse
//! candidate graph it runs in O(n·k·log(n·k)) over the grid-local +
//! frequency-band edges. On a non-complete graph the greedy pass can leave
//! more than one vertex uncovered — `candidates::match_candidates` adds the
//! completion step that turns the result into a near-perfect matching.

use super::graph::{CandidateGraph, Edge};

/// Deterministic greedy matching (ties broken by `(i, j)` lexicographic order
/// so results are stable across runs and platforms).
pub fn greedy_matching<G: CandidateGraph + ?Sized>(graph: &G) -> Vec<(usize, usize)> {
    pick_edges(graph.candidate_edges(), graph.n())
}

/// The shared sort-and-pick core: heaviest edge first, both endpoints free.
/// Sorts an index permutation instead of the edges themselves — the edge key
/// `(weight desc, (i, j))` is unique per edge, so the pick order (and thus
/// the matching) is identical to sorting the edge list directly.
pub(crate) fn pick_edges(edges: &[Edge], n: usize) -> Vec<(usize, usize)> {
    debug_assert!(edges.len() <= u32::MAX as usize);
    let mut order: Vec<u32> = (0..edges.len() as u32).collect();
    order.sort_unstable_by(|&x, &y| {
        let (a, b) = (&edges[x as usize], &edges[y as usize]);
        // total_cmp: branch-free total order, no NaN panic path in the
        // innermost comparator (identical to partial_cmp on non-NaN input).
        b.weight
            .total_cmp(&a.weight)
            .then_with(|| (a.i, a.j).cmp(&(b.i, b.j)))
    });
    let mut covered = vec![false; n];
    let mut out = Vec::with_capacity(n / 2);
    for &x in &order {
        let e = &edges[x as usize];
        if !covered[e.i] && !covered[e.j] {
            covered[e.i] = true;
            covered[e.j] = true;
            out.push((e.i, e.j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::graph::{is_perfect_matching, ClientGraph, Edge};
    use super::*;
    use crate::util::proptest::{check, gen_usize, Gen};
    use crate::util::rng::Rng;

    /// Graph with explicit weights for hand-checkable cases.
    fn graph_from(n: usize, w: &[((usize, usize), f64)]) -> ClientGraph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                let weight = w
                    .iter()
                    .find(|((a, b), _)| (*a, *b) == (i, j))
                    .map(|&(_, w)| w)
                    .unwrap_or(0.0);
                edges.push(Edge { i, j, weight });
            }
        }
        ClientGraph { n, edges }
    }

    fn random_graph(rng: &mut Rng, n: usize) -> ClientGraph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push(Edge {
                    i,
                    j,
                    weight: rng.f64() * 10.0,
                });
            }
        }
        ClientGraph { n, edges }
    }

    #[test]
    fn takes_heaviest_edge_first() {
        let g = graph_from(4, &[((0, 1), 10.0), ((2, 3), 1.0), ((0, 2), 5.0)]);
        let m = greedy_matching(&g);
        assert!(m.contains(&(0, 1)));
        assert!(m.contains(&(2, 3)));
    }

    #[test]
    fn greedy_can_be_suboptimal_but_half_bounded() {
        // Classic adversarial case: path weights 3-4-3. Greedy takes the 4
        // (weight 4), optimal takes both 3s (weight 6) — but as a perfect
        // matching on 4 vertices greedy must still cover everyone.
        let g = graph_from(4, &[((0, 1), 3.0), ((1, 2), 4.0), ((2, 3), 3.0)]);
        let m = greedy_matching(&g);
        assert!(is_perfect_matching(4, &m));
        assert!(m.contains(&(1, 2)));
        let wt = g.matching_weight(&m);
        assert!(wt >= 6.0 / 2.0, "½-approx violated: {wt}");
    }

    #[test]
    fn perfect_matching_on_even_complete_graphs() {
        let mut rng = Rng::new(1);
        for n in [2usize, 4, 6, 10, 20] {
            let g = random_graph(&mut rng, n);
            let m = greedy_matching(&g);
            assert!(is_perfect_matching(n, &m), "n={n}");
        }
    }

    #[test]
    fn deterministic_under_ties() {
        let g = graph_from(6, &[]); // all-zero weights → pure tie-breaking
        let a = greedy_matching(&g);
        let b = greedy_matching(&g);
        assert_eq!(a, b);
        assert!(is_perfect_matching(6, &a));
    }

    #[test]
    fn property_always_valid_matching() {
        check(
            60,
            Gen::new(|rng| {
                let n = 2 * (1 + rng.below(8)); // even 2..16
                random_graph(rng, n)
            }),
            |g| is_perfect_matching(g.n, &greedy_matching(g)),
        );
    }

    #[test]
    fn property_no_improving_uncovered_swap() {
        // Greedy maximality: you cannot add any edge between two distinct
        // pairs that outweighs both edges it would break... weaker check:
        // every edge NOT in the matching has at least one endpoint whose
        // matched edge is at least as heavy (greedy's defining invariant).
        check(
            40,
            gen_usize(1, 7).map(|half| {
                let mut rng = Rng::new(half as u64 * 131);
                random_graph(&mut rng, half * 2)
            }),
            |g| {
                let m = greedy_matching(g);
                let partner = {
                    let mut p = vec![usize::MAX; g.n];
                    for &(a, b) in &m {
                        p[a] = b;
                        p[b] = a;
                    }
                    p
                };
                g.edges.iter().all(|e| {
                    let w_i = g.weight(e.i, partner[e.i]);
                    let w_j = g.weight(e.j, partner[e.j]);
                    // tolerance for float ties
                    e.weight <= w_i + 1e-12 || e.weight <= w_j + 1e-12
                })
            },
        );
    }
}
