//! Client pairing — the paper's Sec. III contribution.
//!
//! [`graph`] models the fleet as the weighted graph of eq. (5) and defines
//! the [`graph::CandidateGraph`] trait both backends implement; [`greedy`] is
//! Algorithm 1 (generic over the trait); [`candidates`] is the sparse
//! fleet-scale backend (spatial grid + frequency band, lazy weights);
//! [`baselines`] are Table I's random/location/compute mechanisms; [`exact`]
//! is the bitmask-DP optimum used as an ablation bound. [`pair_clients`]
//! dispatches on the configured [`PairingStrategy`];
//! [`pair_clients_backend`] additionally selects the candidate backend, and
//! [`pair_clients_with`] further accepts a [`crate::split::SplitCostModel`]
//! so Greedy/Exact optimize the split planner's predicted pair latency
//! instead of the eq. (5) proxy (pairing/splitting co-design, DESIGN.md §7).
//!
//! **Exact at scale:** the DP is O(2ⁿ·n) and hard-capped at
//! [`exact::MAX_N`] = 24 clients. Beyond that, `Exact` no longer aborts the
//! run — it logs a WARN and falls back to the greedy matcher on the same
//! eq. (5) objective (`exact::try_exact_matching` exposes the checked
//! variant for callers that want the error instead).
//!
//! The fleet-dynamics extension lives in [`repair`]: near-perfect matchings
//! with explicit solo clients ([`repair::Matching`]), subset pairing
//! ([`repair::pair_members`]) and incremental re-pairing after churn
//! ([`repair::repair_matching`]). All mechanisms accept odd fleets — one
//! client is left solo instead of panicking.
//!
//! [`incremental`] is the cross-round evolution of the sparse backend: a
//! persistent [`incremental::IncrementalMatcher`] keeps candidate lists, the
//! refcounted edge set and the sorted edge order alive between rounds, so an
//! epoch costs O(affected) instead of a full rebuild — bit-for-bit identical
//! output to `match_candidates` over `over_members` (DESIGN.md §10).

pub mod baselines;
pub mod candidates;
pub mod exact;
pub mod graph;
pub mod greedy;
pub mod incremental;
pub mod repair;

pub use candidates::{match_candidates, EdgeWeightSpec, SparseCandidateGraph};
pub use incremental::IncrementalMatcher;
pub use repair::{
    dense_pool_matching, pair_members, pair_members_with, repair_matching,
    repair_matching_pooled, repair_matching_pooled_memo, Matching, RepairMemo, RepairReport,
};

use crate::config::{PairingBackendConfig, PairingStrategy};
use crate::log_warn;
use crate::sim::channel::Channel;
use crate::sim::latency::Fleet;
use crate::util::rng::Rng;
use graph::ClientGraph;

/// Run the configured pairing mechanism over the fleet with the default
/// (`Auto`) backend: the dense complete graph at paper scale, the sparse
/// candidate graph past [`PairingBackendConfig::AUTO_DENSE_MAX`] clients.
///
/// `alpha`/`beta` are eq. (5)'s weights (used by `Greedy` and `Exact`);
/// `rng` is consumed only by `Random`. Odd fleets yield `⌊n/2⌋` pairs with
/// one client uncovered ([`graph::uncovered`] identifies it).
pub fn pair_clients(
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    rng: &mut Rng,
) -> Vec<(usize, usize)> {
    pair_clients_backend(
        &PairingBackendConfig::default(),
        strategy,
        fleet,
        channel,
        alpha,
        beta,
        rng,
    )
}

/// [`pair_clients`] with an explicit candidate-graph backend.
pub fn pair_clients_backend(
    backend: &PairingBackendConfig,
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    rng: &mut Rng,
) -> Vec<(usize, usize)> {
    pair_clients_with(backend, strategy, fleet, channel, alpha, beta, None, rng)
}

/// [`pair_clients_backend`] with an optional split-cost model: when present,
/// the Greedy/Exact objective becomes the split planner's predicted pair
/// latency (`EdgeWeightSpec::SplitCost`) instead of the eq. (5) proxy —
/// pairing and cut selection co-designed, on both the dense complete graph
/// (greedy *and* the exact DP) and the sparse candidate graph.
#[allow(clippy::too_many_arguments)]
pub fn pair_clients_with(
    backend: &PairingBackendConfig,
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    cost: Option<&crate::split::SplitCostModel>,
    rng: &mut Rng,
) -> Vec<(usize, usize)> {
    let n = fleet.n();
    let sparse = backend.sparse_for(n);
    let sparse_pairs = |spec: EdgeWeightSpec<'_>| -> Vec<(usize, usize)> {
        let g = SparseCandidateGraph::build(fleet, channel, spec, backend.k_near, backend.k_freq);
        let members: Vec<usize> = (0..n).collect();
        match_candidates(&g, &members).pairs
    };
    // The latency-optimizing mechanisms' objective: the co-designed split
    // cost when a model is supplied, the eq. (5) proxy otherwise.
    let latency_spec =
        EdgeWeightSpec::for_strategy_with(PairingStrategy::Greedy, alpha, beta, cost)
            .expect("greedy always has a weight spec");
    match strategy {
        PairingStrategy::Random => baselines::random_matching(rng, n),
        PairingStrategy::Greedy if sparse => sparse_pairs(latency_spec),
        PairingStrategy::Greedy => {
            greedy::greedy_matching(&ClientGraph::build_spec(fleet, channel, latency_spec))
        }
        PairingStrategy::Location if sparse => sparse_pairs(EdgeWeightSpec::NegDistance),
        PairingStrategy::Location => baselines::location_matching(fleet),
        PairingStrategy::Compute if sparse => sparse_pairs(EdgeWeightSpec::FreqGap),
        PairingStrategy::Compute => baselines::compute_matching(fleet),
        PairingStrategy::Exact if exact::fits(n) && !sparse => {
            exact::exact_matching(&ClientGraph::build_spec(fleet, channel, latency_spec))
        }
        PairingStrategy::Exact => {
            if !exact::fits(n) {
                log_warn!(
                    "exact pairing infeasible for n={n} (bitmask-DP limit {}); \
                     falling back to greedy on the same objective",
                    exact::MAX_N
                );
            } else {
                // Feasible n, but the backend is pinned sparse — the DP is
                // only defined on the complete graph.
                log_warn!(
                    "exact pairing requested with the sparse backend; \
                     using sparse greedy on the same objective (n={n})"
                );
            }
            if sparse {
                sparse_pairs(latency_spec)
            } else {
                greedy::greedy_matching(&ClientGraph::build_spec(fleet, channel, latency_spec))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use graph::is_perfect_matching;

    #[test]
    fn dispatch_all_strategies_valid() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 10;
        let mut rng = Rng::new(1);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let ch = Channel::new(ChannelConfig::default());
        for s in [
            PairingStrategy::Greedy,
            PairingStrategy::Random,
            PairingStrategy::Location,
            PairingStrategy::Compute,
            PairingStrategy::Exact,
        ] {
            let m = pair_clients(s, &fleet, &ch, 1.0, 2e-9, &mut rng);
            assert!(is_perfect_matching(10, &m), "{s:?}: {m:?}");
        }
    }

    #[test]
    fn exact_weight_dominates_greedy() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 12;
        let mut rng = Rng::new(2);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let ch = Channel::new(ChannelConfig::default());
        let g = ClientGraph::build(&fleet, &ch, 1.0, 2e-9);
        let wg = g.matching_weight(&pair_clients(
            PairingStrategy::Greedy,
            &fleet,
            &ch,
            1.0,
            2e-9,
            &mut rng,
        ));
        let we = g.matching_weight(&pair_clients(
            PairingStrategy::Exact,
            &fleet,
            &ch,
            1.0,
            2e-9,
            &mut rng,
        ));
        assert!(we + 1e-9 >= wg);
        assert!(wg * 2.0 + 1e-9 >= we);
    }
}
