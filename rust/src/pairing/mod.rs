//! Client pairing — the paper's Sec. III contribution.
//!
//! [`graph`] models the fleet as the weighted graph of eq. (5); [`greedy`] is
//! Algorithm 1; [`baselines`] are Table I's random/location/compute
//! mechanisms; [`exact`] is the bitmask-DP optimum used as an ablation bound.
//! [`pair_clients`] dispatches on the configured [`PairingStrategy`].
//!
//! The fleet-dynamics extension lives in [`repair`]: near-perfect matchings
//! with explicit solo clients ([`repair::Matching`]), subset pairing
//! ([`repair::pair_members`]) and incremental re-pairing after churn
//! ([`repair::repair_matching`]). All mechanisms accept odd fleets — one
//! client is left solo instead of panicking.

pub mod baselines;
pub mod exact;
pub mod graph;
pub mod greedy;
pub mod repair;

pub use repair::{pair_members, repair_matching, Matching, RepairReport};

use crate::config::PairingStrategy;
use crate::sim::channel::Channel;
use crate::sim::latency::Fleet;
use crate::util::rng::Rng;
use graph::ClientGraph;

/// Run the configured pairing mechanism over the fleet.
///
/// `alpha`/`beta` are eq. (5)'s weights (used by `Greedy` and `Exact`);
/// `rng` is consumed only by `Random`. Odd fleets yield `⌊n/2⌋` pairs with
/// one client uncovered ([`graph::uncovered`] identifies it).
pub fn pair_clients(
    strategy: PairingStrategy,
    fleet: &Fleet,
    channel: &Channel,
    alpha: f64,
    beta: f64,
    rng: &mut Rng,
) -> Vec<(usize, usize)> {
    match strategy {
        PairingStrategy::Greedy => {
            greedy::greedy_matching(&ClientGraph::build(fleet, channel, alpha, beta))
        }
        PairingStrategy::Random => baselines::random_matching(rng, fleet.n()),
        PairingStrategy::Location => baselines::location_matching(fleet),
        PairingStrategy::Compute => baselines::compute_matching(fleet),
        PairingStrategy::Exact => {
            exact::exact_matching(&ClientGraph::build(fleet, channel, alpha, beta))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use graph::is_perfect_matching;

    #[test]
    fn dispatch_all_strategies_valid() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 10;
        let mut rng = Rng::new(1);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let ch = Channel::new(ChannelConfig::default());
        for s in [
            PairingStrategy::Greedy,
            PairingStrategy::Random,
            PairingStrategy::Location,
            PairingStrategy::Compute,
            PairingStrategy::Exact,
        ] {
            let m = pair_clients(s, &fleet, &ch, 1.0, 2e-9, &mut rng);
            assert!(is_perfect_matching(10, &m), "{s:?}: {m:?}");
        }
    }

    #[test]
    fn exact_weight_dominates_greedy() {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 12;
        let mut rng = Rng::new(2);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let ch = Channel::new(ChannelConfig::default());
        let g = ClientGraph::build(&fleet, &ch, 1.0, 2e-9);
        let wg = g.matching_weight(&pair_clients(
            PairingStrategy::Greedy,
            &fleet,
            &ch,
            1.0,
            2e-9,
            &mut rng,
        ));
        let we = g.matching_weight(&pair_clients(
            PairingStrategy::Exact,
            &fleet,
            &ch,
            1.0,
            2e-9,
            &mut rng,
        ));
        assert!(we + 1e-9 >= wg);
        assert!(wg * 2.0 + 1e-9 >= we);
    }
}
