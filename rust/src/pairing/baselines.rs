//! Baseline pairing mechanisms compared in paper Table I:
//!
//! * **random** — a uniformly random perfect matching;
//! * **location-based** — greedily pair geographically nearest clients
//!   (optimizes communication time only);
//! * **computation-resource-based** — greedily pair the most
//!   compute-imbalanced clients, maximizing `(f_i − f_j)²` (optimizes
//!   compute balance only).
//!
//! Both greedy baselines are exactly Algorithm 1 run on a degenerate edge
//! weight (β=0 resp. α=0 with distance negated), which is how the paper
//! frames them.

use super::graph::{ClientGraph, Edge};
use super::greedy::greedy_matching;
use crate::sim::latency::Fleet;
use crate::util::rng::Rng;

/// Uniformly random near-perfect matching: `⌊n/2⌋` pairs; for odd `n` one
/// uniformly random client is left solo (the fleet-dynamics fallback).
pub fn random_matching(rng: &mut Rng, n: usize) -> Vec<(usize, usize)> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.chunks_exact(2).map(|c| (c[0], c[1])).collect()
}

/// Location-based pairing: maximize `−distance` greedily (nearest first).
pub fn location_matching(fleet: &Fleet) -> Vec<(usize, usize)> {
    let n = fleet.n();
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            edges.push(Edge {
                i,
                j,
                // Negated distance: greedy picks nearest pairs first.
                weight: -fleet.positions[i].dist(&fleet.positions[j]),
            });
        }
    }
    greedy_matching(&ClientGraph { n, edges })
}

/// Computation-resource-based pairing: maximize `(Δf)²` greedily.
pub fn compute_matching(fleet: &Fleet) -> Vec<(usize, usize)> {
    let n = fleet.n();
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for i in 0..n {
        for j in (i + 1)..n {
            let df = (fleet.freqs_hz[i] - fleet.freqs_hz[j]) / 1e9;
            edges.push(Edge {
                i,
                j,
                weight: df * df,
            });
        }
    }
    greedy_matching(&ClientGraph { n, edges })
}

#[cfg(test)]
mod tests {
    use super::super::graph::is_perfect_matching;
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::util::proptest::{check, gen_usize};

    fn fleet(n: usize, seed: u64) -> Fleet {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        Fleet::sample(&cfg, &mut Rng::new(seed))
    }

    #[test]
    fn random_is_valid_and_varies() {
        let mut rng = Rng::new(1);
        let a = random_matching(&mut rng, 20);
        let b = random_matching(&mut rng, 20);
        assert!(is_perfect_matching(20, &a));
        assert!(is_perfect_matching(20, &b));
        assert_ne!(a, b, "two draws identical — astronomically unlikely");
    }

    #[test]
    fn property_random_always_valid() {
        check(50, gen_usize(1, 12), |&half| {
            let mut rng = Rng::new(half as u64);
            is_perfect_matching(half * 2, &random_matching(&mut rng, half * 2))
        });
    }

    #[test]
    fn random_odd_n_leaves_one_solo() {
        // Regression for the former even-n assert: n = 7 must produce three
        // pairs and exactly one uncovered client.
        let mut rng = Rng::new(11);
        for _ in 0..20 {
            let m = random_matching(&mut rng, 7);
            assert_eq!(m.len(), 3);
            assert!(is_perfect_matching(7, &m), "{m:?}");
            assert_eq!(super::super::graph::uncovered(7, &m).len(), 1);
        }
    }

    #[test]
    fn location_pairs_nearest_first() {
        let f = fleet(6, 2);
        let m = location_matching(&f);
        assert!(is_perfect_matching(6, &m));
        // The globally nearest pair must be matched together (greedy head).
        let mut best = (0, 1);
        let mut best_d = f64::INFINITY;
        for i in 0..6 {
            for j in (i + 1)..6 {
                let d = f.positions[i].dist(&f.positions[j]);
                if d < best_d {
                    best_d = d;
                    best = (i, j);
                }
            }
        }
        assert!(m.contains(&best), "{m:?} missing nearest pair {best:?}");
    }

    #[test]
    fn compute_pairs_extremes_first() {
        let f = fleet(6, 3);
        let m = compute_matching(&f);
        assert!(is_perfect_matching(6, &m));
        // Fastest and slowest client must be paired (largest (Δf)²).
        let fastest = (0..6)
            .max_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let slowest = (0..6)
            .min_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let want = (fastest.min(slowest), fastest.max(slowest));
        assert!(m.contains(&want), "{m:?} missing extreme pair {want:?}");
    }

    #[test]
    fn location_mean_distance_below_random() {
        let f = fleet(20, 4);
        let loc = location_matching(&f);
        let mut rng = Rng::new(5);
        let mean_d = |m: &[(usize, usize)]| {
            m.iter()
                .map(|&(a, b)| f.positions[a].dist(&f.positions[b]))
                .sum::<f64>()
                / m.len() as f64
        };
        let rand_avg: f64 = (0..20)
            .map(|_| mean_d(&random_matching(&mut rng, 20)))
            .sum::<f64>()
            / 20.0;
        assert!(
            mean_d(&loc) < rand_avg,
            "location {} !< random {}",
            mean_d(&loc),
            rand_avg
        );
    }

    #[test]
    fn compute_mean_gap_above_random() {
        let f = fleet(20, 6);
        let cmp = compute_matching(&f);
        let mut rng = Rng::new(7);
        let mean_gap = |m: &[(usize, usize)]| {
            m.iter()
                .map(|&(a, b)| ((f.freqs_hz[a] - f.freqs_hz[b]) / 1e9).powi(2))
                .sum::<f64>()
                / m.len() as f64
        };
        let rand_avg: f64 = (0..20)
            .map(|_| mean_gap(&random_matching(&mut rng, 20)))
            .sum::<f64>()
            / 20.0;
        assert!(mean_gap(&cmp) > rand_avg);
    }
}
