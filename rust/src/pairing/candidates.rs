//! Sparse candidate-graph backend — the fleet-scale alternative to the
//! paper's complete eq. (5) graph.
//!
//! `ClientGraph::build` materializes all O(n²) edges, which caps the fleet at
//! a few hundred clients. [`SparseCandidateGraph`] instead generates O(n·k)
//! candidate edges per round and evaluates their weights lazily through
//! `sim::channel`, never touching a rate or distance matrix:
//!
//! * **grid-local candidates** — each client's `k_near` nearest neighbours,
//!   found by expanding rings over a [`SpatialGrid`] (the β·r_ij term of
//!   eq. (5) decays with distance, so heavy edges are short edges);
//! * **frequency-band candidates** — `k_freq` clients around each client's
//!   *mirrored* rank in the CPU-frequency ordering (rank `r` ↔ rank
//!   `m−1−r`), so the α·(f_i−f_j)² term is never starved when the best
//!   compute-complement happens to sit across the disk.
//!
//! The same machinery serves the Table-I baselines through
//! [`EdgeWeightSpec`]: location-based pairing is grid-candidates-only with
//! `−distance` weights, compute-based pairing is frequency-band-only with
//! `(Δf)²` weights.
//!
//! With `k_near ≥ n−1` the candidate set degenerates to the complete graph
//! and [`match_candidates`] reproduces the dense greedy matching **exactly**
//! (same shared weight function, same sort, same tie-breaks) — the
//! equivalence property `rust/tests/scale.rs` pins down.

use super::graph::{eq5_weight, CandidateGraph, Edge};
use super::greedy::pick_edges;
use super::repair::Matching;
use crate::config::PairingStrategy;
use crate::sim::channel::Channel;
use crate::sim::geometry::SpatialGrid;
use crate::sim::latency::Fleet;
use crate::split::SplitCostModel;
use crate::telemetry::registry::Counter;

/// Per-client cap on grid cells scanned while hunting for `k_near`
/// candidates — bounds the ring walk when members are sparse in the grid
/// (e.g. a small repair pool spread over a metro-scale disk).
const MAX_SCAN_CELLS: usize = 4096;

/// Which edge weight a sparse graph evaluates — eq. (5) for the paper's
/// mechanism, one of its degenerate baseline forms (Table I), or the split
/// planner's predicted pair latency (pairing/splitting co-design,
/// DESIGN.md §7).
#[derive(Clone, Copy, Debug)]
pub enum EdgeWeightSpec<'a> {
    /// `ε_ij = α·(Δf GHz)² + β·r_ij` — Greedy / Exact.
    Eq5 { alpha: f64, beta: f64 },
    /// `−‖p_i − p_j‖` — the location-based baseline (nearest first).
    NegDistance,
    /// `(Δf GHz)²` — the computation-resource baseline (extremes first).
    FreqGap,
    /// `−T̂_ij` — the negated *optimized* pair round seconds predicted by a
    /// split planner ([`SplitCostModel`]): the heaviest edge is the fastest
    /// pair, so matching and cut selection optimize the same objective.
    SplitCost(&'a SplitCostModel),
}

impl PartialEq for EdgeWeightSpec<'_> {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (
                EdgeWeightSpec::Eq5 { alpha: a1, beta: b1 },
                EdgeWeightSpec::Eq5 { alpha: a2, beta: b2 },
            ) => a1 == a2 && b1 == b2,
            (EdgeWeightSpec::NegDistance, EdgeWeightSpec::NegDistance) => true,
            (EdgeWeightSpec::FreqGap, EdgeWeightSpec::FreqGap) => true,
            (EdgeWeightSpec::SplitCost(m1), EdgeWeightSpec::SplitCost(m2)) => {
                std::ptr::eq(*m1, *m2)
            }
            _ => false,
        }
    }
}

impl<'a> EdgeWeightSpec<'a> {
    /// The weight a configured pairing strategy optimizes (`None` for
    /// Random, which never evaluates edges; Exact maps to eq. (5) because its
    /// fleet-scale fallback is the greedy matcher on the same objective).
    pub fn for_strategy(
        strategy: PairingStrategy,
        alpha: f64,
        beta: f64,
    ) -> Option<EdgeWeightSpec<'static>> {
        match strategy {
            PairingStrategy::Greedy | PairingStrategy::Exact => {
                Some(EdgeWeightSpec::Eq5 { alpha, beta })
            }
            PairingStrategy::Location => Some(EdgeWeightSpec::NegDistance),
            PairingStrategy::Compute => Some(EdgeWeightSpec::FreqGap),
            PairingStrategy::Random => None,
        }
    }

    /// [`EdgeWeightSpec::for_strategy`] with an optional split-cost model:
    /// when present, the latency-optimizing mechanisms (Greedy / Exact)
    /// switch from the eq. (5) proxy to the planner's predicted pair
    /// latency. Baselines keep their own degenerate objectives.
    pub fn for_strategy_with(
        strategy: PairingStrategy,
        alpha: f64,
        beta: f64,
        cost: Option<&'a SplitCostModel>,
    ) -> Option<EdgeWeightSpec<'a>> {
        match (strategy, cost) {
            (PairingStrategy::Greedy | PairingStrategy::Exact, Some(m)) => {
                Some(EdgeWeightSpec::SplitCost(m))
            }
            _ => Self::for_strategy(strategy, alpha, beta),
        }
    }

    /// Evaluate the weight of `(a, b)` from live fleet/channel state.
    #[inline]
    pub fn weight(&self, fleet: &Fleet, channel: &Channel, a: usize, b: usize) -> f64 {
        match *self {
            EdgeWeightSpec::Eq5 { alpha, beta } => {
                let rate = channel.rate(&fleet.positions[a], &fleet.positions[b]);
                eq5_weight(alpha, beta, fleet.freqs_hz[a], fleet.freqs_hz[b], rate)
            }
            EdgeWeightSpec::NegDistance => -fleet.positions[a].dist(&fleet.positions[b]),
            EdgeWeightSpec::FreqGap => {
                let df = (fleet.freqs_hz[a] - fleet.freqs_hz[b]) / 1e9;
                df * df
            }
            EdgeWeightSpec::SplitCost(model) => -model.predicted_pair_s(fleet, channel, a, b),
        }
    }

    /// Does this weight benefit from geometric (grid) candidates?
    fn uses_grid(&self) -> bool {
        !matches!(self, EdgeWeightSpec::FreqGap)
    }

    /// Does this weight benefit from frequency-band candidates?
    fn uses_freq_band(&self) -> bool {
        !matches!(self, EdgeWeightSpec::NegDistance)
    }
}

/// Sparse candidate graph over a member subset of a fleet. Vertex ids are the
/// fleet's own indices (universe ids when built over `FleetDynamics`' fleet,
/// compact ids when built over a `Fleet::subset`).
pub struct SparseCandidateGraph<'a> {
    fleet: &'a Fleet,
    channel: &'a Channel,
    spec: EdgeWeightSpec<'a>,
    edges: Vec<Edge>,
}

impl<'a> SparseCandidateGraph<'a> {
    /// Build over the whole fleet (ids `0..fleet.n()`), constructing a
    /// throwaway grid sized to the fleet's bounding box.
    pub fn build(
        fleet: &'a Fleet,
        channel: &'a Channel,
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        let members: Vec<usize> = (0..fleet.n()).collect();
        Self::over_pool(fleet, channel, &members, spec, k_near, k_freq)
    }

    /// Build over an explicit member subset with a private grid containing
    /// only those members — the repair path's "grid-local candidates *within
    /// the pool*" (ids stay the fleet's own indices).
    pub fn over_pool(
        fleet: &'a Fleet,
        channel: &'a Channel,
        pool: &[usize],
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        let extent = pool
            .iter()
            .map(|&c| fleet.positions[c].x.abs().max(fleet.positions[c].y.abs()))
            .fold(1.0f64, f64::max);
        let mut grid = SpatialGrid::new(extent, pool.len());
        for &c in pool {
            grid.insert(c, fleet.positions[c]);
        }
        Self::over_members(fleet, channel, &grid, pool, spec, k_near, k_freq)
    }

    /// Build over an explicit member subset using an existing grid (e.g. the
    /// incrementally-maintained `FleetDynamics` grid). `members` must be a
    /// subset of the grid's contents; non-member grid occupants are filtered
    /// out of the candidate lists.
    #[allow(clippy::too_many_arguments)]
    pub fn over_members(
        fleet: &'a Fleet,
        channel: &'a Channel,
        grid: &SpatialGrid,
        members: &[usize],
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        let n = fleet.n();
        let m = members.len();
        let mut in_members = vec![false; n];
        for &c in members {
            in_members[c] = true;
        }
        // Frequency ordering over the members (ties broken by id so the
        // candidate sets are deterministic).
        let mut by_freq: Vec<usize> = members.to_vec();
        by_freq.sort_by(|&a, &b| {
            fleet.freqs_hz[a]
                .partial_cmp(&fleet.freqs_hz[b])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut rank = vec![usize::MAX; n];
        for (r, &c) in by_freq.iter().enumerate() {
            rank[c] = r;
        }
        let mut cand: Vec<(usize, usize)> = Vec::with_capacity(m * (k_near + k_freq));
        for &i in members {
            if spec.uses_grid() && k_near > 0 {
                for j in nearest_in_grid(grid, fleet, &in_members, i, k_near) {
                    cand.push((i.min(j), i.max(j)));
                }
            }
            if spec.uses_freq_band() && k_freq > 0 && m > 1 {
                // Complementary band: partners around the *mirrored* rank
                // m−1−r, so every client — not just the global extremes —
                // sees a large |Δf| candidate (rank r pairing with rank
                // m−1−r is the |Δf|-maximizing matching of the sorted
                // list). Expanding around one shared extreme instead would
                // give all edges to ~2·k_freq hub clients and starve the
                // rest of the fleet of α-term candidates.
                let r = rank[i];
                let mirror = m - 1 - r;
                let mut taken = 0;
                let mut step = 0usize;
                while taken < k_freq && step < 2 * m {
                    // ranks mirror, mirror−1, mirror+1, mirror−2, …
                    let delta = (step + 1) / 2;
                    let cr = if step % 2 == 0 {
                        mirror.checked_add(delta)
                    } else {
                        mirror.checked_sub(delta)
                    };
                    step += 1;
                    match cr {
                        Some(cr) if cr < m && cr != r => {
                            let j = by_freq[cr];
                            cand.push((i.min(j), i.max(j)));
                            taken += 1;
                        }
                        _ => {}
                    }
                }
            }
        }
        cand.sort_unstable();
        cand.dedup();
        let edges: Vec<Edge> = cand
            .into_iter()
            .map(|(i, j)| Edge {
                i,
                j,
                weight: spec.weight(fleet, channel, i, j),
            })
            .collect();
        crate::tm_count!(Counter::CandidateEdges, edges.len() as u64);
        SparseCandidateGraph {
            fleet,
            channel,
            spec,
            edges,
        }
    }

    /// The generated candidate edges (for diagnostics and the scaling tests —
    /// length is O(members·k), never O(n²)).
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }
}

impl CandidateGraph for SparseCandidateGraph<'_> {
    fn n(&self) -> usize {
        self.fleet.n()
    }

    fn weight(&self, a: usize, b: usize) -> f64 {
        self.spec.weight(self.fleet, self.channel, a, b)
    }

    fn candidate_edges(&self) -> &[Edge] {
        &self.edges
    }
}

/// `k` nearest members to `i`, by expanding grid rings, then keeping the `k`
/// closest by exact distance. The walk stops only once the current k-th-best
/// distance rules out everything unscanned: after ring `R`, any client in
/// ring `R+1` or beyond is ≥ `R·cell_m` from `i`, so `kth ≤ R·cell_m` proves
/// no nearer client remains (merely "one ring past the ring that satisfied
/// `k`" is not enough — a diagonal find can be farther than a straight-line
/// client two rings out).
fn nearest_in_grid(
    grid: &SpatialGrid,
    fleet: &Fleet,
    in_members: &[bool],
    i: usize,
    k: usize,
) -> Vec<usize> {
    if k == 0 {
        return Vec::new();
    }
    let (cx, cy) = grid.cell_xy(&fleet.positions[i]);
    let mut found: Vec<(f64, usize)> = Vec::with_capacity(k * 2);
    let mut scanned = 0usize;
    for ring in 0.. {
        let visited = grid.for_ring(cx, cy, ring, |cell| {
            for &c in cell {
                if c != i && in_members[c] {
                    found.push((fleet.positions[i].dist(&fleet.positions[c]), c));
                }
            }
        });
        scanned += visited;
        if visited == 0 {
            break; // ring fully outside the grid — nothing left to scan
        }
        if found.len() >= k {
            let cmp = |a: &(f64, usize), b: &(f64, usize)| {
                a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1))
            };
            found.select_nth_unstable_by(k - 1, cmp);
            if found[k - 1].0 <= ring as f64 * grid.cell_m() {
                break;
            }
        }
        if scanned >= MAX_SCAN_CELLS {
            break; // sparse membership: fall back to whatever we found
        }
    }
    found.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    found.truncate(k);
    found.into_iter().map(|(_, c)| c).collect()
}

/// Greedy matching over a candidate graph, completed to a **near-perfect
/// matching** of `members`: a sparse graph can leave several vertices
/// uncovered (no surviving candidate edge), so leftovers are paired up
/// deterministically by ascending id; at most one client stays solo.
///
/// `members` must be exactly the vertex set the graph's edges were generated
/// over. On a complete candidate set (dense graph, or sparse with
/// `k_near ≥ n−1`) the completion step is a no-op and the pair list equals
/// `greedy_matching`'s output verbatim.
pub fn match_candidates<G: CandidateGraph + ?Sized>(graph: &G, members: &[usize]) -> Matching {
    let mut pairs = pick_edges(graph.candidate_edges(), graph.n());
    let mut covered = vec![false; graph.n()];
    for &(a, b) in &pairs {
        covered[a] = true;
        covered[b] = true;
    }
    let mut leftovers: Vec<usize> = members.iter().copied().filter(|&c| !covered[c]).collect();
    leftovers.sort_unstable();
    let mut chunks = leftovers.chunks_exact(2);
    for c in chunks.by_ref() {
        pairs.push((c[0], c[1]));
    }
    let solos = chunks.remainder().to_vec();
    Matching { pairs, solos }
}

#[cfg(test)]
mod tests {
    use super::super::graph::{is_perfect_matching, ClientGraph};
    use super::super::greedy::greedy_matching;
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::util::rng::Rng;

    fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        (
            Fleet::sample(&cfg, &mut Rng::new(seed)),
            Channel::new(ChannelConfig::default()),
        )
    }

    #[test]
    fn sparse_with_full_k_equals_dense_greedy() {
        for n in [2usize, 5, 8, 13, 20] {
            let (f, ch) = fleet(n, n as u64);
            let dense = greedy_matching(&ClientGraph::build(&f, &ch, 1.0, 5e-10));
            let spec = EdgeWeightSpec::Eq5 {
                alpha: 1.0,
                beta: 5e-10,
            };
            let g = SparseCandidateGraph::build(&f, &ch, spec, n - 1, 0);
            assert_eq!(g.edges().len(), n * (n - 1) / 2, "n={n}: not complete");
            let members: Vec<usize> = (0..n).collect();
            let m = match_candidates(&g, &members);
            assert_eq!(m.pairs, dense, "n={n}");
            assert_eq!(m.solos.len(), n % 2, "n={n}");
        }
    }

    #[test]
    fn sparse_edge_count_is_linear_in_n() {
        let (f, ch) = fleet(500, 3);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 8, 4);
        assert!(
            g.edges().len() <= 500 * 12,
            "edge count {} not O(n·k)",
            g.edges().len()
        );
        // Far below the dense count.
        assert!(g.edges().len() < 500 * 499 / 2 / 4);
        let members: Vec<usize> = (0..500).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(500, &m.pairs));
        assert!(m.solos.is_empty());
    }

    #[test]
    fn lazy_weight_matches_dense_weight() {
        let (f, ch) = fleet(12, 7);
        let dense = ClientGraph::build(&f, &ch, 1.0, 5e-10);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 11, 0);
        for e in g.edges() {
            assert_eq!(e.weight, dense.weight(e.i, e.j), "({}, {})", e.i, e.j);
            assert_eq!(CandidateGraph::weight(&g, e.i, e.j), e.weight);
        }
    }

    #[test]
    fn freq_band_candidates_bridge_distant_complements() {
        // FreqGap spec: candidates come only from the frequency band, and the
        // fastest/slowest pair must be connected regardless of geometry.
        let (f, ch) = fleet(30, 11);
        let g = SparseCandidateGraph::build(&f, &ch, EdgeWeightSpec::FreqGap, 0, 4);
        let fastest = (0..30)
            .max_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let slowest = (0..30)
            .min_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let want = (fastest.min(slowest), fastest.max(slowest));
        assert!(
            g.edges().iter().any(|e| (e.i, e.j) == want),
            "extreme pair {want:?} missing from freq-band candidates"
        );
        let members: Vec<usize> = (0..30).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(30, &m.pairs));
    }

    #[test]
    fn freq_band_covers_every_client() {
        // Mirrored-rank band: every client gets an incident frequency
        // candidate. Expanding around one shared extreme instead would give
        // all edges to ~2·k_freq hub clients and reduce the compute baseline
        // to id-order completion pairs at scale.
        let (f, ch) = fleet(40, 21);
        let g = SparseCandidateGraph::build(&f, &ch, EdgeWeightSpec::FreqGap, 0, 2);
        let mut deg = vec![0usize; 40];
        for e in g.edges() {
            deg[e.i] += 1;
            deg[e.j] += 1;
        }
        assert!(deg.iter().all(|&d| d >= 1), "starved client: {deg:?}");
        let members: Vec<usize> = (0..40).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(40, &m.pairs));
    }

    #[test]
    fn nearest_in_grid_matches_brute_force() {
        // The ring walk's distance-bound stop rule must return exactly the k
        // nearest (a diagonal find can be farther than a straight-line
        // client two rings out — the naive "one ring past full" rule fails).
        let (f, _ch) = fleet(200, 19);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let in_members = vec![true; 200];
        for i in [0usize, 7, 42, 199] {
            for k in [1usize, 3, 8] {
                let got = nearest_in_grid(&grid, &f, &in_members, i, k);
                let mut want: Vec<(f64, usize)> = (0..200)
                    .filter(|&c| c != i)
                    .map(|c| (f.positions[i].dist(&f.positions[c]), c))
                    .collect();
                want.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
                let want: Vec<usize> = want.into_iter().take(k).map(|(_, c)| c).collect();
                assert_eq!(got, want, "i={i} k={k}");
            }
        }
    }

    #[test]
    fn over_members_respects_subset() {
        let (f, ch) = fleet(20, 13);
        let grid = crate::sim::geometry::SpatialGrid::build(&f.positions, 50.0);
        let members: Vec<usize> = (0..20).filter(|c| c % 2 == 0).collect();
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::over_members(&f, &ch, &grid, &members, spec, 4, 2);
        for e in g.edges() {
            assert!(e.i % 2 == 0 && e.j % 2 == 0, "non-member edge {e:?}");
        }
        let m = match_candidates(&g, &members);
        assert!(m.is_valid_over(&members), "{m:?}");
        assert_eq!(m.pairs.len(), 5);
    }

    #[test]
    fn completion_pairs_isolated_members() {
        // A graph with zero candidate edges still yields a near-perfect
        // matching: every pair comes from the deterministic completion.
        let (f, ch) = fleet(7, 17);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 0, 0);
        assert!(g.edges().is_empty());
        let members: Vec<usize> = (0..7).collect();
        let m = match_candidates(&g, &members);
        assert_eq!(m.pairs, vec![(0, 1), (2, 3), (4, 5)]);
        assert_eq!(m.solos, vec![6]);
    }
}
