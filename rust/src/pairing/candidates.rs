//! Sparse candidate-graph backend — the fleet-scale alternative to the
//! paper's complete eq. (5) graph.
//!
//! `ClientGraph::build` materializes all O(n²) edges, which caps the fleet at
//! a few hundred clients. [`SparseCandidateGraph`] instead generates O(n·k)
//! candidate edges per round and evaluates their weights lazily through
//! `sim::channel`, never touching a rate or distance matrix:
//!
//! * **grid-local candidates** — each client's `k_near` nearest neighbours,
//!   found by expanding rings over a [`SpatialGrid`] (the β·r_ij term of
//!   eq. (5) decays with distance, so heavy edges are short edges);
//! * **frequency-band candidates** — `k_freq` clients around each client's
//!   *mirrored* rank in the CPU-frequency ordering (rank `r` ↔ rank
//!   `m−1−r`), so the α·(f_i−f_j)² term is never starved when the best
//!   compute-complement happens to sit across the disk.
//!
//! The same machinery serves the Table-I baselines through
//! [`EdgeWeightSpec`]: location-based pairing is grid-candidates-only with
//! `−distance` weights, compute-based pairing is frequency-band-only with
//! `(Δf)²` weights.
//!
//! With `k_near ≥ n−1` the candidate set degenerates to the complete graph
//! and [`match_candidates`] reproduces the dense greedy matching **exactly**
//! (same shared weight function, same sort, same tie-breaks) — the
//! equivalence property `rust/tests/scale.rs` pins down.

use super::graph::{eq5_weight, CandidateGraph, Edge};
use super::greedy::pick_edges;
use super::repair::Matching;
use crate::config::PairingStrategy;
use crate::sim::channel::Channel;
use crate::sim::geometry::SpatialGrid;
use crate::sim::latency::Fleet;
use crate::split::SplitCostModel;
use crate::telemetry::registry::Counter;
use crate::util::bitset::BitSet;
use crate::util::pool::FixedPool;

/// Per-client cap on grid cells scanned while hunting for `k_near`
/// candidates — bounds the ring walk when members are sparse in the grid
/// (e.g. a small repair pool spread over a metro-scale disk).
const MAX_SCAN_CELLS: usize = 4096;

/// Members per parallel candidate-generation chunk. The chunk decomposition
/// is **fixed-size**, not split per worker: `FixedPool::map` over chunk
/// *indices* concatenates identical output at any `--threads`, which is what
/// keeps the candidate list (and everything downstream) bit-identical across
/// thread counts.
const GEN_CHUNK: usize = 4096;

/// Which edge weight a sparse graph evaluates — eq. (5) for the paper's
/// mechanism, one of its degenerate baseline forms (Table I), or the split
/// planner's predicted pair latency (pairing/splitting co-design,
/// DESIGN.md §7).
#[derive(Clone, Copy, Debug)]
pub enum EdgeWeightSpec<'a> {
    /// `ε_ij = α·(Δf GHz)² + β·r_ij` — Greedy / Exact.
    Eq5 { alpha: f64, beta: f64 },
    /// `−‖p_i − p_j‖` — the location-based baseline (nearest first).
    NegDistance,
    /// `(Δf GHz)²` — the computation-resource baseline (extremes first).
    FreqGap,
    /// `−T̂_ij` — the negated *optimized* pair round seconds predicted by a
    /// split planner ([`SplitCostModel`]): the heaviest edge is the fastest
    /// pair, so matching and cut selection optimize the same objective.
    SplitCost(&'a SplitCostModel),
}

impl PartialEq for EdgeWeightSpec<'_> {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (
                EdgeWeightSpec::Eq5 { alpha: a1, beta: b1 },
                EdgeWeightSpec::Eq5 { alpha: a2, beta: b2 },
            ) => a1 == a2 && b1 == b2,
            (EdgeWeightSpec::NegDistance, EdgeWeightSpec::NegDistance) => true,
            (EdgeWeightSpec::FreqGap, EdgeWeightSpec::FreqGap) => true,
            (EdgeWeightSpec::SplitCost(m1), EdgeWeightSpec::SplitCost(m2)) => {
                std::ptr::eq(*m1, *m2)
            }
            _ => false,
        }
    }
}

impl<'a> EdgeWeightSpec<'a> {
    /// The weight a configured pairing strategy optimizes (`None` for
    /// Random, which never evaluates edges; Exact maps to eq. (5) because its
    /// fleet-scale fallback is the greedy matcher on the same objective).
    pub fn for_strategy(
        strategy: PairingStrategy,
        alpha: f64,
        beta: f64,
    ) -> Option<EdgeWeightSpec<'static>> {
        match strategy {
            PairingStrategy::Greedy | PairingStrategy::Exact => {
                Some(EdgeWeightSpec::Eq5 { alpha, beta })
            }
            PairingStrategy::Location => Some(EdgeWeightSpec::NegDistance),
            PairingStrategy::Compute => Some(EdgeWeightSpec::FreqGap),
            PairingStrategy::Random => None,
        }
    }

    /// [`EdgeWeightSpec::for_strategy`] with an optional split-cost model:
    /// when present, the latency-optimizing mechanisms (Greedy / Exact)
    /// switch from the eq. (5) proxy to the planner's predicted pair
    /// latency. Baselines keep their own degenerate objectives.
    pub fn for_strategy_with(
        strategy: PairingStrategy,
        alpha: f64,
        beta: f64,
        cost: Option<&'a SplitCostModel>,
    ) -> Option<EdgeWeightSpec<'a>> {
        match (strategy, cost) {
            (PairingStrategy::Greedy | PairingStrategy::Exact, Some(m)) => {
                Some(EdgeWeightSpec::SplitCost(m))
            }
            _ => Self::for_strategy(strategy, alpha, beta),
        }
    }

    /// Evaluate the weight of `(a, b)` from live fleet/channel state.
    #[inline]
    pub fn weight(&self, fleet: &Fleet, channel: &Channel, a: usize, b: usize) -> f64 {
        match *self {
            EdgeWeightSpec::Eq5 { alpha, beta } => {
                let rate = channel.rate(&fleet.positions[a], &fleet.positions[b]);
                eq5_weight(alpha, beta, fleet.freqs_hz[a], fleet.freqs_hz[b], rate)
            }
            EdgeWeightSpec::NegDistance => -fleet.positions[a].dist(&fleet.positions[b]),
            EdgeWeightSpec::FreqGap => {
                let df = (fleet.freqs_hz[a] - fleet.freqs_hz[b]) / 1e9;
                df * df
            }
            EdgeWeightSpec::SplitCost(model) => -model.predicted_pair_s(fleet, channel, a, b),
        }
    }

    /// Does this weight benefit from geometric (grid) candidates?
    pub(crate) fn uses_grid(&self) -> bool {
        !matches!(self, EdgeWeightSpec::FreqGap)
    }

    /// Does this weight benefit from frequency-band candidates?
    pub(crate) fn uses_freq_band(&self) -> bool {
        !matches!(self, EdgeWeightSpec::NegDistance)
    }

    /// The `Sync` value-only core of this spec, if it has one. `SplitCost`
    /// returns `None`: its planner memoizes through a `RefCell`, so its
    /// weights must be evaluated on one thread.
    pub(crate) fn pure(&self) -> Option<PureSpec> {
        match *self {
            EdgeWeightSpec::Eq5 { alpha, beta } => Some(PureSpec::Eq5 { alpha, beta }),
            EdgeWeightSpec::NegDistance => Some(PureSpec::NegDistance),
            EdgeWeightSpec::FreqGap => Some(PureSpec::FreqGap),
            EdgeWeightSpec::SplitCost(_) => None,
        }
    }
}

/// Reference-free mirror of the non-`SplitCost` [`EdgeWeightSpec`] variants.
/// `EdgeWeightSpec` as a *type* is never `Sync` (the `SplitCost` variant
/// holds a `&SplitCostModel` whose memo is a `RefCell`), so parallel weight
/// evaluation captures this value type instead and rebuilds the spec inside
/// each worker.
#[derive(Clone, Copy, Debug)]
pub(crate) enum PureSpec {
    Eq5 { alpha: f64, beta: f64 },
    NegDistance,
    FreqGap,
}

impl PureSpec {
    #[inline]
    pub(crate) fn weight(self, fleet: &Fleet, channel: &Channel, a: usize, b: usize) -> f64 {
        let spec = match self {
            PureSpec::Eq5 { alpha, beta } => EdgeWeightSpec::Eq5 { alpha, beta },
            PureSpec::NegDistance => EdgeWeightSpec::NegDistance,
            PureSpec::FreqGap => EdgeWeightSpec::FreqGap,
        };
        spec.weight(fleet, channel, a, b)
    }
}

/// Sparse candidate graph over a member subset of a fleet. Vertex ids are the
/// fleet's own indices (universe ids when built over `FleetDynamics`' fleet,
/// compact ids when built over a `Fleet::subset`).
pub struct SparseCandidateGraph<'a> {
    fleet: &'a Fleet,
    channel: &'a Channel,
    spec: EdgeWeightSpec<'a>,
    edges: Vec<Edge>,
}

impl<'a> SparseCandidateGraph<'a> {
    /// Build over the whole fleet (ids `0..fleet.n()`), constructing a
    /// throwaway grid sized to the fleet's bounding box.
    pub fn build(
        fleet: &'a Fleet,
        channel: &'a Channel,
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        let members: Vec<usize> = (0..fleet.n()).collect();
        Self::over_pool(fleet, channel, &members, spec, k_near, k_freq)
    }

    /// Build over an explicit member subset with a private grid containing
    /// only those members — the repair path's "grid-local candidates *within
    /// the pool*" (ids stay the fleet's own indices).
    pub fn over_pool(
        fleet: &'a Fleet,
        channel: &'a Channel,
        pool: &[usize],
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        let extent = pool
            .iter()
            .map(|&c| fleet.positions[c].x.abs().max(fleet.positions[c].y.abs()))
            .fold(1.0f64, f64::max);
        let mut grid = SpatialGrid::new(extent, pool.len());
        for &c in pool {
            grid.insert(c, fleet.positions[c]);
        }
        Self::over_members(fleet, channel, &grid, pool, spec, k_near, k_freq)
    }

    /// Build over an explicit member subset using an existing grid (e.g. the
    /// incrementally-maintained `FleetDynamics` grid). `members` must be a
    /// subset of the grid's contents; non-member grid occupants are filtered
    /// out of the candidate lists.
    #[allow(clippy::too_many_arguments)]
    pub fn over_members(
        fleet: &'a Fleet,
        channel: &'a Channel,
        grid: &SpatialGrid,
        members: &[usize],
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
    ) -> SparseCandidateGraph<'a> {
        Self::over_members_pooled(
            fleet,
            channel,
            grid,
            members,
            spec,
            k_near,
            k_freq,
            &FixedPool::serial(),
        )
    }

    /// [`Self::over_members`] with candidate generation (ring walks + band
    /// walks) and weight evaluation fanned out over `pool` in fixed-size
    /// member chunks. Output is bit-identical to the serial path at any
    /// thread count: chunks are index-ordered and concatenated before the
    /// global sort+dedup, and each edge's weight is a pure function of the
    /// edge. `SplitCost` weights are evaluated serially (the planner's memo
    /// is single-threaded), but its candidate walks still parallelize.
    #[allow(clippy::too_many_arguments)]
    pub fn over_members_pooled(
        fleet: &'a Fleet,
        channel: &'a Channel,
        grid: &SpatialGrid,
        members: &[usize],
        spec: EdgeWeightSpec<'a>,
        k_near: usize,
        k_freq: usize,
        pool: &FixedPool,
    ) -> SparseCandidateGraph<'a> {
        let n = fleet.n();
        debug_assert!(n <= u32::MAX as usize);
        let m = members.len();
        let in_members = BitSet::from_ids(n, members.iter().copied());
        // Frequency ordering over the members (ties broken by id so the
        // candidate sets are deterministic).
        let by_freq = freq_order(fleet, members);
        let mut rank = vec![u32::MAX; n];
        for (r, &c) in by_freq.iter().enumerate() {
            rank[c as usize] = r as u32;
        }
        // `spec` itself is not Sync (see PureSpec); the generation workers
        // only need these two flags from it.
        let use_grid = spec.uses_grid() && k_near > 0;
        let use_band = spec.uses_freq_band() && k_freq > 0 && m > 1;
        let gen_chunk = |ci: usize| -> Vec<(u32, u32)> {
            let lo = ci * GEN_CHUNK;
            let hi = (lo + GEN_CHUNK).min(m);
            let mut out: Vec<(u32, u32)> = Vec::with_capacity((hi - lo) * (k_near + k_freq));
            for &i in &members[lo..hi] {
                let iu = i as u32;
                if use_grid {
                    for &j in &knn_scan(grid, fleet, &in_members, i, k_near).partners {
                        out.push((iu.min(j), iu.max(j)));
                    }
                }
                if use_band {
                    freq_band_partners(&by_freq, rank[i] as usize, k_freq, |j| {
                        out.push((iu.min(j), iu.max(j)));
                    });
                }
            }
            out
        };
        let mut cand: Vec<(u32, u32)> = pool
            .map(m.div_ceil(GEN_CHUNK), gen_chunk)
            .into_iter()
            .flatten()
            .collect();
        cand.sort_unstable();
        cand.dedup();
        let edges: Vec<Edge> = match spec.pure() {
            Some(pure) if cand.len() > GEN_CHUNK => pool
                .map(cand.len().div_ceil(GEN_CHUNK), |ci| {
                    let lo = ci * GEN_CHUNK;
                    let hi = (lo + GEN_CHUNK).min(cand.len());
                    cand[lo..hi]
                        .iter()
                        .map(|&(i, j)| Edge {
                            i: i as usize,
                            j: j as usize,
                            weight: pure.weight(fleet, channel, i as usize, j as usize),
                        })
                        .collect::<Vec<Edge>>()
                })
                .into_iter()
                .flatten()
                .collect(),
            _ => cand
                .into_iter()
                .map(|(i, j)| Edge {
                    i: i as usize,
                    j: j as usize,
                    weight: spec.weight(fleet, channel, i as usize, j as usize),
                })
                .collect(),
        };
        crate::tm_count!(Counter::CandidateEdges, edges.len() as u64);
        SparseCandidateGraph {
            fleet,
            channel,
            spec,
            edges,
        }
    }

    /// The generated candidate edges (for diagnostics and the scaling tests —
    /// length is O(members·k), never O(n²)).
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }
}

impl CandidateGraph for SparseCandidateGraph<'_> {
    fn n(&self) -> usize {
        self.fleet.n()
    }

    fn weight(&self, a: usize, b: usize) -> f64 {
        self.spec.weight(self.fleet, self.channel, a, b)
    }

    fn candidate_edges(&self) -> &[Edge] {
        &self.edges
    }
}

/// Frequency ordering over `members`: ascending `(freq, id)` — the shared
/// rank axis of the band candidates (`total_cmp`: no NaN panic path).
pub(crate) fn freq_order(fleet: &Fleet, members: &[usize]) -> Vec<u32> {
    let mut by_freq: Vec<u32> = members.iter().map(|&c| c as u32).collect();
    by_freq.sort_by(|&a, &b| {
        fleet.freqs_hz[a as usize]
            .total_cmp(&fleet.freqs_hz[b as usize])
            .then(a.cmp(&b))
    });
    by_freq
}

/// Mirrored-rank frequency-band walk for the member at rank `r`:
/// partners around rank `m−1−r`, so every client — not just the global
/// extremes — sees a large |Δf| candidate (rank `r` pairing with rank
/// `m−1−r` is the |Δf|-maximizing matching of the sorted list). Expanding
/// around one shared extreme instead would give all edges to ~2·k_freq hub
/// clients and starve the rest of the fleet of α-term candidates.
///
/// One implementation shared by the batch generator and the incremental
/// matcher — the bit-for-bit equivalence property leans on there being
/// exactly one definition of this walk.
pub(crate) fn freq_band_partners(
    by_freq: &[u32],
    r: usize,
    k_freq: usize,
    mut push: impl FnMut(u32),
) {
    let m = by_freq.len();
    let mirror = m - 1 - r;
    let mut taken = 0;
    let mut step = 0usize;
    while taken < k_freq && step < 2 * m {
        // ranks mirror, mirror−1, mirror+1, mirror−2, …
        let delta = (step + 1) / 2;
        let cr = if step % 2 == 0 {
            mirror.checked_add(delta)
        } else {
            mirror.checked_sub(delta)
        };
        step += 1;
        match cr {
            Some(cr) if cr < m && cr != r => {
                push(by_freq[cr]);
                taken += 1;
            }
            _ => {}
        }
    }
}

/// One ring-walk kNN scan (see [`knn_scan`]).
pub(crate) struct KnnScan {
    /// The `k` nearest members, ascending `(distance, id)`.
    pub partners: Vec<u32>,
    /// Last ring index the walk visited. Membership changes in rings
    /// ≤ `reach + 1` of the scan's center can change `partners`; anything
    /// farther is strictly beyond the k-th distance bound and cannot — the
    /// incremental matcher's invalidation radius.
    pub reach: u16,
}

/// `k` nearest members to `i`, by expanding grid rings, then keeping the `k`
/// closest by exact distance. The walk stops only once the current k-th-best
/// distance rules out everything unscanned: after ring `R`, any client in
/// ring `R+1` or beyond is ≥ `R·cell_m` from `i`, so `kth ≤ R·cell_m` proves
/// no nearer client remains (merely "one ring past the ring that satisfied
/// `k`" is not enough — a diagonal find can be farther than a straight-line
/// client two rings out).
pub(crate) fn knn_scan(
    grid: &SpatialGrid,
    fleet: &Fleet,
    in_members: &BitSet,
    i: usize,
    k: usize,
) -> KnnScan {
    if k == 0 {
        return KnnScan { partners: Vec::new(), reach: 0 };
    }
    let (cx, cy) = grid.cell_xy(&fleet.positions[i]);
    let mut found: Vec<(f64, u32)> = Vec::with_capacity(k * 2);
    let mut scanned = 0usize;
    let mut reach = 0u16;
    for ring in 0.. {
        let visited = grid.for_ring(cx, cy, ring, |cell| {
            for &c in cell {
                let c = c as usize;
                if c != i && in_members.contains(c) {
                    found.push((fleet.positions[i].dist(&fleet.positions[c]), c as u32));
                }
            }
        });
        reach = ring as u16;
        scanned += visited;
        if visited == 0 {
            break; // ring fully outside the grid — nothing left to scan
        }
        if found.len() >= k {
            let cmp =
                |a: &(f64, u32), b: &(f64, u32)| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1));
            found.select_nth_unstable_by(k - 1, cmp);
            if found[k - 1].0 <= ring as f64 * grid.cell_m() {
                break;
            }
        }
        if scanned >= MAX_SCAN_CELLS {
            break; // sparse membership: fall back to whatever we found
        }
    }
    found.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    found.truncate(k);
    KnnScan {
        partners: found.into_iter().map(|(_, c)| c).collect(),
        reach,
    }
}

/// [`knn_scan`] returning just the partner ids (test-facing shim).
#[cfg(test)]
fn nearest_in_grid(
    grid: &SpatialGrid,
    fleet: &Fleet,
    in_members: &BitSet,
    i: usize,
    k: usize,
) -> Vec<usize> {
    knn_scan(grid, fleet, in_members, i, k)
        .partners
        .into_iter()
        .map(|c| c as usize)
        .collect()
}

/// Greedy matching over a candidate graph, completed to a **near-perfect
/// matching** of `members`: a sparse graph can leave several vertices
/// uncovered (no surviving candidate edge), so leftovers are paired up
/// deterministically by ascending id; at most one client stays solo.
///
/// `members` must be exactly the vertex set the graph's edges were generated
/// over. On a complete candidate set (dense graph, or sparse with
/// `k_near ≥ n−1`) the completion step is a no-op and the pair list equals
/// `greedy_matching`'s output verbatim.
pub fn match_candidates<G: CandidateGraph + ?Sized>(graph: &G, members: &[usize]) -> Matching {
    let mut pairs = pick_edges(graph.candidate_edges(), graph.n());
    let mut covered = vec![false; graph.n()];
    for &(a, b) in &pairs {
        covered[a] = true;
        covered[b] = true;
    }
    let mut leftovers: Vec<usize> = members.iter().copied().filter(|&c| !covered[c]).collect();
    leftovers.sort_unstable();
    let mut chunks = leftovers.chunks_exact(2);
    for c in chunks.by_ref() {
        pairs.push((c[0], c[1]));
    }
    let solos = chunks.remainder().to_vec();
    Matching { pairs, solos }
}

#[cfg(test)]
mod tests {
    use super::super::graph::{is_perfect_matching, ClientGraph};
    use super::super::greedy::greedy_matching;
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::util::rng::Rng;

    fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        (
            Fleet::sample(&cfg, &mut Rng::new(seed)),
            Channel::new(ChannelConfig::default()),
        )
    }

    #[test]
    fn sparse_with_full_k_equals_dense_greedy() {
        for n in [2usize, 5, 8, 13, 20] {
            let (f, ch) = fleet(n, n as u64);
            let dense = greedy_matching(&ClientGraph::build(&f, &ch, 1.0, 5e-10));
            let spec = EdgeWeightSpec::Eq5 {
                alpha: 1.0,
                beta: 5e-10,
            };
            let g = SparseCandidateGraph::build(&f, &ch, spec, n - 1, 0);
            assert_eq!(g.edges().len(), n * (n - 1) / 2, "n={n}: not complete");
            let members: Vec<usize> = (0..n).collect();
            let m = match_candidates(&g, &members);
            assert_eq!(m.pairs, dense, "n={n}");
            assert_eq!(m.solos.len(), n % 2, "n={n}");
        }
    }

    #[test]
    fn sparse_edge_count_is_linear_in_n() {
        let (f, ch) = fleet(500, 3);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 8, 4);
        assert!(
            g.edges().len() <= 500 * 12,
            "edge count {} not O(n·k)",
            g.edges().len()
        );
        // Far below the dense count.
        assert!(g.edges().len() < 500 * 499 / 2 / 4);
        let members: Vec<usize> = (0..500).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(500, &m.pairs));
        assert!(m.solos.is_empty());
    }

    #[test]
    fn lazy_weight_matches_dense_weight() {
        let (f, ch) = fleet(12, 7);
        let dense = ClientGraph::build(&f, &ch, 1.0, 5e-10);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 11, 0);
        for e in g.edges() {
            assert_eq!(e.weight, dense.weight(e.i, e.j), "({}, {})", e.i, e.j);
            assert_eq!(CandidateGraph::weight(&g, e.i, e.j), e.weight);
        }
    }

    #[test]
    fn freq_band_candidates_bridge_distant_complements() {
        // FreqGap spec: candidates come only from the frequency band, and the
        // fastest/slowest pair must be connected regardless of geometry.
        let (f, ch) = fleet(30, 11);
        let g = SparseCandidateGraph::build(&f, &ch, EdgeWeightSpec::FreqGap, 0, 4);
        let fastest = (0..30)
            .max_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let slowest = (0..30)
            .min_by(|&a, &b| f.freqs_hz[a].partial_cmp(&f.freqs_hz[b]).unwrap())
            .unwrap();
        let want = (fastest.min(slowest), fastest.max(slowest));
        assert!(
            g.edges().iter().any(|e| (e.i, e.j) == want),
            "extreme pair {want:?} missing from freq-band candidates"
        );
        let members: Vec<usize> = (0..30).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(30, &m.pairs));
    }

    #[test]
    fn freq_band_covers_every_client() {
        // Mirrored-rank band: every client gets an incident frequency
        // candidate. Expanding around one shared extreme instead would give
        // all edges to ~2·k_freq hub clients and reduce the compute baseline
        // to id-order completion pairs at scale.
        let (f, ch) = fleet(40, 21);
        let g = SparseCandidateGraph::build(&f, &ch, EdgeWeightSpec::FreqGap, 0, 2);
        let mut deg = vec![0usize; 40];
        for e in g.edges() {
            deg[e.i] += 1;
            deg[e.j] += 1;
        }
        assert!(deg.iter().all(|&d| d >= 1), "starved client: {deg:?}");
        let members: Vec<usize> = (0..40).collect();
        let m = match_candidates(&g, &members);
        assert!(is_perfect_matching(40, &m.pairs));
    }

    #[test]
    fn nearest_in_grid_matches_brute_force() {
        // The ring walk's distance-bound stop rule must return exactly the k
        // nearest (a diagonal find can be farther than a straight-line
        // client two rings out — the naive "one ring past full" rule fails).
        let (f, _ch) = fleet(200, 19);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let in_members = BitSet::full(200);
        for i in [0usize, 7, 42, 199] {
            for k in [1usize, 3, 8] {
                let got = nearest_in_grid(&grid, &f, &in_members, i, k);
                let mut want: Vec<(f64, usize)> = (0..200)
                    .filter(|&c| c != i)
                    .map(|c| (f.positions[i].dist(&f.positions[c]), c))
                    .collect();
                want.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let want: Vec<usize> = want.into_iter().take(k).map(|(_, c)| c).collect();
                assert_eq!(got, want, "i={i} k={k}");
            }
        }
    }

    #[test]
    fn pooled_generation_is_thread_count_invariant() {
        // Enough members for multiple GEN_CHUNK chunks, so the parallel path
        // genuinely interleaves workers. Every thread count must reproduce
        // the serial edge list bit-for-bit (ids AND weight bits).
        let (f, ch) = fleet(5000, 23);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let members: Vec<usize> = (0..5000).collect();
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let serial = SparseCandidateGraph::over_members(&f, &ch, &grid, &members, spec, 4, 2);
        for threads in [2usize, 4] {
            let pooled = SparseCandidateGraph::over_members_pooled(
                &f,
                &ch,
                &grid,
                &members,
                spec,
                4,
                2,
                &FixedPool::new(threads),
            );
            assert_eq!(pooled.edges().len(), serial.edges().len(), "threads={threads}");
            for (a, b) in pooled.edges().iter().zip(serial.edges()) {
                assert_eq!((a.i, a.j), (b.i, b.j), "threads={threads}");
                assert_eq!(
                    a.weight.to_bits(),
                    b.weight.to_bits(),
                    "threads={threads} edge ({}, {})",
                    a.i,
                    a.j
                );
            }
        }
    }

    #[test]
    fn over_members_respects_subset() {
        let (f, ch) = fleet(20, 13);
        let grid = crate::sim::geometry::SpatialGrid::build(&f.positions, 50.0);
        let members: Vec<usize> = (0..20).filter(|c| c % 2 == 0).collect();
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::over_members(&f, &ch, &grid, &members, spec, 4, 2);
        for e in g.edges() {
            assert!(e.i % 2 == 0 && e.j % 2 == 0, "non-member edge {e:?}");
        }
        let m = match_candidates(&g, &members);
        assert!(m.is_valid_over(&members), "{m:?}");
        assert_eq!(m.pairs.len(), 5);
    }

    #[test]
    fn completion_pairs_isolated_members() {
        // A graph with zero candidate edges still yields a near-perfect
        // matching: every pair comes from the deterministic completion.
        let (f, ch) = fleet(7, 17);
        let spec = EdgeWeightSpec::Eq5 {
            alpha: 1.0,
            beta: 5e-10,
        };
        let g = SparseCandidateGraph::build(&f, &ch, spec, 0, 0);
        assert!(g.edges().is_empty());
        let members: Vec<usize> = (0..7).collect();
        let m = match_candidates(&g, &members);
        assert_eq!(m.pairs, vec![(0, 1), (2, 3), (4, 5)]);
        assert_eq!(m.solos, vec![6]);
    }
}
