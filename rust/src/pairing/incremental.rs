//! Incremental cross-round matching — the persistent alternative to
//! rebuilding the sparse candidate graph from scratch every epoch
//! (DESIGN.md §10).
//!
//! The batch path ([`super::candidates`]) regenerates every member's
//! grid-kNN and frequency-band candidate lists, re-evaluates every edge
//! weight, and re-sorts the whole edge list each epoch — O(m·k) scans plus
//! O(E log E) sort even when one client departed. [`IncrementalMatcher`]
//! keeps all of that state alive between epochs:
//!
//! * per-client candidate lists (flat `u32` SoA) with the ring-walk `reach`
//!   of each kNN scan, so an epoch re-scans only clients whose scan could
//!   have changed: a membership/position change in cell `C` invalidates
//!   exactly the clients whose watch radius `reach + 1` (Chebyshev cell
//!   distance, computed by a two-pass chamfer transform) covers `C`;
//! * a reference-counted edge slab (an edge exists while ≥ 1 directed list
//!   entry references it; ≤ 4 refs: `a.near`, `a.band`, `b.near`, `b.band`);
//! * a [`BucketQueue`] holding every live edge under the order-preserving
//!   [`weight_key`] of its weight, so the greedy pick order survives between
//!   epochs and a repair epoch re-sorts only the buckets it touched.
//!
//! Change detection is **self-contained and exact**: the matcher stores the
//! raw `f64` bit patterns of every member's position and frequency plus the
//! channel-config fields, and diffs them against the live state each epoch.
//! Those bit patterns are deliberately *not* compacted to `f32` — a missed
//! change would silently break the equivalence contract below (this is the
//! "where f64 stays load-bearing" line of the fleet memory diet; everything
//! else here is `u32`/`u16`/`u8`).
//!
//! **Equivalence contract** (property-tested in
//! `rust/tests/incremental_matching.rs`): after every `update`, the returned
//! matching is bit-for-bit identical — same pair order, same solos — to
//!
//! ```text
//! match_candidates(&SparseCandidateGraph::over_members(...), members)
//! ```
//!
//! on the same state, for every weight spec and any `--threads`. The proof
//! obligations: the candidate *set* equals the union of the per-client lists
//! (refcounts make the queue exactly that union); every live edge's key is
//! the `weight_key` of its current-state weight (dirty tracking re-keys on
//! any position/frequency/channel change the spec reads); and the descending
//! queue walk visits edges in `(weight desc, (i, j) asc)` order — precisely
//! `pick_edges`' sort order, because `weight_key` is monotone and injective
//! under `total_cmp` and ties fall back to the same endpoint order.

use super::candidates::{freq_band_partners, freq_order, knn_scan, EdgeWeightSpec, KnnScan};
use super::repair::Matching;
use crate::sim::channel::Channel;
use crate::sim::geometry::{Pos, SpatialGrid};
use crate::sim::latency::Fleet;
use crate::telemetry::registry::{self, Histo};
use crate::util::bitset::BitSet;
use crate::util::bucketq::{weight_key, BucketQueue};
use crate::util::pool::FixedPool;
use std::time::Instant;

/// "No queue handle yet" — edges created this epoch carry this until the
/// deferred-weight flush assigns their key.
const NO_HANDLE: u32 = u32::MAX;

/// Clients per parallel kNN-scan chunk. Fixed-size chunks (not per-worker
/// splits) keep the concatenated scan results — and therefore every
/// downstream structure — bit-identical at any thread count.
const SCAN_CHUNK: usize = 2048;

/// Below this many scans / deferred weights, fan-out overhead beats the win;
/// run serially (results are identical either way).
const PAR_MIN: usize = 4096;

/// Hard cap on per-client list lengths (diff buffers live on the stack).
const MAX_K: usize = 64;

/// One reference-counted candidate edge (`a < b`).
#[derive(Clone, Copy)]
struct EdgeRec {
    a: u32,
    b: u32,
    /// Bucket-queue handle ([`NO_HANDLE`] until the epoch's weight flush).
    handle: u32,
    /// Epoch of the last weight refresh — dedups re-keys when several dirty
    /// clients share an edge.
    stamp: u32,
    /// Directed list references (≤ 4).
    refs: u8,
}

/// Persistent cross-round sparse matcher. See module docs.
pub struct IncrementalMatcher {
    k_near: usize,
    k_freq: usize,
    n: usize,
    epoch: u32,
    started: bool,
    // Membership.
    alive: BitSet,
    members: Vec<usize>,
    // Per-client candidate-list state (flat SoA, memory diet).
    near: Vec<u32>,
    near_len: Vec<u8>,
    reach: Vec<u16>,
    band: Vec<u32>,
    band_len: Vec<u8>,
    // Exact change-detection fingerprints (f64 bits — load-bearing).
    pos_bits: Vec<(u64, u64)>,
    freq_bits: Vec<u64>,
    chan_sig: [u64; 6],
    spec_sig: (u8, u64, u64),
    grid_sig: (usize, u64),
    // Frequency-band axis (valid while membership and freqs are unchanged).
    by_freq: Vec<u32>,
    rank: Vec<u32>,
    // Edge store: slab + per-client incidence + persistent order.
    recs: Vec<EdgeRec>,
    free_slots: Vec<u32>,
    /// `adj[c]` = `(other, slot)` sorted by `other`; each edge appears in
    /// both endpoints' lists.
    adj: Vec<Vec<(u32, u32)>>,
    queue: BucketQueue,
    /// Slots created this epoch, awaiting weight evaluation + queue insert.
    pending: Vec<u32>,
    // Solver state.
    covered: BitSet,
    matching: Matching,
    // Chebyshev distance-transform scratch (`dims × dims`).
    dist: Vec<u16>,
    /// Epochs that actually re-solved (vs returned the cached matching).
    pub solves: u64,
    /// Total kNN ring-walk scans performed (O(affected) under churn).
    pub scans: u64,
}

impl IncrementalMatcher {
    /// Matcher over a fixed universe of `n` client ids with the sparse
    /// backend's `k_near`/`k_freq` candidate budgets.
    pub fn new(n: usize, k_near: usize, k_freq: usize) -> IncrementalMatcher {
        assert!(n < u32::MAX as usize, "universe too large for u32 ids");
        assert!(
            k_near <= MAX_K && k_freq <= MAX_K,
            "candidate budgets above {MAX_K} are unsupported"
        );
        IncrementalMatcher {
            k_near,
            k_freq,
            n,
            epoch: 0,
            started: false,
            alive: BitSet::new(n),
            members: Vec::new(),
            near: vec![0; n * k_near],
            near_len: vec![0; n],
            reach: vec![0; n],
            band: vec![0; n * k_freq],
            band_len: vec![0; n],
            pos_bits: vec![(0, 0); n],
            freq_bits: vec![0; n],
            chan_sig: [0; 6],
            spec_sig: (u8::MAX, 0, 0),
            grid_sig: (0, 0),
            by_freq: Vec::new(),
            rank: vec![0; n],
            recs: Vec::new(),
            free_slots: Vec::new(),
            adj: vec![Vec::new(); n],
            queue: BucketQueue::new(),
            pending: Vec::new(),
            covered: BitSet::new(n),
            matching: Matching::default(),
            dist: Vec::new(),
            solves: 0,
            scans: 0,
        }
    }

    /// Live candidate edges currently in the queue.
    pub fn edge_count(&self) -> usize {
        self.queue.len()
    }

    /// The matching computed by the last [`Self::update`].
    pub fn matching(&self) -> &Matching {
        &self.matching
    }

    fn sig_of(spec: &EdgeWeightSpec<'_>) -> (u8, u64, u64) {
        match *spec {
            EdgeWeightSpec::Eq5 { alpha, beta } => (0, alpha.to_bits(), beta.to_bits()),
            EdgeWeightSpec::NegDistance => (1, 0, 0),
            EdgeWeightSpec::FreqGap => (2, 0, 0),
            // Model params are fixed per session; swapping models mid-session
            // requires a new matcher (the session layer never does this).
            EdgeWeightSpec::SplitCost(_) => (3, 0, 0),
        }
    }

    fn chan_sig_of(channel: &Channel) -> [u64; 6] {
        let c = channel.config();
        [
            c.bandwidth_hz.to_bits(),
            c.tx_power_w.to_bits(),
            c.noise_w.to_bits(),
            c.ref_gain.to_bits(),
            c.ref_dist_m.to_bits(),
            c.pathloss_exp.to_bits(),
        ]
    }

    fn cell_idx(grid: &SpatialGrid, p: &Pos) -> u32 {
        let (x, y) = grid.cell_xy(p);
        (y * grid.dims() + x) as u32
    }

    /// Advance the matcher to the current fleet state and return the
    /// matching over `members` (sorted ascending, deduped, ids `< n`).
    ///
    /// Everything else is self-detected: membership joins/departs (diff vs
    /// the previous epoch), moves and frequency changes (stored bit
    /// patterns), channel changes (config fingerprint). `grid` must be the
    /// same spatial index the batch path would use (the fleet-dynamics
    /// grid); `pool` parallelizes bulk scan/weight phases without affecting
    /// the result.
    pub fn update(
        &mut self,
        fleet: &Fleet,
        channel: &Channel,
        grid: &SpatialGrid,
        members: &[usize],
        spec: &EdgeWeightSpec<'_>,
        pool: &FixedPool,
    ) -> &Matching {
        let t0 = registry::enabled().then(Instant::now);
        debug_assert_eq!(fleet.n(), self.n, "fleet/universe size is fixed at construction");
        debug_assert!(members.windows(2).all(|w| w[0] < w[1]), "members must be sorted+deduped");
        debug_assert!(members.last().is_none_or(|&m| m < self.n));
        self.epoch = self.epoch.wrapping_add(1);

        // 0. Structural invalidation: a different weight spec or grid
        // geometry voids every list, reach and key — start over.
        let ssig = Self::sig_of(spec);
        let gsig = (grid.dims(), grid.cell_m().to_bits());
        if self.started && (ssig != self.spec_sig || gsig != self.grid_sig) {
            let (solves, scans) = (self.solves, self.scans);
            *self = Self::new(self.n, self.k_near, self.k_freq);
            self.solves = solves;
            self.scans = scans;
        }
        self.spec_sig = ssig;
        self.grid_sig = gsig;
        let init = !self.started;
        self.started = true;

        // 1. Membership diff vs the previous epoch.
        let old_members = std::mem::take(&mut self.members);
        let mut joined: Vec<usize> = Vec::new();
        let mut departed: Vec<usize> = Vec::new();
        {
            let (mut i, mut j) = (0usize, 0usize);
            while i < old_members.len() || j < members.len() {
                match (old_members.get(i), members.get(j)) {
                    (Some(&o), Some(&m)) if o == m => {
                        i += 1;
                        j += 1;
                    }
                    (Some(&o), Some(&m)) if o < m => {
                        departed.push(o);
                        i += 1;
                    }
                    (Some(_), Some(&m)) => {
                        joined.push(m);
                        j += 1;
                    }
                    (Some(&o), None) => {
                        departed.push(o);
                        i += 1;
                    }
                    (None, Some(&m)) => {
                        joined.push(m);
                        j += 1;
                    }
                    (None, None) => unreachable!(),
                }
            }
        }
        for &d in &departed {
            self.alive.remove(d);
        }
        for &c in &joined {
            self.alive.insert(c);
        }
        self.members = members.to_vec();
        let m = members.len();
        let membership_changed = !joined.is_empty() || !departed.is_empty();

        // 2. Position / frequency change scan. Joined clients refresh their
        // fingerprints but are excluded from `moved`/`freq_changed` (their
        // stale bits describe a previous life; they regenerate as joins).
        let mut moved: Vec<usize> = Vec::new();
        let mut freq_changed: Vec<usize> = Vec::new();
        let mut dirty_cells: Vec<u32> = Vec::new();
        {
            let mut jp = 0usize;
            for &c in members {
                let p = &fleet.positions[c];
                let pb = (p.x.to_bits(), p.y.to_bits());
                let fb = fleet.freqs_hz[c].to_bits();
                if jp < joined.len() && joined[jp] == c {
                    jp += 1;
                    self.pos_bits[c] = pb;
                    self.freq_bits[c] = fb;
                    dirty_cells.push(Self::cell_idx(grid, p));
                    continue;
                }
                if pb != self.pos_bits[c] {
                    let old = Pos {
                        x: f64::from_bits(self.pos_bits[c].0),
                        y: f64::from_bits(self.pos_bits[c].1),
                    };
                    dirty_cells.push(Self::cell_idx(grid, &old));
                    dirty_cells.push(Self::cell_idx(grid, p));
                    self.pos_bits[c] = pb;
                    moved.push(c);
                }
                if fb != self.freq_bits[c] {
                    self.freq_bits[c] = fb;
                    freq_changed.push(c);
                }
            }
        }
        // Departed clients' positions are frozen at departure, so their
        // current cell is exactly where surviving scans last saw them.
        for &d in &departed {
            dirty_cells.push(Self::cell_idx(grid, &fleet.positions[d]));
        }

        // 3. Departed clients drop their own directed references.
        for &d in &departed {
            self.drop_lists(d);
        }

        // 4. Frequency-band lists. Any membership or frequency change shifts
        // ranks and mirrors globally, so every band list regenerates; the
        // per-client diff then touches only edges that actually changed
        // (most windows slide *with* their contents).
        let use_band = spec.uses_freq_band() && self.k_freq > 0 && m > 1;
        let band_rebuild =
            use_band && (init || membership_changed || !freq_changed.is_empty());
        if band_rebuild {
            self.by_freq = freq_order(fleet, members);
            for (r, &c) in self.by_freq.iter().enumerate() {
                self.rank[c as usize] = r as u32;
            }
            let mut buf: Vec<u32> = Vec::with_capacity(self.k_freq);
            for &c in members {
                buf.clear();
                {
                    let by_freq = &self.by_freq;
                    freq_band_partners(by_freq, self.rank[c] as usize, self.k_freq, |j| {
                        buf.push(j)
                    });
                }
                self.apply_list_diff(c, true, &buf);
            }
        } else if !use_band {
            // `use_band` can flap when m crosses 1 (the batch path gates on
            // `m > 1`): stale lists would keep edges to departed partners.
            for &c in members {
                if self.band_len[c] > 0 {
                    self.apply_list_diff(c, true, &[]);
                }
            }
        }

        // 5. Grid-kNN lists: re-scan exactly the clients whose previous walk
        // could see a dirty cell. `joined` and `moved` clients made their own
        // current cell dirty, so `dist == 0` pulls them in without special
        // cases; anything at Chebyshev distance > reach + 1 provably cannot
        // have changed partners (see `KnnScan::reach`).
        let use_grid = spec.uses_grid() && self.k_near > 0;
        let mut regen: Vec<usize> = Vec::new();
        if use_grid {
            if init || moved.len() * 2 >= m {
                regen.extend_from_slice(members);
            } else if !dirty_cells.is_empty() {
                self.mark_watch(grid, &dirty_cells);
                let dist = &self.dist;
                let dims = grid.dims();
                regen.extend(members.iter().copied().filter(|&c| {
                    let (x, y) = grid.cell_xy(&fleet.positions[c]);
                    dist[y * dims + x] as u32 <= self.reach[c] as u32 + 1
                }));
            }
            if !regen.is_empty() {
                self.scans += regen.len() as u64;
                let scans: Vec<KnnScan> = {
                    let (alive, k) = (&self.alive, self.k_near);
                    let scan_one = |c: usize| knn_scan(grid, fleet, alive, c, k);
                    if regen.len() >= PAR_MIN && pool.threads() > 1 {
                        pool.map(regen.len().div_ceil(SCAN_CHUNK), |ci| {
                            let lo = ci * SCAN_CHUNK;
                            let hi = (lo + SCAN_CHUNK).min(regen.len());
                            regen[lo..hi].iter().map(|&c| scan_one(c)).collect::<Vec<_>>()
                        })
                        .into_iter()
                        .flatten()
                        .collect()
                    } else {
                        regen.iter().map(|&c| scan_one(c)).collect()
                    }
                };
                for (&c, scan) in regen.iter().zip(&scans) {
                    self.apply_list_diff(c, false, &scan.partners);
                    self.reach[c] = scan.reach;
                }
            }
        }

        // 6. Evaluate weights for edges created this epoch and admit them to
        // the queue (deferred so pure specs batch the evaluation in parallel).
        let created = !self.pending.is_empty();
        self.flush_pending(fleet, channel, spec, pool);

        // 7. Re-key surviving edges whose weight inputs changed. Only the
        // state the spec actually reads matters; recomputing an unchanged
        // weight would be a no-op, so the filters are pure savings.
        let csig = Self::chan_sig_of(channel);
        let chan_changed = csig != self.chan_sig;
        self.chan_sig = csig;
        let reads_chan = matches!(
            spec,
            EdgeWeightSpec::Eq5 { .. } | EdgeWeightSpec::SplitCost(_)
        );
        let reads_pos = !matches!(spec, EdgeWeightSpec::FreqGap);
        let reads_freq = !matches!(spec, EdgeWeightSpec::NegDistance);
        let mut rekey_targets: Vec<usize> = Vec::new();
        if reads_pos {
            rekey_targets.extend_from_slice(&moved);
        }
        if reads_freq {
            rekey_targets.extend_from_slice(&freq_changed);
        }
        let rekeyed = (chan_changed && reads_chan) || !rekey_targets.is_empty();
        if (chan_changed && reads_chan)
            || (!rekey_targets.is_empty() && rekey_targets.len() >= m / 2)
        {
            // Most edges are incident to a dirty client (or all keys are
            // stale): re-key the whole slab, batched.
            self.rekey_all(fleet, channel, spec, pool);
        } else {
            for &c in &rekey_targets {
                for t in 0..self.adj[c].len() {
                    let slot = self.adj[c][t].1 as usize;
                    if self.recs[slot].stamp == self.epoch {
                        continue;
                    }
                    self.recs[slot].stamp = self.epoch;
                    let (a, b, h) =
                        (self.recs[slot].a, self.recs[slot].b, self.recs[slot].handle);
                    let w = spec.weight(fleet, channel, a as usize, b as usize);
                    self.queue.update_key(h, weight_key(w));
                }
            }
        }

        // 8. Solve — or return the cached matching when provably nothing
        // about the candidate graph changed this epoch.
        let dirty =
            init || membership_changed || band_rebuild || !regen.is_empty() || created || rekeyed;
        if dirty {
            self.solve();
        }
        #[cfg(debug_assertions)]
        self.debug_validate();
        if let Some(t0) = t0 {
            crate::tm_observe!(Histo::MatcherEpochNanos, t0.elapsed().as_nanos() as u64);
        }
        &self.matching
    }

    /// Greedy pick over the persistent queue + ascending-id completion —
    /// exactly `match_candidates(pick_edges(...))` on the equivalent batch
    /// graph (same visit order, same completion rule).
    fn solve(&mut self) {
        self.solves += 1;
        self.covered.clear();
        let target = self.members.len() / 2;
        let mut pairs: Vec<(usize, usize)> = Vec::with_capacity(target);
        let covered = &mut self.covered;
        self.queue.for_each_desc(|_k, a, b| {
            let (a, b) = (a as usize, b as usize);
            if !covered.contains(a) && !covered.contains(b) {
                covered.insert(a);
                covered.insert(b);
                pairs.push((a, b));
                if pairs.len() == target {
                    return false;
                }
            }
            true
        });
        // Leftovers pair up by ascending id; at most one stays solo.
        let mut solos: Vec<usize> = Vec::new();
        let mut half: Option<usize> = None;
        for &c in &self.members {
            if covered.contains(c) {
                continue;
            }
            match half.take() {
                Some(p) => pairs.push((p, c)),
                None => half = Some(c),
            }
        }
        solos.extend(half);
        self.matching = Matching { pairs, solos };
    }

    /// Diff a client's stored candidate list against `new`, ref/unref the
    /// changed edges, and store the new list. Entries within a list are
    /// distinct clients, so set-diff semantics are exact.
    fn apply_list_diff(&mut self, c: usize, is_band: bool, new: &[u32]) {
        debug_assert!(new.len() <= MAX_K);
        debug_assert!(new.iter().all(|&x| self.alive.contains(x as usize)));
        let (base, olen) = if is_band {
            (c * self.k_freq, self.band_len[c] as usize)
        } else {
            (c * self.k_near, self.near_len[c] as usize)
        };
        let mut old_buf = [0u32; MAX_K];
        {
            let store = if is_band { &self.band } else { &self.near };
            old_buf[..olen].copy_from_slice(&store[base..base + olen]);
        }
        let old = &old_buf[..olen];
        if old != new {
            for &o in old {
                if !new.contains(&o) {
                    self.unref_edge(c as u32, o);
                }
            }
            for &x in new {
                if !old.contains(&x) {
                    self.ref_edge(c as u32, x);
                }
            }
        }
        let store = if is_band { &mut self.band } else { &mut self.near };
        store[base..base + new.len()].copy_from_slice(new);
        if is_band {
            self.band_len[c] = new.len() as u8;
        } else {
            self.near_len[c] = new.len() as u8;
        }
    }

    /// Release every directed reference a departing client holds.
    fn drop_lists(&mut self, d: usize) {
        for t in 0..self.near_len[d] as usize {
            let o = self.near[d * self.k_near + t];
            self.unref_edge(d as u32, o);
        }
        self.near_len[d] = 0;
        for t in 0..self.band_len[d] as usize {
            let o = self.band[d * self.k_freq + t];
            self.unref_edge(d as u32, o);
        }
        self.band_len[d] = 0;
        self.reach[d] = 0;
    }

    /// Add one directed reference to edge `(c, o)`, creating the edge (with
    /// its weight deferred to the epoch flush) on first reference.
    fn ref_edge(&mut self, c: u32, o: u32) {
        debug_assert_ne!(c, o);
        let (lo, hi) = if c < o { (c, o) } else { (o, c) };
        match self.adj[lo as usize].binary_search_by_key(&hi, |e| e.0) {
            Ok(p) => {
                let slot = self.adj[lo as usize][p].1 as usize;
                self.recs[slot].refs += 1;
                debug_assert!(self.recs[slot].refs <= 4);
            }
            Err(p) => {
                let rec = EdgeRec {
                    a: lo,
                    b: hi,
                    handle: NO_HANDLE,
                    stamp: self.epoch,
                    refs: 1,
                };
                let slot = match self.free_slots.pop() {
                    Some(s) => {
                        self.recs[s as usize] = rec;
                        s
                    }
                    None => {
                        self.recs.push(rec);
                        (self.recs.len() - 1) as u32
                    }
                };
                self.adj[lo as usize].insert(p, (hi, slot));
                let q = self.adj[hi as usize]
                    .binary_search_by_key(&lo, |e| e.0)
                    .unwrap_err();
                self.adj[hi as usize].insert(q, (lo, slot));
                self.pending.push(slot);
            }
        }
    }

    /// Drop one directed reference; the last reference removes the edge from
    /// the queue, the incidence lists and the slab.
    fn unref_edge(&mut self, c: u32, o: u32) {
        let (lo, hi) = if c < o { (c, o) } else { (o, c) };
        let p = self.adj[lo as usize]
            .binary_search_by_key(&hi, |e| e.0)
            .expect("unref of absent edge");
        let slot = self.adj[lo as usize][p].1 as usize;
        self.recs[slot].refs -= 1;
        if self.recs[slot].refs == 0 {
            let handle = self.recs[slot].handle;
            if handle != NO_HANDLE {
                self.queue.remove(handle);
            }
            self.adj[lo as usize].remove(p);
            let q = self.adj[hi as usize]
                .binary_search_by_key(&lo, |e| e.0)
                .expect("adj symmetry");
            self.adj[hi as usize].remove(q);
            self.free_slots.push(slot as u32);
        }
    }

    /// Evaluate this epoch's new edges and insert them into the queue. Pure
    /// specs batch the weight evaluation across `pool` in fixed chunks;
    /// `SplitCost` (single-threaded memo) evaluates serially. Entries whose
    /// edge died again within the epoch, or whose slot was re-created and
    /// already flushed, are skipped.
    fn flush_pending(
        &mut self,
        fleet: &Fleet,
        channel: &Channel,
        spec: &EdgeWeightSpec<'_>,
        pool: &FixedPool,
    ) {
        if self.pending.is_empty() {
            return;
        }
        let keys: Option<Vec<u64>> = match spec.pure() {
            Some(pure) if self.pending.len() >= PAR_MIN && pool.threads() > 1 => {
                let (pending, recs) = (&self.pending, &self.recs);
                Some(
                    pool.map(pending.len().div_ceil(SCAN_CHUNK), |ci| {
                        let lo = ci * SCAN_CHUNK;
                        let hi = (lo + SCAN_CHUNK).min(pending.len());
                        pending[lo..hi]
                            .iter()
                            .map(|&s| {
                                let r = &recs[s as usize];
                                // Dead slots get a garbage (but in-range) key
                                // that the apply loop below never reads.
                                weight_key(pure.weight(
                                    fleet,
                                    channel,
                                    r.a as usize,
                                    r.b as usize,
                                ))
                            })
                            .collect::<Vec<u64>>()
                    })
                    .into_iter()
                    .flatten()
                    .collect(),
                )
            }
            _ => None,
        };
        let pending = std::mem::take(&mut self.pending);
        for (ix, &slot) in pending.iter().enumerate() {
            let rec = self.recs[slot as usize];
            if rec.refs == 0 || rec.handle != NO_HANDLE {
                continue;
            }
            let key = match &keys {
                Some(ks) => ks[ix],
                None => weight_key(spec.weight(fleet, channel, rec.a as usize, rec.b as usize)),
            };
            self.recs[slot as usize].handle = self.queue.insert(key, rec.a, rec.b);
        }
        self.pending = pending;
        self.pending.clear();
    }

    /// Re-key every live edge not already refreshed this epoch (channel
    /// change, or a dirty-client set so large that per-incidence walking
    /// would visit most edges anyway).
    fn rekey_all(
        &mut self,
        fleet: &Fleet,
        channel: &Channel,
        spec: &EdgeWeightSpec<'_>,
        pool: &FixedPool,
    ) {
        let epoch = self.epoch;
        let live: Vec<u32> = (0..self.recs.len() as u32)
            .filter(|&s| {
                let r = &self.recs[s as usize];
                r.refs > 0 && r.stamp != epoch
            })
            .collect();
        if live.is_empty() {
            return;
        }
        let keys: Vec<u64> = match spec.pure() {
            Some(pure) if live.len() >= PAR_MIN && pool.threads() > 1 => {
                let recs = &self.recs;
                pool.map(live.len().div_ceil(SCAN_CHUNK), |ci| {
                    let lo = ci * SCAN_CHUNK;
                    let hi = (lo + SCAN_CHUNK).min(live.len());
                    live[lo..hi]
                        .iter()
                        .map(|&s| {
                            let r = &recs[s as usize];
                            weight_key(pure.weight(fleet, channel, r.a as usize, r.b as usize))
                        })
                        .collect::<Vec<u64>>()
                })
                .into_iter()
                .flatten()
                .collect()
            }
            _ => live
                .iter()
                .map(|&s| {
                    let r = &self.recs[s as usize];
                    weight_key(spec.weight(fleet, channel, r.a as usize, r.b as usize))
                })
                .collect(),
        };
        for (&slot, &key) in live.iter().zip(&keys) {
            let slot = slot as usize;
            self.recs[slot].stamp = epoch;
            let h = self.recs[slot].handle;
            self.queue.update_key(h, key);
        }
    }

    /// Chebyshev distance transform from the dirty cells over the grid
    /// (two-pass 8-neighbor chamfer — exact for the Chebyshev metric).
    fn mark_watch(&mut self, grid: &SpatialGrid, dirty_cells: &[u32]) {
        let dims = grid.dims();
        let sz = dims * dims;
        if self.dist.len() != sz {
            self.dist = vec![u16::MAX; sz];
        } else {
            self.dist.fill(u16::MAX);
        }
        for &c in dirty_cells {
            self.dist[c as usize] = 0;
        }
        let d = &mut self.dist;
        for y in 0..dims {
            for x in 0..dims {
                let i = y * dims + x;
                let mut v = d[i];
                if v == 0 {
                    continue;
                }
                if x > 0 {
                    v = v.min(d[i - 1].saturating_add(1));
                }
                if y > 0 {
                    let up = i - dims;
                    v = v.min(d[up].saturating_add(1));
                    if x > 0 {
                        v = v.min(d[up - 1].saturating_add(1));
                    }
                    if x + 1 < dims {
                        v = v.min(d[up + 1].saturating_add(1));
                    }
                }
                d[i] = v;
            }
        }
        for y in (0..dims).rev() {
            for x in (0..dims).rev() {
                let i = y * dims + x;
                let mut v = d[i];
                if v == 0 {
                    continue;
                }
                if x + 1 < dims {
                    v = v.min(d[i + 1].saturating_add(1));
                }
                if y + 1 < dims {
                    let down = i + dims;
                    v = v.min(d[down].saturating_add(1));
                    if x > 0 {
                        v = v.min(d[down - 1].saturating_add(1));
                    }
                    if x + 1 < dims {
                        v = v.min(d[down + 1].saturating_add(1));
                    }
                }
                d[i] = v;
            }
        }
    }

    /// Structural invariants, re-checked after every update in debug builds:
    /// list entries are members, refcounts equal the directed-reference
    /// count, and the queue holds exactly the live edges.
    #[cfg(debug_assertions)]
    fn debug_validate(&self) {
        use std::collections::HashMap;
        let mut refs: HashMap<(u32, u32), u8> = HashMap::new();
        for &c in &self.members {
            for t in 0..self.near_len[c] as usize {
                let o = self.near[c * self.k_near + t];
                assert!(self.alive.contains(o as usize), "near[{c}] holds dead {o}");
                let (lo, hi) = (o.min(c as u32), o.max(c as u32));
                *refs.entry((lo, hi)).or_insert(0) += 1;
            }
            for t in 0..self.band_len[c] as usize {
                let o = self.band[c * self.k_freq + t];
                assert!(self.alive.contains(o as usize), "band[{c}] holds dead {o}");
                let (lo, hi) = (o.min(c as u32), o.max(c as u32));
                *refs.entry((lo, hi)).or_insert(0) += 1;
            }
        }
        let mut live_slots = 0usize;
        for r in &self.recs {
            if r.refs > 0 {
                live_slots += 1;
                assert_eq!(
                    refs.get(&(r.a, r.b)).copied().unwrap_or(0),
                    r.refs,
                    "refcount drift on ({}, {})",
                    r.a,
                    r.b
                );
                assert_ne!(r.handle, NO_HANDLE, "unflushed live edge");
            }
        }
        assert_eq!(live_slots, refs.len(), "slab/list edge sets diverged");
        assert_eq!(self.queue.len(), live_slots, "queue/slab length drift");
    }
}

#[cfg(test)]
mod tests {
    use super::super::candidates::{match_candidates, SparseCandidateGraph};
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::util::rng::Rng;

    fn fleet(n: usize, seed: u64) -> (Fleet, Channel) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = n;
        (
            Fleet::sample(&cfg, &mut Rng::new(seed)),
            Channel::new(ChannelConfig::default()),
        )
    }

    fn rebuild(
        fleet: &Fleet,
        ch: &Channel,
        grid: &SpatialGrid,
        members: &[usize],
        spec: EdgeWeightSpec<'_>,
        k_near: usize,
        k_freq: usize,
    ) -> Matching {
        let g = SparseCandidateGraph::over_members(fleet, ch, grid, members, spec, k_near, k_freq);
        match_candidates(&g, members)
    }

    #[test]
    fn tracks_rebuild_under_membership_churn() {
        let n = 60;
        let (f, ch) = fleet(n, 41);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let mut alive: Vec<bool> = vec![true; n];
        let mut rng = Rng::new(7);
        let mut matcher = IncrementalMatcher::new(n, 4, 2);
        let pool = FixedPool::serial();
        for epoch in 0..30 {
            if epoch > 0 {
                for a in alive.iter_mut() {
                    if rng.f64() < 0.15 {
                        *a = !*a;
                    }
                }
            }
            let members: Vec<usize> = (0..n).filter(|&c| alive[c]).collect();
            let got = matcher.update(&f, &ch, &grid, &members, &spec, &pool).clone();
            let want = rebuild(&f, &ch, &grid, &members, spec, 4, 2);
            assert_eq!(got, want, "epoch {epoch}, m={}", members.len());
        }
    }

    #[test]
    fn tracks_rebuild_under_mobility_and_straggle() {
        let n = 50;
        let (mut f, ch) = fleet(n, 43);
        let mut grid = SpatialGrid::build(&f.positions, 50.0);
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let base = f.freqs_hz.clone();
        let mut rng = Rng::new(9);
        let mut matcher = IncrementalMatcher::new(n, 4, 2);
        let pool = FixedPool::serial();
        let members: Vec<usize> = (0..n).collect();
        for epoch in 0..20 {
            if epoch > 0 {
                for c in 0..n {
                    // Mobility (grid follows) + straggler churn.
                    let p = &mut f.positions[c];
                    p.x = (p.x + rng.normal_ms(0.0, 2.0)).clamp(-50.0, 50.0);
                    p.y = (p.y + rng.normal_ms(0.0, 2.0)).clamp(-50.0, 50.0);
                    grid.relocate(c, *p);
                    f.freqs_hz[c] = if rng.f64() < 0.2 { base[c] * 0.3 } else { base[c] };
                }
            }
            let got = matcher.update(&f, &ch, &grid, &members, &spec, &pool).clone();
            let want = rebuild(&f, &ch, &grid, &members, spec, 4, 2);
            assert_eq!(got, want, "epoch {epoch}");
        }
    }

    #[test]
    fn unchanged_state_skips_the_solve() {
        let (f, ch) = fleet(30, 47);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let members: Vec<usize> = (0..30).collect();
        let mut matcher = IncrementalMatcher::new(30, 4, 2);
        let pool = FixedPool::serial();
        let a = matcher.update(&f, &ch, &grid, &members, &spec, &pool).clone();
        assert_eq!(matcher.solves, 1);
        let b = matcher.update(&f, &ch, &grid, &members, &spec, &pool).clone();
        assert_eq!(matcher.solves, 1, "identical state must not re-solve");
        assert_eq!(a, b);
    }

    #[test]
    fn channel_shadowing_rekeys_everything() {
        let (f, ch) = fleet(40, 51);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let members: Vec<usize> = (0..40).collect();
        let mut matcher = IncrementalMatcher::new(40, 4, 2);
        let pool = FixedPool::serial();
        matcher.update(&f, &ch, &grid, &members, &spec, &pool);
        // A faded channel (shadowing redraw) changes every eq. (5) weight.
        let mut cfg = *ch.config();
        cfg.ref_gain *= 0.4;
        let faded = Channel::new(cfg);
        let got = matcher.update(&f, &faded, &grid, &members, &spec, &pool).clone();
        let want = rebuild(&f, &faded, &grid, &members, spec, 4, 2);
        assert_eq!(got, want);
    }

    #[test]
    fn thread_count_is_invisible() {
        let n = 80;
        let (f, ch) = fleet(n, 53);
        let grid = SpatialGrid::build(&f.positions, 50.0);
        let spec = EdgeWeightSpec::Eq5 { alpha: 1.0, beta: 5e-10 };
        let mut m1 = IncrementalMatcher::new(n, 4, 2);
        let mut m4 = IncrementalMatcher::new(n, 4, 2);
        let (p1, p4) = (FixedPool::new(1), FixedPool::new(4));
        let mut rng = Rng::new(11);
        let mut alive: Vec<bool> = vec![true; n];
        for _ in 0..10 {
            let members: Vec<usize> = (0..n).filter(|&c| alive[c]).collect();
            let a = m1.update(&f, &ch, &grid, &members, &spec, &p1).clone();
            let b = m4.update(&f, &ch, &grid, &members, &spec, &p4).clone();
            assert_eq!(a, b);
            for al in alive.iter_mut() {
                if rng.f64() < 0.1 {
                    *al = !*al;
                }
            }
        }
    }
}
