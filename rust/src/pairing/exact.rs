//! Exact maximum-weight perfect matching by bitmask dynamic programming —
//! the optimality baseline for the greedy heuristic (problem 2 is solvable
//! exactly in O(2ᴺ·N) for the paper's N=20 fleet; the NP-hardness the paper
//! cites concerns the general ILP formulation).
//!
//! `dp[mask]` = best weight matching exactly the vertices in `mask`. The
//! lowest vertex still missing from `mask` is always matched first, so
//! each mask is expanded at most N ways: `O(2^N · N)` time, `O(2^N)` space —
//! ~8 MiB of f64 for N=20, and milliseconds of work.

use super::graph::ClientGraph;
use anyhow::Result;

/// Maximum fleet size the DP will attempt (2^24 doubles = 128 MiB ceiling).
pub const MAX_N: usize = 24;

/// Is the exact DP feasible for a fleet of `n` clients (after the odd-`n`
/// virtual-vertex augmentation)?
pub fn fits(n: usize) -> bool {
    n + n % 2 <= MAX_N
}

/// Exact max-weight near-perfect matching, checked: returns an error instead
/// of aborting when the fleet exceeds [`MAX_N`]. `pair_clients` catches this
/// case up front and falls back to the greedy matcher (logged at WARN), so a
/// churn run that grows past 24 clients mid-flight no longer panics.
pub fn try_exact_matching(graph: &ClientGraph) -> Result<Vec<(usize, usize)>> {
    let n = graph.n;
    anyhow::ensure!(
        fits(n),
        "exact pairing is O(2^n·n): n={n} exceeds the bitmask-DP limit {MAX_N}; \
         use the greedy strategy (or rely on its automatic fallback) at this scale"
    );
    // Augment odd fleets with virtual vertex `n` (zero-weight edges to all).
    let n_eff = n + n % 2;
    if n == 0 {
        return Ok(Vec::new());
    }
    let weight = |i: usize, j: usize| -> f64 {
        if i >= n || j >= n {
            0.0
        } else {
            graph.weight(i, j)
        }
    };
    let full: usize = (1 << n_eff) - 1;
    const NEG: f64 = f64::NEG_INFINITY;
    let mut dp = vec![NEG; full + 1];
    // choice[mask] = (i, j) matched first at this mask (for reconstruction)
    let mut choice = vec![(usize::MAX, usize::MAX); full + 1];
    dp[0] = 0.0;
    for mask in 0..=full {
        if dp[mask] == NEG {
            continue;
        }
        // Vertices still unmatched = !mask; match the lowest one.
        let rem = full & !mask;
        if rem == 0 {
            continue;
        }
        let i = rem.trailing_zeros() as usize;
        let mut rest = rem & !(1 << i);
        while rest != 0 {
            let j = rest.trailing_zeros() as usize;
            rest &= !(1 << j);
            let next = mask | (1 << i) | (1 << j);
            let cand = dp[mask] + weight(i, j);
            if cand > dp[next] {
                dp[next] = cand;
                choice[next] = (i, j);
            }
        }
    }
    // Reconstruct, dropping the pair that contains the virtual vertex.
    let mut out = Vec::with_capacity(n / 2);
    let mut mask = full;
    while mask != 0 {
        let (i, j) = choice[mask];
        assert!(i != usize::MAX, "unreachable mask during reconstruction");
        if i < n && j < n {
            out.push((i, j));
        }
        mask &= !(1 << i);
        mask &= !(1 << j);
    }
    out.reverse();
    Ok(out)
}

/// Exact matching for fleets known to fit the DP (tests, benches, ablations).
/// Panics past [`MAX_N`]; run-time paths go through [`try_exact_matching`].
pub fn exact_matching(graph: &ClientGraph) -> Vec<(usize, usize)> {
    try_exact_matching(graph).expect("fleet exceeds the exact-DP limit")
}

/// Optimal matching weight only (no reconstruction) — for bounds in tests.
pub fn exact_weight(graph: &ClientGraph) -> f64 {
    let m = exact_matching(graph);
    graph.matching_weight(&m)
}

#[cfg(test)]
mod tests {
    use super::super::graph::{is_perfect_matching, ClientGraph, Edge};
    use super::super::greedy::greedy_matching;
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Rng;

    fn random_graph(rng: &mut Rng, n: usize) -> ClientGraph {
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                edges.push(Edge {
                    i,
                    j,
                    weight: rng.f64() * 10.0,
                });
            }
        }
        ClientGraph { n, edges }
    }

    /// Brute-force optimum by recursion (for cross-checking small n).
    fn brute(graph: &ClientGraph, unmatched: &mut Vec<usize>) -> f64 {
        if unmatched.is_empty() {
            return 0.0;
        }
        let i = unmatched[0];
        let mut best = f64::NEG_INFINITY;
        for k in 1..unmatched.len() {
            let j = unmatched[k];
            let mut rest: Vec<usize> = unmatched
                .iter()
                .cloned()
                .filter(|&v| v != i && v != j)
                .collect();
            let w = graph.weight(i, j) + brute(graph, &mut rest);
            best = best.max(w);
        }
        best
    }

    #[test]
    fn beats_greedy_on_adversarial_path() {
        // 3-4-3 path: exact picks the two 3s (6), greedy picks the 4.
        let mut edges = Vec::new();
        for i in 0..4 {
            for j in (i + 1)..4 {
                let weight = match (i, j) {
                    (0, 1) => 3.0,
                    (1, 2) => 4.0,
                    (2, 3) => 3.0,
                    _ => 0.0,
                };
                edges.push(Edge { i, j, weight });
            }
        }
        let g = ClientGraph { n: 4, edges };
        let m = exact_matching(&g);
        assert!((g.matching_weight(&m) - 6.0).abs() < 1e-12);
        assert!(g.matching_weight(&m) > g.matching_weight(&greedy_matching(&g)));
    }

    #[test]
    fn matches_brute_force_small() {
        let mut rng = Rng::new(2);
        for n in [2usize, 4, 6, 8] {
            for _ in 0..5 {
                let g = random_graph(&mut rng, n);
                let exact = exact_weight(&g);
                let bf = brute(&g, &mut (0..n).collect());
                assert!((exact - bf).abs() < 1e-9, "n={n}: dp={exact} brute={bf}");
            }
        }
    }

    #[test]
    fn always_valid_and_at_least_greedy() {
        check(
            30,
            Gen::new(|rng| {
                let n = 2 * (1 + rng.below(6)); // 2..12
                random_graph(rng, n)
            }),
            |g| {
                let ex = exact_matching(g);
                if !is_perfect_matching(g.n, &ex) {
                    return false;
                }
                let gw = g.matching_weight(&greedy_matching(g));
                let ew = g.matching_weight(&ex);
                // optimal ≥ greedy ≥ optimal/2
                ew + 1e-9 >= gw && gw * 2.0 + 1e-9 >= ew
            },
        );
    }

    #[test]
    fn n20_paper_scale_runs_fast() {
        let mut rng = Rng::new(3);
        let g = random_graph(&mut rng, 20);
        let t = std::time::Instant::now();
        let m = exact_matching(&g);
        assert!(is_perfect_matching(20, &m));
        assert!(t.elapsed().as_secs_f64() < 5.0, "DP too slow");
    }

    #[test]
    fn oversized_fleet_errors_instead_of_aborting() {
        assert!(fits(24) && fits(23) && !fits(25));
        let mut rng = Rng::new(9);
        let g = random_graph(&mut rng, 30);
        let err = try_exact_matching(&g).unwrap_err();
        assert!(err.to_string().contains("bitmask-DP limit"), "{err}");
        // Odd 23 augments to 24 and stays feasible; 25 augments past it.
        let g = random_graph(&mut rng, 5);
        assert!(is_perfect_matching(5, &try_exact_matching(&g).unwrap()));
    }

    #[test]
    fn empty_graph() {
        let g = ClientGraph {
            n: 0,
            edges: vec![],
        };
        assert!(exact_matching(&g).is_empty());
    }

    #[test]
    fn odd_n_leaves_optimal_solo() {
        // Regression for the former even-n assert: n = 3 must keep the
        // heaviest edge and leave its complement solo.
        let g = ClientGraph {
            n: 3,
            edges: vec![
                Edge { i: 0, j: 1, weight: 1.0 },
                Edge { i: 0, j: 2, weight: 5.0 },
                Edge { i: 1, j: 2, weight: 1.0 },
            ],
        };
        let m = exact_matching(&g);
        assert_eq!(m, vec![(0, 2)]);
        assert!(is_perfect_matching(3, &m));
    }

    #[test]
    fn odd_n7_valid_and_at_least_greedy() {
        // Regression test for n_clients = 7 (near-perfect matching).
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            let g = random_graph(&mut rng, 7);
            let ex = exact_matching(&g);
            assert_eq!(ex.len(), 3);
            assert!(is_perfect_matching(7, &ex), "{ex:?}");
            let gr = greedy_matching(&g);
            assert!(is_perfect_matching(7, &gr), "{gr:?}");
            assert!(g.matching_weight(&ex) + 1e-9 >= g.matching_weight(&gr));
        }
    }
}
