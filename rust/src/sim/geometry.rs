//! Client placement geometry: the paper's "20 clients distributed randomly in
//! a 50 m radius circular area" with the aggregation server at the center —
//! plus the [`SpatialGrid`] bucketing that lets the sparse pairing backend and
//! the fleet layer answer "who is near client i?" in O(k) instead of scanning
//! all n clients.

use crate::util::matrix::FlatMatrix;
use crate::util::pool::FixedPool;
use crate::util::rng::Rng;

/// A 2-D position in meters; the server sits at the origin.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub const ORIGIN: Pos = Pos { x: 0.0, y: 0.0 };

    pub fn dist(&self, other: &Pos) -> f64 {
        let dx = self.x - other.x;
        let dy = self.y - other.y;
        (dx * dx + dy * dy).sqrt()
    }

    /// Distance to the aggregation server (the area center).
    pub fn dist_to_server(&self) -> f64 {
        self.dist(&Pos::ORIGIN)
    }
}

/// Sample `n` positions uniformly over a disk of radius `radius_m`.
///
/// Uses the area-correct transform `r = R·√u` (naive `r = R·u` over-samples
/// the center — tested below).
pub fn place_uniform_disk(rng: &mut Rng, n: usize, radius_m: f64) -> Vec<Pos> {
    (0..n)
        .map(|_| {
            let r = radius_m * rng.f64().sqrt();
            let theta = 2.0 * std::f64::consts::PI * rng.f64();
            Pos {
                x: r * theta.cos(),
                y: r * theta.sin(),
            }
        })
        .collect()
}

/// Full pairwise distance matrix (symmetric, zero diagonal). One flat
/// allocation; prefer lazy per-edge evaluation (the sparse pairing backend)
/// when n is large — this is O(n²) by construction.
pub fn distance_matrix(positions: &[Pos]) -> FlatMatrix {
    let n = positions.len();
    let mut m = FlatMatrix::new(n, 0.0);
    for i in 0..n {
        for j in (i + 1)..n {
            m.set_sym(i, j, positions[i].dist(&positions[j]));
        }
    }
    m
}

/// Default target bucket occupancy used to size a [`SpatialGrid`].
pub const GRID_TARGET_PER_CELL: f64 = 4.0;

/// Hard cap on cells per side (512² = 262 144 buckets ≈ a few MiB of `Vec`
/// headers — plenty of resolution for 100k+ clients in a metro disk).
const GRID_MAX_DIMS: usize = 512;

/// Uniform spatial hash over the deployment square `[-extent, extent]²`.
///
/// Buckets client ids by cell so "nearby clients" is a ring walk over a few
/// cells rather than an O(n) scan. Membership updates are O(1)
/// (`insert`/`remove`/`relocate`), which is what lets `fleet::FleetDynamics`
/// keep the grid current under churn and mobility instead of rebuilding
/// global state every round. Positions outside the extent clamp to the border
/// cells, so callers never need to guard stray coordinates.
/// Ids are stored as `u32` internally (memory diet: half the bucket and
/// index footprint at 1M clients); the public API stays `usize`.
#[derive(Clone, Debug)]
pub struct SpatialGrid {
    extent_m: f64,
    cell_m: f64,
    dims: usize,
    /// `dims × dims` buckets of client ids (row-major, `y * dims + x`).
    cells: Vec<Vec<u32>>,
    /// id → bucket index (`u32::MAX` = not in the grid). Grows on demand.
    cell_of: Vec<u32>,
    /// id → slot within its bucket (for O(1) swap-removal).
    slot_of: Vec<u32>,
    len: usize,
}

const ABSENT: u32 = u32::MAX;

impl SpatialGrid {
    /// Empty grid covering `[-extent_m, extent_m]²`, sized so that
    /// `expected_members` clients average ~[`GRID_TARGET_PER_CELL`] per cell.
    pub fn new(extent_m: f64, expected_members: usize) -> SpatialGrid {
        assert!(extent_m > 0.0, "grid extent must be positive");
        let dims = ((expected_members.max(1) as f64 / GRID_TARGET_PER_CELL).sqrt().ceil()
            as usize)
            .clamp(1, GRID_MAX_DIMS);
        SpatialGrid {
            extent_m,
            cell_m: 2.0 * extent_m / dims as f64,
            dims,
            cells: vec![Vec::new(); dims * dims],
            cell_of: Vec::new(),
            slot_of: Vec::new(),
            len: 0,
        }
    }

    /// Build a grid holding ids `0..positions.len()`.
    pub fn build(positions: &[Pos], extent_m: f64) -> SpatialGrid {
        let mut g = SpatialGrid::new(extent_m, positions.len());
        for (i, p) in positions.iter().enumerate() {
            g.insert(i, *p);
        }
        g
    }

    /// [`Self::build`] with the cell-index pass fanned out over `pool`.
    /// The scatter into buckets stays serial and ascending-id, so every cell
    /// holds its occupants in exactly the order the serial build produces —
    /// ring walks (and everything seeded from them) are bit-identical at any
    /// thread count.
    pub fn build_parallel(positions: &[Pos], extent_m: f64, pool: &FixedPool) -> SpatialGrid {
        const CHUNK: usize = 8192;
        let n = positions.len();
        debug_assert!(n < ABSENT as usize);
        let mut g = SpatialGrid::new(extent_m, n);
        let idx: Vec<Vec<u32>> = pool.map(n.div_ceil(CHUNK), |ci| {
            let lo = ci * CHUNK;
            let hi = (lo + CHUNK).min(n);
            positions[lo..hi].iter().map(|p| g.cell_idx(p) as u32).collect()
        });
        g.cell_of = vec![ABSENT; n];
        g.slot_of = vec![ABSENT; n];
        let mut id = 0u32;
        for chunk in idx {
            for c in chunk {
                let c = c as usize;
                g.cell_of[id as usize] = c as u32;
                g.slot_of[id as usize] = g.cells[c].len() as u32;
                g.cells[c].push(id);
                id += 1;
            }
        }
        g.len = n;
        g
    }

    /// Cells per side.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Cell side length in meters (ring `R+1` occupants are ≥ `R·cell_m()`
    /// away from any point of the center cell — the kNN walk's stop bound).
    pub fn cell_m(&self) -> f64 {
        self.cell_m
    }

    /// Number of clients currently in the grid.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Is `id` currently in the grid?
    pub fn contains(&self, id: usize) -> bool {
        self.cell_of.get(id).is_some_and(|&c| c != ABSENT)
    }

    /// Cell coordinates of a position (clamped to the grid).
    pub fn cell_xy(&self, p: &Pos) -> (usize, usize) {
        let axis = |v: f64| -> usize {
            let c = ((v + self.extent_m) / self.cell_m).floor();
            (c.max(0.0) as usize).min(self.dims - 1)
        };
        (axis(p.x), axis(p.y))
    }

    fn cell_idx(&self, p: &Pos) -> usize {
        let (x, y) = self.cell_xy(p);
        y * self.dims + x
    }

    /// Add `id` at `p`. Must not already be present.
    pub fn insert(&mut self, id: usize, p: Pos) {
        debug_assert!(id < ABSENT as usize);
        if self.cell_of.len() <= id {
            self.cell_of.resize(id + 1, ABSENT);
            self.slot_of.resize(id + 1, ABSENT);
        }
        debug_assert!(self.cell_of[id] == ABSENT, "insert of present id {id}");
        let c = self.cell_idx(&p);
        self.cell_of[id] = c as u32;
        self.slot_of[id] = self.cells[c].len() as u32;
        self.cells[c].push(id as u32);
        self.len += 1;
    }

    /// Remove `id`. Must be present.
    pub fn remove(&mut self, id: usize) {
        let c = self.cell_of[id];
        assert!(c != ABSENT, "remove of absent id {id}");
        let c = c as usize;
        let s = self.slot_of[id] as usize;
        self.cells[c].swap_remove(s);
        if let Some(&moved) = self.cells[c].get(s) {
            self.slot_of[moved as usize] = s as u32;
        }
        self.cell_of[id] = ABSENT;
        self.slot_of[id] = ABSENT;
        self.len -= 1;
    }

    /// Move a present `id` to position `p` (no-op when the cell is unchanged).
    pub fn relocate(&mut self, id: usize, p: Pos) {
        let c = self.cell_idx(&p);
        if self.cell_of[id] == c as u32 {
            return;
        }
        self.remove(id);
        self.insert(id, p);
    }

    /// Visit every in-bounds cell at Chebyshev distance exactly `ring` from
    /// `(cx, cy)`; returns how many cells were visited (0 once the ring lies
    /// fully outside the grid).
    pub fn for_ring(&self, cx: usize, cy: usize, ring: usize, mut f: impl FnMut(&[u32])) -> usize {
        let (cx, cy, r) = (cx as isize, cy as isize, ring as isize);
        let dims = self.dims as isize;
        let mut visited = 0usize;
        let mut visit = |x: isize, y: isize, f: &mut dyn FnMut(&[u32])| {
            if (0..dims).contains(&x) && (0..dims).contains(&y) {
                f(&self.cells[(y * dims + x) as usize]);
                visited += 1;
            }
        };
        if ring == 0 {
            visit(cx, cy, &mut f);
            return visited;
        }
        for x in (cx - r)..=(cx + r) {
            visit(x, cy - r, &mut f);
            visit(x, cy + r, &mut f);
        }
        for y in (cy - r + 1)..=(cy + r - 1) {
            visit(cx - r, y, &mut f);
            visit(cx + r, y, &mut f);
        }
        visited
    }

    /// All member ids, ascending (test/debug helper — O(id range)).
    pub fn members(&self) -> Vec<usize> {
        (0..self.cell_of.len()).filter(|&c| self.contains(c)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances_basic() {
        let a = Pos { x: 0.0, y: 0.0 };
        let b = Pos { x: 3.0, y: 4.0 };
        assert!((a.dist(&b) - 5.0).abs() < 1e-12);
        assert!((b.dist_to_server() - 5.0).abs() < 1e-12);
        assert_eq!(a.dist(&a), 0.0);
    }

    #[test]
    fn placement_within_radius() {
        let mut rng = Rng::new(1);
        let pts = place_uniform_disk(&mut rng, 500, 50.0);
        assert_eq!(pts.len(), 500);
        assert!(pts.iter().all(|p| p.dist_to_server() <= 50.0 + 1e-9));
    }

    #[test]
    fn placement_is_area_uniform() {
        // Under area-uniformity, P(r <= R/2) = 1/4.
        let mut rng = Rng::new(2);
        let n = 20_000;
        let pts = place_uniform_disk(&mut rng, n, 1.0);
        let inner = pts.iter().filter(|p| p.dist_to_server() <= 0.5).count();
        let frac = inner as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let mut rng = Rng::new(3);
        let pts = place_uniform_disk(&mut rng, 10, 50.0);
        let m = distance_matrix(&pts);
        assert_eq!(m.n(), 10);
        for i in 0..10 {
            assert_eq!(m[(i, i)], 0.0);
            for j in 0..10 {
                assert!((m[(i, j)] - m[(j, i)]).abs() < 1e-12);
                if i != j {
                    assert!(m[(i, j)] > 0.0);
                }
            }
        }
    }

    #[test]
    fn grid_insert_remove_relocate() {
        let mut g = SpatialGrid::new(50.0, 16);
        assert!(g.is_empty());
        g.insert(3, Pos { x: -40.0, y: -40.0 });
        g.insert(7, Pos { x: 40.0, y: 40.0 });
        assert_eq!(g.len(), 2);
        assert!(g.contains(3) && g.contains(7) && !g.contains(0));
        assert_eq!(g.members(), vec![3, 7]);
        // Relocating across the grid moves the id to the new cell.
        let before = g.cell_xy(&Pos { x: -40.0, y: -40.0 });
        g.relocate(3, Pos { x: 40.0, y: -40.0 });
        let after = g.cell_xy(&Pos { x: 40.0, y: -40.0 });
        if g.dims() > 1 {
            assert_ne!(before, after);
        }
        g.remove(7);
        assert_eq!(g.members(), vec![3]);
        assert!(!g.contains(7));
    }

    #[test]
    fn grid_rings_cover_every_client_exactly_once() {
        let mut rng = Rng::new(5);
        let pts = place_uniform_disk(&mut rng, 200, 50.0);
        let g = SpatialGrid::build(&pts, 50.0);
        let (cx, cy) = g.cell_xy(&pts[0]);
        let mut seen: Vec<u32> = Vec::new();
        for ring in 0.. {
            let visited = g.for_ring(cx, cy, ring, |cell| seen.extend_from_slice(cell));
            if visited == 0 {
                break;
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..200).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_build_matches_serial_build() {
        let mut rng = Rng::new(11);
        let pts = place_uniform_disk(&mut rng, 3000, 50.0);
        let serial = SpatialGrid::build(&pts, 50.0);
        for threads in [1usize, 2, 4] {
            let par = SpatialGrid::build_parallel(&pts, 50.0, &FixedPool::new(threads));
            assert_eq!(par.len(), serial.len());
            assert_eq!(par.dims(), serial.dims());
            // Identical bucket contents in identical order: ring walks over
            // either grid see the same occupant sequence.
            let (cx, cy) = serial.cell_xy(&pts[0]);
            for ring in 0..par.dims() {
                let mut a: Vec<u32> = Vec::new();
                let mut b: Vec<u32> = Vec::new();
                serial.for_ring(cx, cy, ring, |cell| a.extend_from_slice(cell));
                par.for_ring(cx, cy, ring, |cell| b.extend_from_slice(cell));
                assert_eq!(a, b, "threads={threads} ring={ring}");
            }
        }
    }

    #[test]
    fn grid_clamps_out_of_extent_positions() {
        let mut g = SpatialGrid::new(50.0, 64);
        // Way outside the disk: lands in a border cell instead of panicking.
        g.insert(0, Pos { x: 900.0, y: -900.0 });
        assert!(g.contains(0));
        let (cx, cy) = g.cell_xy(&Pos { x: 900.0, y: -900.0 });
        assert_eq!(cx, g.dims() - 1);
        assert_eq!(cy, 0);
    }

    #[test]
    fn grid_swap_removal_keeps_slots_consistent() {
        // Several ids in one cell; removing the first must keep the others
        // findable (the swap-moved id's slot is patched).
        let p = Pos { x: 1.0, y: 1.0 };
        let mut g = SpatialGrid::new(50.0, 4);
        for id in 0..5 {
            g.insert(id, p);
        }
        g.remove(0);
        g.remove(2);
        assert_eq!(g.members(), vec![1, 3, 4]);
        for id in [1, 3, 4] {
            g.remove(id);
        }
        assert!(g.is_empty());
    }

    #[test]
    fn deterministic_placement() {
        let a = place_uniform_disk(&mut Rng::new(7), 5, 50.0);
        let b = place_uniform_disk(&mut Rng::new(7), 5, 50.0);
        assert_eq!(a, b);
    }
}
