//! Client placement geometry: the paper's "20 clients distributed randomly in
//! a 50 m radius circular area" with the aggregation server at the center.

use crate::util::rng::Rng;

/// A 2-D position in meters; the server sits at the origin.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pos {
    pub x: f64,
    pub y: f64,
}

impl Pos {
    pub const ORIGIN: Pos = Pos { x: 0.0, y: 0.0 };

    pub fn dist(&self, other: &Pos) -> f64 {
        let dx = self.x - other.x;
        let dy = self.y - other.y;
        (dx * dx + dy * dy).sqrt()
    }

    /// Distance to the aggregation server (the area center).
    pub fn dist_to_server(&self) -> f64 {
        self.dist(&Pos::ORIGIN)
    }
}

/// Sample `n` positions uniformly over a disk of radius `radius_m`.
///
/// Uses the area-correct transform `r = R·√u` (naive `r = R·u` over-samples
/// the center — tested below).
pub fn place_uniform_disk(rng: &mut Rng, n: usize, radius_m: f64) -> Vec<Pos> {
    (0..n)
        .map(|_| {
            let r = radius_m * rng.f64().sqrt();
            let theta = 2.0 * std::f64::consts::PI * rng.f64();
            Pos {
                x: r * theta.cos(),
                y: r * theta.sin(),
            }
        })
        .collect()
}

/// Full pairwise distance matrix (symmetric, zero diagonal).
pub fn distance_matrix(positions: &[Pos]) -> Vec<Vec<f64>> {
    let n = positions.len();
    let mut m = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in (i + 1)..n {
            let d = positions[i].dist(&positions[j]);
            m[i][j] = d;
            m[j][i] = d;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distances_basic() {
        let a = Pos { x: 0.0, y: 0.0 };
        let b = Pos { x: 3.0, y: 4.0 };
        assert!((a.dist(&b) - 5.0).abs() < 1e-12);
        assert!((b.dist_to_server() - 5.0).abs() < 1e-12);
        assert_eq!(a.dist(&a), 0.0);
    }

    #[test]
    fn placement_within_radius() {
        let mut rng = Rng::new(1);
        let pts = place_uniform_disk(&mut rng, 500, 50.0);
        assert_eq!(pts.len(), 500);
        assert!(pts.iter().all(|p| p.dist_to_server() <= 50.0 + 1e-9));
    }

    #[test]
    fn placement_is_area_uniform() {
        // Under area-uniformity, P(r <= R/2) = 1/4.
        let mut rng = Rng::new(2);
        let n = 20_000;
        let pts = place_uniform_disk(&mut rng, n, 1.0);
        let inner = pts.iter().filter(|p| p.dist_to_server() <= 0.5).count();
        let frac = inner as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let mut rng = Rng::new(3);
        let pts = place_uniform_disk(&mut rng, 10, 50.0);
        let m = distance_matrix(&pts);
        for i in 0..10 {
            assert_eq!(m[i][i], 0.0);
            for j in 0..10 {
                assert!((m[i][j] - m[j][i]).abs() < 1e-12);
                if i != j {
                    assert!(m[i][j] > 0.0);
                }
            }
        }
    }

    #[test]
    fn deterministic_placement() {
        let a = place_uniform_disk(&mut Rng::new(7), 5, 50.0);
        let b = place_uniform_disk(&mut Rng::new(7), 5, 50.0);
        assert_eq!(a, b);
    }
}
