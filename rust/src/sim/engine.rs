//! The incremental round-time engine: analytic per-pair kernels, a
//! cross-round memo cache, and deterministic parallel evaluation — the
//! O(changed pairs) replacement for running one BinaryHeap DES per pair per
//! round (DESIGN.md §6).
//!
//! Wireless-SFL latency models in the literature (arXiv:2310.15584,
//! arXiv:2504.15724) are closed-form per pair/session because the two-flow
//! ping-pong pipeline admits an O(1)-per-batch recurrence. This module
//! computes that recurrence exactly:
//!
//! * **Analytic pair kernel** ([`two_chain_shop`]): the 2-chain / 4-resource
//!   job shop of `fedpairing_round_with_solos`, solved by an exact event
//!   recurrence in O(batches) time and O(1) space — no heap, no queues, no
//!   allocation. It replicates [`super::des::simulate`]'s `(time, seq)` event
//!   ordering (including FIFO tie-breaks at batch boundaries) and adds the
//!   same durations to the same accumulators in the same order, so its
//!   makespans are **bit-identical** to the DES, not merely close. The same
//!   treatment covers the other three shapes: vanilla FL is already closed
//!   form, a vanilla-SL session is a single uncontended chain (stage-order
//!   sum), and SplitFed reduces to a FIFO recurrence on the one shared
//!   resource — the server (per-client CPUs and links are private, so only
//!   server arrivals need ordering).
//! * **Cross-round memo cache**: pair results are keyed by the full set of
//!   latency-relevant inputs `(f_i, f_j, n_i, n_j, pair rate)` — bit
//!   patterns, not rounded values — so stable scenarios hit 100 % after
//!   round 1 while shadowing/mobility/straggler rounds recompute exactly the
//!   pairs whose inputs actually moved. A two-generation swap evicts entries
//!   not touched this round, bounding the cache at O(live pairs).
//! * **Deterministic parallel evaluation**: cache misses are evaluated on a
//!   [`FixedPool`] (fork-join, contiguous index chunks) and reduced in pair
//!   order, so any `threads` setting reproduces the single-thread trace bit
//!   for bit.
//!
//! The DES stays available as the opt-in correctness oracle
//! ([`RoundBackend::Des`]); the `engine_matches_des` property suite pins the
//! two backends together across randomized fleets for all four algorithms.

use super::channel::Channel;
use super::compute::transmit_time;
use super::latency::{
    self, full_local_time, mean_cut_of, split_stage_durations, upload_time, ClientSet, RoundTime,
    Schedule,
};
use super::profile::ModelProfile;
use crate::config::{ComputeConfig, EngineConfig, RoundBackend, SplitConfig, SplitPolicy};
use crate::split::{self, PairContext};
use crate::telemetry::breakdown::{self, StageBreakdown};
use crate::telemetry::registry::{self, Counter, Gauge};
use crate::util::pool::FixedPool;
use crate::util::rng::splitmix64;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashMap};

/// Below this many cache misses a round is evaluated serially — forking the
/// pool costs more than the kernels themselves.
const PAR_MIN_MISSES: usize = 64;

/// Memo-cache key: the complete set of inputs a pair's training makespan
/// depends on (the model profile, schedule and compute calibration are
/// covered by the engine-level context fingerprint). Exact bit patterns —
/// two rates that differ in the last ulp are different keys.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct PairKey {
    f_i: u64,
    f_j: u64,
    n_i: u64,
    n_j: u64,
    rate: u64,
}

impl PairKey {
    #[inline]
    fn new(f_i: f64, f_j: f64, n_i: usize, n_j: usize, rate: f64) -> PairKey {
        PairKey {
            f_i: f_i.to_bits(),
            f_j: f_j.to_bits(),
            n_i: n_i as u64,
            n_j: n_j as u64,
            rate: rate.to_bits(),
        }
    }
}

/// One pair's cached evaluation: training makespan (upload excluded — it
/// depends on the uplink rates, which are re-priced per round in O(1)),
/// per-resource busy seconds, the two flow finish times, and the planned
/// cut `L_i` the evaluation was made at. `pub(crate)` so the split planner
/// (`crate::split`) can search over candidate evaluations.
#[derive(Clone, Copy, Debug)]
pub(crate) struct PairEval {
    pub(crate) makespan: f64,
    pub(crate) busy: [f64; 4],
    pub(crate) finish: [f64; 2],
    pub(crate) cut: usize,
}

impl PairEval {
    const ZERO: PairEval = PairEval {
        makespan: 0.0,
        busy: [0.0; 4],
        finish: [0.0; 2],
        cut: 0,
    };
}

// ---------------------------------------------------------------------------
// Analytic kernels
// ---------------------------------------------------------------------------

/// A training flow as the DES sees it: a 5-stage `(resource, duration)`
/// cycle repeated once per mini-batch.
#[derive(Clone, Copy, Debug)]
struct ChainSpec {
    res: [usize; 5],
    dur: [f64; 5],
    n_stages: usize,
}

impl ChainSpec {
    #[inline]
    fn resource(&self, stage: usize) -> usize {
        self.res[stage % 5]
    }
    #[inline]
    fn duration(&self, stage: usize) -> f64 {
        self.dur[stage % 5]
    }
}

/// A chain's scheduling state inside [`two_chain_shop`]. `Ready`/`Complete`
/// mirror the DES's pending events (with their push seq for tie-breaks);
/// `Queued` chains sit in a resource's FIFO slot and have no event.
#[derive(Clone, Copy, Debug)]
enum ChainState {
    Ready { t: f64, seq: u64 },
    Complete { t: f64, seq: u64 },
    Queued,
    Done,
}

/// Exact event recurrence for the 2-chain / 4-resource pair job shop.
///
/// This is `des::simulate` specialized to two cyclic chains: each chain has
/// at most one pending event at a time, so the global event heap degenerates
/// to a 2-way `(time, seq)` minimum and the per-resource FIFO queues to a
/// single waiting slot. Seq numbers are assigned in the same order as the
/// DES pushes events (init in chain order; on completion the successor
/// StageReady before the waiting chain's service start), so tie-breaks —
/// which genuinely fire at batch boundaries, where a chain re-requests the
/// resource it just released — resolve identically. Durations are added to
/// the same accumulators in the same order, making every output bit-equal to
/// the DES report.
fn two_chain_shop(a: ChainSpec, b: ChainSpec) -> PairEval {
    let chains = [a, b];
    let mut state = [ChainState::Done; 2];
    let mut stage = [0usize; 2];
    let mut busy: [Option<usize>; 4] = [None; 4];
    let mut waiting: [Option<usize>; 4] = [None; 4];
    let mut busy_s = [0.0f64; 4];
    let mut finish = [0.0f64; 2];
    let mut seq: u64 = 0;
    for c in 0..2 {
        if chains[c].n_stages > 0 {
            state[c] = ChainState::Ready { t: 0.0, seq };
            seq += 1;
        }
    }
    loop {
        // The 2-way event "heap": earliest (time, seq) pending event wins.
        let mut pick: Option<(usize, f64, u64, bool)> = None;
        for c in 0..2 {
            let (t, s, is_complete) = match state[c] {
                ChainState::Ready { t, seq } => (t, seq, false),
                ChainState::Complete { t, seq } => (t, seq, true),
                _ => continue,
            };
            if pick.is_none_or(|(_, pt, ps, _)| (t, s) < (pt, ps)) {
                pick = Some((c, t, s, is_complete));
            }
        }
        let Some((c, now, _, is_complete)) = pick else {
            break;
        };
        let r = chains[c].resource(stage[c]);
        if !is_complete {
            // StageReady: enqueue; start service only if the resource idles.
            if busy[r].is_some() {
                debug_assert!(waiting[r].is_none());
                state[c] = ChainState::Queued;
                waiting[r] = Some(c);
            } else {
                let d = chains[c].duration(stage[c]);
                busy[r] = Some(c);
                busy_s[r] += d;
                state[c] = ChainState::Complete { t: now + d, seq };
                seq += 1;
            }
        } else {
            // Complete: free the resource, advance the chain, then serve the
            // waiting chain — in that order, so the successor StageReady
            // takes the earlier seq exactly like the DES push order.
            busy[r] = None;
            stage[c] += 1;
            if stage[c] < chains[c].n_stages {
                state[c] = ChainState::Ready { t: now, seq };
                seq += 1;
            } else {
                state[c] = ChainState::Done;
                finish[c] = now;
            }
            if let Some(w) = waiting[r].take() {
                let d = chains[w].duration(stage[w]);
                busy[r] = Some(w);
                busy_s[r] += d;
                state[w] = ChainState::Complete { t: now + d, seq };
                seq += 1;
            }
        }
    }
    PairEval {
        makespan: finish[0].max(finish[1]),
        busy: busy_s,
        finish,
        cut: 0,
    }
}

/// Analytic evaluation of one FedPairing pair at an explicit cut `L_i` —
/// the exact inputs and resource layout of the DES path in
/// `fedpairing_round_with_solos`. This is the kernel the split planner's
/// `Optimal` policy searches over (`crate::split`), so every candidate cut
/// is priced with bit-identical arithmetic to the round evaluation itself.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pair_eval_at_cut(
    profile: &ModelProfile,
    sched: &Schedule,
    comp: &ComputeConfig,
    f_i: f64,
    f_j: f64,
    n_i: usize,
    n_j: usize,
    rate: f64,
    cut: usize,
) -> PairEval {
    let w = profile.w();
    debug_assert!(cut >= 1 && cut < w, "cut {cut} out of range for W={w}");
    let (l_i, l_j) = (cut, w - cut);
    // Resources: 0 = cpu_i, 1 = cpu_j, 2 = link i→j, 3 = link j→i.
    let dir_i = ChainSpec {
        res: [0, 2, 1, 3, 0],
        dur: split_stage_durations(profile, comp, sched.batch_size, l_i, f_i, f_j, rate),
        n_stages: 5 * sched.batches(n_i),
    };
    let dir_j = ChainSpec {
        res: [1, 3, 0, 2, 1],
        dur: split_stage_durations(profile, comp, sched.batch_size, l_j, f_j, f_i, rate),
        n_stages: 5 * sched.batches(n_j),
    };
    let mut e = two_chain_shop(dir_i, dir_j);
    e.cut = cut;
    e
}

/// Plan the pair's cut under the configured split policy and evaluate it —
/// the engine's miss path. The pair rate arrives precomputed (it was
/// already evaluated for the cache key — same bits, no second eq. (3)
/// evaluation per miss). With the default `Paper` policy this reduces to
/// the pre-planner kernel bit-for-bit: `split_lengths` cut, one
/// `two_chain_shop` evaluation.
#[allow(clippy::too_many_arguments)]
fn pair_kernel<C: ClientSet>(
    fleet: &C,
    i: usize,
    j: usize,
    rate: f64,
    profile: &ModelProfile,
    sched: &Schedule,
    comp: &ComputeConfig,
    split_cfg: &SplitConfig,
) -> PairEval {
    split::plan_eval(
        split_cfg,
        &PairContext {
            profile,
            sched,
            comp,
            f_i_hz: fleet.freq_hz(i),
            f_j_hz: fleet.freq_hz(j),
            n_i: fleet.n_samples(i),
            n_j: fleet.n_samples(j),
            rate_bps: rate,
        },
    )
}

/// A pending server arrival in the SplitFed recurrence. Min-ordered by
/// `(time, chain)` — see the tie-break note on
/// [`RoundEngine::splitfed_round`].
#[derive(Debug)]
struct Arrival {
    t: f64,
    chain: usize,
}

impl PartialEq for Arrival {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.chain == other.chain
    }
}
impl Eq for Arrival {}
impl PartialOrd for Arrival {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Arrival {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare; arrival times are finite (asserted
        // stage durations), so the Equal fallback is unreachable.
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.chain.cmp(&self.chain))
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// Per-round latency evaluator: analytic kernels + memo cache + parallel
/// evaluation behind the same call shapes as the `latency` module, with the
/// DES available as an opt-in oracle backend. One instance is meant to live
/// for a whole multi-round run so the cache can work across rounds.
#[derive(Debug)]
pub struct RoundEngine {
    backend: RoundBackend,
    pool: FixedPool,
    flow_diagnostics: bool,
    /// Split-planning policy deciding each pair's cut (default `Paper`).
    split: SplitConfig,
    /// Fingerprint of the (profile, schedule, compute, split-config)
    /// context the cached entries were computed under; a context switch
    /// clears the cache. Folding the split config here is what makes the
    /// memo key cut-aware: a cached entry can only be reused under the
    /// policy (and search bounds) that chose its cut.
    context: u64,
    cache: HashMap<PairKey, PairEval>,
    next: HashMap<PairKey, PairEval>,
    // Reusable per-round scratch (amortized zero-allocation).
    keys: Vec<PairKey>,
    miss: Vec<usize>,
    evals: Vec<PairEval>,
    /// Participant totals of the last round (p50 slack baseline scratch).
    totals: Vec<f64>,
    /// `(i, j, pair_total_s)` of the last FedPairing round — collected only
    /// while telemetry is enabled, for the trace exporter's pair lanes.
    lanes: Vec<(usize, usize, f64)>,
    /// When set, each round evaluation also records its per-unit durations
    /// in [`RoundEngine::unit_times`] — the async scheduler's price feed.
    record_units: bool,
    /// Per-unit durations of the last round (see [`RoundEngine::unit_times`]).
    unit_times: Vec<f64>,
    /// Per-unit `[compute_a, comm_a, compute_b, comm_b]` attribution (see
    /// [`RoundEngine::unit_splits`]).
    unit_splits: Vec<[f64; 4]>,
    hits: u64,
    misses: u64,
}

impl RoundEngine {
    pub fn new(cfg: &EngineConfig) -> RoundEngine {
        RoundEngine {
            backend: cfg.backend,
            pool: FixedPool::new(cfg.threads),
            flow_diagnostics: cfg.flow_diagnostics,
            split: SplitConfig::default(),
            context: 0,
            cache: HashMap::new(),
            next: HashMap::new(),
            keys: Vec::new(),
            miss: Vec::new(),
            evals: Vec::new(),
            totals: Vec::new(),
            lanes: Vec::new(),
            record_units: false,
            unit_times: Vec::new(),
            unit_splits: Vec::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// Toggle per-unit duration recording. The async scheduler needs the
    /// individual participant totals the synchronous reduction folds into a
    /// max; this exposes them without changing any of the round arithmetic.
    pub fn set_record_units(&mut self, on: bool) {
        self.record_units = on;
    }

    /// Per-unit durations of the last analytic round, in evaluation order:
    /// FedPairing = pairs (in call order) then solos; FL/SL/SplitFed = one
    /// entry per client in fleet order. FedPairing/FL entries include the
    /// model upload when the round did; SplitFed entries are the pre-upload
    /// server-pipeline finish times; SL entries are per-session durations
    /// (the round total is their running sum). Empty on the DES backend or
    /// while recording is off.
    pub fn unit_times(&self) -> &[f64] {
        &self.unit_times
    }

    /// Per-unit compute/communication attribution of the last analytic
    /// round, aligned index-for-index with [`RoundEngine::unit_times`]:
    /// `[compute_a, comm_a, compute_b, comm_b]` seconds per unit. For
    /// FedPairing pairs the split is resource-sided — client `a`'s CPU busy
    /// time and its transmit link (plus its own model upload when the round
    /// uploads), likewise for `b`. Solo/FL/SL/SplitFed units fill the
    /// a-slots and zero the b-slots (SL server compute and SplitFed's shared
    /// FedAvg upload tail are not client-attributed). The observatory's
    /// fairness ledger feeds on this. Empty on the DES backend or while
    /// recording is off.
    pub fn unit_splits(&self) -> &[[f64; 4]] {
        &self.unit_splits
    }

    /// Install a split-planning config (builder style; default is `Paper`,
    /// which reproduces the pre-planner engine bit-for-bit).
    pub fn with_split(mut self, split: SplitConfig) -> RoundEngine {
        self.split = split;
        self
    }

    pub fn backend(&self) -> RoundBackend {
        self.backend
    }

    pub fn split(&self) -> &SplitConfig {
        &self.split
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Cumulative pair-cache hits across all rounds evaluated so far.
    pub fn cache_hits(&self) -> u64 {
        self.hits
    }

    /// Cumulative pair-cache misses (= kernel evaluations).
    pub fn cache_misses(&self) -> u64 {
        self.misses
    }

    /// `(i, j, total_s)` per pair of the last FedPairing round, for the
    /// trace exporter's pair lanes. Empty unless telemetry was enabled
    /// during the round (and on the DES backend, which skips collection).
    pub fn pair_lanes(&self) -> &[(usize, usize, f64)] {
        &self.lanes
    }

    /// Clear the memo cache if the model/schedule/compute context changed
    /// since the cached entries were computed.
    fn ensure_context(&mut self, profile: &ModelProfile, sched: &Schedule, comp: &ComputeConfig) {
        let mut s = 0xC0FF_EE00_D15E_A5E5u64;
        let mut acc = 0u64;
        let mut fold = |v: u64| {
            s ^= v;
            acc ^= splitmix64(&mut s);
        };
        fold(profile.w() as u64);
        for l in &profile.layers {
            fold(l.flops_fwd.to_bits());
            fold(l.act_bytes.to_bits());
            fold(l.params as u64);
        }
        fold(profile.input_bytes.to_bits());
        fold(sched.batch_size as u64);
        fold(sched.epochs as u64);
        fold(comp.cycles_per_flop.to_bits());
        // The split config decides each cached entry's cut — switching
        // policy or search bounds must invalidate everything.
        fold(match self.split.policy {
            SplitPolicy::Paper => 0,
            SplitPolicy::Balanced => 1,
            SplitPolicy::Optimal => 2,
        });
        fold(self.split.min_layers as u64);
        if acc != self.context {
            self.cache.clear();
            self.next.clear();
            self.context = acc;
        }
    }

    /// FedPairing round time under a given pairing + solo set — the metro
    /// hot path: O(changed pairs · batches) instead of O(pairs · batches ·
    /// log) with per-pair allocations.
    #[allow(clippy::too_many_arguments)]
    pub fn fedpairing_round<C: ClientSet + Sync>(
        &mut self,
        fleet: &C,
        pairs: &[(usize, usize)],
        solos: &[usize],
        profile: &ModelProfile,
        sched: &Schedule,
        channel: &Channel,
        comp: &ComputeConfig,
        include_upload: bool,
    ) -> RoundTime {
        self.lanes.clear();
        self.unit_times.clear();
        self.unit_splits.clear();
        if self.backend == RoundBackend::Des {
            registry::count(Counter::KernelEvalsDes, 1);
            let mut rt = latency::fedpairing_round_planned(
                fleet,
                pairs,
                solos,
                profile,
                sched,
                channel,
                comp,
                include_upload,
                &self.split,
            );
            if !self.flow_diagnostics {
                rt.flow_finish_s = Vec::new();
            }
            return rt;
        }
        self.ensure_context(profile, sched, comp);
        // Phase 1: keys + cache lookups (serial, O(pairs)).
        self.keys.clear();
        self.miss.clear();
        self.evals.clear();
        self.evals.resize(pairs.len(), PairEval::ZERO);
        for (k, &(i, j)) in pairs.iter().enumerate() {
            let key = PairKey::new(
                fleet.freq_hz(i),
                fleet.freq_hz(j),
                fleet.n_samples(i),
                fleet.n_samples(j),
                channel.rate(&fleet.pos(i), &fleet.pos(j)),
            );
            if let Some(e) = self.cache.get(&key) {
                self.evals[k] = *e;
            } else {
                self.miss.push(k);
            }
            self.keys.push(key);
        }
        self.hits += (pairs.len() - self.miss.len()) as u64;
        self.misses += self.miss.len() as u64;
        registry::count(Counter::MemoHits, (pairs.len() - self.miss.len()) as u64);
        registry::count(Counter::MemoMisses, self.miss.len() as u64);
        // (kernel_evals_analytic_total is counted at the kernel funnel,
        // `split::eval_at`, so the `Optimal` policy's search evaluations are
        // visible — one increment per candidate cut, not per miss.)
        // Phase 2: evaluate the misses — in parallel when it pays. Each
        // kernel is a pure function of its pair's inputs and results are
        // merged back by pair index, so any thread count is bit-identical.
        let computed: Vec<PairEval> = {
            let miss = &self.miss;
            let keys = &self.keys;
            let split_cfg = self.split;
            let eval_one = |m: usize| {
                let k = miss[m];
                let (i, j) = pairs[k];
                // Reuse the rate evaluated for the cache key — bit-exactly
                // the value the kernel would recompute.
                pair_kernel(
                    fleet,
                    i,
                    j,
                    f64::from_bits(keys[k].rate),
                    profile,
                    sched,
                    comp,
                    &split_cfg,
                )
            };
            if miss.len() < PAR_MIN_MISSES || self.pool.threads() == 1 {
                (0..miss.len()).map(eval_one).collect()
            } else {
                self.pool.map(miss.len(), eval_one)
            }
        };
        for (slot, e) in self.miss.iter().zip(computed) {
            self.evals[*slot] = e;
        }
        // Phase 3: generation swap — everything this round touched survives
        // into the next round's cache; untouched entries are evicted, so the
        // cache stays O(live pairs) even under per-round churn.
        for (k, key) in self.keys.iter().enumerate() {
            self.next.insert(*key, self.evals[k]);
        }
        if registry::enabled() {
            // Exact when this round's pair keys are distinct (the usual
            // case): survivors = |next|, so evicted = old + new − survivors.
            let evicted = (self.cache.len() + self.miss.len()).saturating_sub(self.next.len());
            registry::count(Counter::MemoEvictions, evicted as u64);
            registry::gauge_set(Gauge::MemoCacheEntries, self.next.len() as u64);
        }
        std::mem::swap(&mut self.cache, &mut self.next);
        self.next.clear();
        // Phase 4: ordered reduction — identical op order to the DES path.
        let diag = self.flow_diagnostics;
        let lanes_on = registry::enabled();
        let mut total = 0.0f64;
        let mut max_cpu = 0.0f64;
        let mut max_link = 0.0f64;
        let mut cut_sum = 0usize;
        let mut finishes = if diag {
            Vec::with_capacity(pairs.len() * 2 + solos.len())
        } else {
            Vec::new()
        };
        self.totals.clear();
        let mut crit_total = f64::NEG_INFINITY;
        let mut crit_pair: Option<(usize, usize, usize, f64, f64)> = None;
        let mut crit_solo: Option<(usize, f64, f64)> = None;
        for (k, &(i, j)) in pairs.iter().enumerate() {
            let e = &self.evals[k];
            let mut pair_total = e.makespan;
            let mut up = 0.0f64;
            let mut up_i = 0.0f64;
            let mut up_j = 0.0f64;
            if include_upload {
                up_i = upload_time(fleet, channel, i, profile.param_bytes());
                up_j = upload_time(fleet, channel, j, profile.param_bytes());
                up = up_i.max(up_j);
                pair_total += up;
            }
            if self.record_units {
                // Resource-sided attribution: each member's own CPU busy
                // time plus its transmit link and model upload.
                self.unit_splits.push([
                    e.busy[0],
                    e.busy[2] + up_i,
                    e.busy[1],
                    e.busy[3] + up_j,
                ]);
            }
            total = total.max(pair_total);
            max_cpu = max_cpu.max(e.busy[0]).max(e.busy[1]);
            max_link = max_link.max(e.busy[2]).max(e.busy[3]);
            cut_sum += e.cut;
            if diag {
                finishes.extend_from_slice(&e.finish);
            }
            self.totals.push(pair_total);
            if pair_total > crit_total {
                crit_total = pair_total;
                crit_pair = Some((i, j, e.cut, f64::from_bits(self.keys[k].rate), up));
            }
            if lanes_on {
                self.lanes.push((i, j, pair_total));
            }
        }
        for &s in solos {
            let (compute_s, t) =
                full_local_time(fleet, s, profile, sched, channel, comp, include_upload);
            if self.record_units {
                self.unit_splits.push([compute_s, (t - compute_s).max(0.0), 0.0, 0.0]);
            }
            max_cpu = max_cpu.max(compute_s);
            total = total.max(t);
            if diag {
                finishes.push(t);
            }
            self.totals.push(t);
            if t > crit_total {
                crit_total = t;
                crit_pair = None;
                crit_solo = Some((s, compute_s, t - compute_s));
            }
        }
        if self.record_units {
            // Snapshot before the breakdown's p50 selection reorders totals.
            self.unit_times.extend_from_slice(&self.totals);
        }
        let stages = latency::fedpairing_breakdown(
            fleet,
            profile,
            sched,
            comp,
            crit_pair,
            crit_solo,
            crit_total,
            &mut self.totals,
        );
        RoundTime {
            total_s: total,
            max_cpu_busy_s: max_cpu,
            max_link_busy_s: max_link,
            mean_cut: mean_cut_of(cut_sum, pairs.len()),
            stages,
            faults: Default::default(),
            flow_finish_s: finishes,
        }
    }

    /// Vanilla-FL round: already closed form — both backends share the
    /// `latency` arithmetic. With diagnostics off the per-client finish
    /// times are never materialized (running max instead of an n-element
    /// Vec per round — the allocation the knob exists to skip).
    pub fn fl_round<C: ClientSet>(
        &mut self,
        fleet: &C,
        profile: &ModelProfile,
        sched: &Schedule,
        channel: &Channel,
        comp: &ComputeConfig,
        include_upload: bool,
    ) -> RoundTime {
        self.unit_times.clear();
        self.unit_splits.clear();
        if self.flow_diagnostics {
            let rt = latency::fl_round(fleet, profile, sched, channel, comp, include_upload);
            if self.record_units {
                // The diagnostics path already materializes per-client finish
                // times — they are exactly the per-unit durations. The
                // compute/comm split is recovered from the same closed form
                // (attribution only; round arithmetic is untouched).
                self.unit_times.extend_from_slice(&rt.flow_finish_s);
                for i in 0..fleet.n() {
                    let (compute_s, t) =
                        full_local_time(fleet, i, profile, sched, channel, comp, include_upload);
                    self.unit_splits.push([compute_s, (t - compute_s).max(0.0), 0.0, 0.0]);
                }
            }
            return rt;
        }
        let mut total = 0.0f64;
        let mut max_cpu = 0.0f64;
        let mut stages = StageBreakdown::default();
        let mut crit_total = f64::NEG_INFINITY;
        self.totals.clear();
        for i in 0..fleet.n() {
            let (compute_s, t) =
                full_local_time(fleet, i, profile, sched, channel, comp, include_upload);
            if self.record_units {
                self.unit_splits.push([compute_s, (t - compute_s).max(0.0), 0.0, 0.0]);
            }
            max_cpu = max_cpu.max(compute_s);
            if t > crit_total {
                crit_total = t;
                stages.stage_s = breakdown::solo_stages(compute_s, t - compute_s);
                stages.crit_a = i as i64;
            }
            total = total.max(t);
            self.totals.push(t);
        }
        if self.record_units {
            self.unit_times.extend_from_slice(&self.totals);
        }
        if !self.totals.is_empty() {
            stages.crit_slack_s = crit_total - breakdown::p50(&mut self.totals);
        }
        RoundTime {
            total_s: total,
            max_cpu_busy_s: max_cpu,
            max_link_busy_s: 0.0,
            mean_cut: f64::NAN,
            stages,
            faults: Default::default(),
            flow_finish_s: Vec::new(),
        }
    }

    /// Vanilla-SL round: one uncontended chain per session, so the DES
    /// makespan is the exact stage-order sum — computed directly.
    #[allow(clippy::too_many_arguments)]
    pub fn sl_round<C: ClientSet>(
        &mut self,
        fleet: &C,
        profile: &ModelProfile,
        sched: &Schedule,
        channel: &Channel,
        comp: &ComputeConfig,
        cut: usize,
        server_freq_hz: f64,
    ) -> RoundTime {
        self.unit_times.clear();
        self.unit_splits.clear();
        if self.backend == RoundBackend::Des {
            let mut rt =
                latency::sl_round(fleet, profile, sched, channel, comp, cut, server_freq_hz);
            if !self.flow_diagnostics {
                rt.flow_finish_s = Vec::new();
            }
            return rt;
        }
        assert!(cut >= 1 && cut < profile.w(), "cut {cut} out of range");
        let n = fleet.n();
        // Stage → resource of the session chain (0 = cpu, 1 = server,
        // 2 = uplink, 3 = downlink), in DES push order.
        const RES: [usize; 5] = [0, 2, 1, 3, 0];
        let mut total = 0.0f64;
        let mut max_cpu = 0.0f64;
        let mut max_link = 0.0f64;
        let mut finishes = if self.flow_diagnostics {
            Vec::with_capacity(n)
        } else {
            Vec::new()
        };
        let mut stages = StageBreakdown::default();
        self.totals.clear();
        let mut crit_session = f64::NEG_INFINITY;
        for i in 0..n {
            let rate = channel.rate_to_server(&fleet.pos(i));
            let dur = split_stage_durations(
                profile,
                comp,
                sched.batch_size,
                cut,
                fleet.freq_hz(i),
                server_freq_hz,
                rate,
            );
            let nb = sched.batches(fleet.n_samples(i));
            let mut t = 0.0f64;
            let mut busy = [0.0f64; 4];
            for _ in 0..nb {
                for (s, &d) in dur.iter().enumerate() {
                    t += d;
                    busy[RES[s]] += d;
                }
            }
            for (acc, &d) in stages.stage_s.iter_mut().take(5).zip(dur.iter()) {
                *acc += d * nb as f64;
            }
            let mut session = t;
            // Client-model relay to the next client in the ring.
            let next = (i + 1) % n;
            let mut relay_s = 0.0f64;
            if n > 1 {
                let front_bytes = profile.params(0, cut) as f64 * 4.0;
                relay_s =
                    transmit_time(front_bytes, channel.rate(&fleet.pos(i), &fleet.pos(next)));
                session += relay_s;
                stages.stage_s[5] += relay_s;
            }
            if self.record_units {
                // Client-side attribution: own CPU, uplink + downlink + ring
                // relay. Server compute (busy[1]) is not client-attributed.
                self.unit_splits.push([busy[0], busy[2] + busy[3] + relay_s, 0.0, 0.0]);
            }
            total += session;
            self.totals.push(session);
            if session > crit_session {
                crit_session = session;
                stages.crit_a = i as i64;
            }
            if self.flow_diagnostics {
                finishes.push(total);
            }
            max_cpu = max_cpu.max(busy[0]).max(busy[1]);
            max_link = max_link.max(busy[2]).max(busy[3]);
        }
        if self.record_units {
            self.unit_times.extend_from_slice(&self.totals);
        }
        if !self.totals.is_empty() {
            stages.crit_slack_s = crit_session - breakdown::p50(&mut self.totals);
        }
        RoundTime {
            total_s: total,
            max_cpu_busy_s: max_cpu,
            max_link_busy_s: max_link,
            mean_cut: cut as f64,
            stages,
            faults: Default::default(),
            flow_finish_s: finishes,
        }
    }

    /// SplitFed round: per-client CPUs and links are private, so the job
    /// shop reduces to a FIFO recurrence on the shared server — arrivals are
    /// served in arrival order (a binary heap of each chain's next arrival),
    /// each service feeding the chain's next arrival time. Equal arrival
    /// times break by chain id, which matches the DES seq order whenever the
    /// tied chains are configured identically (the only way exact float ties
    /// arise from sampled fleets).
    #[allow(clippy::too_many_arguments)]
    pub fn splitfed_round<C: ClientSet>(
        &mut self,
        fleet: &C,
        profile: &ModelProfile,
        sched: &Schedule,
        channel: &Channel,
        comp: &ComputeConfig,
        cut: usize,
        server_freq_hz: f64,
        include_upload: bool,
    ) -> RoundTime {
        self.unit_times.clear();
        self.unit_splits.clear();
        if self.backend == RoundBackend::Des {
            let mut rt = latency::splitfed_round(
                fleet,
                profile,
                sched,
                channel,
                comp,
                cut,
                server_freq_hz,
                include_upload,
            );
            if !self.flow_diagnostics {
                rt.flow_finish_s = Vec::new();
            }
            return rt;
        }
        assert!(cut >= 1 && cut < profile.w(), "cut {cut} out of range");
        let n = fleet.n();
        let mut durs: Vec<[f64; 5]> = Vec::with_capacity(n);
        let mut nbs: Vec<usize> = Vec::with_capacity(n);
        let mut max_cpu = 0.0f64;
        let mut max_link = 0.0f64;
        let mut heap: BinaryHeap<Arrival> = BinaryHeap::with_capacity(n);
        for i in 0..n {
            let rate = channel.rate_to_server(&fleet.pos(i));
            let dur = split_stage_durations(
                profile,
                comp,
                sched.batch_size,
                cut,
                fleet.freq_hz(i),
                server_freq_hz,
                rate,
            );
            let nb = sched.batches(fleet.n_samples(i));
            // Private resources never queue: their busy totals are plain
            // stage sums, accumulated in the DES's per-resource add order.
            let mut cpu = 0.0f64;
            let mut up = 0.0f64;
            let mut down = 0.0f64;
            for _ in 0..nb {
                cpu += dur[0];
                cpu += dur[4];
                up += dur[1];
                down += dur[3];
            }
            max_cpu = max_cpu.max(cpu);
            max_link = max_link.max(up).max(down);
            if self.record_units {
                // Private-resource attribution (fleet order, aligned with the
                // finish times recorded below); the shared FedAvg upload tail
                // is not per-client.
                self.unit_splits.push([cpu, up + down, 0.0, 0.0]);
            }
            if nb > 0 {
                // First server arrival: front-fwd then uplink.
                let mut t = 0.0f64;
                t += dur[0];
                t += dur[1];
                heap.push(Arrival { t, chain: i });
            }
            durs.push(dur);
            nbs.push(nb);
        }
        let mut batch = vec![0usize; n];
        let mut finish = vec![0.0f64; n];
        let mut server_busy = 0.0f64;
        let mut server_free = 0.0f64;
        while let Some(Arrival { t: arrival, chain: i }) = heap.pop() {
            let dur = durs[i];
            let start = arrival.max(server_free);
            server_busy += dur[2];
            let completion = start + dur[2];
            server_free = completion;
            batch[i] += 1;
            // Downlink then front-bwd, then (for non-final batches) the next
            // batch's front-fwd + uplink — sequential adds, DES op order.
            let mut t = completion;
            t += dur[3];
            t += dur[4];
            if batch[i] < nbs[i] {
                t += dur[0];
                t += dur[1];
                heap.push(Arrival { t, chain: i });
            } else {
                finish[i] = t;
            }
        }
        if self.record_units {
            // Pre-upload pipeline finishes: the async scheduler re-prices the
            // FedAvg upload per merge, over the merge's actual contributors.
            self.unit_times.extend_from_slice(&finish);
        }
        let mut total = finish.iter().cloned().fold(0.0, f64::max);
        max_cpu = max_cpu.max(server_busy);
        let mut stages = latency::splitfed_breakdown(fleet, sched, &durs, &finish);
        if include_upload {
            // FedAvg sync of the client-side models.
            let front_bytes = profile.params(0, cut) as f64 * 4.0;
            let up = (0..n)
                .map(|i| upload_time(fleet, channel, i, front_bytes))
                .fold(0.0, f64::max);
            total += up;
            stages.stage_s[5] = up;
        }
        RoundTime {
            total_s: total,
            max_cpu_busy_s: max_cpu,
            max_link_busy_s: max_link,
            mean_cut: cut as f64,
            stages,
            faults: Default::default(),
            flow_finish_s: if self.flow_diagnostics {
                finish
            } else {
                Vec::new()
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};
    use crate::sim::compute::split_lengths;
    use crate::sim::latency::Fleet;
    use crate::util::rng::Rng;

    fn setup() -> (Fleet, ModelProfile, Schedule, Channel, ComputeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 10;
        cfg.samples_per_client = 96;
        let mut rng = Rng::new(11);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let profile = ModelProfile::resnet10_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: 2,
        };
        let channel = Channel::new(ChannelConfig::default());
        (fleet, profile, sched, channel, cfg.compute)
    }

    fn engine(threads: usize) -> RoundEngine {
        RoundEngine::new(&EngineConfig {
            backend: RoundBackend::Analytic,
            threads,
            flow_diagnostics: true,
        })
    }

    fn pair_all(n: usize) -> Vec<(usize, usize)> {
        (0..n / 2).map(|k| (2 * k, 2 * k + 1)).collect()
    }

    #[test]
    fn pair_kernel_bit_identical_to_des() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let des = latency::fedpairing_round_with_solos(
            &fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true,
        );
        let mut eng = engine(1);
        let ana =
            eng.fedpairing_round(&fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true);
        assert_eq!(ana.total_s.to_bits(), des.total_s.to_bits());
        assert_eq!(ana.max_cpu_busy_s.to_bits(), des.max_cpu_busy_s.to_bits());
        assert_eq!(ana.max_link_busy_s.to_bits(), des.max_link_busy_s.to_bits());
        assert_eq!(ana.flow_finish_s, des.flow_finish_s);
    }

    #[test]
    fn sl_and_splitfed_kernels_match_des() {
        let (fleet, profile, sched, channel, comp) = setup();
        let mut eng = engine(1);
        let sl_a = eng.sl_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9);
        let sl_d = latency::sl_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9);
        assert_eq!(sl_a.total_s.to_bits(), sl_d.total_s.to_bits());
        assert_eq!(sl_a.flow_finish_s, sl_d.flow_finish_s);
        let sf_a = eng.splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9, true);
        let sf_d =
            latency::splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9, true);
        assert_eq!(sf_a.total_s.to_bits(), sf_d.total_s.to_bits());
        assert_eq!(sf_a.max_cpu_busy_s.to_bits(), sf_d.max_cpu_busy_s.to_bits());
        assert_eq!(sf_a.flow_finish_s, sf_d.flow_finish_s);
    }

    #[test]
    fn cache_hits_after_first_round() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        let a = eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.cache_misses(), pairs.len() as u64);
        assert_eq!(eng.cache_hits(), 0);
        let b = eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.cache_misses(), pairs.len() as u64, "stable round recomputed");
        assert_eq!(eng.cache_hits(), pairs.len() as u64);
        assert_eq!(a.total_s.to_bits(), b.total_s.to_bits());
    }

    #[test]
    fn channel_change_invalidates_affected_pairs() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        // Global shadowing draw: every pair rate moves → every pair misses.
        let mut faded_cfg = *channel.config();
        faded_cfg.ref_gain *= 0.5;
        let faded = Channel::new(faded_cfg);
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &faded, &comp, true);
        assert_eq!(eng.cache_misses(), 2 * pairs.len() as u64);
        // And back: the faded-round generation evicted the originals.
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.cache_misses(), 3 * pairs.len() as u64);
    }

    #[test]
    fn straggler_invalidates_only_its_pair() {
        let (mut fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        fleet.freqs_hz[3] *= 0.35; // straggle one member of pair (2, 3)
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.cache_misses(), pairs.len() as u64 + 1);
        assert_eq!(eng.cache_hits(), pairs.len() as u64 - 1);
    }

    #[test]
    fn thread_count_never_changes_results() {
        let (fleet, profile, sched, channel, comp) = setup();
        // Enough pairs to cross PAR_MIN_MISSES: replicate the fleet pairing
        // across many (i, j) combinations.
        let pairs: Vec<(usize, usize)> = (0..fleet.n())
            .flat_map(|i| (0..fleet.n()).filter(move |&j| j != i).map(move |j| (i, j)))
            .collect();
        assert!(pairs.len() >= PAR_MIN_MISSES);
        let mut serial = engine(1);
        let a =
            serial.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        for threads in [2, 4, 7] {
            let mut par = engine(threads);
            let b =
                par.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
            assert_eq!(a.total_s.to_bits(), b.total_s.to_bits(), "threads={threads}");
            assert_eq!(a.flow_finish_s, b.flow_finish_s, "threads={threads}");
        }
    }

    #[test]
    fn context_switch_clears_the_cache() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        // Same pair inputs, different model: must not reuse cached makespans.
        let other = ModelProfile::resnet18_cifar();
        eng.fedpairing_round(&fleet, &pairs, &[], &other, &sched, &channel, &comp, true);
        assert_eq!(eng.cache_misses(), 2 * pairs.len() as u64);
        let a = eng.fedpairing_round(&fleet, &pairs, &[], &other, &sched, &channel, &comp, true);
        let d = latency::fedpairing_round(&fleet, &pairs, &other, &sched, &channel, &comp, true);
        assert_eq!(a.total_s.to_bits(), d.total_s.to_bits());
    }

    #[test]
    fn diagnostics_off_skips_flow_finish_only() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut quiet = RoundEngine::new(&EngineConfig {
            backend: RoundBackend::Analytic,
            threads: 1,
            flow_diagnostics: false,
        });
        let q =
            quiet.fedpairing_round(&fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true);
        let full = latency::fedpairing_round_with_solos(
            &fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true,
        );
        assert!(q.flow_finish_s.is_empty());
        assert_eq!(q.total_s.to_bits(), full.total_s.to_bits());
        let sl = quiet.sl_round(&fleet, &profile, &sched, &channel, &comp, 1, 100e9);
        assert!(sl.flow_finish_s.is_empty());
        let sf = quiet.splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9, true);
        assert!(sf.flow_finish_s.is_empty());
        let fl = quiet.fl_round(&fleet, &profile, &sched, &channel, &comp, true);
        assert!(fl.flow_finish_s.is_empty());
    }

    #[test]
    fn des_backend_delegates_to_the_oracle() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = RoundEngine::new(&EngineConfig {
            backend: RoundBackend::Des,
            threads: 1,
            flow_diagnostics: true,
        });
        let a = eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        let d = latency::fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, true);
        assert_eq!(a.total_s.to_bits(), d.total_s.to_bits());
        assert_eq!(eng.cache_misses(), 0, "oracle backend must not touch the cache");
    }

    #[test]
    fn split_policy_switch_clears_the_cache() {
        use crate::config::{SplitConfig, SplitPolicy};
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut paper = engine(1);
        let a =
            paper.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        let mut opt = engine(1).with_split(SplitConfig {
            policy: SplitPolicy::Optimal,
            ..SplitConfig::default()
        });
        // Same inputs, different policy: full recompute, and the optimal
        // round can never be slower than the paper round.
        let b = opt.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(opt.cache_misses(), pairs.len() as u64);
        assert!(b.total_s <= a.total_s + 1e-9, "{} !<= {}", b.total_s, a.total_s);
        assert!(b.mean_cut.is_finite() && a.mean_cut.is_finite());
        // Switching the policy on a live engine invalidates its entries.
        let c = opt.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(opt.cache_misses(), pairs.len() as u64, "stable round recomputed");
        assert_eq!(b.total_s.to_bits(), c.total_s.to_bits());
        let mut flipped = RoundEngine::new(&EngineConfig {
            backend: RoundBackend::Analytic,
            threads: 1,
            flow_diagnostics: true,
        })
        .with_split(SplitConfig {
            policy: SplitPolicy::Balanced,
            ..SplitConfig::default()
        });
        flipped.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        flipped = flipped.with_split(SplitConfig::default());
        flipped.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(
            flipped.cache_misses(),
            2 * pairs.len() as u64,
            "policy switch must clear the memo cache"
        );
    }

    #[test]
    fn planned_engine_matches_planned_des_bit_for_bit() {
        use crate::config::{SplitConfig, SplitPolicy};
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        for policy in [SplitPolicy::Balanced, SplitPolicy::Optimal] {
            let split = SplitConfig {
                policy,
                ..SplitConfig::default()
            };
            let mut eng = engine(1).with_split(split);
            let ana = eng
                .fedpairing_round(&fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true);
            let des = latency::fedpairing_round_planned(
                &fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true, &split,
            );
            assert_eq!(ana.total_s.to_bits(), des.total_s.to_bits(), "{policy:?}");
            assert_eq!(ana.flow_finish_s, des.flow_finish_s, "{policy:?}");
            assert_eq!(ana.mean_cut.to_bits(), des.mean_cut.to_bits(), "{policy:?}");
        }
    }

    #[test]
    fn paper_policy_round_reports_paper_cuts() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        let rt = eng.fedpairing_round(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, true);
        let expect: usize = pairs
            .iter()
            .map(|&(i, j)| split_lengths(fleet.freqs_hz[i], fleet.freqs_hz[j], profile.w()).0)
            .sum();
        assert_eq!(rt.mean_cut, expect as f64 / pairs.len() as f64);
    }

    #[test]
    fn record_units_captures_aligned_splits() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let mut eng = engine(1);
        eng.set_record_units(true);
        let rt =
            eng.fedpairing_round(&fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.unit_times().len(), pairs.len() + 1);
        assert_eq!(eng.unit_splits().len(), eng.unit_times().len());
        // Solo unit: compute + comm reconstructs its total; b-slots zero.
        let solo = eng.unit_splits()[pairs.len()];
        let solo_t = eng.unit_times()[pairs.len()];
        assert!((solo[0] + solo[1] - solo_t).abs() < 1e-9);
        assert_eq!((solo[2], solo[3]), (0.0, 0.0));
        // Pair units attribute both members.
        let pair = eng.unit_splits()[0];
        assert!(pair[0] > 0.0 && pair[2] > 0.0);
        // Recording is attribution only: a non-recording engine produces a
        // bit-identical round and no splits.
        let mut quiet = engine(1);
        let rt2 =
            quiet.fedpairing_round(&fleet, &pairs, &[9], &profile, &sched, &channel, &comp, true);
        assert_eq!(rt.total_s.to_bits(), rt2.total_s.to_bits());
        assert!(quiet.unit_splits().is_empty());
        // The other three kernels record one aligned split per client.
        eng.fl_round(&fleet, &profile, &sched, &channel, &comp, true);
        assert_eq!(eng.unit_splits().len(), fleet.n());
        assert_eq!(eng.unit_times().len(), fleet.n());
        eng.sl_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9);
        assert_eq!(eng.unit_splits().len(), fleet.n());
        eng.splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9, true);
        assert_eq!(eng.unit_splits().len(), fleet.n());
        assert_eq!(eng.unit_times().len(), fleet.n());
    }

    #[test]
    fn zero_pairs_and_solos_give_zero_round() {
        let (fleet, profile, sched, channel, comp) = setup();
        let mut eng = engine(1);
        let rt = eng.fedpairing_round(&fleet, &[], &[], &profile, &sched, &channel, &comp, true);
        assert_eq!(rt.total_s, 0.0);
        assert!(rt.flow_finish_s.is_empty());
    }
}
