//! The heterogeneity/latency simulation substrate (DESIGN.md §2): client
//! geometry, the eq. (3) OFDM channel, CPU heterogeneity, static model cost
//! profiles (ResNet-18/10, the AOT MLP), a deterministic discrete-event
//! engine, and per-algorithm round-time models that regenerate the paper's
//! Tables I and II.

pub mod channel;
pub mod compute;
pub mod des;
pub mod geometry;
pub mod latency;
pub mod profile;
