//! The heterogeneity/latency simulation substrate (DESIGN.md §2): client
//! geometry, the eq. (3) OFDM channel, CPU heterogeneity, static model cost
//! profiles (ResNet-18/34/10, the AOT MLP), a deterministic discrete-event
//! engine, per-algorithm round-time models that regenerate the paper's
//! Tables I and II, and the incremental round-time engine (analytic kernels
//! + memo cache + parallel evaluation, DESIGN.md §6) that makes per-round
//! evaluation O(changed pairs) at fleet scale.

pub mod channel;
pub mod compute;
pub mod des;
pub mod engine;
pub mod geometry;
pub mod latency;
pub mod profile;
