//! Static model cost profiles: per-layer FLOPs, activation sizes and parameter
//! counts, used by the latency simulator (Tables I & II) in place of the
//! authors' physical testbed (DESIGN.md §2).
//!
//! The timing experiments need the *cost structure* of the paper's ResNet-18 /
//! ResNet-10 on 3×32×32 CIFAR inputs — not actual CNN training — so we tabulate
//! those architectures layer by layer. "Layer" granularity matches the paper's
//! splittable units: the stem conv, each residual block, and the FC head.

/// Cost of one splittable unit.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    /// Forward FLOPs per input sample.
    pub flops_fwd: f64,
    /// Bytes of this unit's *output* activation per sample (f32).
    pub act_bytes: f64,
    /// Parameter count.
    pub params: usize,
}

/// A full model as an ordered list of splittable units.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelProfile {
    pub name: String,
    pub layers: Vec<LayerProfile>,
    /// Bytes of one input sample (3×32×32 f32 = 12288 for CIFAR).
    pub input_bytes: f64,
}

/// Backward pass ≈ 2× forward FLOPs (grad w.r.t. inputs + grad w.r.t. weights).
pub const BWD_FLOPS_FACTOR: f64 = 2.0;

impl ModelProfile {
    /// Number of splittable units `W`.
    pub fn w(&self) -> usize {
        self.layers.len()
    }

    /// Forward FLOPs per sample over units `[lo, hi)`.
    pub fn fwd_flops(&self, lo: usize, hi: usize) -> f64 {
        self.layers[lo..hi].iter().map(|l| l.flops_fwd).sum()
    }

    /// Forward+backward (training) FLOPs per sample over units `[lo, hi)`.
    pub fn train_flops(&self, lo: usize, hi: usize) -> f64 {
        self.fwd_flops(lo, hi) * (1.0 + BWD_FLOPS_FACTOR)
    }

    /// Total parameters in units `[lo, hi)`.
    pub fn params(&self, lo: usize, hi: usize) -> usize {
        self.layers[lo..hi].iter().map(|l| l.params).sum()
    }

    /// Bytes of all parameters (f32).
    pub fn param_bytes(&self) -> f64 {
        self.params(0, self.w()) as f64 * 4.0
    }

    /// Bytes per sample of the activation crossing a split *after* unit
    /// `split` units (i.e. the output of unit `split-1`); `split=0` is the
    /// raw input.
    pub fn act_bytes_at(&self, split: usize) -> f64 {
        assert!(split <= self.w(), "split {split} > W {}", self.w());
        if split == 0 {
            self.input_bytes
        } else {
            self.layers[split - 1].act_bytes
        }
    }

    // ------------------------------------------------------------------
    // Architectures
    // ------------------------------------------------------------------

    /// CIFAR-style ResNet-18: 3×3/64 stem; stages 64/128/256/512, two basic
    /// blocks each, stride-2 at stage entry; FC head. W = 10 units.
    pub fn resnet18_cifar() -> ModelProfile {
        Self::resnet_cifar("resnet18", &[2, 2, 2, 2])
    }

    /// CIFAR-style ResNet-10: one basic block per stage. W = 6 units.
    pub fn resnet10_cifar() -> ModelProfile {
        Self::resnet_cifar("resnet10", &[1, 1, 1, 1])
    }

    /// CIFAR-style ResNet-34: 3/4/6/3 basic blocks per stage. W = 18 units —
    /// deep enough that the split planner's cut search is non-trivial.
    pub fn resnet34_cifar() -> ModelProfile {
        Self::resnet_cifar("resnet34", &[3, 4, 6, 3])
    }

    /// The profile behind a [`ModelPreset`](crate::config::ModelPreset) —
    /// the single mapping the config layer, CLI and drivers share.
    pub fn from_preset(preset: crate::config::ModelPreset) -> ModelProfile {
        use crate::config::ModelPreset;
        match preset {
            ModelPreset::Resnet18 => Self::resnet18_cifar(),
            ModelPreset::Resnet34 => Self::resnet34_cifar(),
            ModelPreset::Resnet10 => Self::resnet10_cifar(),
            ModelPreset::Mlp => Self::mlp(3072, 256, 10, 8),
        }
    }

    fn resnet_cifar(name: &str, blocks_per_stage: &[usize]) -> ModelProfile {
        let mut layers = Vec::new();
        // Stem: conv3x3, 3→64, 32×32 output.
        layers.push(conv_layer("conv1", 3, 64, 3, 32, 32));
        let stage_ch = [64usize, 128, 256, 512];
        let stage_hw = [32usize, 16, 8, 4];
        let mut c_in = 64;
        for (s, (&c_out, &hw)) in stage_ch.iter().zip(&stage_hw).enumerate() {
            for b in 0..blocks_per_stage[s] {
                let downsample = b == 0 && c_in != c_out;
                layers.push(basic_block(
                    &format!("s{}b{}", s + 1, b + 1),
                    if b == 0 { c_in } else { c_out },
                    c_out,
                    hw,
                    downsample,
                ));
            }
            c_in = c_out;
        }
        // Global average pool + FC 512→10.
        layers.push(LayerProfile {
            name: "fc".into(),
            flops_fwd: 2.0 * 512.0 * 10.0,
            act_bytes: 10.0 * 4.0,
            params: 512 * 10 + 10,
        });
        ModelProfile {
            name: name.into(),
            layers,
            input_bytes: 3.0 * 32.0 * 32.0 * 4.0,
        }
    }

    /// Residual-MLP profile matching the AOT-exported model (`model::Meta`),
    /// so accuracy runs and timing runs share one cost model.
    pub fn mlp(input_dim: usize, hidden: usize, classes: usize, layers_n: usize) -> ModelProfile {
        assert!(layers_n >= 2);
        let mut layers = Vec::new();
        let dims = {
            let mut d = vec![(input_dim, hidden)];
            d.extend(std::iter::repeat((hidden, hidden)).take(layers_n - 2));
            d.push((hidden, classes));
            d
        };
        for (i, (fi, fo)) in dims.iter().enumerate() {
            layers.push(LayerProfile {
                name: format!("fc{i}"),
                flops_fwd: 2.0 * (*fi as f64) * (*fo as f64),
                act_bytes: *fo as f64 * 4.0,
                params: fi * fo + fo,
            });
        }
        ModelProfile {
            name: format!("mlp{layers_n}x{hidden}"),
            layers,
            input_bytes: input_dim as f64 * 4.0,
        }
    }

    /// The paper's original abstraction: `W` identical layers costing `F`
    /// cycles each (used by the faithfulness ablation in bench_ablations).
    pub fn uniform(w: usize, flops_per_layer: f64, act_bytes: f64) -> ModelProfile {
        ModelProfile {
            name: format!("uniform{w}"),
            layers: (0..w)
                .map(|i| LayerProfile {
                    name: format!("l{i}"),
                    flops_fwd: flops_per_layer,
                    act_bytes,
                    params: (flops_per_layer / 2.0) as usize, // dense-equivalent
                })
                .collect(),
            input_bytes: act_bytes,
        }
    }
}

/// conv k×k, `c_in→c_out`, output `h×w` (FLOPs = 2·k²·Cin·Cout·H·W).
fn conv_layer(name: &str, c_in: usize, c_out: usize, k: usize, h: usize, w: usize) -> LayerProfile {
    LayerProfile {
        name: name.into(),
        flops_fwd: 2.0 * (k * k * c_in * c_out * h * w) as f64,
        act_bytes: (c_out * h * w * 4) as f64,
        params: k * k * c_in * c_out + c_out,
    }
}

/// Basic residual block: two 3×3 convs (+1×1 shortcut when downsampling).
fn basic_block(name: &str, c_in: usize, c_out: usize, hw: usize, downsample: bool) -> LayerProfile {
    let conv1 = conv_layer("", c_in, c_out, 3, hw, hw);
    let conv2 = conv_layer("", c_out, c_out, 3, hw, hw);
    let mut flops = conv1.flops_fwd + conv2.flops_fwd;
    let mut params = conv1.params + conv2.params;
    if downsample {
        let sc = conv_layer("", c_in, c_out, 1, hw, hw);
        flops += sc.flops_fwd;
        params += sc.params;
    }
    LayerProfile {
        name: name.into(),
        flops_fwd: flops,
        act_bytes: (c_out * hw * hw * 4) as f64,
        params,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_shape() {
        let p = ModelProfile::resnet18_cifar();
        assert_eq!(p.w(), 10); // stem + 8 blocks + fc
        assert_eq!(p.layers[0].name, "conv1");
        assert_eq!(p.layers[9].name, "fc");
        // CIFAR ResNet-18 ≈ 0.56 GMACs fwd = ≈ 1.11 GFLOPs, ≈ 11.2 M params.
        let gf = p.fwd_flops(0, p.w()) / 1e9;
        assert!((0.9..1.4).contains(&gf), "gflops={gf}");
        let m = p.params(0, p.w()) as f64 / 1e6;
        assert!((10.0..12.5).contains(&m), "params={m}M");
    }

    #[test]
    fn resnet34_shape_and_cost() {
        let p = ModelProfile::resnet34_cifar();
        assert_eq!(p.w(), 18); // stem + 16 blocks + fc
        assert_eq!(p.layers[0].name, "conv1");
        assert_eq!(p.layers[17].name, "fc");
        // CIFAR ResNet-34 ≈ 1.16 GMACs fwd ≈ 2.3 GFLOPs, ≈ 21.3 M params.
        let gf = p.fwd_flops(0, p.w()) / 1e9;
        assert!((1.9..2.8).contains(&gf), "gflops={gf}");
        let m = p.params(0, p.w()) as f64 / 1e6;
        assert!((20.0..23.0).contains(&m), "params={m}M");
        // Strictly deeper and costlier than ResNet-18.
        let r18 = ModelProfile::resnet18_cifar();
        assert!(p.fwd_flops(0, 18) > r18.fwd_flops(0, 10));
        assert!(p.params(0, 18) > r18.params(0, 10));
    }

    #[test]
    fn preset_w_matches_config_constants() {
        use crate::config::ModelPreset;
        for preset in [
            ModelPreset::Resnet18,
            ModelPreset::Resnet34,
            ModelPreset::Resnet10,
            ModelPreset::Mlp,
        ] {
            assert_eq!(
                ModelProfile::from_preset(preset).w(),
                preset.w(),
                "{preset}: config W constant out of sync with the profile"
            );
        }
    }

    #[test]
    fn resnet10_smaller_than_18() {
        let a = ModelProfile::resnet10_cifar();
        let b = ModelProfile::resnet18_cifar();
        assert_eq!(a.w(), 6);
        assert!(a.fwd_flops(0, 6) < b.fwd_flops(0, 10));
        assert!(a.params(0, 6) < b.params(0, 10));
    }

    #[test]
    fn flops_partition_sums() {
        let p = ModelProfile::resnet18_cifar();
        for k in 0..=p.w() {
            let total = p.fwd_flops(0, k) + p.fwd_flops(k, p.w());
            assert!((total - p.fwd_flops(0, p.w())).abs() < 1.0);
        }
    }

    #[test]
    fn act_bytes_at_boundaries() {
        let p = ModelProfile::resnet18_cifar();
        assert_eq!(p.act_bytes_at(0), 12288.0); // 3*32*32*4
        assert_eq!(p.act_bytes_at(1), 64.0 * 32.0 * 32.0 * 4.0);
        assert_eq!(p.act_bytes_at(p.w()), 40.0); // logits
    }

    #[test]
    fn train_flops_is_3x_fwd() {
        let p = ModelProfile::resnet10_cifar();
        let f = p.fwd_flops(0, 6);
        assert!((p.train_flops(0, 6) - 3.0 * f).abs() < 1.0);
    }

    #[test]
    fn mlp_profile_matches_architecture() {
        let p = ModelProfile::mlp(3072, 256, 10, 8);
        assert_eq!(p.w(), 8);
        assert_eq!(p.layers[0].params, 3072 * 256 + 256);
        assert_eq!(p.layers[7].params, 256 * 10 + 10);
        assert_eq!(p.act_bytes_at(3), 256.0 * 4.0);
        let n: usize = p.params(0, 8);
        assert_eq!(
            n,
            (3072 * 256 + 256) + 6 * (256 * 256 + 256) + (256 * 10 + 10)
        );
    }

    #[test]
    fn uniform_profile_is_uniform() {
        let p = ModelProfile::uniform(5, 1e6, 1024.0);
        assert_eq!(p.w(), 5);
        assert!(p.layers.iter().all(|l| l.flops_fwd == 1e6));
        assert_eq!(p.act_bytes_at(0), 1024.0);
        assert_eq!(p.act_bytes_at(3), 1024.0);
    }

    #[test]
    fn downsample_blocks_cost_more_than_plain_at_same_width() {
        // First block of stage 2 (64→128, 16×16, with shortcut) vs second
        // (128→128, 16×16): conv1 of the first is half input channels but it
        // adds the shortcut; the second block has two full-width convs and
        // costs more.
        let p = ModelProfile::resnet18_cifar();
        let b1 = &p.layers[3]; // s2b1
        let b2 = &p.layers[4]; // s2b2
        assert_eq!(b1.name, "s2b1");
        assert_eq!(b2.name, "s2b2");
        assert!(b2.flops_fwd > b1.flops_fwd);
    }
}
