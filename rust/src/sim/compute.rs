//! Client compute-heterogeneity model.
//!
//! The paper characterizes each client by a CPU frequency `f_i` (uniform in
//! [0.1, 2] GHz) and charges a layer `F/f_i` seconds where `F` is "the average
//! number of CPU cycles required to update a neural layer once". We refine `F`
//! to per-layer granularity: `cycles(layer) = cycles_per_flop · FLOPs(layer)`
//! with a single global `cycles_per_flop` calibration constant
//! (`ComputeConfig::cycles_per_flop`) — orderings never depend on it.

use crate::config::ComputeConfig;
use crate::util::rng::Rng;

/// One client's static compute/data description (the `(f_i, |D_i|)` state the
/// paper's clients report to the server at initialization).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClientResources {
    /// CPU frequency in Hz.
    pub freq_hz: f64,
    /// Local dataset size `|D_i|`.
    pub n_samples: usize,
}

/// Sample per-client CPU frequencies (uniform, per the paper).
pub fn sample_frequencies(rng: &mut Rng, n: usize, cfg: &ComputeConfig) -> Vec<f64> {
    (0..n)
        .map(|_| rng.range_f64(cfg.f_min_ghz * 1e9, cfg.f_max_ghz * 1e9))
        .collect()
}

/// Seconds to execute `flops` FLOPs on a `freq_hz` device.
#[inline]
pub fn compute_time(flops: f64, freq_hz: f64, cfg: &ComputeConfig) -> f64 {
    debug_assert!(freq_hz > 0.0);
    flops * cfg.cycles_per_flop / freq_hz
}

/// Seconds to transmit `bytes` over a `rate_bps` link — the one place the
/// bytes→bits→seconds conversion lives, so the DES chain builder and the
/// analytic round engine price a transfer identically to the last bit.
#[inline]
pub fn transmit_time(bytes: f64, rate_bps: f64) -> f64 {
    debug_assert!(rate_bps > 0.0);
    bytes * 8.0 / rate_bps
}

/// FedAvg aggregation weight `a_i = |D_i| / Σ|D_j|` (paper Sec. II-A.1).
pub fn aggregation_weights(resources: &[ClientResources]) -> Vec<f64> {
    let total: usize = resources.iter().map(|r| r.n_samples).sum();
    assert!(total > 0, "no samples across fleet");
    resources
        .iter()
        .map(|r| r.n_samples as f64 / total as f64)
        .collect()
}

/// Split-point rule (paper Sec. II-A.2): `L_i = ⌊f_i/(f_i+f_j)·W⌋`, clamped to
/// `[1, W-1]` so both sides hold at least one layer, and `L_j = W − L_i`.
///
/// The clamp departs from the bare floor only in the extreme-imbalance corner
/// (`f_i/(f_i+f_j) < 1/W`), where the paper's formula would assign zero layers
/// — undefined for split learning (the input layer must stay with the data
/// owner for privacy, which the paper itself requires).
pub fn split_lengths(f_i: f64, f_j: f64, w: usize) -> (usize, usize) {
    assert!(w >= 2, "need at least 2 layers to split");
    assert!(f_i > 0.0 && f_j > 0.0);
    let raw = (f_i / (f_i + f_j) * w as f64).floor() as usize;
    let l_i = raw.clamp(1, w - 1);
    (l_i, w - l_i)
}

/// Propagation-time balance diagnostic: `|L_i/f_i − L_j/f_j|` relative to the
/// slower side (0 = perfectly balanced). Used in tests + the pairing ablation.
pub fn split_imbalance(f_i: f64, f_j: f64, w: usize) -> f64 {
    let (l_i, l_j) = split_lengths(f_i, f_j, w);
    let t_i = l_i as f64 / f_i;
    let t_j = l_j as f64 / f_j;
    (t_i - t_j).abs() / t_i.max(t_j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequencies_in_configured_range() {
        let cfg = ComputeConfig::default();
        let mut rng = Rng::new(1);
        let fs = sample_frequencies(&mut rng, 1000, &cfg);
        assert!(fs.iter().all(|&f| (0.1e9..2.0e9).contains(&f)));
        // spread sanity: both halves of the range populated
        assert!(fs.iter().filter(|&&f| f < 1.05e9).count() > 300);
        assert!(fs.iter().filter(|&&f| f >= 1.05e9).count() > 300);
    }

    #[test]
    fn compute_time_scales() {
        let cfg = ComputeConfig {
            cycles_per_flop: 1.0,
            ..Default::default()
        };
        assert_eq!(compute_time(1e9, 1e9, &cfg), 1.0);
        assert_eq!(compute_time(1e9, 2e9, &cfg), 0.5);
        assert_eq!(compute_time(2e9, 1e9, &cfg), 2.0);
    }

    #[test]
    fn transmit_time_is_bits_over_rate() {
        assert_eq!(transmit_time(1.0, 8.0), 1.0);
        assert_eq!(transmit_time(1e6, 8e6), 1.0);
        assert_eq!(transmit_time(0.0, 1e6), 0.0);
    }

    #[test]
    fn aggregation_weights_normalized_and_proportional() {
        let res = [
            ClientResources { freq_hz: 1e9, n_samples: 100 },
            ClientResources { freq_hz: 1e9, n_samples: 300 },
        ];
        let w = aggregation_weights(&res);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[1] / w[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn split_lengths_paper_formula() {
        // f_i = f_j → even split.
        assert_eq!(split_lengths(1e9, 1e9, 8), (4, 4));
        // Paper's Fig. 1 example shape: W=3, slow vs fast.
        let (li, lj) = split_lengths(1.0, 2.0, 3);
        assert_eq!((li, lj), (1, 2));
        // Sum always W.
        for &(fi, fj, w) in &[(0.1e9, 2e9, 8), (1.7e9, 0.3e9, 10), (1e9, 1e9, 2)] {
            let (a, b) = split_lengths(fi, fj, w);
            assert_eq!(a + b, w);
            assert!(a >= 1 && b >= 1);
        }
    }

    #[test]
    fn split_clamps_extreme_imbalance() {
        // f_i/(f_i+f_j) < 1/W would floor to 0 — must clamp to 1.
        let (li, lj) = split_lengths(0.01e9, 2e9, 8);
        assert_eq!(li, 1);
        assert_eq!(lj, 7);
    }

    #[test]
    fn faster_client_gets_more_layers() {
        let (li, lj) = split_lengths(1.9e9, 0.2e9, 10);
        assert!(li > lj, "{li} {lj}");
    }

    #[test]
    fn balance_better_than_no_split() {
        // Split-time balance: for a 10x freq gap the paper's rule should be
        // far closer to equal than assigning all layers to the slow side.
        let imb = split_imbalance(0.2e9, 2e9, 16);
        assert!(imb < 0.5, "imb={imb}");
    }
}
