//! Per-round training-latency models for all four algorithms (paper Tables I
//! and II), built on the discrete-event engine in [`super::des`].
//!
//! Entities are job-shop resources: every client CPU, every directional radio
//! link, and (for SL/SplitFed) the central server CPU. A training *flow* — one
//! client's sequence of mini-batch steps — is a [`Chain`] whose stages
//! alternate compute and transmission, so pipeline overlap, link sharing and
//! server queueing all emerge from the simulation rather than being assumed.
//!
//! Per-batch stage decomposition (`3×fwd` total training FLOPs, split 1×
//! forward / 2× backward — see [`super::profile::BWD_FLOPS_FACTOR`]):
//!
//! * **FedPairing**, direction "data of `c_i`" inside pair `(c_i, c_j)`:
//!   `cpu_i` front-fwd → `link_ij` (activation + logit-grad) → `cpu_j`
//!   back-fwd+bwd → `link_ji` (logits + activation-grad) → `cpu_i` front-bwd.
//!   Both directions run concurrently on the same two CPUs and two links.
//! * **Vanilla FL**: `cpu_i` full fwd+bwd per batch (no peer traffic).
//! * **Vanilla SL**: same stage shape as FedPairing but the back half lives on
//!   the server; clients take sessions *sequentially* (the defining property
//!   of SL), and the client-side model hops client→client between sessions.
//! * **SplitFed**: SL's stage shape, all clients *concurrently*, one shared
//!   server CPU — server queueing contention emerges from FIFO service.

use super::channel::Channel;
use super::compute::{compute_time, transmit_time, ClientResources};
use super::des::{simulate, Chain};
use super::geometry::{place_uniform_disk, Pos};
use super::profile::{ModelProfile, BWD_FLOPS_FACTOR};
use crate::config::{ComputeConfig, ExperimentConfig, SplitConfig};
use crate::telemetry::breakdown::{self, StageBreakdown};
use crate::util::rng::Rng;

/// Read access to a set of clients — either an owned [`Fleet`] or a borrowed
/// [`FleetView`] over a membership slice. Every round-time model is generic
/// over this trait, so the per-round hot path never materializes a
/// [`Fleet::subset`] clone.
pub trait ClientSet {
    fn n(&self) -> usize;
    fn freq_hz(&self, i: usize) -> f64;
    fn n_samples(&self, i: usize) -> usize;
    fn pos(&self, i: usize) -> Pos;
}

impl ClientSet for Fleet {
    #[inline]
    fn n(&self) -> usize {
        self.freqs_hz.len()
    }
    #[inline]
    fn freq_hz(&self, i: usize) -> f64 {
        self.freqs_hz[i]
    }
    #[inline]
    fn n_samples(&self, i: usize) -> usize {
        self.n_samples[i]
    }
    #[inline]
    fn pos(&self, i: usize) -> Pos {
        self.positions[i]
    }
}

/// Borrowed compact view over `members` of a universe fleet: compact index
/// `c` reads universe client `members[c]`. The zero-allocation replacement
/// for the per-round `Fleet::subset` clones in the scenario drivers.
#[derive(Clone, Copy, Debug)]
pub struct FleetView<'a> {
    fleet: &'a Fleet,
    members: &'a [usize],
}

impl<'a> FleetView<'a> {
    pub fn new(fleet: &'a Fleet, members: &'a [usize]) -> FleetView<'a> {
        debug_assert!(members.iter().all(|&u| u < fleet.n()));
        FleetView { fleet, members }
    }

    /// The compact→universe id map this view was built over.
    pub fn members(&self) -> &'a [usize] {
        self.members
    }
}

impl ClientSet for FleetView<'_> {
    #[inline]
    fn n(&self) -> usize {
        self.members.len()
    }
    #[inline]
    fn freq_hz(&self, i: usize) -> f64 {
        self.fleet.freqs_hz[self.members[i]]
    }
    #[inline]
    fn n_samples(&self, i: usize) -> usize {
        self.fleet.n_samples[self.members[i]]
    }
    #[inline]
    fn pos(&self, i: usize) -> Pos {
        self.fleet.positions[self.members[i]]
    }
}

/// The sampled fleet: everything static about the clients.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub positions: Vec<Pos>,
    pub freqs_hz: Vec<f64>,
    pub n_samples: Vec<usize>,
}

impl Fleet {
    /// Sample placement + CPU frequencies per the config (paper Sec. IV-A).
    pub fn sample(cfg: &ExperimentConfig, rng: &mut Rng) -> Fleet {
        let positions = place_uniform_disk(rng, cfg.n_clients, cfg.area_radius_m);
        let freqs_hz = super::compute::sample_frequencies(rng, cfg.n_clients, &cfg.compute);
        Fleet {
            positions,
            freqs_hz,
            n_samples: vec![cfg.samples_per_client; cfg.n_clients],
        }
    }

    pub fn n(&self) -> usize {
        self.freqs_hz.len()
    }

    /// Compact sub-fleet of the clients in `members` (in the given order).
    /// Used by the fleet-dynamics layer to simulate a round over the
    /// currently-present clients only.
    pub fn subset(&self, members: &[usize]) -> Fleet {
        Fleet {
            positions: members.iter().map(|&i| self.positions[i]).collect(),
            freqs_hz: members.iter().map(|&i| self.freqs_hz[i]).collect(),
            n_samples: members.iter().map(|&i| self.n_samples[i]).collect(),
        }
    }

    pub fn resources(&self) -> Vec<ClientResources> {
        self.freqs_hz
            .iter()
            .zip(&self.n_samples)
            .map(|(&f, &n)| ClientResources {
                freq_hz: f,
                n_samples: n,
            })
            .collect()
    }
}

/// Local-training schedule for one round.
#[derive(Clone, Copy, Debug)]
pub struct Schedule {
    pub batch_size: usize,
    pub epochs: usize,
}

impl Schedule {
    /// Mini-batch steps one client performs per round.
    pub fn batches(&self, n_samples: usize) -> usize {
        assert!(self.batch_size > 0);
        self.epochs * n_samples.div_ceil(self.batch_size)
    }
}

/// Round-time report with a compute/comm breakdown.
#[derive(Clone, Debug)]
pub struct RoundTime {
    /// Wall-clock seconds for the round (all entities done).
    pub total_s: f64,
    /// Busiest CPU's busy seconds (compute pressure).
    pub max_cpu_busy_s: f64,
    /// Busiest link's busy seconds (comm pressure).
    pub max_link_busy_s: f64,
    /// Mean planned cut this round: the average front length `L_i` over the
    /// FedPairing pairs (solos excluded), the configured cut for SL /
    /// SplitFed, `NaN` for vanilla FL or a pairless round.
    pub mean_cut: f64,
    /// Critical-path stage attribution + straggler slack. Computed with
    /// telemetry-independent arithmetic by every evaluator that produces it
    /// (default/zeroed where a path has no attribution — see DESIGN.md §8).
    pub stages: StageBreakdown,
    /// Fault/recovery accounting for the round (DESIGN.md §11). The kernels
    /// always construct it zeroed; the drivers' fault pass fills it in, so a
    /// disarmed `FaultConfig` leaves traces bit-identical.
    pub faults: crate::faults::FaultCounters,
    /// Per-flow finish times (diagnostic).
    pub flow_finish_s: Vec<f64>,
}

/// Mean planned cut over a round's pairs (`NaN` when there are none).
/// Shared by the DES path and the analytic engine so both compute the
/// statistic with identical arithmetic.
pub(crate) fn mean_cut_of(cut_sum: usize, n_pairs: usize) -> f64 {
    if n_pairs == 0 {
        f64::NAN
    } else {
        cut_sum as f64 / n_pairs as f64
    }
}

/// Bytes of one f32 logits row set for a batch.
fn logits_bytes(classes: usize, batch: usize) -> f64 {
    (classes * batch * 4) as f64
}

/// Number of label classes assumed for logits traffic (CIFAR-10).
pub const CLASSES: usize = 10;

// ---------------------------------------------------------------------------
// FedPairing
// ---------------------------------------------------------------------------

/// The five per-batch stage durations of one split-training direction —
/// front-fwd, uplink, back fwd+bwd, downlink, front-bwd — shared by the DES
/// chain builder below and the analytic kernels in [`super::engine`], so both
/// paths price a batch with bit-identical arithmetic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn split_stage_durations(
    profile: &ModelProfile,
    comp: &ComputeConfig,
    batch: usize,
    split: usize,
    f_front_hz: f64,
    f_back_hz: f64,
    rate_bps: f64,
) -> [f64; 5] {
    let w = profile.w();
    let front_fwd_flops = batch as f64 * profile.fwd_flops(0, split);
    let back_flops = batch as f64 * profile.train_flops(split, w);
    let front_bwd_flops = front_fwd_flops * BWD_FLOPS_FACTOR;
    let act_bytes = batch as f64 * profile.act_bytes_at(split);
    // Faithful label-private protocol (DESIGN.md §2): activation + logit-grad
    // travel front→back; logits + activation-grad travel back→front.
    let up_bytes = act_bytes + logits_bytes(CLASSES, batch);
    let down_bytes = logits_bytes(CLASSES, batch) + act_bytes;
    [
        compute_time(front_fwd_flops, f_front_hz, comp),
        transmit_time(up_bytes, rate_bps),
        compute_time(back_flops, f_back_hz, comp),
        transmit_time(down_bytes, rate_bps),
        compute_time(front_bwd_flops, f_front_hz, comp),
    ]
}

/// One direction's per-batch stages inside a pair or a client↔server split.
///
/// `front` runs on `cpu_front`, `back` on `cpu_back`; `split` is the unit
/// index where the model is cut (front = `[0, split)`).
#[allow(clippy::too_many_arguments)]
fn push_split_batches(
    chain: &mut Chain,
    profile: &ModelProfile,
    comp: &ComputeConfig,
    n_batches: usize,
    batch: usize,
    split: usize,
    cpu_front: usize,
    f_front_hz: f64,
    cpu_back: usize,
    f_back_hz: f64,
    link_fwd: usize,
    link_bwd: usize,
    rate_bps: f64,
) {
    let [t_fwd, t_up, t_back, t_down, t_bwd] =
        split_stage_durations(profile, comp, batch, split, f_front_hz, f_back_hz, rate_bps);
    for _ in 0..n_batches {
        chain.push(cpu_front, t_fwd);
        chain.push(link_fwd, t_up);
        chain.push(cpu_back, t_back);
        chain.push(link_bwd, t_down);
        chain.push(cpu_front, t_bwd);
    }
}

/// Model upload time to the central server for client `i`.
pub(crate) fn upload_time<C: ClientSet>(fleet: &C, channel: &Channel, i: usize, bytes: f64) -> f64 {
    transmit_time(bytes, channel.rate_to_server(&fleet.pos(i)))
}

/// Build a FedPairing round's [`StageBreakdown`] from the tracked critical
/// participant: `crit_pair = (i, j, l_i, rate, upload_s)` or
/// `crit_solo = (s, compute_s, upload_s)`, whichever gated the round, plus
/// all participant totals for the p50 slack baseline. Shared by the DES path
/// and the analytic engine so both backends attribute stages with
/// bit-identical arithmetic.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fedpairing_breakdown<C: ClientSet>(
    fleet: &C,
    profile: &ModelProfile,
    sched: &Schedule,
    comp: &ComputeConfig,
    crit_pair: Option<(usize, usize, usize, f64, f64)>,
    crit_solo: Option<(usize, f64, f64)>,
    crit_total: f64,
    totals: &mut [f64],
) -> StageBreakdown {
    let mut b = StageBreakdown::default();
    if let Some((i, j, l_i, rate, up)) = crit_pair {
        let d_i = split_stage_durations(
            profile,
            comp,
            sched.batch_size,
            l_i,
            fleet.freq_hz(i),
            fleet.freq_hz(j),
            rate,
        );
        let d_j = split_stage_durations(
            profile,
            comp,
            sched.batch_size,
            profile.w() - l_i,
            fleet.freq_hz(j),
            fleet.freq_hz(i),
            rate,
        );
        b.stage_s = breakdown::pair_stages(
            &d_i,
            sched.batches(fleet.n_samples(i)) as f64,
            &d_j,
            sched.batches(fleet.n_samples(j)) as f64,
            up,
        );
        b.crit_a = i as i64;
        b.crit_b = j as i64;
    } else if let Some((s, compute_s, up)) = crit_solo {
        b.stage_s = breakdown::solo_stages(compute_s, up);
        b.crit_a = s as i64;
    }
    if !totals.is_empty() {
        b.crit_slack_s = crit_total - breakdown::p50(totals);
    }
    b
}

/// One client's full-model local-training time — `(compute_s, total_s)`,
/// where `total_s` includes the model upload when requested. Shared by
/// [`fl_round`], the FedPairing solo fallback and the analytic engine so
/// every path prices a full-model participant identically.
pub(crate) fn full_local_time<C: ClientSet>(
    fleet: &C,
    i: usize,
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    include_upload: bool,
) -> (f64, f64) {
    let nb = sched.batches(fleet.n_samples(i));
    let flops = nb as f64 * sched.batch_size as f64 * profile.train_flops(0, profile.w());
    let compute_s = compute_time(flops, fleet.freq_hz(i), comp);
    let mut total_s = compute_s;
    if include_upload {
        total_s += upload_time(fleet, channel, i, profile.param_bytes());
    }
    (compute_s, total_s)
}

/// FedPairing round time under a given pairing (paper Sec. II-A).
///
/// Pairs are physically independent (own CPUs + own OFDM sub-bands), so each
/// pair is simulated as its own 4-resource job shop; the round ends when the
/// slowest pair has finished local training and uploaded its two models.
pub fn fedpairing_round<C: ClientSet>(
    fleet: &C,
    pairs: &[(usize, usize)],
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    include_upload: bool,
) -> RoundTime {
    fedpairing_round_with_solos(fleet, pairs, &[], profile, sched, channel, comp, include_upload)
}

/// [`fedpairing_round`] extended with **solo clients** (the fleet-dynamics
/// fallback): an unpaired client trains the *full* model locally, exactly
/// like a vanilla-FL participant, and uploads it alongside the pairs. The
/// round ends when the slowest pair *or* solo finishes. Cuts follow the
/// paper's `split_lengths` rule; see [`fedpairing_round_planned`] for the
/// split-planner-aware variant.
#[allow(clippy::too_many_arguments)]
pub fn fedpairing_round_with_solos<C: ClientSet>(
    fleet: &C,
    pairs: &[(usize, usize)],
    solos: &[usize],
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    include_upload: bool,
) -> RoundTime {
    fedpairing_round_planned(
        fleet,
        pairs,
        solos,
        profile,
        sched,
        channel,
        comp,
        include_upload,
        &SplitConfig::default(),
    )
}

/// [`fedpairing_round_with_solos`] with each pair's cut chosen by the
/// configured split-planning policy (`crate::split`) — the DES oracle for
/// the planner-aware engine. The default `Paper` policy computes
/// `split_lengths` exactly, so [`fedpairing_round_with_solos`] delegates
/// here without any float-level change.
#[allow(clippy::too_many_arguments)]
pub fn fedpairing_round_planned<C: ClientSet>(
    fleet: &C,
    pairs: &[(usize, usize)],
    solos: &[usize],
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    include_upload: bool,
    split: &SplitConfig,
) -> RoundTime {
    let w = profile.w();
    let mut total = 0.0f64;
    let mut max_cpu = 0.0f64;
    let mut max_link = 0.0f64;
    let mut cut_sum = 0usize;
    let mut finishes = Vec::with_capacity(pairs.len() * 2);
    // Straggler attribution: the gating participant's identity plus the
    // inputs needed to re-derive its stage durations, and every participant
    // total for the p50 slack baseline.
    let mut totals = Vec::with_capacity(pairs.len() + solos.len());
    let mut crit_total = f64::NEG_INFINITY;
    let mut crit_pair: Option<(usize, usize, usize, f64, f64)> = None;
    let mut crit_solo: Option<(usize, f64, f64)> = None;
    for &(i, j) in pairs {
        let (f_i, f_j) = (fleet.freq_hz(i), fleet.freq_hz(j));
        let rate = channel.rate(&fleet.pos(i), &fleet.pos(j));
        let l_i = crate::split::plan_cut(
            split,
            &crate::split::PairContext {
                profile,
                sched,
                comp,
                f_i_hz: f_i,
                f_j_hz: f_j,
                n_i: fleet.n_samples(i),
                n_j: fleet.n_samples(j),
                rate_bps: rate,
            },
        );
        let l_j = w - l_i;
        cut_sum += l_i;
        // Local resources: 0 = cpu_i, 1 = cpu_j, 2 = link i→j, 3 = link j→i.
        let mut dir_i = Chain::new();
        push_split_batches(
            &mut dir_i,
            profile,
            comp,
            sched.batches(fleet.n_samples(i)),
            sched.batch_size,
            l_i,
            0,
            f_i,
            1,
            f_j,
            2,
            3,
            rate,
        );
        let mut dir_j = Chain::new();
        push_split_batches(
            &mut dir_j,
            profile,
            comp,
            sched.batches(fleet.n_samples(j)),
            sched.batch_size,
            l_j,
            1,
            f_j,
            0,
            f_i,
            3,
            2,
            rate,
        );
        let rep = simulate(4, &[dir_i, dir_j]);
        let mut pair_total = rep.makespan;
        let mut up = 0.0f64;
        if include_upload {
            up = upload_time(fleet, channel, i, profile.param_bytes())
                .max(upload_time(fleet, channel, j, profile.param_bytes()));
            pair_total += up;
        }
        total = total.max(pair_total);
        totals.push(pair_total);
        if pair_total > crit_total {
            crit_total = pair_total;
            crit_pair = Some((i, j, l_i, rate, up));
        }
        max_cpu = max_cpu.max(rep.resource_busy[0]).max(rep.resource_busy[1]);
        max_link = max_link.max(rep.resource_busy[2]).max(rep.resource_busy[3]);
        finishes.extend_from_slice(&rep.chain_finish);
    }
    for &s in solos {
        let (compute_s, t) =
            full_local_time(fleet, s, profile, sched, channel, comp, include_upload);
        max_cpu = max_cpu.max(compute_s);
        total = total.max(t);
        totals.push(t);
        if t > crit_total {
            crit_total = t;
            crit_pair = None;
            crit_solo = Some((s, compute_s, t - compute_s));
        }
        finishes.push(t);
    }
    let stages = fedpairing_breakdown(
        fleet, profile, sched, comp, crit_pair, crit_solo, crit_total, &mut totals,
    );
    RoundTime {
        total_s: total,
        max_cpu_busy_s: max_cpu,
        max_link_busy_s: max_link,
        mean_cut: mean_cut_of(cut_sum, pairs.len()),
        stages,
        faults: Default::default(),
        flow_finish_s: finishes,
    }
}

// ---------------------------------------------------------------------------
// Vanilla FL (FedAvg)
// ---------------------------------------------------------------------------

/// Vanilla-FL round: every client trains the full model locally; the round is
/// gated by the slowest client (the straggler effect the paper targets).
pub fn fl_round<C: ClientSet>(
    fleet: &C,
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    include_upload: bool,
) -> RoundTime {
    let mut finishes = Vec::with_capacity(fleet.n());
    let mut max_cpu = 0.0f64;
    let mut crit_total = f64::NEG_INFINITY;
    let mut stages = StageBreakdown::default();
    for i in 0..fleet.n() {
        let (compute_s, t) =
            full_local_time(fleet, i, profile, sched, channel, comp, include_upload);
        max_cpu = max_cpu.max(compute_s);
        if t > crit_total {
            crit_total = t;
            stages.stage_s = breakdown::solo_stages(compute_s, t - compute_s);
            stages.crit_a = i as i64;
        }
        finishes.push(t);
    }
    if !finishes.is_empty() {
        let mut totals = finishes.clone();
        stages.crit_slack_s = crit_total - breakdown::p50(&mut totals);
    }
    RoundTime {
        total_s: finishes.iter().cloned().fold(0.0, f64::max),
        max_cpu_busy_s: max_cpu,
        max_link_busy_s: 0.0,
        mean_cut: f64::NAN,
        stages,
        faults: Default::default(),
        flow_finish_s: finishes,
    }
}

// ---------------------------------------------------------------------------
// Vanilla SL
// ---------------------------------------------------------------------------

/// Vanilla-SL round: clients hold layers `[0, cut)`, the server holds the
/// rest; clients run **sequentially**, relaying the client-side model to the
/// next client between sessions (Gupta & Raskar 2018).
#[allow(clippy::too_many_arguments)]
pub fn sl_round<C: ClientSet>(
    fleet: &C,
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    cut: usize,
    server_freq_hz: f64,
) -> RoundTime {
    assert!(cut >= 1 && cut < profile.w(), "cut {cut} out of range");
    let mut total = 0.0f64;
    let mut max_cpu = 0.0f64;
    let mut max_link = 0.0f64;
    let mut finishes = Vec::with_capacity(fleet.n());
    // SL's critical path is the whole session ring: stage attribution sums
    // every session's work; the "critical" entity is the longest session.
    let mut stages = StageBreakdown::default();
    let mut session_times = Vec::with_capacity(fleet.n());
    let mut crit_session = f64::NEG_INFINITY;
    for i in 0..fleet.n() {
        let rate = channel.rate_to_server(&fleet.pos(i));
        // Local resources: 0 = cpu_i, 1 = server, 2 = uplink, 3 = downlink.
        let mut chain = Chain::new();
        push_split_batches(
            &mut chain,
            profile,
            comp,
            sched.batches(fleet.n_samples(i)),
            sched.batch_size,
            cut,
            0,
            fleet.freq_hz(i),
            1,
            server_freq_hz,
            2,
            3,
            rate,
        );
        let rep = simulate(4, &[chain]);
        let mut session = rep.makespan;
        // Client-model relay to the next client in the ring.
        let next = (i + 1) % fleet.n();
        let mut relay_s = 0.0f64;
        if fleet.n() > 1 {
            let front_bytes = profile.params(0, cut) as f64 * 4.0;
            relay_s = transmit_time(front_bytes, channel.rate(&fleet.pos(i), &fleet.pos(next)));
            session += relay_s;
        }
        let dur = split_stage_durations(
            profile,
            comp,
            sched.batch_size,
            cut,
            fleet.freq_hz(i),
            server_freq_hz,
            rate,
        );
        let nb = sched.batches(fleet.n_samples(i)) as f64;
        for (s, &d) in stages.stage_s.iter_mut().take(5).zip(dur.iter()) {
            *s += d * nb;
        }
        stages.stage_s[5] += relay_s;
        session_times.push(session);
        if session > crit_session {
            crit_session = session;
            stages.crit_a = i as i64;
        }
        total += session;
        finishes.push(total);
        max_cpu = max_cpu.max(rep.resource_busy[0]).max(rep.resource_busy[1]);
        max_link = max_link.max(rep.resource_busy[2]).max(rep.resource_busy[3]);
    }
    if !session_times.is_empty() {
        stages.crit_slack_s = crit_session - breakdown::p50(&mut session_times);
    }
    RoundTime {
        total_s: total,
        max_cpu_busy_s: max_cpu,
        max_link_busy_s: max_link,
        mean_cut: cut as f64,
        stages,
        faults: Default::default(),
        flow_finish_s: finishes,
    }
}

// ---------------------------------------------------------------------------
// SplitFed
// ---------------------------------------------------------------------------

/// SplitFed round: SL's split, but all clients train **concurrently** against
/// one shared server CPU (FIFO), followed by FedAvg of the client-side models
/// (Thapa et al. 2022). Server queueing is the emergent bottleneck.
#[allow(clippy::too_many_arguments)]
pub fn splitfed_round<C: ClientSet>(
    fleet: &C,
    profile: &ModelProfile,
    sched: &Schedule,
    channel: &Channel,
    comp: &ComputeConfig,
    cut: usize,
    server_freq_hz: f64,
    include_upload: bool,
) -> RoundTime {
    assert!(cut >= 1 && cut < profile.w(), "cut {cut} out of range");
    let n = fleet.n();
    // Resources: 0..n = client CPUs, n = server CPU, n+1+2i / n+2+2i = links.
    let server = n;
    let mut chains = Vec::with_capacity(n);
    let mut durs: Vec<[f64; 5]> = Vec::with_capacity(n);
    for i in 0..n {
        let rate = channel.rate_to_server(&fleet.pos(i));
        durs.push(split_stage_durations(
            profile,
            comp,
            sched.batch_size,
            cut,
            fleet.freq_hz(i),
            server_freq_hz,
            rate,
        ));
        let up = n + 1 + 2 * i;
        let down = n + 2 + 2 * i;
        let mut chain = Chain::new();
        push_split_batches(
            &mut chain,
            profile,
            comp,
            sched.batches(fleet.n_samples(i)),
            sched.batch_size,
            cut,
            i,
            fleet.freq_hz(i),
            server,
            server_freq_hz,
            up,
            down,
            rate,
        );
        chains.push(chain);
    }
    let rep = simulate(n + 1 + 2 * n, &chains);
    let mut total = rep.makespan;
    let mut stages = splitfed_breakdown(fleet, sched, &durs, &rep.chain_finish);
    if include_upload {
        // FedAvg sync of the client-side models.
        let front_bytes = profile.params(0, cut) as f64 * 4.0;
        let up = (0..n)
            .map(|i| upload_time(fleet, channel, i, front_bytes))
            .fold(0.0, f64::max);
        total += up;
        stages.stage_s[5] = up;
    }
    let max_cpu = rep.resource_busy[..=n].iter().cloned().fold(0.0, f64::max);
    let max_link = rep.resource_busy[n + 1..]
        .iter()
        .cloned()
        .fold(0.0, f64::max);
    RoundTime {
        total_s: total,
        max_cpu_busy_s: max_cpu,
        max_link_busy_s: max_link,
        mean_cut: cut as f64,
        stages,
        faults: Default::default(),
        flow_finish_s: rep.chain_finish,
    }
}

/// SplitFed stage attribution from the finished recurrence: the critical
/// client's own per-stage work plus its residual (queue wait + overlap) as
/// `server_agg`, with slack over the p50 client finish. Shared by the DES
/// path and the analytic engine (both feed bit-identical `durs`/`finish`).
pub(crate) fn splitfed_breakdown<C: ClientSet>(
    fleet: &C,
    sched: &Schedule,
    durs: &[[f64; 5]],
    finish: &[f64],
) -> StageBreakdown {
    let mut stages = StageBreakdown::default();
    let mut crit_total = f64::NEG_INFINITY;
    let mut crit_i = None;
    for (i, &t) in finish.iter().enumerate() {
        if t > crit_total {
            crit_total = t;
            crit_i = Some(i);
        }
    }
    if let Some(i) = crit_i {
        let nb = sched.batches(fleet.n_samples(i)) as f64;
        let d = durs[i];
        for (s, &dk) in stages.stage_s.iter_mut().take(5).zip(d.iter()) {
            *s = dk * nb;
        }
        // Time past the client's own stage work is spent waiting on the
        // shared server — attributed as server aggregation/queueing.
        let own = (d[0] + d[1] + d[2] + d[3] + d[4]) * nb;
        stages.stage_s[6] = (crit_total - own).max(0.0);
        stages.crit_a = i as i64;
        let mut totals = finish.to_vec();
        stages.crit_slack_s = crit_total - breakdown::p50(&mut totals);
    }
    stages
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ChannelConfig, ExperimentConfig};

    fn setup() -> (Fleet, ModelProfile, Schedule, Channel, ComputeConfig) {
        let mut cfg = ExperimentConfig::default();
        cfg.n_clients = 8;
        cfg.samples_per_client = 64;
        let mut rng = Rng::new(1);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let profile = ModelProfile::resnet10_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: 1,
        };
        let channel = Channel::new(ChannelConfig::default());
        (fleet, profile, sched, channel, cfg.compute)
    }

    fn pair_all(n: usize) -> Vec<(usize, usize)> {
        (0..n / 2).map(|k| (2 * k, 2 * k + 1)).collect()
    }

    #[test]
    fn fleet_sampling_matches_config() {
        let cfg = ExperimentConfig::default();
        let mut rng = Rng::new(3);
        let fleet = Fleet::sample(&cfg, &mut rng);
        assert_eq!(fleet.n(), 20);
        assert!(fleet
            .positions
            .iter()
            .all(|p| p.dist_to_server() <= cfg.area_radius_m));
        assert!(fleet
            .freqs_hz
            .iter()
            .all(|&f| (0.1e9..=2.0e9).contains(&f)));
        assert!(fleet.n_samples.iter().all(|&s| s == 2500));
    }

    #[test]
    fn schedule_batch_count() {
        let s = Schedule {
            batch_size: 32,
            epochs: 2,
        };
        assert_eq!(s.batches(2500), 2 * 79); // ceil(2500/32) = 79
        assert_eq!(s.batches(32), 2);
        assert_eq!(s.batches(1), 2);
    }

    #[test]
    fn fl_round_gated_by_slowest() {
        let (fleet, profile, sched, channel, comp) = setup();
        let rt = fl_round(&fleet, &profile, &sched, &channel, &comp, false);
        let slowest = fleet
            .freqs_hz
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min);
        let nb = sched.batches(64) as f64;
        let expect =
            nb * 32.0 * profile.train_flops(0, profile.w()) * comp.cycles_per_flop / slowest;
        assert!((rt.total_s - expect).abs() / expect < 1e-9);
    }

    #[test]
    fn fedpairing_beats_fl_on_heterogeneous_fleet() {
        let (fleet, profile, sched, channel, comp) = setup();
        // Pair fastest with slowest (greedy-like) by sorting indices by freq.
        let mut idx: Vec<usize> = (0..fleet.n()).collect();
        idx.sort_by(|&a, &b| fleet.freqs_hz[a].partial_cmp(&fleet.freqs_hz[b]).unwrap());
        let pairs: Vec<(usize, usize)> = (0..fleet.n() / 2)
            .map(|k| (idx[k], idx[fleet.n() - 1 - k]))
            .collect();
        let fp = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, false);
        let fl = fl_round(&fleet, &profile, &sched, &channel, &comp, false);
        assert!(
            fp.total_s < fl.total_s,
            "fedpairing {} !< fl {}",
            fp.total_s,
            fl.total_s
        );
    }

    #[test]
    fn fedpairing_makespan_at_least_busiest_resource() {
        let (fleet, profile, sched, channel, comp) = setup();
        let rt = fedpairing_round(
            &fleet,
            &pair_all(fleet.n()),
            &profile,
            &sched,
            &channel,
            &comp,
            false,
        );
        assert!(rt.total_s >= rt.max_cpu_busy_s - 1e-9);
        assert!(rt.total_s >= rt.max_link_busy_s - 1e-9);
        assert!(rt.total_s > 0.0);
    }

    #[test]
    fn subset_extracts_requested_clients() {
        let (fleet, ..) = setup();
        let sub = fleet.subset(&[1, 3, 6]);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.freqs_hz[0], fleet.freqs_hz[1]);
        assert_eq!(sub.freqs_hz[2], fleet.freqs_hz[6]);
        assert_eq!(sub.positions[1], fleet.positions[3]);
        assert_eq!(sub.n_samples[0], fleet.n_samples[1]);
    }

    #[test]
    fn solo_clients_extend_the_round() {
        // A slow solo client must gate the round like an FL straggler.
        let (mut fleet, profile, sched, channel, comp) = setup();
        fleet.freqs_hz[7] = 0.01e9; // cripple the solo
        let pairs: Vec<(usize, usize)> = vec![(0, 1), (2, 3), (4, 5)];
        let without =
            fedpairing_round_with_solos(&fleet, &pairs, &[], &profile, &sched, &channel, &comp, false);
        let with = fedpairing_round_with_solos(
            &fleet, &pairs, &[7], &profile, &sched, &channel, &comp, false,
        );
        assert!(with.total_s > without.total_s, "{} !> {}", with.total_s, without.total_s);
        assert_eq!(with.flow_finish_s.len(), without.flow_finish_s.len() + 1);
        // The solo's time equals a one-client FL round on the same fleet.
        let solo_fleet = fleet.subset(&[7]);
        let fl = fl_round(&solo_fleet, &profile, &sched, &channel, &comp, false);
        let solo_finish = with.flow_finish_s.last().unwrap();
        assert!((solo_finish - fl.total_s).abs() < 1e-9);
    }

    #[test]
    fn empty_solos_match_plain_fedpairing_round() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let a = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, true);
        let b = fedpairing_round_with_solos(
            &fleet, &pairs, &[], &profile, &sched, &channel, &comp, true,
        );
        assert_eq!(a.total_s, b.total_s);
    }

    #[test]
    fn upload_strictly_increases_round_time() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let a = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, false);
        let b = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, true);
        assert!(b.total_s > a.total_s);
        let a = fl_round(&fleet, &profile, &sched, &channel, &comp, false);
        let b = fl_round(&fleet, &profile, &sched, &channel, &comp, true);
        assert!(b.total_s > a.total_s);
    }

    #[test]
    fn sl_sessions_are_sequential() {
        let (fleet, profile, sched, channel, comp) = setup();
        let rt = sl_round(&fleet, &profile, &sched, &channel, &comp, 1, 100e9);
        // Finish times strictly increase client by client.
        for w in rt.flow_finish_s.windows(2) {
            assert!(w[1] > w[0]);
        }
        // Total is the last finish.
        assert!((rt.total_s - rt.flow_finish_s.last().unwrap()).abs() < 1e-9);
    }

    #[test]
    fn splitfed_parallel_beats_sl_sequential_same_cut() {
        let (fleet, profile, sched, channel, comp) = setup();
        let sl = sl_round(&fleet, &profile, &sched, &channel, &comp, 1, 100e9);
        let sf = splitfed_round(&fleet, &profile, &sched, &channel, &comp, 1, 100e9, false);
        assert!(
            sf.total_s < sl.total_s,
            "splitfed {} !< sl {}",
            sf.total_s,
            sl.total_s
        );
    }

    #[test]
    fn faster_server_never_slower() {
        let (fleet, profile, sched, channel, comp) = setup();
        let slow = splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 5e9, false);
        let fast = splitfed_round(&fleet, &profile, &sched, &channel, &comp, 2, 100e9, false);
        assert!(fast.total_s <= slow.total_s + 1e-9);
    }

    #[test]
    fn deeper_cut_shifts_load_to_clients() {
        let (fleet, profile, sched, channel, comp) = setup();
        // With a super-fast server, moving the cut deeper (more client work)
        // slows the round down.
        let shallow = splitfed_round(&fleet, &profile, &sched, &channel, &comp, 1, 1e12, false);
        let deep = splitfed_round(&fleet, &profile, &sched, &channel, &comp, 4, 1e12, false);
        assert!(deep.total_s > shallow.total_s);
    }

    #[test]
    fn deterministic_round_times() {
        let (fleet, profile, sched, channel, comp) = setup();
        let pairs = pair_all(fleet.n());
        let a = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, true);
        let b = fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &comp, true);
        assert_eq!(a.total_s, b.total_s);
    }

    #[test]
    fn paper_scale_orderings_hold() {
        // The Table-II shape at paper scale: SL < FedPairing < SplitFed < FL.
        let mut cfg = ExperimentConfig::default();
        cfg.samples_per_client = 250; // 1/10 scale for test speed; ratios scale
        let mut rng = Rng::new(42);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let profile = ModelProfile::resnet18_cifar();
        let sched = Schedule {
            batch_size: 32,
            epochs: cfg.local_epochs,
        };
        let channel = Channel::new(cfg.channel);
        let mut idx: Vec<usize> = (0..fleet.n()).collect();
        idx.sort_by(|&a, &b| fleet.freqs_hz[a].partial_cmp(&fleet.freqs_hz[b]).unwrap());
        let pairs: Vec<(usize, usize)> = (0..fleet.n() / 2)
            .map(|k| (idx[k], idx[fleet.n() - 1 - k]))
            .collect();
        let fp =
            fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &cfg.compute, true);
        let fl = fl_round(&fleet, &profile, &sched, &channel, &cfg.compute, true);
        let sl = sl_round(&fleet, &profile, &sched, &channel, &cfg.compute, 1, 100e9);
        let sf = splitfed_round(
            &fleet,
            &profile,
            &sched,
            &channel,
            &cfg.compute,
            cfg.splitfed_cut_layer,
            100e9,
            true,
        );
        // Robust orderings under the calibrated channel (EXPERIMENTS.md):
        // FedPairing < SplitFed < FL, and SL ≪ FL. (The paper's "SL fastest"
        // holds only under its comm-free SL accounting, reproduced in
        // bench_table2 as the comm-free variant.)
        assert!(
            fp.total_s < sf.total_s && sf.total_s < fl.total_s && sl.total_s < fl.total_s,
            "ordering violated: sl={} fp={} sf={} fl={}",
            sl.total_s,
            fp.total_s,
            sf.total_s,
            fl.total_s
        );
    }
}
