//! A small deterministic discrete-event simulator (substrate).
//!
//! Models the timing experiments as a job shop: **resources** are
//! single-server FIFO stations (a client CPU, a directional radio link, the
//! aggregation server), and **chains** are strictly ordered stage sequences
//! (a training flow's per-batch compute/transmit steps). The engine computes
//! when every chain finishes and how busy every resource was.
//!
//! Determinism: ties in event time are broken by monotonic sequence numbers,
//! so identical inputs always produce identical schedules — experiments
//! replay bit-for-bit.
//!
//! The DES backend reports chain finishes and resource busy time but does
//! not expose the per-unit (pair/solo/session) durations the fault layer
//! needs to price retries and survivor-solo recoveries, so fault injection
//! on the DES backend is rejected at config validation (see
//! `config::ExperimentConfig::validate` and DESIGN.md §11).

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

/// One processing step: occupy `resource` exclusively for `duration` seconds.
#[derive(Clone, Copy, Debug)]
pub struct Stage {
    pub resource: usize,
    pub duration: f64,
}

/// A strictly ordered sequence of stages (stage *k+1* starts only after *k*
/// completes, possibly queueing at its resource).
#[derive(Clone, Debug, Default)]
pub struct Chain {
    pub stages: Vec<Stage>,
    /// Earliest time stage 0 may be enqueued (dependencies across chains).
    pub release: f64,
}

impl Chain {
    pub fn new() -> Self {
        Chain::default()
    }

    pub fn with_release(release: f64) -> Self {
        assert!(
            release.is_finite() && release >= 0.0,
            "non-finite or negative chain release {release}"
        );
        Chain {
            stages: Vec::new(),
            release,
        }
    }

    pub fn push(&mut self, resource: usize, duration: f64) -> &mut Self {
        assert!(
            duration.is_finite() && duration >= 0.0,
            "non-finite or negative stage duration {duration}"
        );
        self.stages.push(Stage { resource, duration });
        self
    }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct DesReport {
    /// Completion time of every chain (0 for empty chains at release 0).
    pub chain_finish: Vec<f64>,
    /// Total busy seconds per resource.
    pub resource_busy: Vec<f64>,
    /// max(chain_finish).
    pub makespan: f64,
}

#[derive(Debug)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

#[derive(Debug)]
enum EventKind {
    /// Chain `chain` becomes ready to enqueue its stage `stage`.
    StageReady { chain: usize, stage: usize },
    /// `resource` completes its current task (chain, stage).
    Complete {
        resource: usize,
        chain: usize,
        stage: usize,
    },
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap via reversed compare; ties broken by insertion order.
        // Event times are sums of stage durations and releases, all asserted
        // finite at `Chain::push`/`simulate` entry, so the `partial_cmp`
        // below can never see a NaN — the `Equal` fallback is unreachable
        // rather than a silent mis-ordering.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Run the job shop to completion.
pub fn simulate(n_resources: usize, chains: &[Chain]) -> DesReport {
    for c in chains {
        // Finite-time guard: the event heap orders by `partial_cmp`, so a NaN
        // release or duration would silently mis-order events instead of
        // failing. Durations are asserted at `Chain::push`; releases (and any
        // stages built without `push`) are asserted here at entry.
        assert!(
            c.release.is_finite() && c.release >= 0.0,
            "non-finite or negative chain release {}",
            c.release
        );
        for s in &c.stages {
            assert!(
                s.resource < n_resources,
                "stage references resource {} but only {n_resources} exist",
                s.resource
            );
            assert!(
                s.duration.is_finite() && s.duration >= 0.0,
                "non-finite or negative stage duration {}",
                s.duration
            );
        }
    }
    let mut heap: BinaryHeap<Event> = BinaryHeap::new();
    let mut seq: u64 = 0;
    let push = |heap: &mut BinaryHeap<Event>, seq: &mut u64, time: f64, kind: EventKind| {
        heap.push(Event {
            time,
            seq: *seq,
            kind,
        });
        *seq += 1;
    };

    let mut busy = vec![false; n_resources];
    let mut queues: Vec<VecDeque<(usize, usize)>> = vec![VecDeque::new(); n_resources];
    let mut resource_busy = vec![0.0; n_resources];
    let mut chain_finish = vec![0.0; chains.len()];

    for (ci, c) in chains.iter().enumerate() {
        if c.stages.is_empty() {
            chain_finish[ci] = c.release;
        } else {
            push(
                &mut heap,
                &mut seq,
                c.release,
                EventKind::StageReady { chain: ci, stage: 0 },
            );
        }
    }

    let mut now = 0.0f64;
    while let Some(ev) = heap.pop() {
        debug_assert!(ev.time >= now - 1e-12, "time went backwards");
        now = ev.time;
        match ev.kind {
            EventKind::StageReady { chain, stage } => {
                let r = chains[chain].stages[stage].resource;
                queues[r].push_back((chain, stage));
                if !busy[r] {
                    start_next(
                        r, now, chains, &mut busy, &mut queues, &mut resource_busy, &mut heap,
                        &mut seq,
                    );
                }
            }
            EventKind::Complete {
                resource,
                chain,
                stage,
            } => {
                busy[resource] = false;
                // Advance the chain.
                if stage + 1 < chains[chain].stages.len() {
                    push(
                        &mut heap,
                        &mut seq,
                        now,
                        EventKind::StageReady {
                            chain,
                            stage: stage + 1,
                        },
                    );
                } else {
                    chain_finish[chain] = now;
                }
                // Serve the next queued task on this resource.
                start_next(
                    resource,
                    now,
                    chains,
                    &mut busy,
                    &mut queues,
                    &mut resource_busy,
                    &mut heap,
                    &mut seq,
                );
            }
        }
    }

    let makespan = chain_finish.iter().cloned().fold(0.0, f64::max);
    DesReport {
        chain_finish,
        resource_busy,
        makespan,
    }
}

#[allow(clippy::too_many_arguments)]
fn start_next(
    r: usize,
    now: f64,
    chains: &[Chain],
    busy: &mut [bool],
    queues: &mut [VecDeque<(usize, usize)>],
    resource_busy: &mut [f64],
    heap: &mut BinaryHeap<Event>,
    seq: &mut u64,
) {
    if busy[r] {
        return;
    }
    if let Some((chain, stage)) = queues[r].pop_front() {
        busy[r] = true;
        let d = chains[chain].stages[stage].duration;
        resource_busy[r] += d;
        heap.push(Event {
            time: now + d,
            seq: *seq,
            kind: EventKind::Complete {
                resource: r,
                chain,
                stage,
            },
        });
        *seq += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(stages: &[(usize, f64)]) -> Chain {
        let mut c = Chain::new();
        for &(r, d) in stages {
            c.push(r, d);
        }
        c
    }

    #[test]
    fn single_chain_sums_durations() {
        let rep = simulate(2, &[chain(&[(0, 1.0), (1, 2.0), (0, 3.0)])]);
        assert!((rep.makespan - 6.0).abs() < 1e-12);
        assert!((rep.resource_busy[0] - 4.0).abs() < 1e-12);
        assert!((rep.resource_busy[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn independent_resources_run_in_parallel() {
        let rep = simulate(2, &[chain(&[(0, 5.0)]), chain(&[(1, 3.0)])]);
        assert!((rep.makespan - 5.0).abs() < 1e-12);
        assert!((rep.chain_finish[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn shared_resource_serializes_fifo() {
        let rep = simulate(1, &[chain(&[(0, 2.0)]), chain(&[(0, 3.0)])]);
        // FIFO: chain 0 finishes at 2, chain 1 queues then finishes at 5.
        assert!((rep.chain_finish[0] - 2.0).abs() < 1e-12);
        assert!((rep.chain_finish[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn release_delays_start() {
        let mut c = Chain::with_release(10.0);
        c.push(0, 1.0);
        let rep = simulate(1, &[c]);
        assert!((rep.makespan - 11.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_overlaps_stages() {
        // Two chains ping-ponging between two resources: classic 2-stage
        // pipeline. Chain A: r0(1) r1(1); chain B: r0(1) r1(1).
        // Optimal: A r0 [0,1], B r0 [1,2], A r1 [1,2], B r1 [2,3].
        let rep = simulate(
            2,
            &[chain(&[(0, 1.0), (1, 1.0)]), chain(&[(0, 1.0), (1, 1.0)])],
        );
        assert!((rep.makespan - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_duration_stages_ok() {
        let rep = simulate(1, &[chain(&[(0, 0.0), (0, 0.0)])]);
        assert_eq!(rep.makespan, 0.0);
    }

    #[test]
    fn empty_chain_finishes_at_release() {
        let rep = simulate(1, &[Chain::with_release(4.0)]);
        assert_eq!(rep.chain_finish[0], 4.0);
        assert_eq!(rep.makespan, 4.0);
    }

    #[test]
    fn deterministic_tie_breaking() {
        // Many equal-time contenders on one resource: repeated runs identical.
        let chains: Vec<Chain> = (0..20).map(|_| chain(&[(0, 1.0), (1, 0.5)])).collect();
        let a = simulate(2, &chains);
        let b = simulate(2, &chains);
        assert_eq!(a.chain_finish, b.chain_finish);
        // FIFO order: chain i finishes resource-0 stage at i+1.
        assert!((a.chain_finish[0] - 1.5).abs() < 1e-12);
        assert!((a.chain_finish[19] - 20.5).abs() < 1e-12);
    }

    #[test]
    fn utilization_never_exceeds_makespan() {
        let chains: Vec<Chain> = (0..7)
            .map(|i| chain(&[(i % 3, 1.0 + i as f64 * 0.3), ((i + 1) % 3, 0.7)]))
            .collect();
        let rep = simulate(3, &chains);
        for &b in &rep.resource_busy {
            assert!(b <= rep.makespan + 1e-9);
        }
    }

    #[test]
    #[should_panic(expected = "resource")]
    fn invalid_resource_panics() {
        simulate(1, &[chain(&[(3, 1.0)])]);
    }

    #[test]
    #[should_panic(expected = "non-finite or negative chain release")]
    fn nan_release_rejected_at_simulate_entry() {
        let mut c = Chain::new();
        c.release = f64::NAN; // bypasses with_release's assert on purpose
        c.push(0, 1.0);
        simulate(1, &[c]);
    }

    #[test]
    #[should_panic(expected = "non-finite or negative chain release")]
    fn negative_release_rejected_at_construction() {
        Chain::with_release(-1.0);
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn nan_duration_rejected_at_push() {
        Chain::new().push(0, f64::NAN);
    }

    #[test]
    #[should_panic(expected = "non-finite or negative stage duration")]
    fn infinite_duration_rejected_at_simulate_entry() {
        // Stages built without `push` (struct literal) are still guarded.
        let c = Chain {
            stages: vec![Stage {
                resource: 0,
                duration: f64::INFINITY,
            }],
            release: 0.0,
        };
        simulate(1, &[c]);
    }
}
