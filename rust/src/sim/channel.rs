//! OFDM wireless channel model — eq. (3) of the paper.
//!
//! ```text
//!   r_ij = B · log2(1 + P·h_ij / σ²),     h_ij = h0 · (ζ0 / ‖p_i − p_j‖)^θ
//! ```
//!
//! The paper deliberately ignores interference (OFDM orthogonality), so links
//! are independent and a static rate matrix fully describes the network.

use super::geometry::Pos;
use crate::config::ChannelConfig;
use crate::util::matrix::FlatMatrix;

/// Instantiated channel model.
#[derive(Clone, Debug)]
pub struct Channel {
    cfg: ChannelConfig,
}

impl Channel {
    pub fn new(cfg: ChannelConfig) -> Self {
        Channel { cfg }
    }

    pub fn config(&self) -> &ChannelConfig {
        &self.cfg
    }

    /// Channel gain `h` at distance `d` meters.
    ///
    /// Distances below the reference distance `ζ0` are clamped to `ζ0` — the
    /// far-field path-loss law diverges as d→0 and the paper's clients are
    /// physically separated devices.
    pub fn gain(&self, dist_m: f64) -> f64 {
        let d = dist_m.max(self.cfg.ref_dist_m);
        self.cfg.ref_gain * (self.cfg.ref_dist_m / d).powf(self.cfg.pathloss_exp)
    }

    /// Shannon rate in bits/s between two points at distance `d`.
    pub fn rate_at(&self, dist_m: f64) -> f64 {
        let snr = self.cfg.tx_power_w * self.gain(dist_m) / self.cfg.noise_w;
        self.cfg.bandwidth_hz * (1.0 + snr).log2()
    }

    /// Rate between two positions.
    pub fn rate(&self, a: &Pos, b: &Pos) -> f64 {
        self.rate_at(a.dist(b))
    }

    /// Rate between a client and the central server.
    pub fn rate_to_server(&self, p: &Pos) -> f64 {
        self.rate_at(p.dist_to_server())
    }

    /// Transmission time for `bytes` over the link between `a` and `b`.
    pub fn tx_time(&self, a: &Pos, b: &Pos, bytes: f64) -> f64 {
        bytes * 8.0 / self.rate(a, b)
    }

    /// Full pairwise rate matrix (bits/s); diagonal is +∞ (no self-link cost).
    /// One flat allocation; O(n²) by construction — the sparse pairing
    /// backend evaluates rates lazily per candidate edge instead.
    pub fn rate_matrix(&self, positions: &[Pos]) -> FlatMatrix {
        let n = positions.len();
        let mut m = FlatMatrix::new(n, f64::INFINITY);
        for i in 0..n {
            for j in (i + 1)..n {
                m.set_sym(i, j, self.rate(&positions[i], &positions[j]));
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ch() -> Channel {
        Channel::new(ChannelConfig::default())
    }

    #[test]
    fn rate_decreases_with_distance() {
        let c = ch();
        let r1 = c.rate_at(5.0);
        let r2 = c.rate_at(20.0);
        let r3 = c.rate_at(80.0);
        assert!(r1 > r2 && r2 > r3, "{r1} {r2} {r3}");
        assert!(r3 > 0.0);
    }

    #[test]
    fn gain_clamped_below_ref_dist() {
        let c = ch();
        assert_eq!(c.gain(0.0), c.gain(1.0));
        assert_eq!(c.gain(0.5), c.gain(1.0));
        assert!(c.gain(2.0) < c.gain(1.0));
    }

    #[test]
    fn pathloss_exponent_law() {
        let c = ch();
        // h(2ζ0)/h(ζ0) = 2^{-θ}
        let ratio = c.gain(2.0) / c.gain(1.0);
        let expected = 2f64.powf(-ChannelConfig::default().pathloss_exp);
        assert!((ratio - expected).abs() < 1e-12);
    }

    #[test]
    fn paper_scale_rates_plausible() {
        // At 50 m with the paper's B/P/σ² and the calibrated h0 (−35 dB), θ=3:
        // SNR = P·h0·(1/50)³/σ² → r = B·log2(1+SNR), in the tens of Mb/s.
        let cfg = ChannelConfig::default();
        let c = ch();
        let r = c.rate_at(50.0);
        let snr = cfg.tx_power_w * cfg.ref_gain * (1.0 / 50f64).powi(3) / cfg.noise_w;
        assert!((r - cfg.bandwidth_hz * (1.0 + snr).log2()).abs() / r < 1e-9, "r={r}");
        assert!(r > 1e7 && r < 1e9, "r={r}");
    }

    #[test]
    fn shannon_formula_exact() {
        let c = ch();
        let d = 10.0;
        let snr = 1.0 * c.gain(d) / 1e-9;
        assert!((c.rate_at(d) - 64e6 * (1.0 + snr).log2()).abs() < 1.0);
    }

    #[test]
    fn tx_time_scales_linearly_with_bytes() {
        let c = ch();
        let a = Pos { x: 0.0, y: 0.0 };
        let b = Pos { x: 30.0, y: 0.0 };
        let t1 = c.tx_time(&a, &b, 1e6);
        let t2 = c.tx_time(&a, &b, 2e6);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
        assert!(t1 > 0.0);
    }

    #[test]
    fn rate_matrix_symmetric_inf_diag() {
        let c = ch();
        let pts = vec![
            Pos { x: 0.0, y: 0.0 },
            Pos { x: 10.0, y: 0.0 },
            Pos { x: 0.0, y: 25.0 },
        ];
        let m = c.rate_matrix(&pts);
        for i in 0..3 {
            assert!(m[(i, i)].is_infinite());
            for j in 0..3 {
                assert_eq!(m[(i, j)], m[(j, i)]);
            }
        }
        // Nearer pair has the higher rate.
        assert!(m[(0, 1)] > m[(0, 2)]);
    }
}
