//! Synthetic CIFAR-like dataset (substrate — CIFAR-10 itself is not available
//! offline; DESIGN.md §2 documents the substitution).
//!
//! Generates 10-class, 3×32×32 float images with real class structure so that
//! classification is learnable but not trivial:
//!
//! * each class `c` owns a set of deterministic **basis patterns** — spatial
//!   sinusoids with class-specific frequencies/phases per channel — mixed with
//!   per-sample random coefficients (intra-class variation),
//! * plus isotropic Gaussian pixel noise scaled by `noise_level`,
//! * normalized to roughly zero mean / unit variance per image.
//!
//! The generative process is deterministic given `(seed, index)` so any
//! client can materialize its shard without storing the whole dataset, and
//! train/test splits are disjoint by construction (index ranges).

use crate::util::rng::Rng;

/// Image geometry matching CIFAR-10.
pub const CHANNELS: usize = 3;
pub const SIDE: usize = 32;
pub const DIM: usize = CHANNELS * SIDE * SIDE; // 3072
pub const NUM_CLASSES: usize = 10;

/// Size of the *shared* pattern dictionary. Classes are mixture vectors over
/// one common dictionary (not private pattern sets): they occupy the same
/// low-dimensional subspace, so class boundaries interfere — which is what
/// makes Non-IID training genuinely hard (sequential SL forgets, skewed
/// clients fight) instead of trivially separable.
const DICT_PATTERNS: usize = 12;

/// Per-sample jitter on the class mixture coefficients (intra-class spread).
const COEF_JITTER: f32 = 0.35;

/// A labelled sample: flattened image + class id.
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Vec<f32>,
    pub label: usize,
}

/// Deterministic synthetic dataset generator.
#[derive(Clone, Debug)]
pub struct SynthCifar {
    seed: u64,
    noise_level: f32,
    /// `[pattern][DIM]` shared dictionary, fixed by the seed.
    dict: Vec<Vec<f32>>,
    /// `[class][pattern]` mixture coefficients, fixed by the seed.
    class_coefs: Vec<Vec<f32>>,
}

impl SynthCifar {
    /// Build the generator: dictionary + class mixtures derive from `seed`.
    pub fn new(seed: u64, noise_level: f32) -> Self {
        let mut rng = Rng::with_stream(seed, 0xBA5E);
        let dict: Vec<Vec<f32>> = (0..DICT_PATTERNS)
            .map(|_| Self::make_basis(&mut rng))
            .collect();
        let class_coefs: Vec<Vec<f32>> = (0..NUM_CLASSES)
            .map(|_| {
                (0..DICT_PATTERNS)
                    .map(|_| rng.normal() as f32)
                    .collect()
            })
            .collect();
        SynthCifar {
            seed,
            noise_level,
            dict,
            class_coefs,
        }
    }

    /// One basis pattern: per-channel 2-D sinusoid with random frequency,
    /// orientation and phase (smooth, class-distinctive spatial structure).
    fn make_basis(rng: &mut Rng) -> Vec<f32> {
        let mut img = vec![0f32; DIM];
        for ch in 0..CHANNELS {
            let fx = rng.range_f64(0.5, 4.0);
            let fy = rng.range_f64(0.5, 4.0);
            let phase = rng.range_f64(0.0, std::f64::consts::TAU);
            let amp = rng.range_f64(0.5, 1.0);
            for r in 0..SIDE {
                for c in 0..SIDE {
                    let u = r as f64 / SIDE as f64;
                    let v = c as f64 / SIDE as f64;
                    let val =
                        amp * (std::f64::consts::TAU * (fx * u + fy * v) + phase).sin();
                    img[ch * SIDE * SIDE + r * SIDE + c] = val as f32;
                }
            }
        }
        img
    }

    /// Materialize sample `index` of class `label`. Deterministic in
    /// `(seed, label, index)`.
    pub fn sample(&self, label: usize, index: u64) -> Sample {
        assert!(label < NUM_CLASSES);
        let mut rng = Rng::with_stream(
            self.seed ^ 0x5A5A_0000,
            (label as u64) << 40 | index,
        );
        let mut x = vec![0f32; DIM];
        // Class mixture over the shared dictionary + per-sample jitter.
        for (p, basis) in self.dict.iter().enumerate() {
            let coef = self.class_coefs[label][p] + COEF_JITTER * rng.normal() as f32;
            for (xi, bi) in x.iter_mut().zip(basis) {
                *xi += coef * bi;
            }
        }
        // Pixel noise.
        let nl = self.noise_level;
        if nl > 0.0 {
            for xi in x.iter_mut() {
                *xi += nl * rng.normal() as f32;
            }
        }
        // Per-image standardization (as CIFAR pipelines normalize).
        let mean = x.iter().sum::<f32>() / DIM as f32;
        let var = x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / DIM as f32;
        let std = var.sqrt().max(1e-6);
        for xi in x.iter_mut() {
            *xi = (*xi - mean) / std;
        }
        Sample { x, label }
    }

    /// A balanced test set: `n` samples cycling through classes, drawn from a
    /// dedicated index range disjoint from any training shard.
    pub fn test_set(&self, n: usize) -> Vec<Sample> {
        (0..n)
            .map(|i| self.sample(i % NUM_CLASSES, TEST_INDEX_BASE + (i / NUM_CLASSES) as u64))
            .collect()
    }
}

/// Training shards draw indices `< TEST_INDEX_BASE`; test indices start here.
pub const TEST_INDEX_BASE: u64 = 1 << 32;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let g1 = SynthCifar::new(7, 0.5);
        let g2 = SynthCifar::new(7, 0.5);
        let a = g1.sample(3, 11);
        let b = g2.sample(3, 11);
        assert_eq!(a.x, b.x);
        assert_eq!(a.label, 3);
    }

    #[test]
    fn different_indices_differ() {
        let g = SynthCifar::new(7, 0.5);
        assert_ne!(g.sample(0, 0).x, g.sample(0, 1).x);
        assert_ne!(g.sample(0, 0).x, g.sample(1, 0).x);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SynthCifar::new(1, 0.5).sample(0, 0);
        let b = SynthCifar::new(2, 0.5).sample(0, 0);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn samples_standardized() {
        let g = SynthCifar::new(9, 0.6);
        for label in 0..NUM_CLASSES {
            let s = g.sample(label, 42);
            assert_eq!(s.x.len(), DIM);
            let mean = s.x.iter().sum::<f32>() / DIM as f32;
            let var = s.x.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / DIM as f32;
            assert!(mean.abs() < 1e-3, "mean={mean}");
            assert!((var - 1.0).abs() < 1e-2, "var={var}");
            assert!(s.x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn classes_are_separable_by_nearest_class_mean() {
        // The structure test: a trivial nearest-centroid classifier on raw
        // pixels must beat chance comfortably — i.e. the classes carry signal.
        let g = SynthCifar::new(5, 0.6);
        let train_per_class = 20;
        let mut centroids = vec![vec![0f32; DIM]; NUM_CLASSES];
        for c in 0..NUM_CLASSES {
            for i in 0..train_per_class {
                let s = g.sample(c, i as u64);
                for (acc, v) in centroids[c].iter_mut().zip(&s.x) {
                    *acc += v / train_per_class as f32;
                }
            }
        }
        let test = g.test_set(200);
        let mut correct = 0;
        for s in &test {
            let pred = (0..NUM_CLASSES)
                .min_by(|&a, &b| {
                    let da: f32 = centroids[a]
                        .iter()
                        .zip(&s.x)
                        .map(|(c, v)| (c - v) * (c - v))
                        .sum();
                    let db: f32 = centroids[b]
                        .iter()
                        .zip(&s.x)
                        .map(|(c, v)| (c - v) * (c - v))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == s.label {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.5, "nearest-centroid accuracy {acc} too low — no class signal");
    }

    #[test]
    fn noise_makes_task_harder_not_degenerate() {
        // With heavy noise samples still standardized and distinct.
        let g = SynthCifar::new(3, 2.0);
        let s = g.sample(0, 0);
        assert!(s.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn test_set_balanced_and_disjoint_labels() {
        let g = SynthCifar::new(11, 0.5);
        let t = g.test_set(100);
        assert_eq!(t.len(), 100);
        for c in 0..NUM_CLASSES {
            assert_eq!(t.iter().filter(|s| s.label == c).count(), 10);
        }
    }
}
