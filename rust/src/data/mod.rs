//! Data substrate: synthetic CIFAR-like generation ([`synth`]), IID/Non-IID
//! partitioning across clients ([`partition`]) and mini-batch loading
//! ([`loader`]). See DESIGN.md §2 for the CIFAR-10 substitution rationale.

pub mod loader;
pub mod partition;
pub mod synth;
