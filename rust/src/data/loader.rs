//! Mini-batch loader: materializes shard coordinates into batched, padded
//! f32 tensors ready to become PJRT literals.
//!
//! Training batches are always **full** (`batch_size` rows): the epoch
//! permutation is padded by wrapping around the shard, matching the L2 loss
//! scaling contract (`loss_grad` divides by the padded batch size — see
//! `python/compile/model.py`). Evaluation batches instead zero-pad and rely
//! on the all-zero one-hot convention to mask padding rows exactly.

use crate::data::synth::{Sample, SynthCifar, DIM, NUM_CLASSES};
use crate::data::partition::Shard;
use crate::util::rng::Rng;

/// A materialized batch: row-major `x` (`rows × DIM`) and one-hot labels
/// (`rows × NUM_CLASSES`).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Vec<f32>,
    pub y1hot: Vec<f32>,
    pub rows: usize,
    /// Rows that carry real samples (== `rows` for training batches).
    pub real_rows: usize,
}

impl Batch {
    fn from_samples(samples: &[&Sample], rows: usize) -> Batch {
        assert!(samples.len() <= rows);
        let mut x = vec![0f32; rows * DIM];
        let mut y = vec![0f32; rows * NUM_CLASSES];
        for (r, s) in samples.iter().enumerate() {
            x[r * DIM..(r + 1) * DIM].copy_from_slice(&s.x);
            y[r * NUM_CLASSES + s.label] = 1.0;
        }
        Batch {
            x,
            y1hot: y,
            rows,
            real_rows: samples.len(),
        }
    }
}

/// Epoch iterator over one client's shard.
pub struct Loader {
    gen: SynthCifar,
    shard: Shard,
    batch_size: usize,
    rng: Rng,
    /// Cache of materialized samples (shards are small enough to hold).
    cache: Vec<Sample>,
}

impl Loader {
    pub fn new(gen: SynthCifar, shard: Shard, batch_size: usize, rng: Rng) -> Loader {
        assert!(batch_size > 0);
        let cache = shard
            .coords
            .iter()
            .map(|&(label, idx)| gen.sample(label, idx))
            .collect();
        Loader {
            gen,
            shard,
            batch_size,
            rng,
            cache,
        }
    }

    pub fn n_samples(&self) -> usize {
        self.cache.len()
    }

    /// Batches per epoch (wrap-padded, so `ceil`).
    pub fn batches_per_epoch(&self) -> usize {
        self.n_samples().div_ceil(self.batch_size)
    }

    /// Produce one epoch of full batches in a fresh random order.
    ///
    /// The final partial batch wraps around into the epoch's first samples so
    /// every batch has exactly `batch_size` real rows.
    pub fn epoch(&mut self) -> Vec<Batch> {
        let n = self.cache.len();
        assert!(n > 0, "empty shard");
        let mut order: Vec<usize> = (0..n).collect();
        self.rng.shuffle(&mut order);
        let mut out = Vec::with_capacity(self.batches_per_epoch());
        let mut i = 0;
        while i < n {
            let mut rows: Vec<&Sample> = Vec::with_capacity(self.batch_size);
            for k in 0..self.batch_size {
                // wrap-around padding for the tail batch
                let idx = order[(i + k) % n];
                rows.push(&self.cache[idx]);
            }
            out.push(Batch::from_samples(&rows, self.batch_size));
            i += self.batch_size;
        }
        out
    }

    /// Access the generator (e.g. to derive the shared test set).
    pub fn generator(&self) -> &SynthCifar {
        &self.gen
    }

    /// The shard this loader serves.
    pub fn shard(&self) -> &Shard {
        &self.shard
    }
}

/// Build zero-padded evaluation batches from a flat sample list.
pub fn eval_batches(samples: &[Sample], batch_size: usize) -> Vec<Batch> {
    assert!(batch_size > 0);
    samples
        .chunks(batch_size)
        .map(|chunk| {
            let refs: Vec<&Sample> = chunk.iter().collect();
            Batch::from_samples(&refs, batch_size)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DataDistribution;
    use crate::data::partition::partition;

    fn loader(n_samples: usize, batch: usize) -> Loader {
        let gen = SynthCifar::new(1, 0.5);
        let mut rng = Rng::new(2);
        let shard = partition(&mut rng, 1, n_samples, &DataDistribution::Iid).remove(0);
        Loader::new(gen, shard, batch, Rng::new(3))
    }

    #[test]
    fn epoch_covers_all_samples() {
        let mut l = loader(100, 10);
        let batches = l.epoch();
        assert_eq!(batches.len(), 10);
        for b in &batches {
            assert_eq!(b.rows, 10);
            assert_eq!(b.real_rows, 10);
            assert_eq!(b.x.len(), 10 * DIM);
            assert_eq!(b.y1hot.len(), 10 * NUM_CLASSES);
            // every row has exactly one hot label
            for r in 0..b.rows {
                let s: f32 = b.y1hot[r * NUM_CLASSES..(r + 1) * NUM_CLASSES].iter().sum();
                assert_eq!(s, 1.0);
            }
        }
    }

    #[test]
    fn tail_batch_wraps_to_full_size() {
        let mut l = loader(25, 10);
        let batches = l.epoch();
        assert_eq!(batches.len(), 3);
        for b in &batches {
            assert_eq!(b.rows, 10);
            assert_eq!(b.real_rows, 10); // wrap-padded with real samples
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let mut l = loader(64, 8);
        let e1: Vec<f32> = l.epoch()[0].x.clone();
        let e2: Vec<f32> = l.epoch()[0].x.clone();
        assert_ne!(e1, e2, "epochs should reshuffle");
    }

    #[test]
    fn deterministic_given_seeds() {
        let mut a = loader(32, 8);
        let mut b = loader(32, 8);
        assert_eq!(a.epoch()[0].x, b.epoch()[0].x);
    }

    #[test]
    fn eval_batches_zero_pad_last() {
        let gen = SynthCifar::new(4, 0.5);
        let samples = gen.test_set(23);
        let batches = eval_batches(&samples, 10);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].real_rows, 3);
        assert_eq!(batches[2].rows, 10);
        // padding rows are all-zero one-hot
        for r in 3..10 {
            let s: f32 = batches[2].y1hot[r * NUM_CLASSES..(r + 1) * NUM_CLASSES]
                .iter()
                .sum();
            assert_eq!(s, 0.0);
        }
    }

    #[test]
    fn batches_per_epoch_formula() {
        let l = loader(100, 32);
        assert_eq!(l.batches_per_epoch(), 4);
        assert_eq!(l.n_samples(), 100);
    }
}
