//! Dataset partitioning across clients: IID, the paper's 2-class Non-IID
//! shards, and Dirichlet label skew.
//!
//! A partition assigns each client a list of `(label, index)` generator
//! coordinates (see [`super::synth`]) — samples are never duplicated across
//! clients, and every client receives exactly `samples_per_client` samples
//! (the paper gives each of 20 clients 2500 of CIFAR-10's 50 000).

use crate::config::DataDistribution;
use crate::data::synth::NUM_CLASSES;
use crate::util::rng::Rng;

/// One client's shard: generator coordinates of its local dataset.
#[derive(Clone, Debug, Default)]
pub struct Shard {
    pub coords: Vec<(usize, u64)>, // (label, generator index)
}

impl Shard {
    pub fn len(&self) -> usize {
        self.coords.len()
    }

    pub fn is_empty(&self) -> bool {
        self.coords.is_empty()
    }

    /// Per-class sample counts (diagnostic + tests).
    pub fn class_histogram(&self) -> [usize; NUM_CLASSES] {
        let mut h = [0usize; NUM_CLASSES];
        for &(label, _) in &self.coords {
            h[label] += 1;
        }
        h
    }
}

/// Allocator that hands out fresh generator indices per class, guaranteeing
/// global no-duplication across all shards it produces.
#[derive(Debug, Default)]
struct IndexAllocator {
    next: [u64; NUM_CLASSES],
}

impl IndexAllocator {
    fn take(&mut self, label: usize) -> (usize, u64) {
        let i = self.next[label];
        self.next[label] += 1;
        (label, i)
    }
}

/// Partition `n_clients × samples_per_client` samples per `dist`.
pub fn partition(
    rng: &mut Rng,
    n_clients: usize,
    samples_per_client: usize,
    dist: &DataDistribution,
) -> Vec<Shard> {
    let mut alloc = IndexAllocator::default();
    let mut shards = vec![Shard::default(); n_clients];
    match *dist {
        DataDistribution::Iid => {
            // Equal per-class counts; remainder spread round-robin from a
            // random class offset so no class is systematically favored.
            for shard in shards.iter_mut() {
                let base = samples_per_client / NUM_CLASSES;
                let rem = samples_per_client % NUM_CLASSES;
                let start = rng.below(NUM_CLASSES);
                for c in 0..NUM_CLASSES {
                    let extra = ((c + NUM_CLASSES - start) % NUM_CLASSES < rem) as usize;
                    for _ in 0..base + extra {
                        shard.coords.push(alloc.take(c));
                    }
                }
                rng.shuffle(&mut shard.coords);
            }
        }
        DataDistribution::ClassShards { classes_per_client } => {
            let k = classes_per_client.min(NUM_CLASSES);
            for shard in shards.iter_mut() {
                // Paper: "samples containing two randomly selected categories".
                let classes = rng.sample_indices(NUM_CLASSES, k);
                let base = samples_per_client / k;
                let rem = samples_per_client % k;
                for (ci, &c) in classes.iter().enumerate() {
                    let cnt = base + usize::from(ci < rem);
                    for _ in 0..cnt {
                        shard.coords.push(alloc.take(c));
                    }
                }
                rng.shuffle(&mut shard.coords);
            }
        }
        DataDistribution::Dirichlet { alpha } => {
            for shard in shards.iter_mut() {
                let props = rng.dirichlet(alpha, NUM_CLASSES);
                // Largest-remainder apportionment to hit the exact count.
                let mut counts: Vec<usize> = props
                    .iter()
                    .map(|p| (p * samples_per_client as f64).floor() as usize)
                    .collect();
                let mut assigned: usize = counts.iter().sum();
                let mut order: Vec<usize> = (0..NUM_CLASSES).collect();
                order.sort_by(|&a, &b| {
                    let ra = props[a] * samples_per_client as f64
                        - (props[a] * samples_per_client as f64).floor();
                    let rb = props[b] * samples_per_client as f64
                        - (props[b] * samples_per_client as f64).floor();
                    rb.partial_cmp(&ra).unwrap()
                });
                let mut oi = 0;
                while assigned < samples_per_client {
                    counts[order[oi % NUM_CLASSES]] += 1;
                    assigned += 1;
                    oi += 1;
                }
                for (c, &cnt) in counts.iter().enumerate() {
                    for _ in 0..cnt {
                        shard.coords.push(alloc.take(c));
                    }
                }
                rng.shuffle(&mut shard.coords);
            }
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn no_duplicates(shards: &[Shard]) {
        let mut seen = HashSet::new();
        for s in shards {
            for &c in &s.coords {
                assert!(seen.insert(c), "duplicate coordinate {c:?}");
            }
        }
    }

    #[test]
    fn iid_exact_sizes_and_balance() {
        let mut rng = Rng::new(1);
        let shards = partition(&mut rng, 20, 2500, &DataDistribution::Iid);
        assert_eq!(shards.len(), 20);
        for s in &shards {
            assert_eq!(s.len(), 2500);
            let h = s.class_histogram();
            // 2500/10 exactly divisible: perfectly balanced.
            assert!(h.iter().all(|&c| c == 250), "{h:?}");
        }
        no_duplicates(&shards);
    }

    #[test]
    fn iid_indivisible_remainder_spread() {
        let mut rng = Rng::new(2);
        let shards = partition(&mut rng, 4, 103, &DataDistribution::Iid);
        for s in &shards {
            assert_eq!(s.len(), 103);
            let h = s.class_histogram();
            assert!(h.iter().all(|&c| c == 10 || c == 11), "{h:?}");
        }
        no_duplicates(&shards);
    }

    #[test]
    fn class_shards_two_classes_paper() {
        let mut rng = Rng::new(3);
        let shards = partition(
            &mut rng,
            20,
            2500,
            &DataDistribution::ClassShards {
                classes_per_client: 2,
            },
        );
        for s in &shards {
            assert_eq!(s.len(), 2500);
            let h = s.class_histogram();
            let nonzero = h.iter().filter(|&&c| c > 0).count();
            assert_eq!(nonzero, 2, "{h:?}");
            assert!(h.iter().all(|&c| c == 0 || c == 1250));
        }
        no_duplicates(&shards);
    }

    #[test]
    fn class_shards_k_clamped_to_num_classes() {
        let mut rng = Rng::new(4);
        let shards = partition(
            &mut rng,
            2,
            100,
            &DataDistribution::ClassShards {
                classes_per_client: 99,
            },
        );
        for s in &shards {
            assert_eq!(s.len(), 100);
            assert_eq!(s.class_histogram().iter().filter(|&&c| c > 0).count(), 10);
        }
    }

    #[test]
    fn dirichlet_exact_counts_and_skew() {
        let mut rng = Rng::new(5);
        let shards = partition(
            &mut rng,
            10,
            500,
            &DataDistribution::Dirichlet { alpha: 0.1 },
        );
        for s in &shards {
            assert_eq!(s.len(), 500);
        }
        no_duplicates(&shards);
        // Low alpha → most shards dominated by few classes.
        let dominated = shards
            .iter()
            .filter(|s| {
                let h = s.class_histogram();
                *h.iter().max().unwrap() as f64 > 0.5 * 500.0
            })
            .count();
        assert!(dominated >= 5, "dominated={dominated}");
    }

    #[test]
    fn dirichlet_high_alpha_near_uniform() {
        let mut rng = Rng::new(6);
        let shards = partition(
            &mut rng,
            5,
            1000,
            &DataDistribution::Dirichlet { alpha: 1000.0 },
        );
        for s in &shards {
            let h = s.class_histogram();
            assert!(h.iter().all(|&c| (60..=140).contains(&c)), "{h:?}");
        }
    }

    #[test]
    fn deterministic_partition() {
        let dist = DataDistribution::ClassShards {
            classes_per_client: 2,
        };
        let a = partition(&mut Rng::new(9), 6, 120, &dist);
        let b = partition(&mut Rng::new(9), 6, 120, &dist);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.coords, y.coords);
        }
    }

    #[test]
    fn shards_shuffled_not_sorted() {
        let mut rng = Rng::new(10);
        let shards = partition(&mut rng, 1, 1000, &DataDistribution::Iid);
        let labels: Vec<usize> = shards[0].coords.iter().map(|&(l, _)| l).collect();
        let sorted = {
            let mut s = labels.clone();
            s.sort_unstable();
            s
        };
        assert_ne!(labels, sorted, "shard order should be shuffled");
    }
}
