//! Typed experiment configuration: defaults = the paper's Sec. IV simulation
//! setup, JSON file loading, CLI overrides, validation and named presets.
//!
//! Every experiment (examples, benches, the `fedpairing` binary) is driven by
//! an [`ExperimentConfig`], so a run is fully described by one JSON blob —
//! which the metrics sink embeds in its output for provenance.

use crate::util::json::{Json, JsonObj};
use std::fmt;

/// `Display` impl helper shared by the enums below.
macro_rules! fmt_display_via_name {
    () => {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.name())
        }
    };
}

macro_rules! bail {
    ($($arg:tt)*) => { return Err(ConfigError(format!($($arg)*))) };
}

/// Which FL algorithm drives the round loop (paper Sec. IV benchmarks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's contribution: client pairing + logical split (Sec. II).
    FedPairing,
    /// FedAvg: every client trains the full model locally [McMahan'17].
    VanillaFL,
    /// Sequential split learning against the server [Gupta & Raskar'18].
    VanillaSL,
    /// Parallel split learning + FedAvg aggregation [Thapa'22].
    SplitFed,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedpairing" | "fed-pairing" | "fp" => Some(Algorithm::FedPairing),
            "fl" | "fedavg" | "vanilla_fl" | "vanilla-fl" => Some(Algorithm::VanillaFL),
            "sl" | "vanilla_sl" | "vanilla-sl" => Some(Algorithm::VanillaSL),
            "splitfed" | "sfl" => Some(Algorithm::SplitFed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedPairing => "fedpairing",
            Algorithm::VanillaFL => "vanilla_fl",
            Algorithm::VanillaSL => "vanilla_sl",
            Algorithm::SplitFed => "splitfed",
        }
    }
}

impl fmt::Display for Algorithm {
    fmt_display_via_name!();
}

/// Client-pairing mechanism (paper Table I comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairingStrategy {
    /// Algorithm 1: greedy max-weight matching on eq. (5) weights.
    Greedy,
    /// Uniform random perfect matching.
    Random,
    /// Pair geographically nearest clients (optimizes comm only).
    Location,
    /// Pair most compute-imbalanced clients (optimizes compute only).
    Compute,
    /// Exact max-weight matching (bitmask DP) — optimality ablation.
    Exact,
}

impl PairingStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Some(PairingStrategy::Greedy),
            "random" => Some(PairingStrategy::Random),
            "location" | "location_based" | "location-based" => Some(PairingStrategy::Location),
            "compute" | "computation" | "resource" => Some(PairingStrategy::Compute),
            "exact" | "optimal" => Some(PairingStrategy::Exact),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairingStrategy::Greedy => "greedy",
            PairingStrategy::Random => "random",
            PairingStrategy::Location => "location",
            PairingStrategy::Compute => "compute",
            PairingStrategy::Exact => "exact",
        }
    }
}

impl fmt::Display for PairingStrategy {
    fmt_display_via_name!();
}

/// How the matching is maintained across rounds under fleet dynamics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairingMode {
    /// Keep the standing matching and re-pair only churn-affected clients
    /// (`repair_matching_pooled` — the default; cheapest, but drifts from
    /// the from-scratch matching over time).
    Repair,
    /// Re-run the full pairing mechanism from scratch every round — the
    /// reference answer, O(m·k) candidate generation + sort per round.
    Rebuild,
    /// Persistent cross-round matcher: candidate lists, edge set and sorted
    /// edge order survive between rounds; each round costs O(affected).
    /// Bit-for-bit identical matchings to `rebuild` (DESIGN.md §10).
    Incremental,
}

impl PairingMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "repair" => Some(PairingMode::Repair),
            "rebuild" | "full" => Some(PairingMode::Rebuild),
            "incremental" | "inc" => Some(PairingMode::Incremental),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairingMode::Repair => "repair",
            PairingMode::Rebuild => "rebuild",
            PairingMode::Incremental => "incremental",
        }
    }
}

impl fmt::Display for PairingMode {
    fmt_display_via_name!();
}

/// Which candidate-graph backend feeds the pairing mechanisms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendMode {
    /// Dense below [`PairingBackendConfig::AUTO_DENSE_MAX`] clients, sparse
    /// above — the default; existing paper-scale presets stay bit-identical.
    Auto,
    /// Always the complete eq. (5) graph (O(n²) edges — paper testbed scale).
    Dense,
    /// Always the grid + frequency-band candidate graph (O(n·k) edges).
    Sparse,
}

impl BackendMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(BackendMode::Auto),
            "dense" | "complete" => Some(BackendMode::Dense),
            "sparse" | "grid" => Some(BackendMode::Sparse),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendMode::Auto => "auto",
            BackendMode::Dense => "dense",
            BackendMode::Sparse => "sparse",
        }
    }
}

impl fmt::Display for BackendMode {
    fmt_display_via_name!();
}

/// Candidate-graph backend selection plus the sparse generator's knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PairingBackendConfig {
    pub mode: BackendMode,
    /// Grid-local candidates per client (nearest by distance).
    pub k_near: usize,
    /// Frequency-complementarity candidates per client (around the mirrored
    /// rank of the CPU-frequency ordering, so eq. (5)'s α term isn't
    /// starved).
    pub k_freq: usize,
}

impl PairingBackendConfig {
    /// Largest fleet `Auto` still pairs on the dense complete graph.
    pub const AUTO_DENSE_MAX: usize = 256;

    /// Does a fleet of `n` clients resolve to the sparse backend?
    pub fn sparse_for(&self, n: usize) -> bool {
        match self.mode {
            BackendMode::Dense => false,
            BackendMode::Sparse => true,
            BackendMode::Auto => n > Self::AUTO_DENSE_MAX,
        }
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.mode != BackendMode::Dense && self.k_near + self.k_freq == 0 {
            bail!("sparse pairing backend needs k_near + k_freq >= 1");
        }
        Ok(())
    }
}

impl Default for PairingBackendConfig {
    fn default() -> Self {
        PairingBackendConfig {
            mode: BackendMode::Auto,
            k_near: 8,
            k_freq: 4,
        }
    }
}

/// Which backend evaluates per-round training latency (DESIGN.md §6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundBackend {
    /// Analytic per-pair kernels + cross-round memo cache + parallel
    /// evaluation — O(changed pairs) per round, bit-identical to the DES.
    Analytic,
    /// The discrete-event job shop in `sim::des` — the correctness oracle.
    Des,
}

impl RoundBackend {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "kernel" | "closed-form" | "closed_form" => Some(RoundBackend::Analytic),
            "des" | "oracle" | "event" => Some(RoundBackend::Des),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoundBackend::Analytic => "analytic",
            RoundBackend::Des => "des",
        }
    }
}

impl fmt::Display for RoundBackend {
    fmt_display_via_name!();
}

/// Round-time engine knobs: backend selection, worker threads, diagnostics.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    pub backend: RoundBackend,
    /// Worker threads for pair evaluation. `0` means auto-detect: one worker
    /// per available core (`std::thread::available_parallelism`). Results are
    /// bit-identical for every thread count by construction.
    pub threads: usize,
    /// Collect per-flow finish times in `RoundTime` (2·pairs values per
    /// round — diagnostics the paper-scale presets keep and metro-scale
    /// skips).
    pub flow_diagnostics: bool,
}

impl EngineConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.threads > 4096 {
            bail!("engine threads must be <= 4096, got {}", self.threads);
        }
        Ok(())
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: RoundBackend::Analytic,
            threads: 0,
            flow_diagnostics: true,
        }
    }
}

/// Telemetry knobs: the metrics registry gate, export sampling, trace
/// output and pair-lane depth (DESIGN.md §8). Disabled by default — the
/// registry hooks then cost one atomic load + branch, and the simulation is
/// bit-identical either way (property-tested in `tests/telemetry.rs`).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryConfig {
    /// Master gate for the metrics registry and the exporters.
    pub enabled: bool,
    /// Export every Nth round to the trace / JSONL streams (1 = every
    /// round). The registry counters always run while enabled.
    pub sample_every: usize,
    /// Chrome trace-event output path; also derives the Prometheus
    /// (`<path>.prom`) and JSONL (`<path>.events.jsonl`) sibling outputs.
    /// `None` keeps the registry live without writing files.
    pub trace_out: Option<String>,
    /// Prometheus text-exposition snapshot written once at run exit:
    /// registry counters/gauges/histograms plus the distribution
    /// observatory's quantile-sketch lanes and fairness series. `None`
    /// writes nothing.
    pub metrics_out: Option<String>,
    /// Trace lanes for the k slowest pairs per sampled round.
    pub top_k_pairs: usize,
}

impl TelemetryConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.sample_every == 0 {
            bail!("telemetry sample_every must be >= 1");
        }
        Ok(())
    }
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig {
            enabled: false,
            sample_every: 1,
            trace_out: None,
            metrics_out: None,
            top_k_pairs: 8,
        }
    }
}

/// How the server aggregates client updates (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationMode {
    /// Lockstep rounds: the round ends when the slowest participant finishes
    /// — the paper's model, and the bit-identical default.
    Sync,
    /// Event-driven buffered aggregation: units stream updates as they
    /// finish; the server merges once [`AsyncConfig::buffer_size`] updates
    /// are buffered, subject to the bounded-staleness gate.
    Async,
}

impl AggregationMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sync" | "synchronous" | "round" => Some(AggregationMode::Sync),
            "async" | "asynchronous" | "buffered" => Some(AggregationMode::Async),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregationMode::Sync => "sync",
            AggregationMode::Async => "async",
        }
    }
}

impl fmt::Display for AggregationMode {
    fmt_display_via_name!();
}

/// Staleness-discounting function applied to buffered updates at merge time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessWeighting {
    /// Every update counts with its data weight regardless of staleness.
    Flat,
    /// FedBuff-style polynomial discount: `s(τ) = 1 / (1 + τ)^0.5`. At
    /// `τ = 0` this is exactly 1, so the sync-recovery limit is unaffected.
    Polynomial,
}

impl StalenessWeighting {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "flat" | "uniform" => Some(StalenessWeighting::Flat),
            "poly" | "polynomial" => Some(StalenessWeighting::Polynomial),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            StalenessWeighting::Flat => "flat",
            StalenessWeighting::Polynomial => "polynomial",
        }
    }

    /// The discount factor `s(τ)` for an update that is `tau` merges stale.
    pub fn factor(&self, tau: usize) -> f64 {
        match self {
            StalenessWeighting::Flat => 1.0,
            StalenessWeighting::Polynomial => 1.0 / (1.0 + tau as f64).sqrt(),
        }
    }
}

impl fmt::Display for StalenessWeighting {
    fmt_display_via_name!();
}

/// Buffered-aggregation knobs (only read when
/// [`ExperimentConfig::aggregation`] is [`AggregationMode::Async`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AsyncConfig {
    /// Updates buffered before the server merges (≥ 1). A merge also fires
    /// early whenever no unit is left running, so the engine never deadlocks
    /// on a part-full buffer.
    pub buffer_size: usize,
    /// Bounded staleness: the merge gate defers any merge that would push a
    /// still-running unit's staleness beyond this many versions. `0` degrades
    /// to fully synchronous behaviour; any value ≥ the round budget is
    /// effectively unbounded.
    pub staleness_cap: usize,
    /// Staleness-discounting function for merge weights.
    pub weighting: StalenessWeighting,
}

impl AsyncConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.buffer_size == 0 {
            bail!("async buffer_size must be >= 1");
        }
        Ok(())
    }
}

impl Default for AsyncConfig {
    fn default() -> Self {
        AsyncConfig {
            buffer_size: 8,
            staleness_cap: 16,
            weighting: StalenessWeighting::Polynomial,
        }
    }
}

/// Recovery-policy knobs: what a failed transmission costs before giving up
/// (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryConfig {
    /// Maximum retry attempts per failed transmission (0 = fail fast).
    pub retry_max: usize,
    /// First retry backoff in simulated seconds; retry `k` waits
    /// `backoff_base_s · 2^(k-1)`, jittered.
    pub backoff_base_s: f64,
    /// Uniform jitter fraction added on each backoff wait, in `[0, 1]`.
    pub backoff_jitter: f64,
}

impl RecoveryConfig {
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(self.backoff_base_s.is_finite() && self.backoff_base_s > 0.0) {
            bail!("recovery backoff_base_s must be finite and > 0, got {}", self.backoff_base_s);
        }
        if !(self.backoff_jitter.is_finite() && (0.0..=1.0).contains(&self.backoff_jitter)) {
            bail!("recovery backoff_jitter must be in [0, 1], got {}", self.backoff_jitter);
        }
        // 2^retry_max prices the exponential backoff; beyond 64 doublings the
        // wait overflows any plausible deadline (and f64 exponent headroom).
        if self.retry_max > 64 {
            bail!("recovery retry_max must be <= 64, got {}", self.retry_max);
        }
        Ok(())
    }
}

impl Default for RecoveryConfig {
    fn default() -> Self {
        RecoveryConfig { retry_max: 2, backoff_base_s: 0.5, backoff_jitter: 0.1 }
    }
}

/// Mid-round fault-injection hazards plus the recovery policy (DESIGN.md
/// §11). All hazards and the deadline zero — the default — disarm the
/// subsystem entirely: the fault pass never runs and every trace is
/// bit-identical to a fault-free build.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Per-round probability that a client crashes during local compute.
    pub crash_per_round: f64,
    /// Probability that a pair (or client↔server split) transfer link drops
    /// mid-round.
    pub link_drop: f64,
    /// Probability that a model upload to the aggregator is lost.
    pub uplink_loss: f64,
    /// Server-side round deadline in simulated seconds: updates arriving
    /// later are dropped and the round aggregates partially. `0` disables.
    pub deadline_s: f64,
    pub recovery: RecoveryConfig,
}

impl FaultConfig {
    /// Whether any hazard or the deadline is armed.
    pub fn active(&self) -> bool {
        self.crash_per_round > 0.0
            || self.link_drop > 0.0
            || self.uplink_loss > 0.0
            || self.deadline_s > 0.0
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        for (name, p) in [
            ("crash_per_round", self.crash_per_round),
            ("link_drop", self.link_drop),
            ("uplink_loss", self.uplink_loss),
        ] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                bail!("fault hazard {name} must be a finite probability in [0, 1], got {p}");
            }
        }
        if !(self.deadline_s.is_finite() && self.deadline_s >= 0.0) {
            bail!(
                "fault deadline_s must be finite and >= 0 (0 disables), got {}",
                self.deadline_s
            );
        }
        self.recovery.validate()
    }

    /// Apply a `--faults` CLI spec: `off` disarms every hazard and the
    /// deadline; otherwise a comma list of `crash=P` / `link=P` / `uplink=P`.
    pub fn apply_spec(&mut self, spec: &str) -> Result<(), ConfigError> {
        if spec.eq_ignore_ascii_case("off") {
            self.crash_per_round = 0.0;
            self.link_drop = 0.0;
            self.uplink_loss = 0.0;
            self.deadline_s = 0.0;
            return Ok(());
        }
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((key, val)) = part.split_once('=') else {
                bail!("fault spec entry {part:?} must be key=value");
            };
            let p: f64 = val
                .trim()
                .parse()
                .map_err(|_| ConfigError(format!("fault spec {key}={val}: not a number")))?;
            match key.trim() {
                "crash" => self.crash_per_round = p,
                "link" => self.link_drop = p,
                "uplink" => self.uplink_loss = p,
                other => bail!("unknown fault spec key {other:?} (expected crash/link/uplink)"),
            }
        }
        Ok(())
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            crash_per_round: 0.0,
            link_drop: 0.0,
            uplink_loss: 0.0,
            deadline_s: 0.0,
            recovery: RecoveryConfig::default(),
        }
    }
}

/// Which split-planning policy decides the per-pair model cut (DESIGN.md §7).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SplitPolicy {
    /// The paper's proportional rule `L_i = ⌊f_i/(f_i+f_j)·W⌋` — layer
    /// counts only, reproduced bit-for-bit. The default.
    Paper,
    /// Equalize per-side training FLOP-time using the real `ModelProfile`
    /// (layers cost what they cost, not `1/W` each).
    Balanced,
    /// Exact argmin of the pair's analytic training makespan over every
    /// feasible cut — compute *and* activation traffic priced by the same
    /// kernel the round engine charges.
    Optimal,
}

impl SplitPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "paper" | "proportional" => Some(SplitPolicy::Paper),
            "balanced" | "flops" => Some(SplitPolicy::Balanced),
            "optimal" | "argmin" => Some(SplitPolicy::Optimal),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SplitPolicy::Paper => "paper",
            SplitPolicy::Balanced => "balanced",
            SplitPolicy::Optimal => "optimal",
        }
    }
}

impl fmt::Display for SplitPolicy {
    fmt_display_via_name!();
}

/// Split-planning knobs: policy, search bounds and pairing co-design.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitConfig {
    pub policy: SplitPolicy,
    /// Privacy/feasibility floor: `Balanced`/`Optimal` keep at least this
    /// many layers on *each* side of the cut (the paper requires the data
    /// owner to retain the input layer, hence the default of 1). `Paper`
    /// ignores it — its rule is reproduced bit-for-bit.
    pub min_layers: usize,
    /// Co-design pairing with splitting: when the policy is not `Paper`, the
    /// greedy/exact pairing weights become the planner's predicted pair
    /// latency instead of the eq. (5) proxy.
    pub co_design: bool,
}

impl SplitConfig {
    /// Validate against the latency model's unit count `W`.
    pub fn validate(&self, w: usize) -> Result<(), ConfigError> {
        if self.min_layers == 0 {
            bail!("split min_layers must be >= 1 (the input layer stays with the data owner)");
        }
        if 2 * self.min_layers > w {
            bail!(
                "split min_layers = {} leaves no feasible cut for W = {w}",
                self.min_layers
            );
        }
        Ok(())
    }
}

impl Default for SplitConfig {
    fn default() -> Self {
        SplitConfig {
            policy: SplitPolicy::Paper,
            min_layers: 1,
            co_design: true,
        }
    }
}

/// Which model cost profile drives the latency simulation and cut-knob
/// validation (`sim::profile` holds the actual tables).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelPreset {
    /// CIFAR-style ResNet-18 (W = 10) — the paper's timing model. Default.
    Resnet18,
    /// CIFAR-style ResNet-34 (W = 18) — deeper cut-search space.
    Resnet34,
    /// CIFAR-style ResNet-10 (W = 6).
    Resnet10,
    /// The AOT-exported residual MLP (W = 8).
    Mlp,
}

impl ModelPreset {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "resnet18" | "resnet-18" => Some(ModelPreset::Resnet18),
            "resnet34" | "resnet-34" => Some(ModelPreset::Resnet34),
            "resnet10" | "resnet-10" => Some(ModelPreset::Resnet10),
            "mlp" => Some(ModelPreset::Mlp),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelPreset::Resnet18 => "resnet18",
            ModelPreset::Resnet34 => "resnet34",
            ModelPreset::Resnet10 => "resnet10",
            ModelPreset::Mlp => "mlp",
        }
    }

    /// Splittable units `W` of the preset's profile — pinned against
    /// `ModelProfile::from_preset` by a test, so config validation can bound
    /// the cut knobs without constructing the profile.
    pub const fn w(&self) -> usize {
        match self {
            ModelPreset::Resnet18 => 10,
            ModelPreset::Resnet34 => 18,
            ModelPreset::Resnet10 => 6,
            ModelPreset::Mlp => 8,
        }
    }
}

impl fmt::Display for ModelPreset {
    fmt_display_via_name!();
}

/// Local-data distribution across clients (paper Sec. IV-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataDistribution {
    /// Equal share of every class per client.
    Iid,
    /// `classes_per_client` randomly-chosen classes per client (paper: 2).
    ClassShards { classes_per_client: usize },
    /// Dirichlet(α) label skew (common FL extension; ablation material).
    Dirichlet { alpha: f64 },
}

impl DataDistribution {
    pub fn name(&self) -> String {
        match self {
            DataDistribution::Iid => "iid".into(),
            DataDistribution::ClassShards { classes_per_client } => {
                format!("shards{classes_per_client}")
            }
            DataDistribution::Dirichlet { alpha } => format!("dirichlet{alpha}"),
        }
    }
}

/// Named fleet-dynamics scenario (the `fleet` layer's presets).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// The paper's static fleet: nobody joins, leaves, or fades.
    Stable,
    /// Availability follows a day/night wave; light mobility and shadowing.
    Diurnal,
    /// A latent cohort joins at once mid-run; background departures.
    FlashCrowd,
    /// Deep fading, transient failures and stragglers on a jittery radio.
    LossyRadio,
    /// City-scale fleet (n = 50k–100k): light steady churn and drift; pairs
    /// only with the sparse candidate-graph backend in reach.
    MetroScale,
}

impl ScenarioKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "stable" | "static" => Some(ScenarioKind::Stable),
            "diurnal" | "day-night" | "day_night" => Some(ScenarioKind::Diurnal),
            "flash-crowd" | "flash_crowd" | "flashcrowd" => Some(ScenarioKind::FlashCrowd),
            "lossy-radio" | "lossy_radio" | "lossy" => Some(ScenarioKind::LossyRadio),
            "metro-scale" | "metro_scale" | "metro" => Some(ScenarioKind::MetroScale),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Stable => "stable",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::FlashCrowd => "flash-crowd",
            ScenarioKind::LossyRadio => "lossy-radio",
            ScenarioKind::MetroScale => "metro-scale",
        }
    }

    /// All named scenarios (CLI help, examples, benches).
    pub const ALL: [ScenarioKind; 5] = [
        ScenarioKind::Stable,
        ScenarioKind::Diurnal,
        ScenarioKind::FlashCrowd,
        ScenarioKind::LossyRadio,
        ScenarioKind::MetroScale,
    ];
}

impl fmt::Display for ScenarioKind {
    fmt_display_via_name!();
}

/// Fleet-dynamics knobs. [`ScenarioConfig::preset`] fills them per named
/// scenario; JSON configs may override any knob individually. All stochastic
/// draws they parameterize run on dedicated `util::rng` streams, so a
/// `(seed, scenario)` pair replays bit-identically.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioConfig {
    pub kind: ScenarioKind,
    /// Per-alive-client, per-round probability of (durable) departure.
    pub p_depart: f64,
    /// Per-departed-client, per-round probability of rejoining.
    pub p_rejoin: f64,
    /// Per-alive-client, per-round probability of a transient failure
    /// (client stays in the matching but misses this round).
    pub p_transient: f64,
    /// Per-present-client, per-round probability of straggling.
    pub p_straggle: f64,
    /// CPU-frequency multiplier applied while straggling (0 < f ≤ 1).
    pub straggle_factor: f64,
    /// Per-round client random-walk step std-dev in meters (0 = static).
    pub mobility_m: f64,
    /// Std-dev in dB of the per-round log-normal shadowing re-draw layered
    /// on the eq. (3) channel (0 = frozen channel).
    pub shadowing_std_db: f64,
    /// Latent cohort size as a fraction of `n_clients` (flash-crowd).
    pub flash_fraction: f64,
    /// Round at which the latent cohort joins (0 = never).
    pub flash_round: usize,
    /// Rounds per availability cycle (0 = no diurnal wave).
    pub diurnal_period: usize,
    /// Fraction of the fleet asleep at the trough of the wave (0..1).
    pub diurnal_depth: f64,
}

impl ScenarioConfig {
    /// The knob values behind each named scenario.
    pub fn preset(kind: ScenarioKind) -> ScenarioConfig {
        let stable = ScenarioConfig {
            kind,
            p_depart: 0.0,
            p_rejoin: 0.0,
            p_transient: 0.0,
            p_straggle: 0.0,
            straggle_factor: 1.0,
            mobility_m: 0.0,
            shadowing_std_db: 0.0,
            flash_fraction: 0.0,
            flash_round: 0,
            diurnal_period: 0,
            diurnal_depth: 0.0,
        };
        match kind {
            ScenarioKind::Stable => stable,
            ScenarioKind::Diurnal => ScenarioConfig {
                p_transient: 0.02,
                mobility_m: 0.5,
                shadowing_std_db: 1.0,
                diurnal_period: 20,
                diurnal_depth: 0.4,
                ..stable
            },
            ScenarioKind::FlashCrowd => ScenarioConfig {
                p_depart: 0.05,
                p_rejoin: 0.10,
                p_transient: 0.02,
                mobility_m: 1.0,
                shadowing_std_db: 1.0,
                flash_fraction: 0.5,
                flash_round: 5,
                ..stable
            },
            ScenarioKind::LossyRadio => ScenarioConfig {
                p_depart: 0.02,
                p_rejoin: 0.30,
                p_transient: 0.08,
                p_straggle: 0.15,
                straggle_factor: 0.35,
                mobility_m: 2.0,
                shadowing_std_db: 6.0,
                ..stable
            },
            // At 100k clients even 1 %/round churn moves ~1 000 clients, so
            // the incremental repair path is exercised every round.
            ScenarioKind::MetroScale => ScenarioConfig {
                p_depart: 0.01,
                p_rejoin: 0.20,
                p_transient: 0.02,
                mobility_m: 2.0,
                shadowing_std_db: 2.0,
                ..stable
            },
        }
    }

    /// Preset lookup by CLI name.
    pub fn named(s: &str) -> Option<ScenarioConfig> {
        ScenarioKind::parse(s).map(ScenarioConfig::preset)
    }

    fn prob_ok(p: f64) -> bool {
        (0.0..=1.0).contains(&p)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        for (name, p) in [
            ("p_depart", self.p_depart),
            ("p_rejoin", self.p_rejoin),
            ("p_transient", self.p_transient),
            ("p_straggle", self.p_straggle),
            ("diurnal_depth", self.diurnal_depth),
        ] {
            if !Self::prob_ok(p) {
                bail!("scenario {name} must be a probability in [0,1], got {p}");
            }
        }
        if !(self.straggle_factor > 0.0 && self.straggle_factor <= 1.0) {
            bail!(
                "scenario straggle_factor must be in (0,1], got {}",
                self.straggle_factor
            );
        }
        if self.mobility_m < 0.0 || self.shadowing_std_db < 0.0 {
            bail!("scenario mobility/shadowing must be >= 0");
        }
        if self.flash_fraction < 0.0 {
            bail!("scenario flash_fraction must be >= 0");
        }
        if self.flash_round > 0 && self.flash_fraction == 0.0 {
            bail!("scenario flash_round set but flash_fraction is 0");
        }
        Ok(())
    }
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig::preset(ScenarioKind::Stable)
    }
}

/// Wireless channel parameters — eq. (3) of the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelConfig {
    /// Spectral bandwidth `B` in Hz (paper: 64 MHz).
    pub bandwidth_hz: f64,
    /// Transmit power `P` in W (paper: 1 W).
    pub tx_power_w: f64,
    /// Noise power `σ²` in W (paper: 1e-9 W).
    pub noise_w: f64,
    /// Reference channel gain `h0` at unit distance (paper leaves this free;
    /// we use −35 dB, calibrated so the comm/compute balance reproduces the Table I/II orderings — see EXPERIMENTS.md).
    pub ref_gain: f64,
    /// Reference distance `ζ0` in m.
    pub ref_dist_m: f64,
    /// Path-loss exponent `θ` (urban micro ≈ 3).
    pub pathloss_exp: f64,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        ChannelConfig {
            bandwidth_hz: 64e6,
            tx_power_w: 1.0,
            noise_w: 1e-9,
            ref_gain: 3e-4,
            ref_dist_m: 1.0,
            pathloss_exp: 3.0,
        }
    }
}

/// Client compute heterogeneity (paper: f ~ U[0.1, 2] GHz).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeConfig {
    pub f_min_ghz: f64,
    pub f_max_ghz: f64,
    /// Server CPU frequency for SL/SplitFed offloading ("super computing
    /// power" in the paper's Sec. IV-D discussion).
    pub server_freq_ghz: f64,
    /// Calibration constant: effective cycles per FLOP of the training
    /// workload. One global scalar; only absolute seconds depend on it,
    /// never orderings (DESIGN.md §2).
    pub cycles_per_flop: f64,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig {
            f_min_ghz: 0.1,
            f_max_ghz: 2.0,
            server_freq_ghz: 100.0,
            cycles_per_flop: 0.085,
        }
    }
}

/// Top-level experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub pairing: PairingStrategy,
    /// Cross-round matching maintenance: repair the standing matching
    /// (default), rebuild from scratch each round, or the persistent
    /// incremental matcher (rebuild-identical output at O(affected) cost).
    pub pairing_mode: PairingMode,
    /// Candidate-graph backend feeding the pairing mechanisms (dense complete
    /// graph vs sparse grid + frequency-band candidates; `Auto` switches on
    /// fleet size so paper-scale presets stay bit-identical).
    pub backend: PairingBackendConfig,
    /// Round-time evaluation engine (analytic kernels vs the DES oracle,
    /// worker threads, flow diagnostics).
    pub engine: EngineConfig,
    /// Split-planning subsystem: per-pair cut policy, search floor, pairing
    /// co-design (DESIGN.md §7). Default `paper` reproduces `split_lengths`.
    pub split: SplitConfig,
    /// Observability: metrics registry gate, stage-breakdown export
    /// sampling, trace output (DESIGN.md §8). Off by default; never affects
    /// simulation results.
    pub telemetry: TelemetryConfig,
    /// Server aggregation discipline: lockstep rounds (default) or the
    /// event-driven bounded-staleness buffer (DESIGN.md §9).
    pub aggregation: AggregationMode,
    /// Buffered-aggregation knobs; only read when `aggregation` is `Async`.
    pub async_agg: AsyncConfig,
    /// Mid-round fault injection + recovery policy (DESIGN.md §11). Fully
    /// disarmed by default — traces are then bit-identical to a fault-free
    /// build.
    pub faults: FaultConfig,
    /// Stream per-round records incrementally to
    /// `<dir>/<name>_<algo>_<dist>.stream.{csv,jsonl}` as they are produced,
    /// instead of only buffering them for the end-of-run sink. `None`
    /// disables streaming.
    pub stream_out: Option<String>,
    /// Model cost profile for the engine-free latency paths (`fedpairing
    /// churn`, `simulate_scenario`, planner) and cut-knob validation.
    pub model: ModelPreset,

    // fleet
    pub n_clients: usize,
    pub area_radius_m: f64,
    pub channel: ChannelConfig,
    pub compute: ComputeConfig,
    /// Fleet-dynamics scenario (churn, fading, stragglers). The default
    /// `stable` preset reproduces the paper's static fleet exactly.
    pub scenario: ScenarioConfig,

    // training schedule (paper: 100 rounds × 2 local epochs, lr 0.1)
    pub rounds: usize,
    pub local_epochs: usize,
    pub lr: f32,

    // data (paper: CIFAR-10, 2500 samples/client; we synthesize — DESIGN.md §2)
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub distribution: DataDistribution,
    pub noise_level: f32,

    // pairing objective weights (eq. 5); α scales (Δf)², β scales r_ij.
    pub alpha: f64,
    pub beta: f64,

    // FedPairing mechanics
    /// Apply the eq. (7) 2× step on overlapping layers.
    pub overlap_boost: bool,
    /// Split point for vanilla SL (client keeps layers < cut). SL offloads
    /// aggressively — the client retains only the input layer (privacy floor).
    pub sl_cut_layer: usize,
    /// Split point for SplitFed. SplitFed-style systems keep a deeper client
    /// prefix (the client-side model that gets FedAvg'd); with the ResNet-18
    /// profile cut=3 puts ~27% of FLOPs client-side, matching Table II's
    /// "SplitFed slower than FedPairing" regime.
    pub splitfed_cut_layer: usize,

    /// Evaluate every `eval_every` rounds (0 = only final).
    pub eval_every: usize,
    /// Artifact directory holding manifest.json + *.hlo.txt.
    pub artifacts_dir: String,
    /// Metrics/output directory.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 17,
            algorithm: Algorithm::FedPairing,
            pairing: PairingStrategy::Greedy,
            pairing_mode: PairingMode::Repair,
            backend: PairingBackendConfig::default(),
            engine: EngineConfig::default(),
            split: SplitConfig::default(),
            telemetry: TelemetryConfig::default(),
            aggregation: AggregationMode::Sync,
            async_agg: AsyncConfig::default(),
            faults: FaultConfig::default(),
            stream_out: None,
            model: ModelPreset::Resnet18,
            n_clients: 20,
            area_radius_m: 50.0,
            channel: ChannelConfig::default(),
            compute: ComputeConfig::default(),
            scenario: ScenarioConfig::default(),
            rounds: 100,
            local_epochs: 2,
            // Paper: 0.1 for ResNet-18 (with batch-norm). The substitute
            // ResNet-MLP has no normalization layers and diverges at 0.1 on
            // the shared-dictionary task; 0.05 is its stable equivalent.
            lr: 0.05,
            samples_per_client: 2500,
            test_samples: 2000,
            distribution: DataDistribution::Iid,
            noise_level: 1.5,
            alpha: 1.0,
            beta: 5e-10,
            overlap_boost: true,
            sl_cut_layer: 1,
            splitfed_cut_layer: 3,
            eval_every: 1,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
        }
    }
}

/// Validation failure.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl ExperimentConfig {
    /// Install a scenario plus its derived engine defaults — the one place
    /// the "metro scale skips flow diagnostics" policy lives. Presets, CLI
    /// `--scenario` and JSON scenario blocks all route through it (JSON only
    /// when the `engine` block didn't pin `flow_diagnostics` explicitly).
    pub fn set_scenario(&mut self, sc: ScenarioConfig) {
        self.scenario = sc;
        if sc.kind == ScenarioKind::MetroScale {
            self.engine.flow_diagnostics = false;
        }
    }

    /// Sanity-check invariants the rest of the system assumes.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.n_clients == 0 {
            bail!("n_clients must be > 0");
        }
        // Odd fleets are fine for every algorithm: FedPairing leaves one
        // client solo (near-perfect matching; the solo client trains the
        // full model locally) — required anyway once churn can kill a
        // client mid-run.
        self.scenario.validate()?;
        self.backend.validate()?;
        self.engine.validate()?;
        self.split.validate(self.model.w())?;
        self.telemetry.validate()?;
        self.async_agg.validate()?;
        self.faults.validate()?;
        // The DES oracle is round-synchronous by construction: it prices one
        // lockstep round at a time and has no notion of units carrying over a
        // merge boundary. Reject the combination instead of silently running
        // the analytic path.
        if self.aggregation == AggregationMode::Async && self.engine.backend == RoundBackend::Des {
            bail!("async aggregation requires the analytic engine (engine.backend = des is round-synchronous)");
        }
        // The fault pass replays the engine's recorded per-unit times; the
        // DES oracle records none, so faults there would silently no-op.
        if self.faults.active() && self.engine.backend == RoundBackend::Des {
            bail!("fault injection requires the analytic engine (engine.backend = des records no per-unit times)");
        }
        // A server deadline is a round-synchronous concept; buffered
        // aggregation has no round barrier for it to cut.
        if self.faults.deadline_s > 0.0 && self.aggregation == AggregationMode::Async {
            bail!("faults deadline_s requires sync aggregation (async merges have no round deadline)");
        }
        // Cut knobs are bounded here, against the configured model profile,
        // instead of being silently clamped deep inside the drivers.
        let w = self.model.w();
        for (name, cut) in [
            ("sl_cut_layer", self.sl_cut_layer),
            ("splitfed_cut_layer", self.splitfed_cut_layer),
        ] {
            if cut == 0 || cut >= w {
                bail!(
                    "{name} = {cut} out of range [1, {}] for model {} (W = {w})",
                    w - 1,
                    self.model
                );
            }
        }
        // A sparse backend must generate candidates from the source the
        // configured objective actually uses, or the matching silently
        // degenerates to id-order completion pairs.
        if self.backend.sparse_for(self.n_clients) {
            if self.pairing == PairingStrategy::Location && self.backend.k_near == 0 {
                bail!("location pairing on the sparse backend needs k_near >= 1");
            }
            if self.pairing == PairingStrategy::Compute && self.backend.k_freq == 0 {
                bail!("compute pairing on the sparse backend needs k_freq >= 1");
            }
        }
        // Rebuild/Incremental maintenance re-runs a *deterministic* weight
        // objective each round; Random has no edge weights to maintain.
        if self.pairing == PairingStrategy::Random && self.pairing_mode != PairingMode::Repair {
            bail!(
                "pairing_mode {} requires a weight-based pairing strategy (random has none)",
                self.pairing_mode
            );
        }
        if self.compute.f_min_ghz <= 0.0 || self.compute.f_max_ghz < self.compute.f_min_ghz {
            bail!(
                "invalid CPU frequency range [{}, {}]",
                self.compute.f_min_ghz,
                self.compute.f_max_ghz
            );
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be > 0");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be > 0, got {}", self.lr);
        }
        if self.samples_per_client == 0 {
            bail!("samples_per_client must be > 0");
        }
        if self.area_radius_m <= 0.0 {
            bail!("area_radius_m must be > 0");
        }
        if self.channel.bandwidth_hz <= 0.0
            || self.channel.noise_w <= 0.0
            || self.channel.tx_power_w <= 0.0
        {
            bail!("channel parameters must be positive");
        }
        if self.alpha < 0.0 || self.beta < 0.0 {
            bail!("pairing weights alpha/beta must be >= 0");
        }
        if let DataDistribution::ClassShards { classes_per_client } = self.distribution {
            if classes_per_client == 0 {
                bail!("classes_per_client must be > 0");
            }
        }
        if let DataDistribution::Dirichlet { alpha } = self.distribution {
            if alpha <= 0.0 {
                bail!("dirichlet alpha must be > 0");
            }
        }
        Ok(())
    }

    /// Named presets for the paper's experiments.
    pub fn preset(name: &str) -> Option<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        c.name = name.into();
        match name {
            // Fig. 2: IID convergence comparison (algorithm set via CLI/bench).
            "fig2" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Fig. 3: Non-IID — 2 random classes per client.
            "fig3" => {
                c.distribution = DataDistribution::ClassShards {
                    classes_per_client: 2,
                };
                Some(c)
            }
            // Table I: pairing-mechanism timing (latency sim; model = ResNet-18 profile).
            "table1" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Table II: algorithm timing.
            "table2" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Reduced-scale smoke config used by tests/examples.
            "quick" => {
                c.n_clients = 4;
                c.rounds = 3;
                c.samples_per_client = 64;
                c.test_samples = 128;
                Some(c)
            }
            // City-scale fleet for the engine-free scenario path: 50k clients
            // (override higher with --n-clients), sparse pairing backend via
            // Auto, light data so the latency DES stays cheap per pair.
            "metro-scale" => {
                c.n_clients = 50_000;
                c.rounds = 5;
                c.samples_per_client = 64;
                c.test_samples = 256;
                c.eval_every = 0;
                // set_scenario also drops the 2·pairs-per-round flow
                // diagnostics — pure overhead at 50k clients; the
                // paper-scale presets keep them.
                c.set_scenario(ScenarioConfig::preset(ScenarioKind::MetroScale));
                Some(c)
            }
            // Metro fleet over the deeper ResNet-34 profile (W = 18): the
            // cut-search space is non-trivial and bandwidth/depth effects
            // dominate — the split planner's stress preset.
            "metro-deep" => {
                c.n_clients = 50_000;
                c.rounds = 5;
                c.samples_per_client = 64;
                c.test_samples = 256;
                c.eval_every = 0;
                c.model = ModelPreset::Resnet34;
                c.set_scenario(ScenarioConfig::preset(ScenarioKind::MetroScale));
                Some(c)
            }
            _ => None,
        }
    }

    // ------------------------------------------------------------------
    // JSON round-trip
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::str(&self.name));
        o.insert("seed", Json::num(self.seed as f64));
        o.insert("algorithm", Json::str(self.algorithm.name()));
        o.insert("pairing", Json::str(self.pairing.name()));
        o.insert("pairing_mode", Json::str(self.pairing_mode.name()));
        let mut be = JsonObj::new();
        be.insert("mode", Json::str(self.backend.mode.name()));
        be.insert("k_near", Json::num(self.backend.k_near as f64));
        be.insert("k_freq", Json::num(self.backend.k_freq as f64));
        o.insert("backend", Json::Obj(be));
        let mut en = JsonObj::new();
        en.insert("backend", Json::str(self.engine.backend.name()));
        en.insert("threads", Json::num(self.engine.threads as f64));
        en.insert("flow_diagnostics", Json::Bool(self.engine.flow_diagnostics));
        o.insert("engine", Json::Obj(en));
        let mut sp = JsonObj::new();
        sp.insert("policy", Json::str(self.split.policy.name()));
        sp.insert("min_layers", Json::num(self.split.min_layers as f64));
        sp.insert("co_design", Json::Bool(self.split.co_design));
        o.insert("split", Json::Obj(sp));
        let mut tm = JsonObj::new();
        tm.insert("enabled", Json::Bool(self.telemetry.enabled));
        tm.insert("sample_every", Json::num(self.telemetry.sample_every as f64));
        tm.insert(
            "trace_out",
            match &self.telemetry.trace_out {
                Some(p) => Json::str(p),
                None => Json::Null,
            },
        );
        tm.insert(
            "metrics_out",
            match &self.telemetry.metrics_out {
                Some(p) => Json::str(p),
                None => Json::Null,
            },
        );
        tm.insert("top_k_pairs", Json::num(self.telemetry.top_k_pairs as f64));
        o.insert("telemetry", Json::Obj(tm));
        o.insert("aggregation", Json::str(self.aggregation.name()));
        let mut ag = JsonObj::new();
        ag.insert("buffer_size", Json::num(self.async_agg.buffer_size as f64));
        ag.insert("staleness_cap", Json::num(self.async_agg.staleness_cap as f64));
        ag.insert("weighting", Json::str(self.async_agg.weighting.name()));
        o.insert("async", Json::Obj(ag));
        let mut fa = JsonObj::new();
        fa.insert("crash_per_round", Json::num(self.faults.crash_per_round));
        fa.insert("link_drop", Json::num(self.faults.link_drop));
        fa.insert("uplink_loss", Json::num(self.faults.uplink_loss));
        fa.insert("deadline_s", Json::num(self.faults.deadline_s));
        let mut rc = JsonObj::new();
        rc.insert("retry_max", Json::num(self.faults.recovery.retry_max as f64));
        rc.insert("backoff_base_s", Json::num(self.faults.recovery.backoff_base_s));
        rc.insert("backoff_jitter", Json::num(self.faults.recovery.backoff_jitter));
        fa.insert("recovery", Json::Obj(rc));
        o.insert("faults", Json::Obj(fa));
        o.insert(
            "stream_out",
            match &self.stream_out {
                Some(p) => Json::str(p),
                None => Json::Null,
            },
        );
        o.insert("model", Json::str(self.model.name()));
        o.insert("n_clients", Json::num(self.n_clients as f64));
        o.insert("area_radius_m", Json::num(self.area_radius_m));
        let mut ch = JsonObj::new();
        ch.insert("bandwidth_hz", Json::num(self.channel.bandwidth_hz));
        ch.insert("tx_power_w", Json::num(self.channel.tx_power_w));
        ch.insert("noise_w", Json::num(self.channel.noise_w));
        ch.insert("ref_gain", Json::num(self.channel.ref_gain));
        ch.insert("ref_dist_m", Json::num(self.channel.ref_dist_m));
        ch.insert("pathloss_exp", Json::num(self.channel.pathloss_exp));
        o.insert("channel", Json::Obj(ch));
        let mut cp = JsonObj::new();
        cp.insert("f_min_ghz", Json::num(self.compute.f_min_ghz));
        cp.insert("f_max_ghz", Json::num(self.compute.f_max_ghz));
        cp.insert("server_freq_ghz", Json::num(self.compute.server_freq_ghz));
        cp.insert("cycles_per_flop", Json::num(self.compute.cycles_per_flop));
        o.insert("compute", Json::Obj(cp));
        let mut sc = JsonObj::new();
        sc.insert("kind", Json::str(self.scenario.kind.name()));
        sc.insert("p_depart", Json::num(self.scenario.p_depart));
        sc.insert("p_rejoin", Json::num(self.scenario.p_rejoin));
        sc.insert("p_transient", Json::num(self.scenario.p_transient));
        sc.insert("p_straggle", Json::num(self.scenario.p_straggle));
        sc.insert("straggle_factor", Json::num(self.scenario.straggle_factor));
        sc.insert("mobility_m", Json::num(self.scenario.mobility_m));
        sc.insert("shadowing_std_db", Json::num(self.scenario.shadowing_std_db));
        sc.insert("flash_fraction", Json::num(self.scenario.flash_fraction));
        sc.insert("flash_round", Json::num(self.scenario.flash_round as f64));
        sc.insert("diurnal_period", Json::num(self.scenario.diurnal_period as f64));
        sc.insert("diurnal_depth", Json::num(self.scenario.diurnal_depth));
        o.insert("scenario", Json::Obj(sc));
        o.insert("rounds", Json::num(self.rounds as f64));
        o.insert("local_epochs", Json::num(self.local_epochs as f64));
        o.insert("lr", Json::num(self.lr as f64));
        o.insert("samples_per_client", Json::num(self.samples_per_client as f64));
        o.insert("test_samples", Json::num(self.test_samples as f64));
        let mut d = JsonObj::new();
        match self.distribution {
            DataDistribution::Iid => {
                d.insert("kind", Json::str("iid"));
            }
            DataDistribution::ClassShards { classes_per_client } => {
                d.insert("kind", Json::str("class_shards"));
                d.insert("classes_per_client", Json::num(classes_per_client as f64));
            }
            DataDistribution::Dirichlet { alpha } => {
                d.insert("kind", Json::str("dirichlet"));
                d.insert("alpha", Json::num(alpha));
            }
        }
        o.insert("distribution", Json::Obj(d));
        o.insert("noise_level", Json::num(self.noise_level as f64));
        o.insert("alpha", Json::num(self.alpha));
        o.insert("beta", Json::num(self.beta));
        o.insert("overlap_boost", Json::Bool(self.overlap_boost));
        o.insert("sl_cut_layer", Json::num(self.sl_cut_layer as f64));
        o.insert("splitfed_cut_layer", Json::num(self.splitfed_cut_layer as f64));
        o.insert("eval_every", Json::num(self.eval_every as f64));
        o.insert("artifacts_dir", Json::str(&self.artifacts_dir));
        o.insert("out_dir", Json::str(&self.out_dir));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, ConfigError> {
        let mut c = ExperimentConfig::default();
        let obj = j
            .as_obj()
            .ok_or_else(|| ConfigError("config must be a JSON object".into()))?;
        let get_f64 = |k: &str, dv: f64| -> Result<f64, ConfigError> {
            match obj.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| ConfigError(format!("field {k} must be a number"))),
            }
        };
        let get_usize = |k: &str, dv: usize| -> Result<usize, ConfigError> {
            match obj.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| ConfigError(format!("field {k} must be a non-negative integer"))),
            }
        };
        if let Some(v) = obj.get("name") {
            c.name = v
                .as_str()
                .ok_or_else(|| ConfigError("name must be a string".into()))?
                .to_string();
        }
        c.seed = get_f64("seed", c.seed as f64)? as u64;
        if let Some(v) = obj.get("algorithm") {
            let s = v.as_str().ok_or_else(|| ConfigError("algorithm must be a string".into()))?;
            c.algorithm = Algorithm::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown algorithm {s:?}")))?;
        }
        if let Some(v) = obj.get("pairing") {
            let s = v.as_str().ok_or_else(|| ConfigError("pairing must be a string".into()))?;
            c.pairing = PairingStrategy::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown pairing strategy {s:?}")))?;
        }
        if let Some(v) = obj.get("pairing_mode") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError("pairing_mode must be a string".into()))?;
            c.pairing_mode = PairingMode::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown pairing mode {s:?}")))?;
        }
        if let Some(be) = obj.get("backend").and_then(|v| v.as_obj()) {
            if let Some(s) = be.get("mode").and_then(|v| v.as_str()) {
                c.backend.mode = BackendMode::parse(s)
                    .ok_or_else(|| ConfigError(format!("unknown backend mode {s:?}")))?;
            }
            let gu = |k: &str, dv: usize| be.get(k).and_then(|v| v.as_usize()).unwrap_or(dv);
            c.backend.k_near = gu("k_near", c.backend.k_near);
            c.backend.k_freq = gu("k_freq", c.backend.k_freq);
        }
        // Whether the JSON explicitly pinned `flow_diagnostics` — an explicit
        // value must survive the metro-scale scenario policy below.
        let mut flow_diag_pinned = false;
        if let Some(en) = obj.get("engine").and_then(|v| v.as_obj()) {
            if let Some(s) = en.get("backend").and_then(|v| v.as_str()) {
                c.engine.backend = RoundBackend::parse(s)
                    .ok_or_else(|| ConfigError(format!("unknown round backend {s:?}")))?;
            }
            if let Some(v) = en.get("threads") {
                c.engine.threads = v.as_usize().ok_or_else(|| {
                    ConfigError("engine threads must be a non-negative integer".into())
                })?;
            }
            if let Some(v) = en.get("flow_diagnostics") {
                c.engine.flow_diagnostics = v
                    .as_bool()
                    .ok_or_else(|| ConfigError("flow_diagnostics must be a bool".into()))?;
                flow_diag_pinned = true;
            }
        }
        if let Some(sp) = obj.get("split").and_then(|v| v.as_obj()) {
            if let Some(s) = sp.get("policy").and_then(|v| v.as_str()) {
                c.split.policy = SplitPolicy::parse(s)
                    .ok_or_else(|| ConfigError(format!("unknown split policy {s:?}")))?;
            }
            if let Some(v) = sp.get("min_layers") {
                c.split.min_layers = v.as_usize().ok_or_else(|| {
                    ConfigError("split min_layers must be a non-negative integer".into())
                })?;
            }
            if let Some(v) = sp.get("co_design") {
                c.split.co_design = v
                    .as_bool()
                    .ok_or_else(|| ConfigError("split co_design must be a bool".into()))?;
            }
        }
        if let Some(tm) = obj.get("telemetry").and_then(|v| v.as_obj()) {
            if let Some(v) = tm.get("enabled") {
                c.telemetry.enabled = v
                    .as_bool()
                    .ok_or_else(|| ConfigError("telemetry enabled must be a bool".into()))?;
            }
            if let Some(v) = tm.get("sample_every") {
                c.telemetry.sample_every = v.as_usize().ok_or_else(|| {
                    ConfigError("telemetry sample_every must be a non-negative integer".into())
                })?;
            }
            match tm.get("trace_out") {
                None | Some(Json::Null) => {}
                Some(v) => {
                    c.telemetry.trace_out = Some(
                        v.as_str()
                            .ok_or_else(|| {
                                ConfigError("telemetry trace_out must be a string or null".into())
                            })?
                            .to_string(),
                    );
                }
            }
            match tm.get("metrics_out") {
                None | Some(Json::Null) => {}
                Some(v) => {
                    c.telemetry.metrics_out = Some(
                        v.as_str()
                            .ok_or_else(|| {
                                ConfigError("telemetry metrics_out must be a string or null".into())
                            })?
                            .to_string(),
                    );
                }
            }
            if let Some(v) = tm.get("top_k_pairs") {
                c.telemetry.top_k_pairs = v.as_usize().ok_or_else(|| {
                    ConfigError("telemetry top_k_pairs must be a non-negative integer".into())
                })?;
            }
        }
        if let Some(v) = obj.get("aggregation") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError("aggregation must be a string".into()))?;
            c.aggregation = AggregationMode::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown aggregation mode {s:?}")))?;
        }
        if let Some(ag) = obj.get("async").and_then(|v| v.as_obj()) {
            if let Some(v) = ag.get("buffer_size") {
                c.async_agg.buffer_size = v.as_usize().ok_or_else(|| {
                    ConfigError("async buffer_size must be a non-negative integer".into())
                })?;
            }
            if let Some(v) = ag.get("staleness_cap") {
                c.async_agg.staleness_cap = v.as_usize().ok_or_else(|| {
                    ConfigError("async staleness_cap must be a non-negative integer".into())
                })?;
            }
            if let Some(s) = ag.get("weighting").and_then(|v| v.as_str()) {
                c.async_agg.weighting = StalenessWeighting::parse(s)
                    .ok_or_else(|| ConfigError(format!("unknown staleness weighting {s:?}")))?;
            }
        }
        if let Some(fa) = obj.get("faults").and_then(|v| v.as_obj()) {
            let g = |k: &str, dv: f64| fa.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            c.faults.crash_per_round = g("crash_per_round", c.faults.crash_per_round);
            c.faults.link_drop = g("link_drop", c.faults.link_drop);
            c.faults.uplink_loss = g("uplink_loss", c.faults.uplink_loss);
            c.faults.deadline_s = g("deadline_s", c.faults.deadline_s);
            if let Some(rc) = fa.get("recovery").and_then(|v| v.as_obj()) {
                if let Some(v) = rc.get("retry_max") {
                    c.faults.recovery.retry_max = v.as_usize().ok_or_else(|| {
                        ConfigError("recovery retry_max must be a non-negative integer".into())
                    })?;
                }
                let gr = |k: &str, dv: f64| rc.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
                c.faults.recovery.backoff_base_s =
                    gr("backoff_base_s", c.faults.recovery.backoff_base_s);
                c.faults.recovery.backoff_jitter =
                    gr("backoff_jitter", c.faults.recovery.backoff_jitter);
            }
        }
        match obj.get("stream_out") {
            None | Some(Json::Null) => {}
            Some(v) => {
                c.stream_out = Some(
                    v.as_str()
                        .ok_or_else(|| ConfigError("stream_out must be a string or null".into()))?
                        .to_string(),
                );
            }
        }
        if let Some(v) = obj.get("model") {
            let s = v
                .as_str()
                .ok_or_else(|| ConfigError("model must be a string".into()))?;
            c.model = ModelPreset::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown model preset {s:?}")))?;
        }
        c.n_clients = get_usize("n_clients", c.n_clients)?;
        c.area_radius_m = get_f64("area_radius_m", c.area_radius_m)?;
        if let Some(ch) = obj.get("channel").and_then(|v| v.as_obj()) {
            let g = |k: &str, dv: f64| ch.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            c.channel = ChannelConfig {
                bandwidth_hz: g("bandwidth_hz", c.channel.bandwidth_hz),
                tx_power_w: g("tx_power_w", c.channel.tx_power_w),
                noise_w: g("noise_w", c.channel.noise_w),
                ref_gain: g("ref_gain", c.channel.ref_gain),
                ref_dist_m: g("ref_dist_m", c.channel.ref_dist_m),
                pathloss_exp: g("pathloss_exp", c.channel.pathloss_exp),
            };
        }
        if let Some(cp) = obj.get("compute").and_then(|v| v.as_obj()) {
            let g = |k: &str, dv: f64| cp.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            c.compute = ComputeConfig {
                f_min_ghz: g("f_min_ghz", c.compute.f_min_ghz),
                f_max_ghz: g("f_max_ghz", c.compute.f_max_ghz),
                server_freq_ghz: g("server_freq_ghz", c.compute.server_freq_ghz),
                cycles_per_flop: g("cycles_per_flop", c.compute.cycles_per_flop),
            };
        }
        if let Some(sc) = obj.get("scenario").and_then(|v| v.as_obj()) {
            // `kind` selects the preset; any knob key present overrides it.
            let mut s = match sc.get("kind").and_then(|v| v.as_str()) {
                Some(k) => ScenarioConfig::named(k)
                    .ok_or_else(|| ConfigError(format!("unknown scenario kind {k:?}")))?,
                None => ScenarioConfig::default(),
            };
            let g = |k: &str, dv: f64| sc.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            let gu = |k: &str, dv: usize| sc.get(k).and_then(|v| v.as_usize()).unwrap_or(dv);
            s.p_depart = g("p_depart", s.p_depart);
            s.p_rejoin = g("p_rejoin", s.p_rejoin);
            s.p_transient = g("p_transient", s.p_transient);
            s.p_straggle = g("p_straggle", s.p_straggle);
            s.straggle_factor = g("straggle_factor", s.straggle_factor);
            s.mobility_m = g("mobility_m", s.mobility_m);
            s.shadowing_std_db = g("shadowing_std_db", s.shadowing_std_db);
            s.flash_fraction = g("flash_fraction", s.flash_fraction);
            s.flash_round = gu("flash_round", s.flash_round);
            s.diurnal_period = gu("diurnal_period", s.diurnal_period);
            s.diurnal_depth = g("diurnal_depth", s.diurnal_depth);
            // Same scenario-derived engine policy as the presets and CLI —
            // unless the JSON's engine block pinned the knob explicitly.
            if flow_diag_pinned {
                c.scenario = s;
            } else {
                c.set_scenario(s);
            }
        }
        c.rounds = get_usize("rounds", c.rounds)?;
        c.local_epochs = get_usize("local_epochs", c.local_epochs)?;
        c.lr = get_f64("lr", c.lr as f64)? as f32;
        c.samples_per_client = get_usize("samples_per_client", c.samples_per_client)?;
        c.test_samples = get_usize("test_samples", c.test_samples)?;
        if let Some(d) = obj.get("distribution").and_then(|v| v.as_obj()) {
            let kind = d.get("kind").and_then(|v| v.as_str()).unwrap_or("iid");
            c.distribution = match kind {
                "iid" => DataDistribution::Iid,
                "class_shards" => DataDistribution::ClassShards {
                    classes_per_client: d
                        .get("classes_per_client")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(2),
                },
                "dirichlet" => DataDistribution::Dirichlet {
                    alpha: d.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.5),
                },
                other => bail!("unknown distribution kind {other:?}"),
            };
        }
        c.noise_level = get_f64("noise_level", c.noise_level as f64)? as f32;
        c.alpha = get_f64("alpha", c.alpha)?;
        c.beta = get_f64("beta", c.beta)?;
        if let Some(v) = obj.get("overlap_boost") {
            c.overlap_boost = v
                .as_bool()
                .ok_or_else(|| ConfigError("overlap_boost must be a bool".into()))?;
        }
        c.sl_cut_layer = get_usize("sl_cut_layer", c.sl_cut_layer)?;
        c.splitfed_cut_layer = get_usize("splitfed_cut_layer", c.splitfed_cut_layer)?;
        c.eval_every = get_usize("eval_every", c.eval_every)?;
        if let Some(v) = obj.get("artifacts_dir") {
            c.artifacts_dir = v
                .as_str()
                .ok_or_else(|| ConfigError("artifacts_dir must be a string".into()))?
                .to_string();
        }
        if let Some(v) = obj.get("out_dir") {
            c.out_dir = v
                .as_str()
                .ok_or_else(|| ConfigError("out_dir must be a string".into()))?
                .to_string();
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<ExperimentConfig, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        Ok(Self::from_json(&j)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n_clients, 20);
        assert_eq!(c.area_radius_m, 50.0);
        assert_eq!(c.channel.bandwidth_hz, 64e6);
        assert_eq!(c.channel.tx_power_w, 1.0);
        assert_eq!(c.channel.noise_w, 1e-9);
        assert_eq!(c.rounds, 100);
        assert_eq!(c.local_epochs, 2);
        assert_eq!(c.lr, 0.05);
        assert_eq!(c.samples_per_client, 2500);
        assert_eq!(c.compute.f_min_ghz, 0.1);
        assert_eq!(c.compute.f_max_ghz, 2.0);
        c.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = ExperimentConfig::default();
        c.algorithm = Algorithm::SplitFed;
        c.pairing = PairingStrategy::Exact;
        c.distribution = DataDistribution::Dirichlet { alpha: 0.3 };
        c.overlap_boost = false;
        c.seed = 12345;
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.algorithm, Algorithm::SplitFed);
        assert_eq!(c2.pairing, PairingStrategy::Exact);
        assert_eq!(c2.distribution, DataDistribution::Dirichlet { alpha: 0.3 });
        assert!(!c2.overlap_boost);
        assert_eq!(c2.seed, 12345);
        // full structural equality via re-serialization
        assert_eq!(j.to_string(), c2.to_json().to_string());
    }

    #[test]
    fn telemetry_config_roundtrips_and_validates() {
        let mut c = ExperimentConfig::default();
        c.telemetry.enabled = true;
        c.telemetry.sample_every = 5;
        c.telemetry.trace_out = Some("out/trace.json".into());
        c.telemetry.metrics_out = Some("out/metrics.prom".into());
        c.telemetry.top_k_pairs = 3;
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.telemetry, c.telemetry);
        assert_eq!(j.to_string(), c2.to_json().to_string());
        // sample_every = 0 is rejected, null trace_out stays None.
        let bad = Json::parse(r#"{"telemetry": {"sample_every": 0}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        let null = Json::parse(r#"{"telemetry": {"trace_out": null}}"#).unwrap();
        assert_eq!(ExperimentConfig::from_json(&null).unwrap().telemetry.trace_out, None);
    }

    #[test]
    fn async_config_roundtrips_and_validates() {
        let mut c = ExperimentConfig::default();
        c.aggregation = AggregationMode::Async;
        c.async_agg.buffer_size = 3;
        c.async_agg.staleness_cap = 7;
        c.async_agg.weighting = StalenessWeighting::Flat;
        c.stream_out = Some("runs/stream".into());
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.aggregation, AggregationMode::Async);
        assert_eq!(c2.async_agg, c.async_agg);
        assert_eq!(c2.stream_out, c.stream_out);
        assert_eq!(j.to_string(), c2.to_json().to_string());
        // Defaults: synchronous aggregation, no streaming sink.
        let d = ExperimentConfig::default();
        assert_eq!(d.aggregation, AggregationMode::Sync);
        assert_eq!(d.stream_out, None);
        assert!(d.async_agg.validate().is_ok());
    }

    #[test]
    fn async_knobs_are_validated_at_parse_time() {
        // buffer_size = 0 is rejected even in sync mode (the knob is invalid,
        // not merely unused).
        let bad = Json::parse(r#"{"async": {"buffer_size": 0}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&bad).is_err());
        // async aggregation on the DES oracle is a nonsensical combo.
        let des =
            Json::parse(r#"{"aggregation": "async", "engine": {"backend": "des"}}"#).unwrap();
        let err = ExperimentConfig::from_json(&des).unwrap_err();
        assert!(err.0.contains("async"), "unexpected error: {}", err.0);
        // ...while async on the analytic engine is fine.
        let ok = Json::parse(r#"{"aggregation": "async"}"#).unwrap();
        assert_eq!(
            ExperimentConfig::from_json(&ok).unwrap().aggregation,
            AggregationMode::Async
        );
        // Unknown weighting names are rejected.
        let w = Json::parse(r#"{"async": {"weighting": "cubic"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&w).is_err());
    }

    #[test]
    fn fault_config_roundtrips_and_validates() {
        let mut c = ExperimentConfig::default();
        c.faults.crash_per_round = 0.02;
        c.faults.link_drop = 0.05;
        c.faults.uplink_loss = 0.01;
        c.faults.deadline_s = 40.0;
        c.faults.recovery =
            RecoveryConfig { retry_max: 5, backoff_base_s: 0.25, backoff_jitter: 0.5 };
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.faults, c.faults);
        assert_eq!(j.to_string(), c2.to_json().to_string());
        // Defaults are fully disarmed and valid.
        let d = ExperimentConfig::default();
        assert!(!d.faults.active());
        assert!(d.faults.validate().is_ok());
    }

    #[test]
    fn fault_knobs_are_validated_at_parse_time() {
        for bad in [
            r#"{"faults": {"crash_per_round": 1.5}}"#,
            r#"{"faults": {"link_drop": -0.1}}"#,
            r#"{"faults": {"uplink_loss": 2.0}}"#,
            r#"{"faults": {"deadline_s": -1.0}}"#,
            r#"{"faults": {"recovery": {"backoff_base_s": 0.0}}}"#,
            r#"{"faults": {"recovery": {"backoff_jitter": 1.5}}}"#,
            r#"{"faults": {"recovery": {"retry_max": 65}}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(ExperimentConfig::from_json(&j).is_err(), "accepted: {bad}");
        }
        // Faults on the DES oracle are rejected (it records no per-unit
        // times for the pass to replay); the analytic engine is fine.
        let des =
            Json::parse(r#"{"faults": {"link_drop": 0.1}, "engine": {"backend": "des"}}"#).unwrap();
        let err = ExperimentConfig::from_json(&des).unwrap_err();
        assert!(err.0.contains("analytic"), "unexpected error: {}", err.0);
        let ok = Json::parse(r#"{"faults": {"link_drop": 0.1}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&ok).unwrap().faults.active());
        // A deadline under buffered aggregation has no round barrier to cut.
        let dl =
            Json::parse(r#"{"faults": {"deadline_s": 5.0}, "aggregation": "async"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&dl).is_err());
    }

    #[test]
    fn fault_spec_parses() {
        let mut f = FaultConfig::default();
        f.apply_spec("crash=0.01, link=0.05,uplink=0.02").unwrap();
        assert_eq!(f.crash_per_round, 0.01);
        assert_eq!(f.link_drop, 0.05);
        assert_eq!(f.uplink_loss, 0.02);
        f.deadline_s = 9.0;
        f.apply_spec("off").unwrap();
        assert!(!f.active());
        assert!(FaultConfig::default().apply_spec("crash").is_err());
        assert!(FaultConfig::default().apply_spec("warp=0.1").is_err());
        assert!(FaultConfig::default().apply_spec("crash=x").is_err());
    }

    #[test]
    fn staleness_weighting_factor_is_one_at_zero_tau() {
        // The sync-recovery invariant leans on s(0) == 1 exactly for both
        // weightings: recovery merges always see τ = 0.
        assert_eq!(StalenessWeighting::Flat.factor(0), 1.0);
        assert_eq!(StalenessWeighting::Polynomial.factor(0), 1.0);
        assert!(StalenessWeighting::Polynomial.factor(3) < 1.0);
        assert_eq!(StalenessWeighting::Flat.factor(3), 1.0);
    }

    #[test]
    fn odd_fedpairing_fleets_are_valid() {
        // Near-perfect matching + solo fallback removed the even-n assumption.
        let mut c = ExperimentConfig::default();
        c.n_clients = 5;
        assert!(c.validate().is_ok());
        c.algorithm = Algorithm::VanillaFL;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn scenario_presets_named_and_validate() {
        for kind in ScenarioKind::ALL {
            let s = ScenarioConfig::preset(kind);
            assert_eq!(s.kind, kind);
            s.validate().unwrap();
            assert_eq!(ScenarioConfig::named(kind.name()).unwrap(), s);
        }
        assert!(ScenarioConfig::named("quantum").is_none());
        assert_eq!(
            ScenarioKind::parse("flash_crowd"),
            Some(ScenarioKind::FlashCrowd)
        );
        // Stable must be a true no-op so the default reproduces the paper.
        let s = ScenarioConfig::default();
        assert_eq!(s.kind, ScenarioKind::Stable);
        assert_eq!(s.p_depart, 0.0);
        assert_eq!(s.mobility_m, 0.0);
        assert_eq!(s.shadowing_std_db, 0.0);
    }

    #[test]
    fn scenario_validation_rejects_bad_knobs() {
        let mut c = ExperimentConfig::default();
        c.scenario.p_depart = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.scenario.straggle_factor = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.scenario.flash_round = 3; // but flash_fraction stays 0
        assert!(c.validate().is_err());
    }

    #[test]
    fn scenario_json_roundtrip_with_overrides() {
        let mut c = ExperimentConfig::default();
        c.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        c.scenario.p_straggle = 0.25;
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(back.scenario, c.scenario);
        // kind alone applies the preset
        let j = Json::parse(r#"{"scenario": {"kind": "diurnal"}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.scenario, ScenarioConfig::preset(ScenarioKind::Diurnal));
        // knob override on top of a named preset
        let j =
            Json::parse(r#"{"scenario": {"kind": "flash-crowd", "flash_round": 9}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::FlashCrowd);
        assert_eq!(c.scenario.flash_round, 9);
        // bad kind rejected
        let j = Json::parse(r#"{"scenario": {"kind": "martian"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn validation_rejects_bad_ranges() {
        let mut c = ExperimentConfig::default();
        c.compute.f_min_ghz = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.distribution = DataDistribution::Dirichlet { alpha: 0.0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn presets_exist_and_validate() {
        for name in [
            "fig2",
            "fig3",
            "table1",
            "table2",
            "quick",
            "metro-scale",
            "metro-deep",
        ] {
            let c = ExperimentConfig::preset(name).unwrap_or_else(|| panic!("{name}"));
            c.validate().unwrap();
        }
        assert!(ExperimentConfig::preset("nope").is_none());
    }

    #[test]
    fn metro_deep_preset_uses_resnet34() {
        let c = ExperimentConfig::preset("metro-deep").unwrap();
        assert_eq!(c.model, ModelPreset::Resnet34);
        assert_eq!(c.model.w(), 18);
        assert_eq!(c.scenario.kind, ScenarioKind::MetroScale);
        assert!(c.backend.sparse_for(c.n_clients));
    }

    #[test]
    fn split_config_parses_roundtrips_and_validates() {
        assert_eq!(SplitPolicy::parse("paper"), Some(SplitPolicy::Paper));
        assert_eq!(SplitPolicy::parse("OPTIMAL"), Some(SplitPolicy::Optimal));
        assert_eq!(SplitPolicy::parse("balanced"), Some(SplitPolicy::Balanced));
        assert_eq!(SplitPolicy::parse("quantum"), None);
        let d = ExperimentConfig::default();
        assert_eq!(d.split.policy, SplitPolicy::Paper);
        assert_eq!(d.split.min_layers, 1);
        assert!(d.split.co_design);
        // JSON round-trip with overrides.
        let mut c = ExperimentConfig::default();
        c.split = SplitConfig {
            policy: SplitPolicy::Optimal,
            min_layers: 2,
            co_design: false,
        };
        c.model = ModelPreset::Resnet34;
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.split, c.split);
        assert_eq!(back.model, ModelPreset::Resnet34);
        // Partial override keeps the remaining defaults.
        let j = Json::parse(r#"{"split": {"policy": "balanced"}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.split.policy, SplitPolicy::Balanced);
        assert_eq!(c.split.min_layers, 1);
        // Bad policy / infeasible floor rejected.
        let j = Json::parse(r#"{"split": {"policy": "quantum"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let mut c = ExperimentConfig::default();
        c.split.min_layers = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.split.min_layers = 6; // 2·6 > W = 10
        assert!(c.validate().is_err());
    }

    #[test]
    fn cut_layers_validated_against_model_w() {
        // Out-of-range cuts error at parse time instead of being clamped
        // deep in the drivers.
        let mut c = ExperimentConfig::default();
        c.sl_cut_layer = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.splitfed_cut_layer = 10; // == W for resnet18
        assert!(c.validate().is_err());
        // The same cut can be valid for a deeper model…
        let mut c = ExperimentConfig::default();
        c.splitfed_cut_layer = 9;
        assert!(c.validate().is_ok());
        c.model = ModelPreset::Resnet10; // W = 6
        assert!(c.validate().is_err());
        // …and JSON loading reports it as a config error.
        let j = Json::parse(r#"{"sl_cut_layer": 99}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn model_presets_parse_and_name() {
        for (s, p, w) in [
            ("resnet18", ModelPreset::Resnet18, 10),
            ("resnet34", ModelPreset::Resnet34, 18),
            ("resnet10", ModelPreset::Resnet10, 6),
            ("mlp", ModelPreset::Mlp, 8),
        ] {
            assert_eq!(ModelPreset::parse(s), Some(p));
            assert_eq!(p.name(), s);
            assert_eq!(p.w(), w);
        }
        assert_eq!(ModelPreset::parse("vgg"), None);
    }

    #[test]
    fn metro_scale_preset_resolves_sparse() {
        let c = ExperimentConfig::preset("metro-scale").unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::MetroScale);
        assert!(c.n_clients >= 50_000);
        assert!(c.backend.sparse_for(c.n_clients));
        // The paper-scale default stays dense under Auto.
        let d = ExperimentConfig::default();
        assert_eq!(d.backend.mode, BackendMode::Auto);
        assert!(!d.backend.sparse_for(d.n_clients));
    }

    #[test]
    fn backend_modes_parse_resolve_and_validate() {
        assert_eq!(BackendMode::parse("sparse"), Some(BackendMode::Sparse));
        assert_eq!(BackendMode::parse("DENSE"), Some(BackendMode::Dense));
        assert_eq!(BackendMode::parse("auto"), Some(BackendMode::Auto));
        assert_eq!(BackendMode::parse("bogus"), None);
        let mut b = PairingBackendConfig::default();
        assert!(!b.sparse_for(PairingBackendConfig::AUTO_DENSE_MAX));
        assert!(b.sparse_for(PairingBackendConfig::AUTO_DENSE_MAX + 1));
        b.mode = BackendMode::Sparse;
        assert!(b.sparse_for(2));
        b.k_near = 0;
        b.k_freq = 0;
        assert!(b.validate().is_err());
        b.mode = BackendMode::Dense;
        assert!(b.validate().is_ok());
    }

    #[test]
    fn backend_json_roundtrip_and_overrides() {
        let mut c = ExperimentConfig::default();
        c.backend = PairingBackendConfig {
            mode: BackendMode::Sparse,
            k_near: 12,
            k_freq: 6,
        };
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.backend, c.backend);
        // Partial override keeps the remaining defaults.
        let j = Json::parse(r#"{"backend": {"mode": "sparse"}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.backend.mode, BackendMode::Sparse);
        assert_eq!(c.backend.k_near, PairingBackendConfig::default().k_near);
        // Bad mode rejected.
        let j = Json::parse(r#"{"backend": {"mode": "quantum"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }

    #[test]
    fn engine_defaults_parse_and_roundtrip() {
        let d = ExperimentConfig::default();
        assert_eq!(d.engine.backend, RoundBackend::Analytic);
        assert_eq!(d.engine.threads, 0);
        assert!(d.engine.flow_diagnostics);
        assert_eq!(RoundBackend::parse("DES"), Some(RoundBackend::Des));
        assert_eq!(RoundBackend::parse("analytic"), Some(RoundBackend::Analytic));
        assert_eq!(RoundBackend::parse("quantum"), None);
        // JSON round-trip with overrides.
        let mut c = ExperimentConfig::default();
        c.engine = EngineConfig {
            backend: RoundBackend::Des,
            threads: 3,
            flow_diagnostics: false,
        };
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.engine, c.engine);
        // Partial override keeps the remaining defaults.
        let j = Json::parse(r#"{"engine": {"threads": 2}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.engine.threads, 2);
        assert_eq!(c.engine.backend, RoundBackend::Analytic);
        // Bad backend rejected; bad/absurd thread counts rejected.
        let j = Json::parse(r#"{"engine": {"backend": "quantum"}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"engine": {"threads": -1}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"engine": {"threads": 2.5}}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let mut c = ExperimentConfig::default();
        c.engine.threads = 100_000;
        assert!(c.validate().is_err());
    }

    #[test]
    fn set_scenario_applies_the_metro_engine_policy() {
        let mut c = ExperimentConfig::default();
        c.set_scenario(ScenarioConfig::preset(ScenarioKind::MetroScale));
        assert!(!c.engine.flow_diagnostics);
        let mut c = ExperimentConfig::default();
        c.set_scenario(ScenarioConfig::preset(ScenarioKind::LossyRadio));
        assert!(c.engine.flow_diagnostics);
        // The JSON entry point applies the same policy…
        let j = Json::parse(r#"{"scenario": {"kind": "metro-scale"}}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert!(!c.engine.flow_diagnostics);
        // …unless the engine block pins the knob explicitly.
        let j = Json::parse(
            r#"{"scenario": {"kind": "metro-scale"},
                "engine": {"flow_diagnostics": true}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert!(c.engine.flow_diagnostics);
        // A metro config round-trips its pinned engine knobs either way.
        let mut c = ExperimentConfig::preset("metro-scale").unwrap();
        c.n_clients = 500;
        let back = ExperimentConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back.engine, c.engine);
    }

    #[test]
    fn metro_scale_preset_skips_flow_diagnostics() {
        let c = ExperimentConfig::preset("metro-scale").unwrap();
        assert!(!c.engine.flow_diagnostics);
        assert_eq!(c.engine.backend, RoundBackend::Analytic);
        // Paper-scale presets keep the diagnostics.
        for name in ["fig2", "table1", "quick"] {
            assert!(ExperimentConfig::preset(name).unwrap().engine.flow_diagnostics);
        }
    }

    #[test]
    fn fig3_is_two_class_shards() {
        let c = ExperimentConfig::preset("fig3").unwrap();
        assert_eq!(
            c.distribution,
            DataDistribution::ClassShards {
                classes_per_client: 2
            }
        );
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(Algorithm::parse("FedPairing"), Some(Algorithm::FedPairing));
        assert_eq!(Algorithm::parse("fedavg"), Some(Algorithm::VanillaFL));
        assert_eq!(Algorithm::parse("x"), None);
        assert_eq!(PairingStrategy::parse("GREEDY"), Some(PairingStrategy::Greedy));
        assert_eq!(PairingStrategy::parse("x"), None);
    }

    #[test]
    fn from_json_partial_uses_defaults() {
        let j = Json::parse(r#"{"n_clients": 6, "rounds": 2}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.n_clients, 6);
        assert_eq!(c.rounds, 2);
        assert_eq!(c.local_epochs, 2); // default preserved
    }

    #[test]
    fn from_json_bad_types_error() {
        let j = Json::parse(r#"{"rounds": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"algorithm": "quantum"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }
}
