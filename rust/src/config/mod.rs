//! Typed experiment configuration: defaults = the paper's Sec. IV simulation
//! setup, JSON file loading, CLI overrides, validation and named presets.
//!
//! Every experiment (examples, benches, the `fedpairing` binary) is driven by
//! an [`ExperimentConfig`], so a run is fully described by one JSON blob —
//! which the metrics sink embeds in its output for provenance.

use crate::util::json::{Json, JsonObj};
use std::fmt;

/// `Display` impl helper shared by the enums below.
macro_rules! fmt_display_via_name {
    () => {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.name())
        }
    };
}

/// Which FL algorithm drives the round loop (paper Sec. IV benchmarks).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's contribution: client pairing + logical split (Sec. II).
    FedPairing,
    /// FedAvg: every client trains the full model locally [McMahan'17].
    VanillaFL,
    /// Sequential split learning against the server [Gupta & Raskar'18].
    VanillaSL,
    /// Parallel split learning + FedAvg aggregation [Thapa'22].
    SplitFed,
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fedpairing" | "fed-pairing" | "fp" => Some(Algorithm::FedPairing),
            "fl" | "fedavg" | "vanilla_fl" | "vanilla-fl" => Some(Algorithm::VanillaFL),
            "sl" | "vanilla_sl" | "vanilla-sl" => Some(Algorithm::VanillaSL),
            "splitfed" | "sfl" => Some(Algorithm::SplitFed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedPairing => "fedpairing",
            Algorithm::VanillaFL => "vanilla_fl",
            Algorithm::VanillaSL => "vanilla_sl",
            Algorithm::SplitFed => "splitfed",
        }
    }
}

impl fmt::Display for Algorithm {
    fmt_display_via_name!();
}

/// Client-pairing mechanism (paper Table I comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairingStrategy {
    /// Algorithm 1: greedy max-weight matching on eq. (5) weights.
    Greedy,
    /// Uniform random perfect matching.
    Random,
    /// Pair geographically nearest clients (optimizes comm only).
    Location,
    /// Pair most compute-imbalanced clients (optimizes compute only).
    Compute,
    /// Exact max-weight matching (bitmask DP) — optimality ablation.
    Exact,
}

impl PairingStrategy {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Some(PairingStrategy::Greedy),
            "random" => Some(PairingStrategy::Random),
            "location" | "location_based" | "location-based" => Some(PairingStrategy::Location),
            "compute" | "computation" | "resource" => Some(PairingStrategy::Compute),
            "exact" | "optimal" => Some(PairingStrategy::Exact),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PairingStrategy::Greedy => "greedy",
            PairingStrategy::Random => "random",
            PairingStrategy::Location => "location",
            PairingStrategy::Compute => "compute",
            PairingStrategy::Exact => "exact",
        }
    }
}

impl fmt::Display for PairingStrategy {
    fmt_display_via_name!();
}

/// Local-data distribution across clients (paper Sec. IV-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataDistribution {
    /// Equal share of every class per client.
    Iid,
    /// `classes_per_client` randomly-chosen classes per client (paper: 2).
    ClassShards { classes_per_client: usize },
    /// Dirichlet(α) label skew (common FL extension; ablation material).
    Dirichlet { alpha: f64 },
}

impl DataDistribution {
    pub fn name(&self) -> String {
        match self {
            DataDistribution::Iid => "iid".into(),
            DataDistribution::ClassShards { classes_per_client } => {
                format!("shards{classes_per_client}")
            }
            DataDistribution::Dirichlet { alpha } => format!("dirichlet{alpha}"),
        }
    }
}

/// Wireless channel parameters — eq. (3) of the paper.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChannelConfig {
    /// Spectral bandwidth `B` in Hz (paper: 64 MHz).
    pub bandwidth_hz: f64,
    /// Transmit power `P` in W (paper: 1 W).
    pub tx_power_w: f64,
    /// Noise power `σ²` in W (paper: 1e-9 W).
    pub noise_w: f64,
    /// Reference channel gain `h0` at unit distance (paper leaves this free;
    /// we use −35 dB, calibrated so the comm/compute balance reproduces the Table I/II orderings — see EXPERIMENTS.md).
    pub ref_gain: f64,
    /// Reference distance `ζ0` in m.
    pub ref_dist_m: f64,
    /// Path-loss exponent `θ` (urban micro ≈ 3).
    pub pathloss_exp: f64,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        ChannelConfig {
            bandwidth_hz: 64e6,
            tx_power_w: 1.0,
            noise_w: 1e-9,
            ref_gain: 3e-4,
            ref_dist_m: 1.0,
            pathloss_exp: 3.0,
        }
    }
}

/// Client compute heterogeneity (paper: f ~ U[0.1, 2] GHz).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ComputeConfig {
    pub f_min_ghz: f64,
    pub f_max_ghz: f64,
    /// Server CPU frequency for SL/SplitFed offloading ("super computing
    /// power" in the paper's Sec. IV-D discussion).
    pub server_freq_ghz: f64,
    /// Calibration constant: effective cycles per FLOP of the training
    /// workload. One global scalar; only absolute seconds depend on it,
    /// never orderings (DESIGN.md §2).
    pub cycles_per_flop: f64,
}

impl Default for ComputeConfig {
    fn default() -> Self {
        ComputeConfig {
            f_min_ghz: 0.1,
            f_max_ghz: 2.0,
            server_freq_ghz: 100.0,
            cycles_per_flop: 0.085,
        }
    }
}

/// Top-level experiment description.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub algorithm: Algorithm,
    pub pairing: PairingStrategy,

    // fleet
    pub n_clients: usize,
    pub area_radius_m: f64,
    pub channel: ChannelConfig,
    pub compute: ComputeConfig,

    // training schedule (paper: 100 rounds × 2 local epochs, lr 0.1)
    pub rounds: usize,
    pub local_epochs: usize,
    pub lr: f32,

    // data (paper: CIFAR-10, 2500 samples/client; we synthesize — DESIGN.md §2)
    pub samples_per_client: usize,
    pub test_samples: usize,
    pub distribution: DataDistribution,
    pub noise_level: f32,

    // pairing objective weights (eq. 5); α scales (Δf)², β scales r_ij.
    pub alpha: f64,
    pub beta: f64,

    // FedPairing mechanics
    /// Apply the eq. (7) 2× step on overlapping layers.
    pub overlap_boost: bool,
    /// Split point for vanilla SL (client keeps layers < cut). SL offloads
    /// aggressively — the client retains only the input layer (privacy floor).
    pub sl_cut_layer: usize,
    /// Split point for SplitFed. SplitFed-style systems keep a deeper client
    /// prefix (the client-side model that gets FedAvg'd); with the ResNet-18
    /// profile cut=3 puts ~27% of FLOPs client-side, matching Table II's
    /// "SplitFed slower than FedPairing" regime.
    pub splitfed_cut_layer: usize,

    /// Evaluate every `eval_every` rounds (0 = only final).
    pub eval_every: usize,
    /// Artifact directory holding manifest.json + *.hlo.txt.
    pub artifacts_dir: String,
    /// Metrics/output directory.
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            seed: 17,
            algorithm: Algorithm::FedPairing,
            pairing: PairingStrategy::Greedy,
            n_clients: 20,
            area_radius_m: 50.0,
            channel: ChannelConfig::default(),
            compute: ComputeConfig::default(),
            rounds: 100,
            local_epochs: 2,
            // Paper: 0.1 for ResNet-18 (with batch-norm). The substitute
            // ResNet-MLP has no normalization layers and diverges at 0.1 on
            // the shared-dictionary task; 0.05 is its stable equivalent.
            lr: 0.05,
            samples_per_client: 2500,
            test_samples: 2000,
            distribution: DataDistribution::Iid,
            noise_level: 1.5,
            alpha: 1.0,
            beta: 5e-10,
            overlap_boost: true,
            sl_cut_layer: 1,
            splitfed_cut_layer: 3,
            eval_every: 1,
            artifacts_dir: "artifacts".into(),
            out_dir: "runs".into(),
        }
    }
}

/// Validation failure.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

macro_rules! bail {
    ($($arg:tt)*) => { return Err(ConfigError(format!($($arg)*))) };
}

impl ExperimentConfig {
    /// Sanity-check invariants the rest of the system assumes.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.n_clients == 0 {
            bail!("n_clients must be > 0");
        }
        if self.n_clients % 2 != 0 && self.algorithm == Algorithm::FedPairing {
            bail!(
                "FedPairing pairs clients; n_clients={} must be even \
                 (the paper's future-work arbitrary-group extension is out of scope)",
                self.n_clients
            );
        }
        if self.compute.f_min_ghz <= 0.0 || self.compute.f_max_ghz < self.compute.f_min_ghz {
            bail!(
                "invalid CPU frequency range [{}, {}]",
                self.compute.f_min_ghz,
                self.compute.f_max_ghz
            );
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.local_epochs == 0 {
            bail!("local_epochs must be > 0");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be > 0, got {}", self.lr);
        }
        if self.samples_per_client == 0 {
            bail!("samples_per_client must be > 0");
        }
        if self.area_radius_m <= 0.0 {
            bail!("area_radius_m must be > 0");
        }
        if self.channel.bandwidth_hz <= 0.0
            || self.channel.noise_w <= 0.0
            || self.channel.tx_power_w <= 0.0
        {
            bail!("channel parameters must be positive");
        }
        if self.alpha < 0.0 || self.beta < 0.0 {
            bail!("pairing weights alpha/beta must be >= 0");
        }
        if let DataDistribution::ClassShards { classes_per_client } = self.distribution {
            if classes_per_client == 0 {
                bail!("classes_per_client must be > 0");
            }
        }
        if let DataDistribution::Dirichlet { alpha } = self.distribution {
            if alpha <= 0.0 {
                bail!("dirichlet alpha must be > 0");
            }
        }
        Ok(())
    }

    /// Named presets for the paper's experiments.
    pub fn preset(name: &str) -> Option<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        c.name = name.into();
        match name {
            // Fig. 2: IID convergence comparison (algorithm set via CLI/bench).
            "fig2" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Fig. 3: Non-IID — 2 random classes per client.
            "fig3" => {
                c.distribution = DataDistribution::ClassShards {
                    classes_per_client: 2,
                };
                Some(c)
            }
            // Table I: pairing-mechanism timing (latency sim; model = ResNet-18 profile).
            "table1" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Table II: algorithm timing.
            "table2" => {
                c.distribution = DataDistribution::Iid;
                Some(c)
            }
            // Reduced-scale smoke config used by tests/examples.
            "quick" => {
                c.n_clients = 4;
                c.rounds = 3;
                c.samples_per_client = 64;
                c.test_samples = 128;
                Some(c)
            }
            _ => None,
        }
    }

    // ------------------------------------------------------------------
    // JSON round-trip
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("name", Json::str(&self.name));
        o.insert("seed", Json::num(self.seed as f64));
        o.insert("algorithm", Json::str(self.algorithm.name()));
        o.insert("pairing", Json::str(self.pairing.name()));
        o.insert("n_clients", Json::num(self.n_clients as f64));
        o.insert("area_radius_m", Json::num(self.area_radius_m));
        let mut ch = JsonObj::new();
        ch.insert("bandwidth_hz", Json::num(self.channel.bandwidth_hz));
        ch.insert("tx_power_w", Json::num(self.channel.tx_power_w));
        ch.insert("noise_w", Json::num(self.channel.noise_w));
        ch.insert("ref_gain", Json::num(self.channel.ref_gain));
        ch.insert("ref_dist_m", Json::num(self.channel.ref_dist_m));
        ch.insert("pathloss_exp", Json::num(self.channel.pathloss_exp));
        o.insert("channel", Json::Obj(ch));
        let mut cp = JsonObj::new();
        cp.insert("f_min_ghz", Json::num(self.compute.f_min_ghz));
        cp.insert("f_max_ghz", Json::num(self.compute.f_max_ghz));
        cp.insert("server_freq_ghz", Json::num(self.compute.server_freq_ghz));
        cp.insert("cycles_per_flop", Json::num(self.compute.cycles_per_flop));
        o.insert("compute", Json::Obj(cp));
        o.insert("rounds", Json::num(self.rounds as f64));
        o.insert("local_epochs", Json::num(self.local_epochs as f64));
        o.insert("lr", Json::num(self.lr as f64));
        o.insert("samples_per_client", Json::num(self.samples_per_client as f64));
        o.insert("test_samples", Json::num(self.test_samples as f64));
        let mut d = JsonObj::new();
        match self.distribution {
            DataDistribution::Iid => {
                d.insert("kind", Json::str("iid"));
            }
            DataDistribution::ClassShards { classes_per_client } => {
                d.insert("kind", Json::str("class_shards"));
                d.insert("classes_per_client", Json::num(classes_per_client as f64));
            }
            DataDistribution::Dirichlet { alpha } => {
                d.insert("kind", Json::str("dirichlet"));
                d.insert("alpha", Json::num(alpha));
            }
        }
        o.insert("distribution", Json::Obj(d));
        o.insert("noise_level", Json::num(self.noise_level as f64));
        o.insert("alpha", Json::num(self.alpha));
        o.insert("beta", Json::num(self.beta));
        o.insert("overlap_boost", Json::Bool(self.overlap_boost));
        o.insert("sl_cut_layer", Json::num(self.sl_cut_layer as f64));
        o.insert("splitfed_cut_layer", Json::num(self.splitfed_cut_layer as f64));
        o.insert("eval_every", Json::num(self.eval_every as f64));
        o.insert("artifacts_dir", Json::str(&self.artifacts_dir));
        o.insert("out_dir", Json::str(&self.out_dir));
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<ExperimentConfig, ConfigError> {
        let mut c = ExperimentConfig::default();
        let obj = j
            .as_obj()
            .ok_or_else(|| ConfigError("config must be a JSON object".into()))?;
        let get_f64 = |k: &str, dv: f64| -> Result<f64, ConfigError> {
            match obj.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| ConfigError(format!("field {k} must be a number"))),
            }
        };
        let get_usize = |k: &str, dv: usize| -> Result<usize, ConfigError> {
            match obj.get(k) {
                None => Ok(dv),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| ConfigError(format!("field {k} must be a non-negative integer"))),
            }
        };
        if let Some(v) = obj.get("name") {
            c.name = v
                .as_str()
                .ok_or_else(|| ConfigError("name must be a string".into()))?
                .to_string();
        }
        c.seed = get_f64("seed", c.seed as f64)? as u64;
        if let Some(v) = obj.get("algorithm") {
            let s = v.as_str().ok_or_else(|| ConfigError("algorithm must be a string".into()))?;
            c.algorithm = Algorithm::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown algorithm {s:?}")))?;
        }
        if let Some(v) = obj.get("pairing") {
            let s = v.as_str().ok_or_else(|| ConfigError("pairing must be a string".into()))?;
            c.pairing = PairingStrategy::parse(s)
                .ok_or_else(|| ConfigError(format!("unknown pairing strategy {s:?}")))?;
        }
        c.n_clients = get_usize("n_clients", c.n_clients)?;
        c.area_radius_m = get_f64("area_radius_m", c.area_radius_m)?;
        if let Some(ch) = obj.get("channel").and_then(|v| v.as_obj()) {
            let g = |k: &str, dv: f64| ch.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            c.channel = ChannelConfig {
                bandwidth_hz: g("bandwidth_hz", c.channel.bandwidth_hz),
                tx_power_w: g("tx_power_w", c.channel.tx_power_w),
                noise_w: g("noise_w", c.channel.noise_w),
                ref_gain: g("ref_gain", c.channel.ref_gain),
                ref_dist_m: g("ref_dist_m", c.channel.ref_dist_m),
                pathloss_exp: g("pathloss_exp", c.channel.pathloss_exp),
            };
        }
        if let Some(cp) = obj.get("compute").and_then(|v| v.as_obj()) {
            let g = |k: &str, dv: f64| cp.get(k).and_then(|v| v.as_f64()).unwrap_or(dv);
            c.compute = ComputeConfig {
                f_min_ghz: g("f_min_ghz", c.compute.f_min_ghz),
                f_max_ghz: g("f_max_ghz", c.compute.f_max_ghz),
                server_freq_ghz: g("server_freq_ghz", c.compute.server_freq_ghz),
                cycles_per_flop: g("cycles_per_flop", c.compute.cycles_per_flop),
            };
        }
        c.rounds = get_usize("rounds", c.rounds)?;
        c.local_epochs = get_usize("local_epochs", c.local_epochs)?;
        c.lr = get_f64("lr", c.lr as f64)? as f32;
        c.samples_per_client = get_usize("samples_per_client", c.samples_per_client)?;
        c.test_samples = get_usize("test_samples", c.test_samples)?;
        if let Some(d) = obj.get("distribution").and_then(|v| v.as_obj()) {
            let kind = d.get("kind").and_then(|v| v.as_str()).unwrap_or("iid");
            c.distribution = match kind {
                "iid" => DataDistribution::Iid,
                "class_shards" => DataDistribution::ClassShards {
                    classes_per_client: d
                        .get("classes_per_client")
                        .and_then(|v| v.as_usize())
                        .unwrap_or(2),
                },
                "dirichlet" => DataDistribution::Dirichlet {
                    alpha: d.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.5),
                },
                other => bail!("unknown distribution kind {other:?}"),
            };
        }
        c.noise_level = get_f64("noise_level", c.noise_level as f64)? as f32;
        c.alpha = get_f64("alpha", c.alpha)?;
        c.beta = get_f64("beta", c.beta)?;
        if let Some(v) = obj.get("overlap_boost") {
            c.overlap_boost = v
                .as_bool()
                .ok_or_else(|| ConfigError("overlap_boost must be a bool".into()))?;
        }
        c.sl_cut_layer = get_usize("sl_cut_layer", c.sl_cut_layer)?;
        c.splitfed_cut_layer = get_usize("splitfed_cut_layer", c.splitfed_cut_layer)?;
        c.eval_every = get_usize("eval_every", c.eval_every)?;
        if let Some(v) = obj.get("artifacts_dir") {
            c.artifacts_dir = v
                .as_str()
                .ok_or_else(|| ConfigError("artifacts_dir must be a string".into()))?
                .to_string();
        }
        if let Some(v) = obj.get("out_dir") {
            c.out_dir = v
                .as_str()
                .ok_or_else(|| ConfigError("out_dir must be a string".into()))?
                .to_string();
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &str) -> Result<ExperimentConfig, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        Ok(Self::from_json(&j)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_setup() {
        let c = ExperimentConfig::default();
        assert_eq!(c.n_clients, 20);
        assert_eq!(c.area_radius_m, 50.0);
        assert_eq!(c.channel.bandwidth_hz, 64e6);
        assert_eq!(c.channel.tx_power_w, 1.0);
        assert_eq!(c.channel.noise_w, 1e-9);
        assert_eq!(c.rounds, 100);
        assert_eq!(c.local_epochs, 2);
        assert_eq!(c.lr, 0.05);
        assert_eq!(c.samples_per_client, 2500);
        assert_eq!(c.compute.f_min_ghz, 0.1);
        assert_eq!(c.compute.f_max_ghz, 2.0);
        c.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_preserves_everything() {
        let mut c = ExperimentConfig::default();
        c.algorithm = Algorithm::SplitFed;
        c.pairing = PairingStrategy::Exact;
        c.distribution = DataDistribution::Dirichlet { alpha: 0.3 };
        c.overlap_boost = false;
        c.seed = 12345;
        let j = c.to_json();
        let c2 = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c2.algorithm, Algorithm::SplitFed);
        assert_eq!(c2.pairing, PairingStrategy::Exact);
        assert_eq!(c2.distribution, DataDistribution::Dirichlet { alpha: 0.3 });
        assert!(!c2.overlap_boost);
        assert_eq!(c2.seed, 12345);
        // full structural equality via re-serialization
        assert_eq!(j.to_string(), c2.to_json().to_string());
    }

    #[test]
    fn validation_rejects_odd_fedpairing_fleet() {
        let mut c = ExperimentConfig::default();
        c.n_clients = 5;
        assert!(c.validate().is_err());
        c.algorithm = Algorithm::VanillaFL;
        assert!(c.validate().is_ok()); // odd fleets fine for FL
    }

    #[test]
    fn validation_rejects_bad_ranges() {
        let mut c = ExperimentConfig::default();
        c.compute.f_min_ghz = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.rounds = 0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::default();
        c.distribution = DataDistribution::Dirichlet { alpha: 0.0 };
        assert!(c.validate().is_err());
    }

    #[test]
    fn presets_exist_and_validate() {
        for name in ["fig2", "fig3", "table1", "table2", "quick"] {
            let c = ExperimentConfig::preset(name).unwrap_or_else(|| panic!("{name}"));
            c.validate().unwrap();
        }
        assert!(ExperimentConfig::preset("nope").is_none());
    }

    #[test]
    fn fig3_is_two_class_shards() {
        let c = ExperimentConfig::preset("fig3").unwrap();
        assert_eq!(
            c.distribution,
            DataDistribution::ClassShards {
                classes_per_client: 2
            }
        );
    }

    #[test]
    fn enum_parsers() {
        assert_eq!(Algorithm::parse("FedPairing"), Some(Algorithm::FedPairing));
        assert_eq!(Algorithm::parse("fedavg"), Some(Algorithm::VanillaFL));
        assert_eq!(Algorithm::parse("x"), None);
        assert_eq!(PairingStrategy::parse("GREEDY"), Some(PairingStrategy::Greedy));
        assert_eq!(PairingStrategy::parse("x"), None);
    }

    #[test]
    fn from_json_partial_uses_defaults() {
        let j = Json::parse(r#"{"n_clients": 6, "rounds": 2}"#).unwrap();
        let c = ExperimentConfig::from_json(&j).unwrap();
        assert_eq!(c.n_clients, 6);
        assert_eq!(c.rounds, 2);
        assert_eq!(c.local_epochs, 2); // default preserved
    }

    #[test]
    fn from_json_bad_types_error() {
        let j = Json::parse(r#"{"rounds": "many"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
        let j = Json::parse(r#"{"algorithm": "quantum"}"#).unwrap();
        assert!(ExperimentConfig::from_json(&j).is_err());
    }
}
