//! Engine-free asynchronous scenario runs: the continuous-time counterpart
//! of [`crate::fleet::sim_driver::simulate_scenario`].
//!
//! Each loop iteration is one *merge window*: fleet dynamics step once, idle
//! present clients (re)start units priced by the memoized
//! [`RoundEngine`] kernels at the planned cut, in-flight units whose inputs
//! changed (straggling, mobility, fading) are re-priced in the same engine
//! call — the memo cache turns unchanged units into O(1) hits — and the
//! [`Timeline`] advances to the next bounded-staleness merge. One window =
//! one [`crate::coordinator::metrics::RoundRecord`] (with `t_wall_s` and
//! `staleness_mean` filled) plus one [`AggregationEvent`].
//!
//! **Sync recovery** (tested in `tests/async_engine.rs`): with
//! `staleness_cap` huge and `buffer_size ≥ fleet`, every window starts all
//! present units at the merge and commits only after the last one arrives,
//! so the merge time is the same `f64` max/sum the synchronous engine
//! computes — the whole trace is bit-identical to `simulate_scenario`.

use super::{AggregationEvent, Merge, Timeline, UnitKind};
use crate::config::{Algorithm, ConfigError, ExperimentConfig, SplitPolicy};
use crate::coordinator::metrics::{streamer_for, RoundRecord, RunResult};
use crate::faults::{self, AsyncFaults, FaultModel, FaultUnit, UnitSpec};
use crate::fleet::dynamics::FleetDynamics;
use crate::fleet::sim_driver::ScenarioRun;
use crate::fleet::{maintain_matching_session, PairingSession};
use crate::sim::engine::RoundEngine;
use crate::sim::latency::{full_local_time, upload_time, Fleet, FleetView, Schedule};
use crate::sim::profile::ModelProfile;
use crate::split::SplitCostModel;
use crate::telemetry::registry::{self, Counter, Gauge, Histo};
use crate::telemetry::{Observatory, Telemetry};
use crate::util::index::InverseIndex;
use crate::util::rng::Rng;

/// This window's FedPairing work: effective pairs/solos whose members are
/// all idle start fresh; in-flight units whose members are all present get
/// re-priced. Ids are universe ids throughout.
#[derive(Debug, Default)]
pub(crate) struct FedPairingPlan {
    pub start_pairs: Vec<(usize, usize)>,
    pub start_solos: Vec<usize>,
    pub reprice_pairs: Vec<(u64, (usize, usize))>,
    pub reprice_solos: Vec<(u64, usize)>,
}

pub(crate) fn plan_fedpairing(
    tl: &Timeline,
    eff_pairs: &[(usize, usize)],
    eff_solos: &[usize],
    inv: &InverseIndex,
) -> FedPairingPlan {
    let mut plan = FedPairingPlan::default();
    for &(a, b) in eff_pairs {
        // A pair starts only when both ends are idle; an idle client whose
        // partner is mid-flight waits for it instead of training solo.
        if !tl.is_member_busy(a) && !tl.is_member_busy(b) {
            plan.start_pairs.push((a, b));
        }
    }
    for &s in eff_solos {
        if !tl.is_member_busy(s) {
            plan.start_solos.push(s);
        }
    }
    for (id, unit) in tl.running_units() {
        match unit {
            UnitKind::Pair(a, b) if inv.get(a).is_some() && inv.get(b).is_some() => {
                plan.reprice_pairs.push((id, (a, b)));
            }
            UnitKind::Solo(s) if inv.get(s).is_some() => plan.reprice_solos.push((id, s)),
            // A transiently-absent member keeps its old finish time.
            _ => {}
        }
    }
    plan
}

/// This window's solo-unit work (FL, SplitFed, SL sessions).
#[derive(Debug, Default)]
pub(crate) struct SoloPlan {
    pub start: Vec<usize>,
    pub reprice: Vec<(u64, usize)>,
    /// Universe ids backing the engine view: started, then re-priced — the
    /// engine's per-unit times map back by position.
    pub view_members: Vec<usize>,
}

pub(crate) fn plan_solo(
    tl: &Timeline,
    members: &[usize],
    inv: &InverseIndex,
    reprice: bool,
) -> SoloPlan {
    let start: Vec<usize> = members
        .iter()
        .copied()
        .filter(|&m| !tl.is_member_busy(m))
        .collect();
    let mut rp: Vec<(u64, usize)> = Vec::new();
    if reprice {
        for (id, unit) in tl.running_units() {
            if let UnitKind::Solo(s) = unit {
                if inv.get(s).is_some() {
                    rp.push((id, s));
                }
            }
        }
    }
    let view_members: Vec<usize> = start
        .iter()
        .copied()
        .chain(rp.iter().map(|&(_, s)| s))
        .collect();
    SoloPlan {
        start,
        reprice: rp,
        view_members,
    }
}

/// Feed one committed merge into the hot-path metrics registry (no-ops when
/// telemetry is disabled).
pub(crate) fn note_merge(merge: &Merge, cancelled: usize) {
    registry::count(Counter::AsyncMerges, 1);
    registry::count(Counter::AsyncUpdatesMerged, merge.contributors.len() as u64);
    if cancelled > 0 {
        registry::count(Counter::AsyncUpdatesCancelled, cancelled as u64);
    }
    registry::count(
        Counter::AsyncWaitEliminatedUs,
        (merge.wait_eliminated_s * 1e6) as u64,
    );
    registry::gauge_set(Gauge::AsyncBufferPeak, merge.buffer_peak as u64);
    for d in &merge.contributors {
        registry::observe(Histo::AsyncMergeStaleness, d.staleness as u64);
    }
    registry::observe(Histo::AsyncBufferOccupancy, merge.contributors.len() as u64);
}

/// Simulate `cfg.rounds` merge windows of the configured algorithm under the
/// configured scenario with buffered asynchronous aggregation (latency +
/// churn only; no training). Called by `simulate_scenario` when
/// `cfg.aggregation` is [`crate::config::AggregationMode::Async`].
pub fn simulate_async(cfg: &ExperimentConfig) -> Result<ScenarioRun, ConfigError> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let base = Fleet::sample(cfg, &mut Rng::new(cfg.seed));
    let mut dynamics = FleetDynamics::new(cfg, base);
    let profile = ModelProfile::from_preset(cfg.model);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let cost = (cfg.split.policy != SplitPolicy::Paper && cfg.split.co_design)
        .then(|| SplitCostModel::new(profile.clone(), sched, cfg.compute, cfg.split));
    let mut pairing_rng = Rng::new(cfg.seed ^ 0x9A1F);
    let mut pairing = PairingSession::new();
    let mut records = Vec::with_capacity(cfg.rounds);
    let mut trace = Vec::with_capacity(cfg.rounds);
    let mut events = Vec::with_capacity(cfg.rounds);
    let mut repaired_rounds = 0usize;
    let mut sim_total = 0.0f64;
    let mut engine = RoundEngine::new(&cfg.engine).with_split(cfg.split);
    engine.set_record_units(true);
    let mut observatory = Observatory::new();
    let obs = &mut observatory;
    // Fault layer (DESIGN.md §11): units get their faulted (retried /
    // re-paired) duration at start, in-flight survivors keep it across
    // reprices, and each merge window folds its fault counters into the
    // record. A disarmed config plans nothing and stays bit-identical.
    let fmodel = FaultModel::new(&cfg.faults, cfg.algorithm, cfg.seed);
    let mut afaults = AsyncFaults::new();
    let mut inv = InverseIndex::new();
    let mut cpairs: Vec<(usize, usize)> = Vec::new();
    let mut csolos: Vec<usize> = Vec::new();
    let mut telemetry = Telemetry::new(&cfg.telemetry);
    let mut streamer =
        streamer_for(cfg).map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
    let mut tl = Timeline::new(cfg.async_agg.buffer_size, cfg.async_agg.staleness_cap);
    // SL sessions relay sequentially: new sessions chain after this tail
    // (relative to the last merge), not at the merge itself.
    let mut sl_tail = 0.0f64;
    let server_hz = cfg.compute.server_freq_ghz * 1e9;
    for seq in 1..=cfg.rounds {
        telemetry.begin_event();
        let ev = dynamics.step(seq);
        let channel = dynamics.channel();
        telemetry.mark("dynamics");
        let mut cancelled = 0usize;
        for &d in &ev.departed {
            for id in tl.cancel_member(d) {
                afaults.forget(id);
                cancelled += 1;
            }
        }
        let members = dynamics.present_members();
        inv.rebuild(dynamics.universe().n(), members);
        // Observatory unit roster for this window, aligned with the engine's
        // unit_times/unit_splits call order; the mask marks *started* units
        // (repriced in-flight units re-enter every window and must not be
        // double-credited in the ledger).
        let mut units: Vec<(usize, Option<usize>)> = Vec::new();
        let mut started_mask: Vec<bool> = Vec::new();
        let rt = match cfg.algorithm {
            Algorithm::FedPairing => {
                let had_matching = pairing.matching.is_some();
                let changed = maintain_matching_session(
                    &mut pairing,
                    &dynamics,
                    &ev,
                    &channel,
                    cfg,
                    cost.as_ref(),
                    &mut pairing_rng,
                );
                telemetry.mark("matcher");
                if had_matching && changed {
                    repaired_rounds += 1;
                }
                let eff = pairing
                    .matching
                    .as_ref()
                    .expect("matching initialized")
                    .restricted_to(members);
                let plan = plan_fedpairing(&tl, &eff.pairs, &eff.solos, &inv);
                let view = FleetView::new(dynamics.universe(), members);
                cpairs.clear();
                cpairs.extend(
                    plan.start_pairs
                        .iter()
                        .chain(plan.reprice_pairs.iter().map(|(_, p)| p))
                        .map(|&(a, b)| (inv.compact(a), inv.compact(b))),
                );
                csolos.clear();
                csolos.extend(
                    plan.start_solos
                        .iter()
                        .chain(plan.reprice_solos.iter().map(|(_, s)| s))
                        .map(|&s| inv.compact(s)),
                );
                telemetry.mark("pairing");
                let mut rt = engine.fedpairing_round(
                    &view,
                    &cpairs,
                    &csolos,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    true,
                );
                rt.stages.remap_crit(members);
                // Unit times in call order: pairs (started, re-priced), then
                // solos (started, re-priced).
                let ut = engine.unit_times();
                let np = plan.start_pairs.len();
                let nrp = plan.reprice_pairs.len();
                let ns = plan.start_solos.len();
                units.extend(
                    plan.start_pairs
                        .iter()
                        .chain(plan.reprice_pairs.iter().map(|(_, p)| p))
                        .map(|&(a, b)| (a, Some(b))),
                );
                units.extend(
                    plan.start_solos
                        .iter()
                        .chain(plan.reprice_solos.iter().map(|(_, s)| s))
                        .map(|&s| (s, None)),
                );
                started_mask.resize(np, true);
                started_mask.resize(np + nrp, false);
                started_mask.resize(np + nrp + ns, true);
                started_mask.resize(units.len(), false);
                for (k, &(a, b)) in plan.start_pairs.iter().enumerate() {
                    let mut dur = ut[k];
                    let mut fplan = None;
                    if fmodel.active() {
                        let spec = UnitSpec {
                            unit: FaultUnit::Pair(a, b),
                            t0: dur,
                            solo_a: full_local_time(
                                &view,
                                inv.compact(a),
                                &profile,
                                &sched,
                                &channel,
                                &cfg.compute,
                                true,
                            )
                            .1,
                            solo_b: full_local_time(
                                &view,
                                inv.compact(b),
                                &profile,
                                &sched,
                                &channel,
                                &cfg.compute,
                                true,
                            )
                            .1,
                        };
                        let p = fmodel.plan_unit(seq, &spec);
                        dur = p.dur_s;
                        fplan = Some(p);
                    }
                    let id = tl.start_unit(UnitKind::Pair(a, b), dur);
                    if let Some(p) = fplan {
                        afaults.register(id, &p);
                    }
                }
                for (k, &(id, _)) in plan.reprice_pairs.iter().enumerate() {
                    tl.reprice(id, afaults.reprice(id, ut[np + k]));
                }
                for (k, &s) in plan.start_solos.iter().enumerate() {
                    let mut dur = ut[np + nrp + k];
                    let mut fplan = None;
                    if fmodel.active() {
                        let spec = UnitSpec {
                            unit: FaultUnit::Solo(s),
                            t0: dur,
                            solo_a: 0.0,
                            solo_b: 0.0,
                        };
                        let p = fmodel.plan_unit(seq, &spec);
                        dur = p.dur_s;
                        fplan = Some(p);
                    }
                    let id = tl.start_unit(UnitKind::Solo(s), dur);
                    if let Some(p) = fplan {
                        afaults.register(id, &p);
                    }
                }
                for (k, &(id, _)) in plan.reprice_solos.iter().enumerate() {
                    tl.reprice(id, afaults.reprice(id, ut[np + nrp + ns + k]));
                }
                rt
            }
            Algorithm::VanillaFL => {
                let plan = plan_solo(&tl, members, &inv, true);
                let view = FleetView::new(dynamics.universe(), &plan.view_members);
                let mut rt =
                    engine.fl_round(&view, &profile, &sched, &channel, &cfg.compute, true);
                rt.stages.remap_crit(&plan.view_members);
                units.extend(plan.view_members.iter().map(|&m| (m, None)));
                started_mask.resize(plan.start.len(), true);
                started_mask.resize(units.len(), false);
                let ut = engine.unit_times();
                for (k, &m) in plan.start.iter().enumerate() {
                    let mut dur = ut[k];
                    let mut fplan = None;
                    if fmodel.active() {
                        let spec = UnitSpec {
                            unit: FaultUnit::Solo(m),
                            t0: dur,
                            solo_a: 0.0,
                            solo_b: 0.0,
                        };
                        let p = fmodel.plan_unit(seq, &spec);
                        dur = p.dur_s;
                        fplan = Some(p);
                    }
                    let id = tl.start_unit(UnitKind::Solo(m), dur);
                    if let Some(p) = fplan {
                        afaults.register(id, &p);
                    }
                }
                for (k, &(id, _)) in plan.reprice.iter().enumerate() {
                    tl.reprice(id, afaults.reprice(id, ut[plan.start.len() + k]));
                }
                rt
            }
            Algorithm::VanillaSL => {
                // Sessions are a sequential relay: price this window's new
                // sessions and chain them after the current tail. Sessions
                // already queued keep their price (the relay is committed).
                let plan = plan_solo(&tl, members, &inv, false);
                let view = FleetView::new(dynamics.universe(), &plan.start);
                let mut rt = engine.sl_round(
                    &view,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    cfg.sl_cut_layer,
                    server_hz,
                );
                rt.stages.remap_crit(&plan.start);
                units.extend(plan.start.iter().map(|&m| (m, None)));
                started_mask.resize(units.len(), true);
                let ut = engine.unit_times();
                for (k, &m) in plan.start.iter().enumerate() {
                    let mut d = ut[k];
                    let mut fplan = None;
                    if fmodel.active() {
                        let spec = UnitSpec {
                            unit: FaultUnit::Session(m),
                            t0: d,
                            solo_a: 0.0,
                            solo_b: 0.0,
                        };
                        let p = fmodel.plan_unit(seq, &spec);
                        d = p.dur_s;
                        fplan = Some(p);
                    }
                    let id = tl.start_unit_at(UnitKind::Solo(m), sl_tail, d);
                    if let Some(p) = fplan {
                        afaults.register(id, &p);
                    }
                    sl_tail += d;
                }
                rt
            }
            Algorithm::SplitFed => {
                let plan = plan_solo(&tl, members, &inv, true);
                let view = FleetView::new(dynamics.universe(), &plan.view_members);
                let mut rt = engine.splitfed_round(
                    &view,
                    &profile,
                    &sched,
                    &channel,
                    &cfg.compute,
                    cfg.splitfed_cut_layer,
                    server_hz,
                    true,
                );
                rt.stages.remap_crit(&plan.view_members);
                units.extend(plan.view_members.iter().map(|&m| (m, None)));
                started_mask.resize(plan.start.len(), true);
                started_mask.resize(units.len(), false);
                // Unit times are the pre-upload pipeline finishes; the
                // FedAvg upload is charged per merge below, over the merge's
                // actual contributors.
                let ut = engine.unit_times();
                for (k, &m) in plan.start.iter().enumerate() {
                    let mut dur = ut[k];
                    let mut fplan = None;
                    if fmodel.active() {
                        let spec = UnitSpec {
                            unit: FaultUnit::Solo(m),
                            t0: dur,
                            solo_a: 0.0,
                            solo_b: 0.0,
                        };
                        let p = fmodel.plan_unit(seq, &spec);
                        dur = p.dur_s;
                        fplan = Some(p);
                    }
                    let id = tl.start_unit(UnitKind::Solo(m), dur);
                    if let Some(p) = fplan {
                        afaults.register(id, &p);
                    }
                }
                for (k, &(id, _)) in plan.reprice.iter().enumerate() {
                    tl.reprice(id, afaults.reprice(id, ut[plan.start.len() + k]));
                }
                rt
            }
        };
        telemetry.mark("engine");
        let mk = obs.note_async_window(
            &units,
            &started_mask,
            engine.unit_times(),
            engine.unit_splits(),
            &[],
        );
        obs.note_stages(&rt.stages);
        let merge = tl.advance_to_merge().ok_or_else(|| {
            ConfigError("async scheduler stalled: nothing in flight or buffered".into())
        })?;
        // SplitFed's FedAvg sync charges the slowest *contributor* upload
        // (clients currently out deliver without re-uploading this window).
        let overhead = if cfg.algorithm == Algorithm::SplitFed {
            let front_bytes = profile.params(0, cfg.splitfed_cut_layer) as f64 * 4.0;
            merge
                .contributors
                .iter()
                .filter_map(|d| match d.unit {
                    UnitKind::Solo(s) if inv.get(s).is_some() => {
                        Some(upload_time(dynamics.universe(), &channel, s, front_bytes))
                    }
                    _ => None,
                })
                .fold(0.0, f64::max)
        } else {
            0.0
        };
        let total = merge.t_rel + overhead;
        tl.commit(total);
        if cfg.algorithm == Algorithm::VanillaSL {
            sl_tail = (sl_tail - total).max(0.0);
        }
        sim_total += total;
        note_merge(&merge, cancelled);
        // Fault accounting for this merge window (events are stamped
        // relative to the window's simulated start).
        for d in &merge.contributors {
            for &m in afaults.lost_of(d.id) {
                obs.ledger.note_lost(m);
            }
            afaults.forget(d.id);
        }
        let (wfaults, wevents) = afaults.take_window();
        faults::note_outcome(&wfaults, &wevents);
        telemetry.fault_events(&wevents, sim_total - total);
        obs.note_fault_recovery(wfaults.recovery_s);
        obs.note_async_event(merge.staleness_mean, merge.wait_eliminated_s);
        let event = AggregationEvent {
            seq,
            t_wall_s: sim_total,
            n_updates: merge.contributors.len(),
            n_running: tl.in_flight(),
            staleness_mean: merge.staleness_mean,
            staleness_max: merge.staleness_max,
            buffer_peak: merge.buffer_peak,
            wait_eliminated_s: merge.wait_eliminated_s,
        };
        let rec = RoundRecord {
            round: seq,
            n_alive: ev.n_alive,
            train_loss: f64::NAN,
            test_acc: f64::NAN,
            test_loss: f64::NAN,
            sim_round_s: total,
            sim_total_s: sim_total,
            t_wall_s: sim_total,
            staleness_mean: merge.staleness_mean,
            faults: wfaults,
            mean_cut: rt.mean_cut,
            stages: rt.stages,
            mk_p50_s: mk.p50_s,
            mk_p90_s: mk.p90_s,
            mk_p99_s: mk.p99_s,
            fairness: obs.ledger.jain(),
        };
        if let Some(s) = streamer.as_mut() {
            s.push(&rec)
                .map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
        }
        records.push(rec);
        let lanes: Vec<(usize, usize, f64)> = engine
            .pair_lanes()
            .iter()
            .map(|&(a, b, t)| (members[a], members[b], t))
            .collect();
        telemetry.end_round(&rt, ev.n_alive, &lanes, sim_total - total);
        telemetry.end_merge(&event);
        events.push(event);
        trace.push(ev);
    }
    if let Some(s) = streamer {
        let (c, j) = s
            .finish()
            .map_err(|e| ConfigError(format!("stream sink failed: {e}")))?;
        crate::log_info!("stream: wrote {c} and {j}");
    }
    for path in telemetry
        .finish()
        .map_err(|e| ConfigError(format!("telemetry export failed: {e}")))?
    {
        crate::log_info!("telemetry: wrote {path}");
    }
    Ok(ScenarioRun {
        result: RunResult {
            config: cfg.clone(),
            rounds: records,
            wall_s: t0.elapsed().as_secs_f64(),
            total_execs: 0,
            observatory,
        },
        trace,
        repaired_rounds,
        events,
    })
}
