//! Asynchronous buffered aggregation: a continuous-time event scheduler for
//! split federated learning (DESIGN.md §9).
//!
//! The synchronous engine prices a round as the max over its units — one
//! straggler pair stalls everyone else. This subsystem replaces the lockstep
//! barrier with a FedBuff-style semi-asynchronous server: units (FedPairing
//! pairs/solos, FL/SplitFed clients, SL sessions) stream their updates as
//! they finish on a shared [`Timeline`], and the server commits a merge when
//! its bounded-staleness buffer fills (or everything in flight has arrived),
//! producing a wall-clock stream of [`AggregationEvent`]s instead of rounds.
//!
//! Two knobs from [`crate::config::AsyncConfig`] govern the server:
//!
//! - `buffer_size` — minimum delivered updates per merge (K of FedBuff);
//! - `staleness_cap` — a merge is *deferred* while it would strand any
//!   running unit more than `staleness_cap` versions behind, so no update is
//!   ever merged with staleness above the cap (gating, not clipping).
//!
//! All timestamps are kept **relative to the last merge** and re-based at
//! every commit (see [`Timeline::commit`]). Relative time is what makes the
//! sync-recovery invariant exact: when every unit starts at the merge and
//! the merge fires only after all of them arrive, the merge time is a plain
//! `f64` max over the same durations the synchronous engine folds —
//! bit-identical, property-tested in `tests/async_engine.rs`.
//!
//! **Faults** (DESIGN.md §11): with `cfg.faults` armed, every unit is
//! planned through [`crate::faults::FaultModel`] at start — its timeline
//! duration becomes the recovered (retried / survivor-solo) occupancy, and
//! members whose update dies in flight are remembered per unit id by
//! [`crate::faults::AsyncFaults`] so the merge can drop exactly their
//! payloads. Round deadlines are a synchronous-barrier concept and are
//! rejected with async mode at config validation.

pub mod driver;

pub use driver::simulate_async;

/// One schedulable work unit on the timeline, in universe client ids.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnitKind {
    /// A FedPairing pair `(i, j)`.
    Pair(usize, usize),
    /// A solo client (FedPairing widow, FL/SplitFed client, SL session).
    Solo(usize),
}

impl UnitKind {
    /// Whether universe client `u` takes part in this unit.
    pub fn contains(&self, u: usize) -> bool {
        match *self {
            UnitKind::Pair(a, b) => a == u || b == u,
            UnitKind::Solo(s) => s == u,
        }
    }
}

/// One committed merge on the wall-clock timeline — the async analogue of a
/// round record, exported to JSONL/trace by the telemetry sink.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AggregationEvent {
    /// 1-based merge sequence number.
    pub seq: usize,
    /// Cumulative simulated wall-clock seconds at commit.
    pub t_wall_s: f64,
    /// Updates merged (buffer occupancy at commit).
    pub n_updates: usize,
    /// Units still in flight after the commit.
    pub n_running: usize,
    /// Mean staleness (merges behind) over the merged updates.
    pub staleness_mean: f64,
    /// Worst staleness over the merged updates (≤ `staleness_cap` always).
    pub staleness_max: usize,
    /// Peak buffer occupancy since the previous commit.
    pub buffer_peak: usize,
    /// Straggler wait eliminated: seconds the merged updates would have
    /// idled waiting for the slowest in-flight unit under the sync barrier.
    pub wait_eliminated_s: f64,
}

/// A unit in flight: started at `start` (relative to the last merge), due to
/// deliver at `start + dur`. `base` is the model version it trained from.
#[derive(Clone, Copy, Debug)]
struct Running {
    id: u64,
    unit: UnitKind,
    base: usize,
    start: f64,
    dur: f64,
}

/// A delivered update waiting in the server buffer.
#[derive(Clone, Copy, Debug)]
pub struct Delivered {
    /// Creation-ordered unit id — merge consumers iterate contributors in
    /// ascending id so aggregation sums run in a deterministic order.
    pub id: u64,
    pub unit: UnitKind,
    /// Versions behind the current global model (0 = fresh).
    pub staleness: usize,
}

/// Everything the server needs to commit one merge.
#[derive(Clone, Debug)]
pub struct Merge {
    /// Merge time in seconds since the previous commit.
    pub t_rel: f64,
    /// Buffer contents, sorted by ascending unit id.
    pub contributors: Vec<Delivered>,
    pub staleness_mean: f64,
    pub staleness_max: usize,
    pub buffer_peak: usize,
    pub wait_eliminated_s: f64,
}

/// The continuous-time scheduler: running units, the delivery buffer, and
/// the bounded-staleness merge rule.
#[derive(Clone, Debug)]
pub struct Timeline {
    buffer_size: usize,
    staleness_cap: usize,
    /// Global model version (number of committed merges).
    version: usize,
    next_id: u64,
    /// Clock, relative to the last commit; advances as deliveries pop.
    now: f64,
    running: Vec<Running>,
    buffer: Vec<Delivered>,
    buffer_peak: usize,
}

impl Timeline {
    pub fn new(buffer_size: usize, staleness_cap: usize) -> Timeline {
        Timeline {
            buffer_size: buffer_size.max(1),
            staleness_cap,
            version: 0,
            next_id: 0,
            now: 0.0,
            running: Vec::new(),
            buffer: Vec::new(),
            buffer_peak: 0,
        }
    }

    /// Committed merges so far (the global model version).
    pub fn version(&self) -> usize {
        self.version
    }

    /// Units currently in flight.
    pub fn in_flight(&self) -> usize {
        self.running.len()
    }

    /// Start a unit now (at the current clock), due after `dur` seconds.
    pub fn start_unit(&mut self, unit: UnitKind, dur: f64) -> u64 {
        self.start_unit_at(unit, self.now, dur)
    }

    /// Start a unit at an explicit (relative) time — SL sessions chain after
    /// the relay tail, which may lie beyond the current clock.
    pub fn start_unit_at(&mut self, unit: UnitKind, start: f64, dur: f64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.running.push(Running {
            id,
            unit,
            base: self.version,
            start,
            dur,
        });
        id
    }

    /// Whether client `u` is tied up in a running unit or a buffered update
    /// (buffered members must not restart before their update is merged).
    pub fn is_member_busy(&self, u: usize) -> bool {
        self.running.iter().any(|r| r.unit.contains(u))
            || self.buffer.iter().any(|d| d.unit.contains(u))
    }

    /// In-flight units as `(id, unit)` — the re-pricing candidates.
    pub fn running_units(&self) -> impl Iterator<Item = (u64, UnitKind)> + '_ {
        self.running.iter().map(|r| (r.id, r.unit))
    }

    /// Cancel every running unit that involves client `u` (durable
    /// departure). Buffered updates are kept — the work already arrived.
    /// Returns the cancelled unit ids so trainers can drop pending payloads.
    pub fn cancel_member(&mut self, u: usize) -> Vec<u64> {
        let mut dropped = Vec::new();
        self.running.retain(|r| {
            if r.unit.contains(u) {
                dropped.push(r.id);
                false
            } else {
                true
            }
        });
        dropped
    }

    /// Replace a running unit's duration (same start fraction elapsed) —
    /// churn/mobility/straggling re-prices only the affected unit's finish.
    /// No-op when the new duration is bit-identical (the memoized engine
    /// returns exact hits for unchanged inputs).
    pub fn reprice(&mut self, id: u64, dur_new: f64) {
        if let Some(r) = self.running.iter_mut().find(|r| r.id == id) {
            if r.dur.to_bits() == dur_new.to_bits() {
                return;
            }
            // Keep the elapsed *fraction*: a unit 30% done stays 30% done
            // under the new price, and its start shifts so that the elapsed
            // fraction re-scales onto the new duration.
            if r.dur > 0.0 && r.start < self.now {
                let frac = (self.now - r.start) / r.dur;
                r.start = self.now - frac * dur_new;
            }
            r.dur = dur_new;
        }
    }

    /// Whether the server may commit right now: something is buffered, and
    /// either nothing is left in flight, or the buffer quorum is met *and*
    /// committing would not strand any running unit beyond `staleness_cap`.
    fn merge_ready(&self) -> bool {
        if self.buffer.is_empty() {
            return false;
        }
        if self.running.is_empty() {
            return true;
        }
        self.buffer.len() >= self.buffer_size
            && !self
                .running
                .iter()
                .any(|r| self.version + 1 - r.base > self.staleness_cap)
    }

    /// Pop deliveries in arrival order until the merge rule fires; returns
    /// `None` only when nothing is running and nothing is buffered.
    pub fn advance_to_merge(&mut self) -> Option<Merge> {
        while !self.merge_ready() {
            // Earliest arrival, ties broken by unit id (deterministic).
            let mut best: Option<usize> = None;
            for (k, r) in self.running.iter().enumerate() {
                let better = match best {
                    None => true,
                    Some(b) => {
                        let o = &self.running[b];
                        match (r.start + r.dur).total_cmp(&(o.start + o.dur)) {
                            std::cmp::Ordering::Less => true,
                            std::cmp::Ordering::Greater => false,
                            std::cmp::Ordering::Equal => r.id < o.id,
                        }
                    }
                };
                if better {
                    best = Some(k);
                }
            }
            let r = self.running.swap_remove(best?);
            let arrival = r.start + r.dur;
            // Deliveries arriving during a previous merge's overhead window
            // land at (relative) negative time; the clock never rewinds.
            if arrival > self.now {
                self.now = arrival;
            }
            self.buffer.push(Delivered {
                id: r.id,
                unit: r.unit,
                staleness: self.version - r.base,
            });
            self.buffer_peak = self.buffer_peak.max(self.buffer.len());
        }
        // Sync-barrier counterfactual: every buffered update would have
        // waited for the slowest projected in-flight finish.
        let mut wait = 0.0;
        if let Some(slow) = self
            .running
            .iter()
            .map(|r| r.start + r.dur)
            .reduce(f64::max)
        {
            if slow > self.now {
                wait = (slow - self.now) * self.buffer.len() as f64;
            }
        }
        let mut contributors = std::mem::take(&mut self.buffer);
        contributors.sort_by_key(|d| d.id);
        let n = contributors.len();
        let staleness_max = contributors.iter().map(|d| d.staleness).max().unwrap_or(0);
        let staleness_mean =
            contributors.iter().map(|d| d.staleness as f64).sum::<f64>() / n.max(1) as f64;
        Some(Merge {
            t_rel: self.now,
            contributors,
            staleness_mean,
            staleness_max,
            buffer_peak: self.buffer_peak,
            wait_eliminated_s: wait,
        })
    }

    /// Commit the merge: bump the version and re-base the clock so the next
    /// window starts at 0. `merge_total_s` is the full window length (merge
    /// time plus any aggregation overhead, e.g. SplitFed's FedAvg upload).
    pub fn commit(&mut self, merge_total_s: f64) {
        self.version += 1;
        for r in &mut self.running {
            r.start -= merge_total_s;
        }
        self.now = 0.0;
        self.buffer_peak = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn merge(tl: &mut Timeline) -> Merge {
        let m = tl.advance_to_merge().expect("units in flight");
        tl.commit(m.t_rel);
        m
    }

    #[test]
    fn single_unit_merges_at_its_duration() {
        let mut tl = Timeline::new(1, 0);
        tl.start_unit(UnitKind::Solo(3), 2.5);
        let m = merge(&mut tl);
        assert_eq!(m.t_rel, 2.5);
        assert_eq!(m.contributors.len(), 1);
        assert_eq!(m.contributors[0].unit, UnitKind::Solo(3));
        assert_eq!(m.staleness_max, 0);
        assert_eq!(tl.version(), 1);
        assert_eq!(tl.in_flight(), 0);
    }

    #[test]
    fn buffer_quorum_fires_before_the_straggler() {
        let mut tl = Timeline::new(2, 1 << 30);
        tl.start_unit(UnitKind::Solo(0), 1.0);
        tl.start_unit(UnitKind::Solo(1), 2.0);
        tl.start_unit(UnitKind::Solo(2), 10.0);
        let m = merge(&mut tl);
        assert_eq!(m.t_rel, 2.0);
        assert_eq!(m.contributors.len(), 2);
        assert_eq!(m.buffer_peak, 2);
        // Both merged updates skip the (10 - 2)s barrier wait each.
        assert!((m.wait_eliminated_s - 16.0).abs() < 1e-12);
        assert_eq!(tl.in_flight(), 1);
        // The straggler arrives one version behind, re-based to 8s.
        let m2 = merge(&mut tl);
        assert_eq!(m2.t_rel, 8.0);
        assert_eq!(m2.contributors[0].staleness, 1);
    }

    #[test]
    fn staleness_cap_zero_recovers_the_barrier() {
        let mut tl = Timeline::new(1, 0);
        tl.start_unit(UnitKind::Solo(0), 1.0);
        tl.start_unit(UnitKind::Solo(1), 7.0);
        // cap = 0: a merge would strand the running unit one version behind,
        // so it defers until everything arrives — the synchronous barrier.
        let m = merge(&mut tl);
        assert_eq!(m.t_rel, 7.0);
        assert_eq!(m.contributors.len(), 2);
        assert_eq!(m.staleness_max, 0);
        assert_eq!(m.wait_eliminated_s, 0.0);
    }

    #[test]
    fn staleness_never_exceeds_the_cap() {
        let mut tl = Timeline::new(1, 2);
        tl.start_unit(UnitKind::Solo(9), 100.0); // the chronic straggler
        let mut straggler_staleness = None;
        for round in 0..6 {
            tl.start_unit(UnitKind::Solo(round), 1.0);
            let m = tl.advance_to_merge().unwrap();
            assert!(m.staleness_max <= 2, "merge {round} exceeded the cap");
            if let Some(d) = m.contributors.iter().find(|d| d.unit == UnitKind::Solo(9)) {
                straggler_staleness = Some(d.staleness);
            }
            tl.commit(m.t_rel);
        }
        // Two fast merges run, the third defers until the straggler lands —
        // exactly at the cap, never beyond it.
        assert_eq!(straggler_staleness, Some(2));
    }

    #[test]
    fn contributors_come_back_in_creation_order() {
        let mut tl = Timeline::new(3, 1 << 30);
        let a = tl.start_unit(UnitKind::Solo(0), 3.0);
        let b = tl.start_unit(UnitKind::Solo(1), 1.0);
        let c = tl.start_unit(UnitKind::Solo(2), 2.0);
        let m = merge(&mut tl);
        let ids: Vec<u64> = m.contributors.iter().map(|d| d.id).collect();
        assert_eq!(ids, vec![a, b, c]);
    }

    #[test]
    fn reprice_keeps_the_elapsed_fraction() {
        let mut tl = Timeline::new(1, 1 << 30);
        let fast = tl.start_unit(UnitKind::Solo(0), 4.0);
        let slow = tl.start_unit(UnitKind::Solo(1), 8.0);
        let m = tl.advance_to_merge().unwrap(); // fast arrives at 4
        assert_eq!(m.t_rel, 4.0);
        tl.commit(m.t_rel);
        // slow is 50% done; re-pricing to 6s leaves 3s remaining.
        tl.reprice(slow, 6.0);
        let m2 = merge(&mut tl);
        assert_eq!(m2.t_rel, 3.0);
        let _ = fast;
    }

    #[test]
    fn cancel_drops_running_but_not_buffered() {
        let mut tl = Timeline::new(2, 1 << 30);
        tl.start_unit(UnitKind::Pair(0, 1), 5.0);
        let solo = tl.start_unit(UnitKind::Solo(2), 1.0);
        assert!(tl.is_member_busy(1));
        let dropped = tl.cancel_member(1);
        assert_eq!(dropped.len(), 1);
        assert!(!tl.is_member_busy(0));
        let m = merge(&mut tl);
        assert_eq!(m.contributors.len(), 1);
        assert_eq!(m.contributors[0].id, solo);
    }

    #[test]
    fn merged_members_free_up_while_stragglers_stay_busy() {
        let mut tl = Timeline::new(2, 1 << 30);
        tl.start_unit(UnitKind::Solo(0), 1.0);
        tl.start_unit(UnitKind::Solo(1), 2.0);
        tl.start_unit(UnitKind::Solo(2), 9.0);
        let m = tl.advance_to_merge().unwrap();
        assert!(m.contributors.iter().any(|d| d.unit == UnitKind::Solo(0)));
        tl.commit(m.t_rel);
        assert!(!tl.is_member_busy(0));
        assert!(tl.is_member_busy(2));
    }

    #[test]
    fn empty_timeline_yields_no_merge() {
        let mut tl = Timeline::new(4, 3);
        assert!(tl.advance_to_merge().is_none());
    }

    #[test]
    fn commit_rebases_leftover_arrivals() {
        let mut tl = Timeline::new(1, 1 << 30);
        tl.start_unit(UnitKind::Solo(0), 2.0);
        tl.start_unit(UnitKind::Solo(1), 7.0);
        let m = tl.advance_to_merge().unwrap();
        // Commit with 1s of aggregation overhead on top of the merge time.
        tl.commit(m.t_rel + 1.0);
        let m2 = merge(&mut tl);
        assert_eq!(m2.t_rel, 4.0); // 7 - (2 + 1)
    }
}
