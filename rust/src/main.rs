//! `fedpairing` — the leader binary: run experiments, inspect pairings,
//! regenerate the paper's timing tables, or dump artifact info.
//!
//! ```text
//! fedpairing run --preset fig2 --algorithm fedpairing --rounds 30
//! fedpairing run --scenario lossy-radio --rounds 50
//! fedpairing churn --scenario flash-crowd --rounds 30
//! fedpairing churn --scenario metro-scale --n-clients 100000 --backend sparse
//! fedpairing churn --scenario metro-scale --split-policy optimal --model resnet34
//! fedpairing pair --clients 20 --strategy greedy --split-policy optimal
//! fedpairing latency --samples 2500
//! fedpairing report out/quick_fedpairing_iid.stream.csv
//! fedpairing info
//! ```

use fedpairing::cli::{CliError, Command, Parsed};
use fedpairing::config::{
    AggregationMode, Algorithm, BackendMode, DataDistribution, ExperimentConfig, ModelPreset,
    PairingMode, PairingStrategy, RoundBackend, ScenarioConfig, SplitPolicy, StalenessWeighting,
};
use fedpairing::coordinator::run_experiment;
use fedpairing::fleet::simulate_scenario;
use fedpairing::model::ModelMeta;
use fedpairing::pairing::{graph::ClientGraph, pair_clients, pair_clients_with};
use fedpairing::sim::channel::Channel;
use fedpairing::sim::latency::{self, Fleet, Schedule};
use fedpairing::sim::profile::ModelProfile;
use fedpairing::split::SplitCostModel;
use fedpairing::util::logging;
use fedpairing::util::rng::Rng;

fn cli() -> Command {
    Command::new("fedpairing", "client-pairing split federated learning (Shen et al. 2023)")
        .flag("log-level", None, Some("LEVEL"), "error|warn|info|debug|trace", Some("info"))
        .subcommand(
            Command::new("run", "run a full FL experiment against the AOT artifacts")
                .flag("preset", None, Some("NAME"), "fig2|fig3|table1|table2|quick|metro-scale|metro-deep", Some("quick"))
                .flag("config", None, Some("FILE"), "JSON config file (overrides preset)", None)
                .flag("algorithm", Some('a'), Some("ALGO"), "fedpairing|fl|sl|splitfed", None)
                .flag("pairing", Some('p'), Some("STRAT"), "greedy|random|location|compute|exact", None)
                .flag("pairing-mode", None, Some("MODE"), "cross-round matching maintenance: repair|rebuild|incremental", None)
                .flag("backend", None, Some("MODE"), "pairing candidate backend: auto|dense|sparse", None)
                .flag("rounds", Some('r'), Some("N"), "communication rounds", None)
                .flag("clients", Some('n'), Some("N"), "fleet size", None)
                .flag("n-clients", None, Some("N"), "fleet size (alias of --clients)", None)
                .flag("samples", None, Some("N"), "samples per client", None)
                .flag("seed", Some('s'), Some("N"), "experiment seed", None)
                .flag("noniid", None, None, "2-class shards instead of IID", None)
                .flag("no-overlap-boost", None, None, "disable the eq.(7) 2x overlap step", None)
                .flag("scenario", None, Some("NAME"), "stable|diurnal|flash-crowd|lossy-radio|metro-scale", None)
                .flag("engine", None, Some("MODE"), "round-time engine: analytic|des", None)
                .flag("threads", None, Some("N"), "engine worker threads (0 = one per core)", None)
                .flag("split-policy", None, Some("POLICY"), "split planner: paper|balanced|optimal", None)
                .flag("aggregation", None, Some("MODE"), "server aggregation: sync|async (buffered)", None)
                .flag("buffer-size", None, Some("N"), "async: updates buffered per merge (>= 1)", None)
                .flag("staleness-cap", None, Some("N"), "async: max merges an update may lag (0 = sync barrier)", None)
                .flag("weighting", None, Some("FN"), "async merge discount: flat|polynomial", None)
                .flag("faults", None, Some("SPEC"), "fault hazards: off | crash=P,link=P,uplink=P", None)
                .flag("deadline", None, Some("S"), "server round deadline in sim seconds (0 = off)", None)
                .flag("retry-max", None, Some("N"), "max retries per failed transfer (<= 64)", None)
                .flag("retry-backoff", None, Some("S"), "first retry backoff in sim seconds", None)
                .flag("retry-jitter", None, Some("J"), "backoff jitter fraction in [0, 1]", None)
                .flag("stream-out", None, Some("DIR"), "stream per-round records to DIR/*.stream.{csv,jsonl}", None)
                .flag("telemetry", None, None, "enable the metrics registry + stage counters", None)
                .flag("trace-out", None, Some("FILE"), "Chrome trace + .prom/.jsonl sidecars; implies --telemetry", None)
                .flag("metrics-out", None, Some("FILE"), "Prometheus snapshot (registry + observatory) at exit; implies --telemetry", None)
                .flag("artifacts", None, Some("DIR"), "artifact directory", None)
                .flag("out", Some('o'), Some("DIR"), "metrics output directory", None),
        )
        .subcommand(
            Command::new("churn", "simulate a fleet-dynamics scenario (latency + churn, no training)")
                .flag("scenario", None, Some("NAME"), "stable|diurnal|flash-crowd|lossy-radio|metro-scale", Some("flash-crowd"))
                .flag("algorithm", Some('a'), Some("ALGO"), "fedpairing|fl|sl|splitfed", Some("fedpairing"))
                .flag("pairing", Some('p'), Some("STRAT"), "greedy|random|location|compute|exact", Some("greedy"))
                .flag("pairing-mode", None, Some("MODE"), "cross-round matching maintenance: repair|rebuild|incremental", Some("repair"))
                .flag("backend", None, Some("MODE"), "pairing candidate backend: auto|dense|sparse", Some("auto"))
                .flag("clients", Some('n'), Some("N"), "fleet size", Some("20"))
                .flag("n-clients", None, Some("N"), "fleet size (alias of --clients)", None)
                .flag("rounds", Some('r'), Some("N"), "communication rounds", Some("30"))
                .flag("samples", None, Some("N"), "samples per client [default: 2500; 64 under metro-scale]", None)
                .flag("seed", Some('s'), Some("N"), "experiment seed", Some("17"))
                .flag("engine", None, Some("MODE"), "round-time engine: analytic|des", None)
                .flag("threads", None, Some("N"), "engine worker threads (0 = one per core)", None)
                .flag("split-policy", None, Some("POLICY"), "split planner: paper|balanced|optimal", None)
                .flag("model", None, Some("NAME"), "latency cost profile: resnet18|resnet34|resnet10|mlp", None)
                .flag("aggregation", None, Some("MODE"), "server aggregation: sync|async (buffered)", None)
                .flag("buffer-size", None, Some("N"), "async: updates buffered per merge (>= 1)", None)
                .flag("staleness-cap", None, Some("N"), "async: max merges an update may lag (0 = sync barrier)", None)
                .flag("weighting", None, Some("FN"), "async merge discount: flat|polynomial", None)
                .flag("faults", None, Some("SPEC"), "fault hazards: off | crash=P,link=P,uplink=P", None)
                .flag("deadline", None, Some("S"), "server round deadline in sim seconds (0 = off)", None)
                .flag("retry-max", None, Some("N"), "max retries per failed transfer (<= 64)", None)
                .flag("retry-backoff", None, Some("S"), "first retry backoff in sim seconds", None)
                .flag("retry-jitter", None, Some("J"), "backoff jitter fraction in [0, 1]", None)
                .flag("stream-out", None, Some("DIR"), "stream per-round records to DIR/*.stream.{csv,jsonl}", None)
                .flag("telemetry", None, None, "enable the metrics registry + stage counters", None)
                .flag("trace-out", None, Some("FILE"), "Chrome trace + .prom/.jsonl sidecars; implies --telemetry", None)
                .flag("metrics-out", None, Some("FILE"), "Prometheus snapshot (registry + observatory) at exit; implies --telemetry", None)
                .flag("out", Some('o'), Some("DIR"), "metrics output directory", None),
        )
        .subcommand(
            Command::new("pair", "sample a fleet and show the pairing a strategy produces")
                .flag("clients", Some('n'), Some("N"), "fleet size", Some("20"))
                .flag("strategy", Some('p'), Some("STRAT"), "greedy|random|location|compute|exact", Some("greedy"))
                .flag("backend", None, Some("MODE"), "pairing candidate backend: auto|dense|sparse", Some("auto"))
                .flag("seed", Some('s'), Some("N"), "fleet seed", Some("17"))
                .flag("alpha", None, Some("A"), "eq.(5) compute weight", Some("1.0"))
                .flag("beta", None, Some("B"), "eq.(5) rate weight", Some("2e-9"))
                .flag("split-policy", None, Some("POLICY"), "split planner: paper|balanced|optimal", Some("paper"))
                .flag("model", None, Some("NAME"), "latency cost profile: resnet18|resnet34|resnet10|mlp", Some("resnet18")),
        )
        .subcommand(
            Command::new("latency", "simulated round times for all algorithms + pairings (Tables I/II)")
                .flag("clients", Some('n'), Some("N"), "fleet size", Some("20"))
                .flag("samples", None, Some("N"), "samples per client", Some("2500"))
                .flag("seed", Some('s'), Some("N"), "fleet seed", Some("17"))
                .flag("profile", None, Some("NAME"), "resnet18|resnet34|resnet10|mlp", Some("resnet18")),
        )
        .subcommand(
            Command::new("report", "replay a streamed run record into a tail/fairness report")
                .flag("json-out", None, Some("FILE"), "also write the analysis as JSON", None)
                .positional("stream", "path to a *.stream.csv or *.stream.jsonl record stream"),
        )
        .subcommand(Command::new("info", "print the AOT manifest summary")
            .flag("artifacts", None, Some("DIR"), "artifact directory", Some("artifacts")))
}

fn main() {
    logging::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = cli();
    let parsed = match cmd.parse(&args) {
        Ok(p) => p,
        Err(CliError::HelpRequested(h)) => {
            println!("{h}");
            return;
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Some(level) = parsed.get("log-level").and_then(logging::Level::from_str) {
        logging::set_level(level);
    }
    let result = match parsed.subcommand() {
        Some("run") => cmd_run(&parsed),
        Some("churn") => cmd_churn(&parsed),
        Some("pair") => cmd_pair(&parsed),
        Some("latency") => cmd_latency(&parsed),
        Some("report") => cmd_report(&parsed),
        Some("info") => cmd_info(&parsed),
        _ => {
            println!("{}", cli().help());
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn req_parsed<T: std::str::FromStr>(p: &Parsed, name: &str) -> anyhow::Result<Option<T>> {
    p.get_parsed::<T>(name).map_err(|e| anyhow::anyhow!("{e}"))
}

/// Apply the shared `--engine` / `--threads` round-engine overrides.
fn apply_engine_flags(cfg: &mut ExperimentConfig, p: &Parsed) -> anyhow::Result<()> {
    if let Some(e) = p.get("engine") {
        cfg.engine.backend = RoundBackend::parse(e)
            .ok_or_else(|| anyhow::anyhow!("unknown round engine {e:?}"))?;
    }
    if let Some(t) = req_parsed::<usize>(p, "threads")? {
        cfg.engine.threads = t;
    }
    Ok(())
}

/// Apply the shared `--telemetry` / `--trace-out` / `--metrics-out`
/// observability flags (the output flags imply `--telemetry`).
fn apply_telemetry_flags(cfg: &mut ExperimentConfig, p: &Parsed) {
    if p.has("telemetry") {
        cfg.telemetry.enabled = true;
    }
    if let Some(path) = p.get("trace-out") {
        cfg.telemetry.enabled = true;
        cfg.telemetry.trace_out = Some(path.to_string());
    }
    if let Some(path) = p.get("metrics-out") {
        cfg.telemetry.enabled = true;
        cfg.telemetry.metrics_out = Some(path.to_string());
    }
}

/// Print the distribution observatory's end-of-run summary (fairness plus
/// the top stragglers) and, when configured, write the Prometheus snapshot:
/// registry series followed by the observatory's sketch histograms.
fn finish_observatory(
    obs: &fedpairing::telemetry::Observatory,
    telemetry: &fedpairing::config::TelemetryConfig,
) -> anyhow::Result<()> {
    let jain = obs.ledger.jain();
    if !jain.is_nan() {
        println!("fairness (Jain, busy time): {jain:.4}");
    }
    let top = obs.ledger.top_stragglers(3);
    if !top.is_empty() {
        let rows: Vec<String> = top
            .iter()
            .map(|&(id, c)| format!("#{id} x{c} (crit x{})", obs.ledger.crit_of(id)))
            .collect();
        println!("top stragglers (> round p50): {}", rows.join(", "));
    }
    if let Some(path) = &telemetry.metrics_out {
        let mut text =
            fedpairing::telemetry::export::prometheus(&fedpairing::telemetry::registry::snapshot());
        text.push_str(&fedpairing::telemetry::export::observatory(obs, telemetry.top_k_pairs));
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, text)?;
        println!("metrics snapshot: {path}");
    }
    Ok(())
}

/// Apply the shared buffered-aggregation flags (`--aggregation`,
/// `--buffer-size`, `--staleness-cap`, `--weighting`) and the incremental
/// record stream (`--stream-out`). Knob bounds are enforced by
/// `ExperimentConfig::validate` at run start.
fn apply_aggregation_flags(cfg: &mut ExperimentConfig, p: &Parsed) -> anyhow::Result<()> {
    if let Some(m) = p.get("aggregation") {
        cfg.aggregation = AggregationMode::parse(m)
            .ok_or_else(|| anyhow::anyhow!("unknown aggregation mode {m:?}"))?;
    }
    if let Some(b) = req_parsed::<usize>(p, "buffer-size")? {
        cfg.async_agg.buffer_size = b;
    }
    if let Some(c) = req_parsed::<usize>(p, "staleness-cap")? {
        cfg.async_agg.staleness_cap = c;
    }
    if let Some(w) = p.get("weighting") {
        cfg.async_agg.weighting = StalenessWeighting::parse(w)
            .ok_or_else(|| anyhow::anyhow!("unknown staleness weighting {w:?}"))?;
    }
    if let Some(d) = p.get("stream-out") {
        cfg.stream_out = Some(d.to_string());
    }
    Ok(())
}

/// Apply the shared fault-injection flags (`--faults`, `--deadline`,
/// `--retry-max`, `--retry-backoff`, `--retry-jitter`). Hazard and recovery
/// bounds are enforced by `ExperimentConfig::validate` at run start.
fn apply_fault_flags(cfg: &mut ExperimentConfig, p: &Parsed) -> anyhow::Result<()> {
    if let Some(spec) = p.get("faults") {
        cfg.faults.apply_spec(spec).map_err(|e| anyhow::anyhow!("{e}"))?;
    }
    if let Some(d) = req_parsed::<f64>(p, "deadline")? {
        cfg.faults.deadline_s = d;
    }
    if let Some(n) = req_parsed::<usize>(p, "retry-max")? {
        cfg.faults.recovery.retry_max = n;
    }
    if let Some(b) = req_parsed::<f64>(p, "retry-backoff")? {
        cfg.faults.recovery.backoff_base_s = b;
    }
    if let Some(j) = req_parsed::<f64>(p, "retry-jitter")? {
        cfg.faults.recovery.backoff_jitter = j;
    }
    Ok(())
}

/// Apply the shared `--split-policy` / `--model` split-planner overrides.
fn apply_split_flags(cfg: &mut ExperimentConfig, p: &Parsed) -> anyhow::Result<()> {
    if let Some(s) = p.get("split-policy") {
        cfg.split.policy = SplitPolicy::parse(s)
            .ok_or_else(|| anyhow::anyhow!("unknown split policy {s:?}"))?;
    }
    if let Some(m) = p.get("model") {
        cfg.model = ModelPreset::parse(m)
            .ok_or_else(|| anyhow::anyhow!("unknown model preset {m:?}"))?;
    }
    Ok(())
}

fn cmd_run(p: &Parsed) -> anyhow::Result<()> {
    let mut cfg = if let Some(file) = p.get("config") {
        ExperimentConfig::load(file).map_err(|e| anyhow::anyhow!("{e}"))?
    } else {
        let preset = p.get("preset").unwrap_or("quick");
        ExperimentConfig::preset(preset)
            .ok_or_else(|| anyhow::anyhow!("unknown preset {preset:?}"))?
    };
    if let Some(a) = p.get("algorithm") {
        cfg.algorithm =
            Algorithm::parse(a).ok_or_else(|| anyhow::anyhow!("unknown algorithm {a:?}"))?;
    }
    if let Some(s) = p.get("pairing") {
        cfg.pairing =
            PairingStrategy::parse(s).ok_or_else(|| anyhow::anyhow!("unknown strategy {s:?}"))?;
    }
    if let Some(m) = p.get("pairing-mode") {
        cfg.pairing_mode =
            PairingMode::parse(m).ok_or_else(|| anyhow::anyhow!("unknown pairing mode {m:?}"))?;
    }
    if let Some(b) = p.get("backend") {
        cfg.backend.mode =
            BackendMode::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    if let Some(r) = req_parsed::<usize>(p, "rounds")? {
        cfg.rounds = r;
    }
    if let Some(n) = req_parsed::<usize>(p, "clients")? {
        cfg.n_clients = n;
    }
    if let Some(n) = req_parsed::<usize>(p, "n-clients")? {
        cfg.n_clients = n;
    }
    if let Some(n) = req_parsed::<usize>(p, "samples")? {
        cfg.samples_per_client = n;
    }
    if let Some(s) = req_parsed::<u64>(p, "seed")? {
        cfg.seed = s;
    }
    if p.has("noniid") {
        cfg.distribution = DataDistribution::ClassShards { classes_per_client: 2 };
    }
    if p.has("no-overlap-boost") {
        cfg.overlap_boost = false;
    }
    if let Some(s) = p.get("scenario") {
        let sc = ScenarioConfig::named(s)
            .ok_or_else(|| anyhow::anyhow!("unknown scenario {s:?}"))?;
        cfg.set_scenario(sc);
    }
    apply_engine_flags(&mut cfg, p)?;
    apply_split_flags(&mut cfg, p)?;
    apply_aggregation_flags(&mut cfg, p)?;
    apply_fault_flags(&mut cfg, p)?;
    apply_telemetry_flags(&mut cfg, p);
    if let Some(d) = p.get("artifacts") {
        cfg.artifacts_dir = d.to_string();
    }
    if let Some(d) = p.get("out") {
        cfg.out_dir = d.to_string();
    }
    println!(
        "running {} / {} / {} / scenario={} — {} clients, {} rounds",
        cfg.algorithm,
        cfg.pairing,
        cfg.distribution.name(),
        cfg.scenario.kind,
        cfg.n_clients,
        cfg.rounds
    );
    let res = run_experiment(cfg)?;
    println!(
        "done: final_acc={:.4} best_acc={:.4} mean_round={:.1}s wall={:.1}s execs={}",
        res.final_acc(),
        res.best_acc(),
        res.mean_round_s(),
        res.wall_s,
        res.total_execs
    );
    finish_observatory(&res.observatory, &res.config.telemetry)?;
    let (csv, json) = res.save(&res.config.out_dir.clone())?;
    println!("metrics: {csv} / {json}");
    Ok(())
}

fn cmd_churn(p: &Parsed) -> anyhow::Result<()> {
    let scenario = p.get("scenario").unwrap_or("flash-crowd");
    let mut cfg = ExperimentConfig::default();
    let sc = ScenarioConfig::named(scenario)
        .ok_or_else(|| anyhow::anyhow!("unknown scenario {scenario:?}"))?;
    cfg.set_scenario(sc);
    cfg.name = format!("churn_{}", cfg.scenario.kind);
    if let Some(a) = p.get("algorithm") {
        cfg.algorithm =
            Algorithm::parse(a).ok_or_else(|| anyhow::anyhow!("unknown algorithm {a:?}"))?;
    }
    if let Some(s) = p.get("pairing") {
        cfg.pairing =
            PairingStrategy::parse(s).ok_or_else(|| anyhow::anyhow!("unknown strategy {s:?}"))?;
    }
    if let Some(m) = p.get("pairing-mode") {
        cfg.pairing_mode =
            PairingMode::parse(m).ok_or_else(|| anyhow::anyhow!("unknown pairing mode {m:?}"))?;
    }
    if let Some(b) = p.get("backend") {
        cfg.backend.mode =
            BackendMode::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    cfg.n_clients = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(n) = req_parsed::<usize>(p, "n-clients")? {
        cfg.n_clients = n;
    }
    cfg.rounds = p.req("rounds").map_err(|e| anyhow::anyhow!("{e}"))?;
    cfg.seed = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    // Metro-scale fleets through the paper's 2500-samples DES schedule would
    // spend most of their time simulating batches, so the default thins out;
    // an explicit --samples always wins.
    cfg.samples_per_client = match req_parsed::<usize>(p, "samples")? {
        Some(s) => s,
        None if cfg.scenario.kind == fedpairing::config::ScenarioKind::MetroScale => {
            fedpairing::log_info!(
                "metro-scale: samples/client defaulted to 64 (pass --samples to override)"
            );
            64
        }
        None => 2500,
    };
    apply_engine_flags(&mut cfg, p)?;
    apply_split_flags(&mut cfg, p)?;
    apply_aggregation_flags(&mut cfg, p)?;
    apply_fault_flags(&mut cfg, p)?;
    apply_telemetry_flags(&mut cfg, p);
    if let Some(d) = p.get("out") {
        cfg.out_dir = d.to_string();
    }
    println!(
        "simulating {} / {} under scenario={} — {} clients, {} rounds, {} backend, {} engine, \
         {} split on {}, {} aggregation (latency only)",
        cfg.algorithm,
        cfg.pairing,
        cfg.scenario.kind,
        cfg.n_clients,
        cfg.rounds,
        if cfg.backend.sparse_for(cfg.n_clients) { "sparse" } else { "dense" },
        cfg.engine.backend,
        cfg.split.policy,
        cfg.model,
        cfg.aggregation
    );
    let run = simulate_scenario(&cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "{:>5} {:>7} {:>8} {:>8} {:>10} {:>12}",
        "round", "alive", "joined", "departed", "round s", "cumulative s"
    );
    for (ev, rec) in run.trace.iter().zip(&run.result.rounds) {
        println!(
            "{:>5} {:>7} {:>8} {:>8} {:>10.1} {:>12.1}",
            ev.round,
            rec.n_alive,
            ev.joined.len(),
            ev.departed.len(),
            rec.sim_round_s,
            rec.sim_total_s
        );
    }
    println!(
        "done: mean_alive={:.1} departures={} joins={} repaired_rounds={} total_sim={:.0}s",
        run.mean_alive(),
        run.total_departures(),
        run.total_joins(),
        run.repaired_rounds,
        run.result.rounds.last().map(|r| r.sim_total_s).unwrap_or(0.0)
    );
    if !run.events.is_empty() {
        let n = run.events.len() as f64;
        let updates: usize = run.events.iter().map(|e| e.n_updates).sum();
        let stale_mean: f64 = run.events.iter().map(|e| e.staleness_mean).sum::<f64>() / n;
        let stale_max = run.events.iter().map(|e| e.staleness_max).max().unwrap_or(0);
        let wait: f64 = run.events.iter().map(|e| e.wait_eliminated_s).sum();
        println!(
            "async: {} merges, {updates} updates, staleness mean={stale_mean:.2} max={stale_max}, \
             straggler wait eliminated={wait:.0}s",
            run.events.len()
        );
    }
    finish_observatory(&run.result.observatory, &cfg.telemetry)?;
    let (csv, json) = run.result.save(&cfg.out_dir)?;
    println!("metrics: {csv} / {json}");
    Ok(())
}

fn cmd_pair(p: &Parsed) -> anyhow::Result<()> {
    let n: usize = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let alpha: f64 = p.req("alpha").map_err(|e| anyhow::anyhow!("{e}"))?;
    let beta: f64 = p.req("beta").map_err(|e| anyhow::anyhow!("{e}"))?;
    let strat = PairingStrategy::parse(p.get("strategy").unwrap_or("greedy"))
        .ok_or_else(|| anyhow::anyhow!("unknown strategy"))?;
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    cfg.seed = seed;
    if let Some(b) = p.get("backend") {
        cfg.backend.mode =
            BackendMode::parse(b).ok_or_else(|| anyhow::anyhow!("unknown backend {b:?}"))?;
    }
    apply_split_flags(&mut cfg, p)?;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let channel = Channel::new(cfg.channel);
    // The planner prices pairs for the cut display (always) and, under a
    // non-paper policy with co-design on, supplies the pairing objective.
    let profile = ModelProfile::from_preset(cfg.model);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    let planner = SplitCostModel::new(profile.clone(), sched, cfg.compute, cfg.split);
    let cost = (cfg.split.policy != SplitPolicy::Paper && cfg.split.co_design)
        .then_some(&planner);
    let pairs =
        pair_clients_with(&cfg.backend, strat, &fleet, &channel, alpha, beta, cost, &mut rng);
    // The dense graph is only for the ε total — skip it past paper scale
    // (O(n²) edges) and report the lazily-summed weight instead.
    if n <= 2048 {
        let graph = ClientGraph::build(&fleet, &channel, alpha, beta);
        println!(
            "strategy={strat} n={n} seed={seed} split={} model={}  total ε = {:.3}",
            cfg.split.policy,
            cfg.model,
            graph.matching_weight(&pairs)
        );
    } else {
        let total: f64 = pairs
            .iter()
            .map(|&(i, j)| {
                let rate = channel.rate(&fleet.positions[i], &fleet.positions[j]);
                fedpairing::pairing::graph::eq5_weight(
                    alpha,
                    beta,
                    fleet.freqs_hz[i],
                    fleet.freqs_hz[j],
                    rate,
                )
            })
            .sum();
        println!("strategy={strat} n={n} seed={seed}  total ε = {total:.3} (lazy)");
    }
    println!(
        "{:<12} {:>9} {:>9} {:>8} {:>10} {:>7} {:>10}",
        "pair", "f_i GHz", "f_j GHz", "dist m", "rate Mb/s", "L_i/L_j", "pred s"
    );
    const MAX_ROWS: usize = 32;
    if pairs.len() > MAX_ROWS {
        println!("  (showing first {MAX_ROWS} of {} pairs)", pairs.len());
    }
    for &(i, j) in pairs.iter().take(MAX_ROWS) {
        let d = fleet.positions[i].dist(&fleet.positions[j]);
        let r = channel.rate(&fleet.positions[i], &fleet.positions[j]) / 1e6;
        let decision = planner.decide(&fleet, &channel, i, j);
        let (li, lj) = (decision.cut, profile.w() - decision.cut);
        println!(
            "({i:>2},{j:>2})     {:>9.2} {:>9.2} {:>8.1} {:>10.0} {:>4}/{:<4} {:>10.1}",
            fleet.freqs_hz[i] / 1e9,
            fleet.freqs_hz[j] / 1e9,
            d,
            r,
            li,
            lj,
            decision.predicted_round_s
        );
    }
    for s in fedpairing::pairing::graph::uncovered(n, &pairs) {
        println!(
            "({s:>2}, —)     {:>9.2}      solo — trains the full model locally",
            fleet.freqs_hz[s] / 1e9
        );
    }
    Ok(())
}

fn cmd_latency(p: &Parsed) -> anyhow::Result<()> {
    let n: usize = p.req("clients").map_err(|e| anyhow::anyhow!("{e}"))?;
    let samples: usize = p.req("samples").map_err(|e| anyhow::anyhow!("{e}"))?;
    let seed: u64 = p.req("seed").map_err(|e| anyhow::anyhow!("{e}"))?;
    let name = p.get("profile").unwrap_or("resnet18");
    let profile = ModelPreset::parse(name)
        .map(ModelProfile::from_preset)
        .ok_or_else(|| anyhow::anyhow!("unknown profile {name:?}"))?;
    let mut cfg = ExperimentConfig::default();
    cfg.n_clients = n;
    cfg.samples_per_client = samples;
    cfg.seed = seed;
    let mut rng = Rng::new(seed);
    let fleet = Fleet::sample(&cfg, &mut rng);
    let channel = Channel::new(cfg.channel);
    let sched = Schedule {
        batch_size: 32,
        epochs: cfg.local_epochs,
    };
    println!("— Table I: pairing mechanisms (FedPairing round, {}) —", profile.name);
    for strat in [
        PairingStrategy::Greedy,
        PairingStrategy::Random,
        PairingStrategy::Location,
        PairingStrategy::Compute,
        PairingStrategy::Exact,
    ] {
        let pairs = pair_clients(strat, &fleet, &channel, cfg.alpha, cfg.beta, &mut rng.fork(1));
        let rt = latency::fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &cfg.compute, true);
        println!("  {:<10} {:>10.0} s", strat.name(), rt.total_s);
    }
    println!("— Table II: algorithms —");
    let pairs = pair_clients(
        PairingStrategy::Greedy,
        &fleet,
        &channel,
        cfg.alpha,
        cfg.beta,
        &mut rng.fork(2),
    );
    let fp = latency::fedpairing_round(&fleet, &pairs, &profile, &sched, &channel, &cfg.compute, true);
    let fl = latency::fl_round(&fleet, &profile, &sched, &channel, &cfg.compute, true);
    let sl = latency::sl_round(
        &fleet,
        &profile,
        &sched,
        &channel,
        &cfg.compute,
        cfg.sl_cut_layer,
        cfg.compute.server_freq_ghz * 1e9,
    );
    let sf = latency::splitfed_round(
        &fleet,
        &profile,
        &sched,
        &channel,
        &cfg.compute,
        cfg.splitfed_cut_layer,
        cfg.compute.server_freq_ghz * 1e9,
        true,
    );
    for (name, t) in [
        ("fedpairing", fp.total_s),
        ("splitfed", sf.total_s),
        ("vanilla_fl", fl.total_s),
        ("vanilla_sl", sl.total_s),
    ] {
        println!("  {:<10} {:>10.0} s", name, t);
    }
    Ok(())
}

fn cmd_report(p: &Parsed) -> anyhow::Result<()> {
    let path = p
        .positionals
        .first()
        .ok_or_else(|| anyhow::anyhow!("report needs a stream path (*.stream.csv or *.stream.jsonl)"))?;
    let report = fedpairing::telemetry::report::Report::load(path)
        .map_err(|e| anyhow::anyhow!("loading {path}: {e}"))?;
    print!("{}", report.render_text());
    if let Some(out) = p.get("json-out") {
        if let Some(parent) = std::path::Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(out, report.to_json().to_string())?;
        println!("report json: {out}");
    }
    Ok(())
}

fn cmd_info(p: &Parsed) -> anyhow::Result<()> {
    let dir = p.get("artifacts").unwrap_or("artifacts");
    let meta = ModelMeta::load(dir).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "model: resnet-mlp W={} hidden={} in={} classes={} params={}",
        meta.layers, meta.hidden, meta.input_dim, meta.classes, meta.n_params
    );
    println!("batches: train={} eval={}", meta.train_batch, meta.eval_batch);
    println!("entries: {}", meta.entries.len());
    for (name, e) in &meta.entries {
        println!(
            "  {:<14} {} in / {} out — {}",
            name,
            e.inputs.len(),
            e.outputs.len(),
            e.file
        );
    }
    Ok(())
}
