//! Algorithm drivers: the full multi-round FL loops for FedPairing and the
//! three benchmarks (vanilla FL, vanilla SL, SplitFed), all executing the same
//! AOT artifacts through one [`Engine`] and all charged by the same latency
//! simulator — so accuracy curves (Figs. 2–3) and round times (Tables I–II)
//! come from one consistent system.
//!
//! Every loop runs under the configured fleet-dynamics scenario: each round
//! steps [`FleetDynamics`], trains only the *present* clients, renormalizes
//! the FedAvg weights over the participants (dropped clients contribute
//! nothing), and records the per-round alive count. FedPairing additionally
//! maintains its matching incrementally — departures trigger
//! [`crate::pairing::repair_matching`] instead of a full re-pair, and an
//! unpaired (solo) client trains the full model locally. Under the default
//! `stable` scenario all of this reduces exactly to the paper's static loops.

use crate::asyncsim::driver::{note_merge, plan_fedpairing, plan_solo};
use crate::asyncsim::{AggregationEvent, Timeline, UnitKind};
use crate::config::{AggregationMode, Algorithm, ExperimentConfig, SplitPolicy};
use crate::coordinator::metrics::{streamer_for, RecordStreamer, RoundRecord, RunResult};
use crate::coordinator::split::train_pair;
use crate::data::loader::{eval_batches, Batch, Loader};
use crate::data::partition::partition;
use crate::data::synth::SynthCifar;
use crate::faults::{self, AsyncFaults, FaultModel, FaultUnit, UnitSpec};
use crate::fleet::{maintain_matching_session, universe_size, FleetDynamics, PairingSession};
use crate::nn::{self, Params};
use crate::runtime::Engine;
use crate::sim::channel::Channel;
use crate::sim::compute::{aggregation_weights, split_lengths};
use crate::sim::engine::RoundEngine;
use crate::sim::latency::{full_local_time, upload_time, Fleet, FleetView, RoundTime, Schedule};
use crate::split::SplitCostModel;
use crate::telemetry::{registry, Counter, Observatory, RoundLanes, Telemetry};
use crate::util::index::InverseIndex;
use crate::{log_debug, log_info, log_warn};
use anyhow::{Context, Result};
use std::collections::HashMap;

/// A fully materialized experiment: fleet, data, engine, channel.
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub engine: Engine,
    /// The base fleet (initially-active clients; universe ids `0..n_clients`).
    pub fleet: Fleet,
    /// The static eq. (3) channel (scenarios layer fading on top per round).
    pub channel: Channel,
    /// The full universe fleet in its initial state (base + latent cohort) —
    /// sampled once, so loaders, weights and per-run dynamics all index the
    /// same clients.
    universe: Fleet,
    /// One loader per *universe* client (incl. any latent flash cohort).
    loaders: Vec<Loader>,
    /// FedAvg weights `a_i` over the universe (renormalized per round over
    /// the participants).
    weights: Vec<f64>,
    test: Vec<Batch>,
    /// Round-time evaluation engine (analytic kernels + memo cache; one
    /// instance per experiment so the cache works across rounds).
    round_engine: RoundEngine,
}

impl Experiment {
    /// Build everything deterministically from the config.
    pub fn new(cfg: ExperimentConfig) -> Result<Experiment> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let engine = Engine::load(&cfg.artifacts_dir)?;
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let channel = Channel::new(cfg.channel);
        let gen = SynthCifar::new(cfg.seed, cfg.noise_level);
        // Data is partitioned over the whole universe so flash-crowd joiners
        // arrive with their own shards. Under `stable` the universe equals
        // the base fleet and this is byte-identical to the static path.
        let n_universe = universe_size(&cfg);
        let shards = partition(
            &mut rng.fork(1),
            n_universe,
            cfg.samples_per_client,
            &cfg.distribution,
        );
        let train_batch = engine.meta().train_batch;
        let loaders: Vec<Loader> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                Loader::new(
                    gen.clone(),
                    shard,
                    train_batch,
                    crate::util::rng::Rng::with_stream(cfg.seed ^ 0xC11E47, i as u64),
                )
            })
            .collect();
        // Materialize the universe (base fleet + latent flash cohort) once;
        // per-run dynamics are rebuilt from this exact fleet.
        let universe = FleetDynamics::new(&cfg, fleet.clone()).universe().clone();
        let weights = aggregation_weights(&universe.resources());
        let test = eval_batches(&gen.test_set(cfg.test_samples), engine.meta().eval_batch);
        let round_engine = RoundEngine::new(&cfg.engine).with_split(cfg.split);
        Ok(Experiment {
            cfg,
            engine,
            fleet,
            channel,
            universe,
            loaders,
            weights,
            test,
            round_engine,
        })
    }

    /// Fresh fleet dynamics for one run (deterministic in the config).
    fn dynamics(&self) -> FleetDynamics {
        FleetDynamics::from_universe(&self.cfg, self.universe.clone())
    }

    /// Participant weights renormalized to sum to 1 (weighted FedAvg input).
    fn renormalized_weights(&self, members: &[usize]) -> Result<Vec<f64>> {
        let total: f64 = members.iter().map(|&c| self.weights[c]).sum();
        anyhow::ensure!(total > 0.0, "no data among participants");
        Ok(members.iter().map(|&c| self.weights[c] / total).collect())
    }

    fn schedule(&self) -> Schedule {
        Schedule {
            batch_size: self.engine.meta().train_batch,
            epochs: self.cfg.local_epochs,
        }
    }

    /// Evaluate a model on the shared test set: `(mean_loss, accuracy)`.
    pub fn evaluate(&mut self, params: &Params) -> Result<(f64, f64)> {
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut rows = 0f64;
        // Upload the model once, reuse the device buffers across test batches.
        let dev = self.engine.upload_params(params, 0)?;
        for b in &self.test {
            let (l, c, n) = self.engine.eval_batch_b(&dev, &b.x, &b.y1hot)?;
            loss_sum += l as f64;
            correct += c as f64;
            rows += n as f64;
        }
        anyhow::ensure!(rows > 0.0, "empty test set");
        Ok((loss_sum / rows, correct / rows))
    }

    fn should_eval(&self, round: usize) -> bool {
        round == self.cfg.rounds
            || (self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0)
    }

    /// Run the configured algorithm to completion.
    pub fn run(&mut self) -> Result<RunResult> {
        let t0 = std::time::Instant::now();
        let mut dynamics = self.dynamics();
        let mut telemetry = Telemetry::new(&self.cfg.telemetry);
        let mut streamer = streamer_for(&self.cfg).context("opening stream sink")?;
        // Distribution observatory (DESIGN.md §12): quantile-sketch lanes +
        // the per-client fairness ledger, fed unconditionally by every loop
        // (feeds only read simulation state, so the RoundRecord trace stays
        // bit-identical to a pre-observatory build).
        let mut observatory = Observatory::new();
        let obs = &mut observatory;
        let rounds = if self.cfg.aggregation == AggregationMode::Async {
            self.run_async(&mut dynamics, &mut telemetry, &mut streamer, obs)?
        } else {
            match self.cfg.algorithm {
                Algorithm::FedPairing => {
                    self.run_fedpairing(&mut dynamics, &mut telemetry, &mut streamer, obs)?
                }
                Algorithm::VanillaFL => {
                    self.run_fl(&mut dynamics, &mut telemetry, &mut streamer, obs)?
                }
                Algorithm::VanillaSL => {
                    self.run_sl(&mut dynamics, &mut telemetry, &mut streamer, obs)?
                }
                Algorithm::SplitFed => {
                    self.run_splitfed(&mut dynamics, &mut telemetry, &mut streamer, obs)?
                }
            }
        };
        if let Some(s) = streamer {
            let (c, j) = s.finish().context("closing stream sink")?;
            log_info!("stream: wrote {c} and {j}");
        }
        for path in telemetry.finish().context("writing telemetry exports")? {
            log_info!("telemetry: wrote {path}");
        }
        Ok(RunResult {
            config: self.cfg.clone(),
            rounds,
            wall_s: t0.elapsed().as_secs_f64(),
            total_execs: self.engine.total_execs(),
            observatory,
        })
    }

    // ------------------------------------------------------------------
    // FedPairing (the paper's system)
    // ------------------------------------------------------------------

    fn run_fedpairing(
        &mut self,
        dynamics: &mut FleetDynamics,
        telemetry: &mut Telemetry,
        streamer: &mut Option<RecordStreamer>,
        obs: &mut Observatory,
    ) -> Result<Vec<RoundRecord>> {
        let w = self.engine.meta().layers;
        let profile = self.engine.meta().profile();
        let sched = self.schedule();
        // Config validation bounded the split floor against the *configured*
        // model profile; the loaded artifacts may be shallower, so re-check
        // here (the cut analogue lives in `checked_cut`).
        anyhow::ensure!(
            2 * self.cfg.split.min_layers <= w,
            "split min_layers = {} leaves no feasible cut for the loaded artifacts (W = {w})",
            self.cfg.split.min_layers
        );
        // Split planner (DESIGN.md §7): under a non-paper policy the trained
        // cut comes from the same memoized planner the latency engine
        // charges, and — with co-design on — Greedy/Exact pairing weights
        // become the planner's predicted pair latency.
        let planner = (self.cfg.split.policy != SplitPolicy::Paper)
            .then(|| SplitCostModel::new(profile.clone(), sched, self.cfg.compute, self.cfg.split));
        let cost = planner.as_ref().filter(|_| self.cfg.split.co_design);
        let mut pairing_rng = crate::util::rng::Rng::new(self.cfg.seed ^ 0x9A1F);
        // Initialization phase (paper Sec. II-A.1) happens lazily inside
        // `maintain_matching_session` on round 1; later rounds maintain the
        // matching per the configured pairing mode (repair/rebuild/
        // incremental) instead of re-pairing the whole fleet.
        let mut pairing = PairingSession::new();
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut sim_total = 0.0f64;
        // Zero-allocation round views: borrow the universe fleet instead of
        // cloning a sub-fleet, and invert universe→compact ids through a
        // reusable scratch map instead of per-member binary searches.
        let mut inv = InverseIndex::new();
        let mut cpairs: Vec<(usize, usize)> = Vec::new();
        let mut csolos: Vec<usize> = Vec::new();
        // Mid-round fault injection (DESIGN.md §11). A disarmed config skips
        // the whole pass, so fault-free traces stay bit-identical.
        let fcfg = self.cfg.faults;
        let fmodel = FaultModel::new(&fcfg, Algorithm::FedPairing, self.cfg.seed);
        // Always on: the fault model replays unit times and the observatory
        // attributes per-unit splits; recording never changes round math.
        self.round_engine.set_record_units(true);
        for round in 1..=self.cfg.rounds {
            telemetry.begin_round(round);
            let ev = dynamics.step(round);
            let channel = dynamics.channel();
            telemetry.mark("dynamics");
            maintain_matching_session(
                &mut pairing,
                dynamics,
                &ev,
                &channel,
                &self.cfg,
                cost,
                &mut pairing_rng,
            );
            telemetry.mark("matcher");
            let m = pairing.matching.as_ref().expect("matching initialized");
            // Transient failures demote a pair's survivor to solo for this
            // round only; the stored matching is untouched.
            let members = dynamics.present_members();
            let view = FleetView::new(dynamics.universe(), members);
            let eff = m.restricted_to(members);
            inv.rebuild(dynamics.universe().n(), members);
            cpairs.clear();
            cpairs.extend(
                eff.pairs
                    .iter()
                    .map(|&(a, b)| (inv.compact(a), inv.compact(b))),
            );
            csolos.clear();
            csolos.extend(eff.solos.iter().map(|&s| inv.compact(s)));
            telemetry.mark("pairing");
            let mut rt = self.round_engine.fedpairing_round(
                &view,
                &cpairs,
                &csolos,
                &profile,
                &sched,
                &channel,
                &self.cfg.compute,
                true,
            );
            rt.stages.remap_crit(members);
            // Fault pass: replay the round's units through the fault model;
            // the round time becomes the recovered (retried / re-paired /
            // deadline-clamped) finish and lost updates are dropped from the
            // merge below. Inactive models leave `rt` bit-untouched.
            let mut fault_lost: Vec<usize> = Vec::new();
            if fmodel.active() {
                let specs = faults::fedpairing_unit_specs(
                    self.round_engine.unit_times(),
                    &cpairs,
                    &csolos,
                    members,
                    &view,
                    &profile,
                    &sched,
                    &channel,
                    &self.cfg.compute,
                );
                let out = fmodel.inject_round(round, &specs, 0.0, rt.total_s);
                rt.total_s = out.total_s;
                rt.faults = out.counters;
                faults::note_outcome(&out.counters, &out.events);
                telemetry.fault_events(&out.events, sim_total);
                fault_lost = out.lost;
            }
            telemetry.mark("engine");
            // Observatory feed (side-channel: reads the engine's recorded
            // units, never writes back into the round arithmetic).
            let units: Vec<(usize, Option<usize>)> = cpairs
                .iter()
                .map(|&(a, b)| (members[a], Some(members[b])))
                .chain(csolos.iter().map(|&s| (members[s], None)))
                .collect();
            let mk = obs.note_sync_round(
                &units,
                self.round_engine.unit_times(),
                self.round_engine.unit_splits(),
                rt.total_s,
                &fault_lost,
            );
            obs.note_stages(&rt.stages);
            obs.note_fault_recovery(rt.faults.recovery_s);
            let round_time = rt.total_s;
            // Participants this round (pairs + solos) and their weights.
            let participants: Vec<usize> = eff
                .pairs
                .iter()
                .flat_map(|&(a, b)| [a, b])
                .chain(eff.solos.iter().copied())
                .collect();
            let part_total: f64 = participants.iter().map(|&c| self.weights[c]).sum();
            anyhow::ensure!(part_total > 0.0, "no data among participants");
            let n_part = participants.len() as f64;
            let mut locals: Vec<Params> = Vec::with_capacity(participants.len());
            let mut agg_weights: Vec<f64> = Vec::with_capacity(participants.len());
            let mut contributors: Vec<usize> = Vec::with_capacity(participants.len());
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            let uni = dynamics.universe();
            for &(i, j) in &eff.pairs {
                // Split on *current* (straggle-adjusted) frequencies and
                // link rates, through the same planner the latency engine
                // charges. Non-paper policies go through the memoized model
                // (stable fleets pay each pair's search once); the paper
                // default is the O(1) rule, exactly as before.
                let l_i = match &planner {
                    Some(m) => {
                        m.decide_raw(
                            uni.freqs_hz[i],
                            uni.freqs_hz[j],
                            uni.n_samples[i],
                            uni.n_samples[j],
                            channel.rate(&uni.positions[i], &uni.positions[j]),
                        )
                        .cut
                    }
                    None => split_lengths(uni.freqs_hz[i], uni.freqs_hz[j], w).0,
                };
                let l_j = w - l_i;
                // Normalized data weights â_i = N·a_i over this round's
                // participants (≡ 1 for equal shards). The paper's literal
                // eq.(1) scales local grads by a_i ≈ 1/N *and* averages
                // models at the server — a double shrink that makes the net
                // step η/N² (inconsistent with its own Fig. 2, where
                // FedPairing out-converges FL). We keep the *relative* a_i
                // weighting inside the pair and restore the magnitude at
                // aggregation via the standard weighted FedAvg, which is the
                // consistent reading (DESIGN.md §2).
                let (a_i, a_j) = (
                    (self.weights[i] / part_total * n_part) as f32,
                    (self.weights[j] / part_total * n_part) as f32,
                );
                // Loaders for i and j (split_at to appease the borrow checker).
                let (li, lj) = {
                    let (lo, hi) = (i.min(j), i.max(j));
                    let (a, b) = self.loaders.split_at_mut(hi);
                    if i < j {
                        (&mut a[lo], &mut b[0])
                    } else {
                        (&mut b[0], &mut a[lo])
                    }
                };
                let out = train_pair(
                    &mut self.engine,
                    &global,
                    li,
                    lj,
                    l_i,
                    l_j,
                    a_i,
                    a_j,
                    self.cfg.lr,
                    self.cfg.local_epochs,
                    self.cfg.overlap_boost,
                )?;
                loss_sum += out.mean_loss * out.n_steps as f64;
                steps += out.n_steps;
                locals.push(out.model_i);
                locals.push(out.model_j);
                agg_weights.push(self.weights[i]);
                agg_weights.push(self.weights[j]);
                contributors.push(i);
                contributors.push(j);
            }
            // Solo clients (odd fleets / widowed partners) train the full
            // model locally, like a vanilla-FL participant.
            for &s in &eff.solos {
                let (local, l, st) = self.local_training(&global, s)?;
                loss_sum += l;
                steps += st;
                locals.push(local);
                agg_weights.push(self.weights[s]);
                contributors.push(s);
            }
            // Model aggregation (Sec. II-A.3): weighted FedAvg over this
            // round's participant models minus fault-lost / non-finite
            // updates, weights renormalized so dropped clients contribute
            // nothing.
            merge_weighted(&mut global, &contributors, locals, agg_weights, &fault_lost)?;
            telemetry.mark("train");
            sim_total += round_time;
            let rec = self.record(
                round,
                &global,
                loss_sum / steps.max(1) as f64,
                &rt,
                sim_total,
                ev.n_alive,
                mk,
                obs.ledger.jain(),
            )?;
            stream_push(streamer, &rec)?;
            records.push(rec);
            // Lane ids leave the engine in round-compact space; export them
            // in universe ids to match the fleet trace. Empty unless
            // telemetry is on, so the remap is free when disabled.
            let lanes: Vec<(usize, usize, f64)> = self
                .round_engine
                .pair_lanes()
                .iter()
                .map(|&(a, b, t)| (members[a], members[b], t))
                .collect();
            telemetry.end_round(&rt, ev.n_alive, &lanes, sim_total - round_time);
        }
        Ok(records)
    }

    /// One client's full-model local training (vanilla-FL step; also the
    /// FedPairing solo fallback): returns `(model, loss_sum, steps)`.
    fn local_training(&mut self, global: &Params, client: usize) -> Result<(Params, f64, usize)> {
        let mut local = global.clone();
        let mut loss_sum = 0.0;
        let mut steps = 0usize;
        for _ in 0..self.cfg.local_epochs {
            for b in self.loaders[client].epoch() {
                let (grads, loss) = self.engine.full_step(&local, &b.x, &b.y1hot)?;
                nn::sgd_apply(&mut local, &grads, self.cfg.lr);
                loss_sum += loss as f64;
                steps += 1;
            }
        }
        Ok((local, loss_sum, steps))
    }

    // ------------------------------------------------------------------
    // Vanilla FL (FedAvg)
    // ------------------------------------------------------------------

    fn run_fl(
        &mut self,
        dynamics: &mut FleetDynamics,
        telemetry: &mut Telemetry,
        streamer: &mut Option<RecordStreamer>,
        obs: &mut Observatory,
    ) -> Result<Vec<RoundRecord>> {
        let profile = self.engine.meta().profile();
        let sched = self.schedule();
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut sim_total = 0.0f64;
        let fcfg = self.cfg.faults;
        let fmodel = FaultModel::new(&fcfg, Algorithm::VanillaFL, self.cfg.seed);
        self.round_engine.set_record_units(true);
        for round in 1..=self.cfg.rounds {
            telemetry.begin_round(round);
            let ev = dynamics.step(round);
            let channel = dynamics.channel();
            let members = dynamics.present_members();
            let view = FleetView::new(dynamics.universe(), members);
            telemetry.mark("dynamics");
            let mut rt = self
                .round_engine
                .fl_round(&view, &profile, &sched, &channel, &self.cfg.compute, true);
            rt.stages.remap_crit(members);
            let mut fault_lost: Vec<usize> = Vec::new();
            if fmodel.active() {
                let specs = faults::solo_unit_specs(
                    Algorithm::VanillaFL,
                    self.round_engine.unit_times(),
                    members,
                );
                let out = fmodel.inject_round(round, &specs, 0.0, rt.total_s);
                rt.total_s = out.total_s;
                rt.faults = out.counters;
                faults::note_outcome(&out.counters, &out.events);
                telemetry.fault_events(&out.events, sim_total);
                fault_lost = out.lost;
            }
            telemetry.mark("engine");
            let units: Vec<(usize, Option<usize>)> =
                members.iter().map(|&m| (m, None)).collect();
            let mk = obs.note_sync_round(
                &units,
                self.round_engine.unit_times(),
                self.round_engine.unit_splits(),
                rt.total_s,
                &fault_lost,
            );
            obs.note_stages(&rt.stages);
            obs.note_fault_recovery(rt.faults.recovery_s);
            let round_time = rt.total_s;
            let mut locals: Vec<Params> = Vec::with_capacity(members.len());
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            for &c in members {
                let (local, l, st) = self.local_training(&global, c)?;
                loss_sum += l;
                steps += st;
                locals.push(local);
            }
            let raw_w: Vec<f64> = members.iter().map(|&c| self.weights[c]).collect();
            merge_weighted(&mut global, members, locals, raw_w, &fault_lost)?;
            telemetry.mark("train");
            sim_total += round_time;
            let rec = self.record(
                round,
                &global,
                loss_sum / steps.max(1) as f64,
                &rt,
                sim_total,
                ev.n_alive,
                mk,
                obs.ledger.jain(),
            )?;
            stream_push(streamer, &rec)?;
            records.push(rec);
            telemetry.end_round(&rt, ev.n_alive, &[], sim_total - round_time);
        }
        Ok(records)
    }

    // ------------------------------------------------------------------
    // Vanilla SL (sequential relay)
    // ------------------------------------------------------------------

    fn run_sl(
        &mut self,
        dynamics: &mut FleetDynamics,
        telemetry: &mut Telemetry,
        streamer: &mut Option<RecordStreamer>,
        obs: &mut Observatory,
    ) -> Result<Vec<RoundRecord>> {
        let cut = checked_cut("sl_cut_layer", self.cfg.sl_cut_layer, self.engine.meta().layers)?;
        let profile = self.engine.meta().profile();
        let sched = self.schedule();
        let global = self.engine.init_params(self.cfg.seed as u32)?;
        let (mut front, mut back) = split_params(&global, cut);
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut sim_total = 0.0f64;
        let fcfg = self.cfg.faults;
        let fmodel = FaultModel::new(&fcfg, Algorithm::VanillaSL, self.cfg.seed);
        self.round_engine.set_record_units(true);
        for round in 1..=self.cfg.rounds {
            telemetry.begin_round(round);
            let ev = dynamics.step(round);
            let channel = dynamics.channel();
            let members = dynamics.present_members();
            let view = FleetView::new(dynamics.universe(), members);
            telemetry.mark("dynamics");
            let mut rt = self.round_engine.sl_round(
                &view,
                &profile,
                &sched,
                &channel,
                &self.cfg.compute,
                cut,
                self.cfg.compute.server_freq_ghz * 1e9,
            );
            rt.stages.remap_crit(members);
            // SL's relay mutates the shared halves in place, so a lost
            // session cannot be unwound from the model — faults here shape
            // the round time and the loss accounting only (DESIGN.md §11).
            let mut fault_lost: Vec<usize> = Vec::new();
            if fmodel.active() {
                let specs = faults::solo_unit_specs(
                    Algorithm::VanillaSL,
                    self.round_engine.unit_times(),
                    members,
                );
                let out = fmodel.inject_round(round, &specs, 0.0, rt.total_s);
                rt.total_s = out.total_s;
                rt.faults = out.counters;
                faults::note_outcome(&out.counters, &out.events);
                telemetry.fault_events(&out.events, sim_total);
                fault_lost = out.lost;
            }
            telemetry.mark("engine");
            let units: Vec<(usize, Option<usize>)> =
                members.iter().map(|&m| (m, None)).collect();
            let mk = obs.note_sync_round(
                &units,
                self.round_engine.unit_times(),
                self.round_engine.unit_splits(),
                rt.total_s,
                &fault_lost,
            );
            obs.note_stages(&rt.stages);
            obs.note_fault_recovery(rt.faults.recovery_s);
            let round_time = rt.total_s;
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            // Present clients take sessions sequentially; the client-side
            // model and the server-side model both persist across the relay
            // (absent clients are simply skipped this round).
            for &c in members {
                let (l, s) = self.split_session(&mut front, &mut back, cut, c)?;
                loss_sum += l;
                steps += s;
            }
            let full = join_params(&front, &back);
            anyhow::ensure!(nn::all_finite(&full), "SL model diverged (NaN/Inf)");
            telemetry.mark("train");
            sim_total += round_time;
            let rec = self.record(
                round,
                &full,
                loss_sum / steps.max(1) as f64,
                &rt,
                sim_total,
                ev.n_alive,
                mk,
                obs.ledger.jain(),
            )?;
            stream_push(streamer, &rec)?;
            records.push(rec);
            telemetry.end_round(&rt, ev.n_alive, &[], sim_total - round_time);
        }
        Ok(records)
    }

    // ------------------------------------------------------------------
    // SplitFed
    // ------------------------------------------------------------------

    fn run_splitfed(
        &mut self,
        dynamics: &mut FleetDynamics,
        telemetry: &mut Telemetry,
        streamer: &mut Option<RecordStreamer>,
        obs: &mut Observatory,
    ) -> Result<Vec<RoundRecord>> {
        let cut = checked_cut(
            "splitfed_cut_layer",
            self.cfg.splitfed_cut_layer,
            self.engine.meta().layers,
        )?;
        let profile = self.engine.meta().profile();
        let sched = self.schedule();
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut sim_total = 0.0f64;
        let fcfg = self.cfg.faults;
        let fmodel = FaultModel::new(&fcfg, Algorithm::SplitFed, self.cfg.seed);
        self.round_engine.set_record_units(true);
        for round in 1..=self.cfg.rounds {
            telemetry.begin_round(round);
            let ev = dynamics.step(round);
            let channel = dynamics.channel();
            let members = dynamics.present_members();
            let view = FleetView::new(dynamics.universe(), members);
            telemetry.mark("dynamics");
            let mut rt = self.round_engine.splitfed_round(
                &view,
                &profile,
                &sched,
                &channel,
                &self.cfg.compute,
                cut,
                self.cfg.compute.server_freq_ghz * 1e9,
                true,
            );
            rt.stages.remap_crit(members);
            // SplitFed clients share the FedAvg sync stage: per-unit times
            // are pre-upload pipeline finishes, with the upload charged as a
            // shared delivery tail (`stage_s[5]`) on every survivor.
            let mut fault_lost: Vec<usize> = Vec::new();
            if fmodel.active() {
                let specs = faults::solo_unit_specs(
                    Algorithm::SplitFed,
                    self.round_engine.unit_times(),
                    members,
                );
                let shared = rt.stages.stage_s[5];
                let out = fmodel.inject_round(round, &specs, shared, rt.total_s);
                rt.total_s = out.total_s;
                rt.faults = out.counters;
                faults::note_outcome(&out.counters, &out.events);
                telemetry.fault_events(&out.events, sim_total);
                fault_lost = out.lost;
            }
            telemetry.mark("engine");
            let units: Vec<(usize, Option<usize>)> =
                members.iter().map(|&m| (m, None)).collect();
            let mk = obs.note_sync_round(
                &units,
                self.round_engine.unit_times(),
                self.round_engine.unit_splits(),
                rt.total_s,
                &fault_lost,
            );
            obs.note_stages(&rt.stages);
            obs.note_fault_recovery(rt.faults.recovery_s);
            let round_time = rt.total_s;
            let mut fronts: Vec<Params> = Vec::with_capacity(members.len());
            let mut backs: Vec<Params> = Vec::with_capacity(members.len());
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            for &c in members {
                // Every present client gets a fresh copy of both halves (the
                // server keeps one server-side instance per client,
                // SplitFed-V1).
                let (mut front, mut back) = split_params(&global, cut);
                let (l, s) = self.split_session(&mut front, &mut back, cut, c)?;
                loss_sum += l;
                steps += s;
                fronts.push(front);
                backs.push(back);
            }
            // Fed server averages client-side models; main server averages
            // server-side models (both weighted by a_i over the present set,
            // minus fault-lost / non-finite contributors).
            let raw_w: Vec<f64> = members.iter().map(|&c| self.weights[c]).collect();
            merge_split_halves(&mut global, members, fronts, backs, raw_w, &fault_lost)?;
            telemetry.mark("train");
            sim_total += round_time;
            let rec = self.record(
                round,
                &global,
                loss_sum / steps.max(1) as f64,
                &rt,
                sim_total,
                ev.n_alive,
                mk,
                obs.ledger.jain(),
            )?;
            stream_push(streamer, &rec)?;
            records.push(rec);
            telemetry.end_round(&rt, ev.n_alive, &[], sim_total - round_time);
        }
        Ok(records)
    }

    /// One client's split-learning session against the server (shared by SL
    /// and SplitFed): plain unweighted SGD on both halves, per batch.
    fn split_session(
        &mut self,
        front: &mut Params,
        back: &mut Params,
        cut: usize,
        client: usize,
    ) -> Result<(f64, usize)> {
        let mut loss_sum = 0.0;
        let mut steps = 0usize;
        let meta = self.engine.meta();
        let (bt, di, h) = (meta.train_batch, meta.input_dim, meta.hidden);
        for _ in 0..self.cfg.local_epochs {
            for b in self.loaders[client].epoch() {
                // Device buffers shared between the fwd and bwd of this batch.
                let pf = self.engine.upload_params(front, 0)?;
                let pb = self.engine.upload_params(back, cut)?;
                let xb = self.engine.upload_f32(&[bt, di], &b.x)?;
                let act = self.engine.front_fwd_b(cut, &pf, &xb)?;
                let ab = self.engine.upload_f32(&[bt, h], &act)?;
                let logits = self.engine.back_fwd_b(cut, &pb, &ab)?;
                let (loss, g_logits) = self.engine.loss_grad(&logits, &b.y1hot)?;
                let (g_back, g_act) = self.engine.back_bwd_b(cut, &pb, &ab, &g_logits)?;
                let g_front = self.engine.front_bwd_b(cut, &pf, &xb, &g_act)?;
                for (t, g) in front.iter_mut().zip(&g_front) {
                    for (p, &gv) in t.iter_mut().zip(g) {
                        *p -= self.cfg.lr * gv;
                    }
                }
                for (t, g) in back.iter_mut().zip(&g_back) {
                    for (p, &gv) in t.iter_mut().zip(g) {
                        *p -= self.cfg.lr * gv;
                    }
                }
                loss_sum += loss as f64;
                steps += 1;
            }
        }
        Ok((loss_sum, steps))
    }

    /// Assemble a round record (evaluating if scheduled). `rt.stages` must
    /// already carry universe client ids (`remap_crit` at the call site);
    /// `mk`/`fairness` come from the observatory feed for this round.
    #[allow(clippy::too_many_arguments)]
    fn record(
        &mut self,
        round: usize,
        model: &Params,
        train_loss: f64,
        rt: &RoundTime,
        sim_total: f64,
        n_alive: usize,
        mk: RoundLanes,
        fairness: f64,
    ) -> Result<RoundRecord> {
        let (test_loss, test_acc) = if self.should_eval(round) {
            self.evaluate(model)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let round_time = rt.total_s;
        log_debug!(
            "round {round}: alive={n_alive} train_loss={train_loss:.4} acc={test_acc:.4} \
             sim={round_time:.1}s"
        );
        Ok(RoundRecord {
            round,
            n_alive,
            train_loss,
            test_acc,
            test_loss,
            sim_round_s: rt.total_s,
            sim_total_s: sim_total,
            // Synchronous rounds: wall clock == cumulative round time, and
            // staleness is undefined (every update is merged fresh).
            t_wall_s: sim_total,
            staleness_mean: f64::NAN,
            faults: rt.faults,
            mean_cut: rt.mean_cut,
            stages: rt.stages,
            mk_p50_s: mk.p50_s,
            mk_p90_s: mk.p90_s,
            mk_p99_s: mk.p99_s,
            fairness,
        })
    }

    // ------------------------------------------------------------------
    // Buffered asynchronous aggregation (DESIGN.md §9)
    // ------------------------------------------------------------------

    /// Event-driven counterpart of the four synchronous loops: units train
    /// the moment they go idle, deliver into the bounded-staleness buffer,
    /// and the server merges with staleness-discounted FedAvg weights
    /// (`cfg.async_agg.weighting`). One merge window = one record; with
    /// `staleness_cap` huge and `buffer_size ≥ fleet` every window
    /// degenerates to the synchronous round bit for bit (the latency-only
    /// counterpart is property-tested in `tests/async_engine.rs`).
    fn run_async(
        &mut self,
        dynamics: &mut FleetDynamics,
        telemetry: &mut Telemetry,
        streamer: &mut Option<RecordStreamer>,
        obs: &mut Observatory,
    ) -> Result<Vec<RoundRecord>> {
        /// A trained update waiting in flight or in the buffer. FedPairing
        /// pair: `[model_i, model_j]`; FL solo: `[local]`; SplitFed:
        /// `[front, back]` under one weight; SL: no models (the sequential
        /// relay mutates the shared halves at session start).
        struct Pending {
            models: Vec<Params>,
            weights: Vec<f64>,
            loss: f64,
            steps: usize,
        }
        let algo = self.cfg.algorithm;
        let w = self.engine.meta().layers;
        let profile = self.engine.meta().profile();
        let sched = self.schedule();
        if algo == Algorithm::FedPairing {
            anyhow::ensure!(
                2 * self.cfg.split.min_layers <= w,
                "split min_layers = {} leaves no feasible cut for the loaded artifacts (W = {w})",
                self.cfg.split.min_layers
            );
        }
        let planner = (algo == Algorithm::FedPairing && self.cfg.split.policy != SplitPolicy::Paper)
            .then(|| SplitCostModel::new(profile.clone(), sched, self.cfg.compute, self.cfg.split));
        let cost = planner.as_ref().filter(|_| self.cfg.split.co_design);
        let mut pairing_rng = crate::util::rng::Rng::new(self.cfg.seed ^ 0x9A1F);
        let mut pairing = PairingSession::new();
        let cut = match algo {
            Algorithm::VanillaSL => checked_cut("sl_cut_layer", self.cfg.sl_cut_layer, w)?,
            Algorithm::SplitFed => {
                checked_cut("splitfed_cut_layer", self.cfg.splitfed_cut_layer, w)?
            }
            _ => 0,
        };
        let server_hz = self.cfg.compute.server_freq_ghz * 1e9;
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        // SL's relay halves persist across windows (there is no averaging);
        // empty for every other algorithm.
        let (mut sl_front, mut sl_back) = if algo == Algorithm::VanillaSL {
            split_params(&global, cut)
        } else {
            (Params::new(), Params::new())
        };
        self.round_engine.set_record_units(true);
        // Fault layer (DESIGN.md §11): units are planned at start (their
        // occupied duration replaces the fault-free one), lost members are
        // remembered per Timeline id and dropped at merge. Repricing a
        // planned unit keeps its planned duration; unplanned ids pass the
        // engine's duration through bit-exactly.
        let fcfg = self.cfg.faults;
        let fmodel = FaultModel::new(&fcfg, algo, self.cfg.seed);
        let mut afaults = AsyncFaults::new();
        let mut tl = Timeline::new(self.cfg.async_agg.buffer_size, self.cfg.async_agg.staleness_cap);
        let mut pending: HashMap<u64, Pending> = HashMap::new();
        let mut inv = InverseIndex::new();
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut sim_total = 0.0f64;
        let mut sl_tail = 0.0f64;
        for seq in 1..=self.cfg.rounds {
            telemetry.begin_event();
            let ev = dynamics.step(seq);
            let channel = dynamics.channel();
            telemetry.mark("dynamics");
            let mut cancelled = 0usize;
            for &d in &ev.departed {
                for id in tl.cancel_member(d) {
                    pending.remove(&id);
                    afaults.forget(id);
                    cancelled += 1;
                }
            }
            let members = dynamics.present_members();
            inv.rebuild(dynamics.universe().n(), members);
            // Observatory unit roster for this window, aligned with the
            // engine's unit_times/unit_splits call order; the mask marks
            // *started* units (repriced in-flight units re-enter every
            // window and must not be double-credited in the ledger).
            let mut units: Vec<(usize, Option<usize>)> = Vec::new();
            let mut started_mask: Vec<bool> = Vec::new();
            let rt = match algo {
                Algorithm::FedPairing => {
                    maintain_matching_session(
                        &mut pairing,
                        dynamics,
                        &ev,
                        &channel,
                        &self.cfg,
                        cost,
                        &mut pairing_rng,
                    );
                    telemetry.mark("matcher");
                    let eff = pairing
                        .matching
                        .as_ref()
                        .expect("matching initialized")
                        .restricted_to(members);
                    let plan = plan_fedpairing(&tl, &eff.pairs, &eff.solos, &inv);
                    let view = FleetView::new(dynamics.universe(), members);
                    let cpairs: Vec<(usize, usize)> = plan
                        .start_pairs
                        .iter()
                        .chain(plan.reprice_pairs.iter().map(|(_, p)| p))
                        .map(|&(a, b)| (inv.compact(a), inv.compact(b)))
                        .collect();
                    let csolos: Vec<usize> = plan
                        .start_solos
                        .iter()
                        .chain(plan.reprice_solos.iter().map(|(_, s)| s))
                        .map(|&s| inv.compact(s))
                        .collect();
                    telemetry.mark("pairing");
                    let mut rt = self.round_engine.fedpairing_round(
                        &view,
                        &cpairs,
                        &csolos,
                        &profile,
                        &sched,
                        &channel,
                        &self.cfg.compute,
                        true,
                    );
                    rt.stages.remap_crit(members);
                    // Unit times in call order: pairs (started, re-priced),
                    // then solos (started, re-priced).
                    let ut: Vec<f64> = self.round_engine.unit_times().to_vec();
                    let np = plan.start_pairs.len();
                    let nrp = plan.reprice_pairs.len();
                    let ns = plan.start_solos.len();
                    units.extend(
                        plan.start_pairs
                            .iter()
                            .chain(plan.reprice_pairs.iter().map(|(_, p)| p))
                            .map(|&(a, b)| (a, Some(b))),
                    );
                    units.extend(
                        plan.start_solos
                            .iter()
                            .chain(plan.reprice_solos.iter().map(|(_, s)| s))
                            .map(|&s| (s, None)),
                    );
                    started_mask.resize(np, true);
                    started_mask.resize(np + nrp, false);
                    started_mask.resize(np + nrp + ns, true);
                    started_mask.resize(units.len(), false);
                    for (k, &(id, _)) in plan.reprice_pairs.iter().enumerate() {
                        tl.reprice(id, afaults.reprice(id, ut[np + k]));
                    }
                    for (k, &(id, _)) in plan.reprice_solos.iter().enumerate() {
                        tl.reprice(id, afaults.reprice(id, ut[np + nrp + ns + k]));
                    }
                    // Normalized data weights â over this *window's* started
                    // participants — the async analogue of the sync round's
                    // participant set (identical in the sync-recovery limit).
                    let started: Vec<usize> = plan
                        .start_pairs
                        .iter()
                        .flat_map(|&(a, b)| [a, b])
                        .chain(plan.start_solos.iter().copied())
                        .collect();
                    if !started.is_empty() {
                        let part_total: f64 = started.iter().map(|&c| self.weights[c]).sum();
                        anyhow::ensure!(part_total > 0.0, "no data among participants");
                        let n_part = started.len() as f64;
                        let uni = dynamics.universe();
                        for (k, &(i, j)) in plan.start_pairs.iter().enumerate() {
                            let l_i = match &planner {
                                Some(m) => {
                                    m.decide_raw(
                                        uni.freqs_hz[i],
                                        uni.freqs_hz[j],
                                        uni.n_samples[i],
                                        uni.n_samples[j],
                                        channel.rate(&uni.positions[i], &uni.positions[j]),
                                    )
                                    .cut
                                }
                                None => split_lengths(uni.freqs_hz[i], uni.freqs_hz[j], w).0,
                            };
                            let l_j = w - l_i;
                            let (a_i, a_j) = (
                                (self.weights[i] / part_total * n_part) as f32,
                                (self.weights[j] / part_total * n_part) as f32,
                            );
                            let (li, lj) = {
                                let (lo, hi) = (i.min(j), i.max(j));
                                let (a, b) = self.loaders.split_at_mut(hi);
                                if i < j {
                                    (&mut a[lo], &mut b[0])
                                } else {
                                    (&mut b[0], &mut a[lo])
                                }
                            };
                            let out = train_pair(
                                &mut self.engine,
                                &global,
                                li,
                                lj,
                                l_i,
                                l_j,
                                a_i,
                                a_j,
                                self.cfg.lr,
                                self.cfg.local_epochs,
                                self.cfg.overlap_boost,
                            )?;
                            let mut dur = ut[k];
                            let mut fplan = None;
                            if fmodel.active() {
                                let spec = UnitSpec {
                                    unit: FaultUnit::Pair(i, j),
                                    t0: dur,
                                    solo_a: full_local_time(
                                        &view,
                                        inv.compact(i),
                                        &profile,
                                        &sched,
                                        &channel,
                                        &self.cfg.compute,
                                        true,
                                    )
                                    .1,
                                    solo_b: full_local_time(
                                        &view,
                                        inv.compact(j),
                                        &profile,
                                        &sched,
                                        &channel,
                                        &self.cfg.compute,
                                        true,
                                    )
                                    .1,
                                };
                                let p = fmodel.plan_unit(seq, &spec);
                                dur = p.dur_s;
                                fplan = Some(p);
                            }
                            let id = tl.start_unit(UnitKind::Pair(i, j), dur);
                            if let Some(p) = fplan {
                                afaults.register(id, &p);
                            }
                            pending.insert(
                                id,
                                Pending {
                                    models: vec![out.model_i, out.model_j],
                                    weights: vec![self.weights[i], self.weights[j]],
                                    loss: out.mean_loss * out.n_steps as f64,
                                    steps: out.n_steps,
                                },
                            );
                        }
                        for (k, &s) in plan.start_solos.iter().enumerate() {
                            let (local, l, st) = self.local_training(&global, s)?;
                            let mut dur = ut[np + nrp + k];
                            let mut fplan = None;
                            if fmodel.active() {
                                let spec = UnitSpec {
                                    unit: FaultUnit::Solo(s),
                                    t0: dur,
                                    solo_a: 0.0,
                                    solo_b: 0.0,
                                };
                                let p = fmodel.plan_unit(seq, &spec);
                                dur = p.dur_s;
                                fplan = Some(p);
                            }
                            let id = tl.start_unit(UnitKind::Solo(s), dur);
                            if let Some(p) = fplan {
                                afaults.register(id, &p);
                            }
                            pending.insert(
                                id,
                                Pending {
                                    models: vec![local],
                                    weights: vec![self.weights[s]],
                                    loss: l,
                                    steps: st,
                                },
                            );
                        }
                    }
                    rt
                }
                Algorithm::VanillaFL => {
                    let plan = plan_solo(&tl, members, &inv, true);
                    let view = FleetView::new(dynamics.universe(), &plan.view_members);
                    let mut rt = self.round_engine.fl_round(
                        &view,
                        &profile,
                        &sched,
                        &channel,
                        &self.cfg.compute,
                        true,
                    );
                    rt.stages.remap_crit(&plan.view_members);
                    units.extend(plan.view_members.iter().map(|&m| (m, None)));
                    started_mask.resize(plan.start.len(), true);
                    started_mask.resize(units.len(), false);
                    let ut: Vec<f64> = self.round_engine.unit_times().to_vec();
                    for (k, &(id, _)) in plan.reprice.iter().enumerate() {
                        tl.reprice(id, afaults.reprice(id, ut[plan.start.len() + k]));
                    }
                    for (k, &m) in plan.start.iter().enumerate() {
                        let (local, l, st) = self.local_training(&global, m)?;
                        let mut dur = ut[k];
                        let mut fplan = None;
                        if fmodel.active() {
                            let spec = UnitSpec {
                                unit: FaultUnit::Solo(m),
                                t0: dur,
                                solo_a: 0.0,
                                solo_b: 0.0,
                            };
                            let p = fmodel.plan_unit(seq, &spec);
                            dur = p.dur_s;
                            fplan = Some(p);
                        }
                        let id = tl.start_unit(UnitKind::Solo(m), dur);
                        if let Some(p) = fplan {
                            afaults.register(id, &p);
                        }
                        pending.insert(
                            id,
                            Pending {
                                models: vec![local],
                                weights: vec![self.weights[m]],
                                loss: l,
                                steps: st,
                            },
                        );
                    }
                    rt
                }
                Algorithm::VanillaSL => {
                    // Sessions are a sequential relay: new sessions chain
                    // after the current tail and mutate the shared halves at
                    // start, in relay order (exactly the sync session order).
                    let plan = plan_solo(&tl, members, &inv, false);
                    let view = FleetView::new(dynamics.universe(), &plan.start);
                    let mut rt = self.round_engine.sl_round(
                        &view,
                        &profile,
                        &sched,
                        &channel,
                        &self.cfg.compute,
                        cut,
                        server_hz,
                    );
                    rt.stages.remap_crit(&plan.start);
                    units.extend(plan.start.iter().map(|&m| (m, None)));
                    started_mask.resize(units.len(), true);
                    let ut: Vec<f64> = self.round_engine.unit_times().to_vec();
                    for (k, &m) in plan.start.iter().enumerate() {
                        let (l, st) = self.split_session(&mut sl_front, &mut sl_back, cut, m)?;
                        let mut d = ut[k];
                        let mut fplan = None;
                        if fmodel.active() {
                            let spec = UnitSpec {
                                unit: FaultUnit::Session(m),
                                t0: d,
                                solo_a: 0.0,
                                solo_b: 0.0,
                            };
                            let p = fmodel.plan_unit(seq, &spec);
                            d = p.dur_s;
                            fplan = Some(p);
                        }
                        let id = tl.start_unit_at(UnitKind::Solo(m), sl_tail, d);
                        if let Some(p) = fplan {
                            afaults.register(id, &p);
                        }
                        sl_tail += d;
                        pending.insert(
                            id,
                            Pending {
                                models: Vec::new(),
                                weights: Vec::new(),
                                loss: l,
                                steps: st,
                            },
                        );
                    }
                    rt
                }
                Algorithm::SplitFed => {
                    let plan = plan_solo(&tl, members, &inv, true);
                    let view = FleetView::new(dynamics.universe(), &plan.view_members);
                    let mut rt = self.round_engine.splitfed_round(
                        &view,
                        &profile,
                        &sched,
                        &channel,
                        &self.cfg.compute,
                        cut,
                        server_hz,
                        true,
                    );
                    rt.stages.remap_crit(&plan.view_members);
                    units.extend(plan.view_members.iter().map(|&m| (m, None)));
                    started_mask.resize(plan.start.len(), true);
                    started_mask.resize(units.len(), false);
                    let ut: Vec<f64> = self.round_engine.unit_times().to_vec();
                    for (k, &(id, _)) in plan.reprice.iter().enumerate() {
                        tl.reprice(id, afaults.reprice(id, ut[plan.start.len() + k]));
                    }
                    for (k, &m) in plan.start.iter().enumerate() {
                        let (mut front, mut back) = split_params(&global, cut);
                        let (l, st) = self.split_session(&mut front, &mut back, cut, m)?;
                        let mut dur = ut[k];
                        let mut fplan = None;
                        if fmodel.active() {
                            let spec = UnitSpec {
                                unit: FaultUnit::Solo(m),
                                t0: dur,
                                solo_a: 0.0,
                                solo_b: 0.0,
                            };
                            let p = fmodel.plan_unit(seq, &spec);
                            dur = p.dur_s;
                            fplan = Some(p);
                        }
                        let id = tl.start_unit(UnitKind::Solo(m), dur);
                        if let Some(p) = fplan {
                            afaults.register(id, &p);
                        }
                        pending.insert(
                            id,
                            Pending {
                                models: vec![front, back],
                                weights: vec![self.weights[m]],
                                loss: l,
                                steps: st,
                            },
                        );
                    }
                    rt
                }
            };
            telemetry.mark("engine");
            let mk = obs.note_async_window(
                &units,
                &started_mask,
                self.round_engine.unit_times(),
                self.round_engine.unit_splits(),
                &[],
            );
            obs.note_stages(&rt.stages);
            let merge = tl.advance_to_merge().ok_or_else(|| {
                anyhow::anyhow!("async scheduler stalled: nothing in flight or buffered")
            })?;
            // SplitFed's FedAvg sync charges the slowest *contributor* upload
            // (clients currently out deliver without re-uploading).
            let overhead = if algo == Algorithm::SplitFed {
                let front_bytes = profile.params(0, cut) as f64 * 4.0;
                merge
                    .contributors
                    .iter()
                    .filter_map(|d| match d.unit {
                        UnitKind::Solo(s) if inv.get(s).is_some() => {
                            Some(upload_time(dynamics.universe(), &channel, s, front_bytes))
                        }
                        _ => None,
                    })
                    .fold(0.0, f64::max)
            } else {
                0.0
            };
            let total = merge.t_rel + overhead;
            tl.commit(total);
            if algo == Algorithm::VanillaSL {
                sl_tail = (sl_tail - total).max(0.0);
            }
            sim_total += total;
            // Merge: staleness-discounted weighted FedAvg over the buffered
            // contributors, in delivery-id (creation) order — the sync
            // participant order in the recovery limit.
            let weighting = self.cfg.async_agg.weighting;
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            match algo {
                Algorithm::VanillaSL => {
                    for d in &merge.contributors {
                        if let Some(p) = pending.remove(&d.id) {
                            loss_sum += p.loss;
                            steps += p.steps;
                        }
                        for &m in afaults.lost_of(d.id) {
                            obs.ledger.note_lost(m);
                        }
                        afaults.forget(d.id);
                    }
                    // The relay already mutated the shared halves; the merge
                    // snapshots them.
                    global = join_params(&sl_front, &sl_back);
                }
                Algorithm::SplitFed => {
                    let n = merge.contributors.len();
                    let mut fronts: Vec<Params> = Vec::with_capacity(n);
                    let mut backs: Vec<Params> = Vec::with_capacity(n);
                    let mut agg: Vec<f64> = Vec::with_capacity(n);
                    for d in &merge.contributors {
                        let p = pending
                            .remove(&d.id)
                            .ok_or_else(|| anyhow::anyhow!("merged unit lost its payload"))?;
                        let lost_members = afaults.lost_of(d.id);
                        let doomed = !lost_members.is_empty();
                        for &m in lost_members {
                            obs.ledger.note_lost(m);
                        }
                        afaults.forget(d.id);
                        loss_sum += p.loss;
                        steps += p.steps;
                        if doomed {
                            continue;
                        }
                        let mut m = p.models.into_iter();
                        fronts.push(m.next().expect("splitfed front"));
                        backs.push(m.next().expect("splitfed back"));
                        agg.push(p.weights[0] * weighting.factor(d.staleness));
                    }
                    let rejected = reject_nonfinite_halves(&mut fronts, &mut backs, &mut agg);
                    if rejected > 0 {
                        registry::count(Counter::AggRejectedUpdates, rejected as u64);
                        log_warn!("merge {seq}: rejected {rejected} non-finite update(s)");
                    }
                    if fronts.is_empty() {
                        log_debug!("merge {seq}: every update lost; global unchanged");
                    } else {
                        let t: f64 = agg.iter().sum();
                        anyhow::ensure!(t > 0.0, "no data among merge contributors");
                        for x in &mut agg {
                            *x /= t;
                        }
                        let front = nn::fedavg_weighted(&fronts, &agg);
                        let back = nn::fedavg_weighted(&backs, &agg);
                        global = join_params(&front, &back);
                    }
                }
                Algorithm::FedPairing | Algorithm::VanillaFL => {
                    let mut locals: Vec<Params> = Vec::new();
                    let mut agg: Vec<f64> = Vec::new();
                    for d in &merge.contributors {
                        let p = pending
                            .remove(&d.id)
                            .ok_or_else(|| anyhow::anyhow!("merged unit lost its payload"))?;
                        let s = weighting.factor(d.staleness);
                        let doomed = afaults.lost_of(d.id);
                        if doomed.is_empty() {
                            for (model, &w_raw) in p.models.into_iter().zip(&p.weights) {
                                locals.push(model);
                                agg.push(w_raw * s);
                            }
                        } else {
                            // A pair unit can lose one member and still
                            // deliver the survivor's (re-paired) update.
                            let mm: Vec<usize> = match d.unit {
                                UnitKind::Pair(a, b) => vec![a, b],
                                UnitKind::Solo(u) => vec![u],
                            };
                            for ((model, &w_raw), m) in
                                p.models.into_iter().zip(&p.weights).zip(mm)
                            {
                                if doomed.contains(&m) {
                                    continue;
                                }
                                locals.push(model);
                                agg.push(w_raw * s);
                            }
                        }
                        for &m in doomed {
                            obs.ledger.note_lost(m);
                        }
                        afaults.forget(d.id);
                        loss_sum += p.loss;
                        steps += p.steps;
                    }
                    let rejected = nn::reject_nonfinite(&mut locals, &mut agg);
                    if rejected > 0 {
                        registry::count(Counter::AggRejectedUpdates, rejected as u64);
                        log_warn!("merge {seq}: rejected {rejected} non-finite update(s)");
                    }
                    if locals.is_empty() {
                        log_debug!("merge {seq}: every update lost; global unchanged");
                    } else {
                        let t: f64 = agg.iter().sum();
                        anyhow::ensure!(t > 0.0, "no data among merge contributors");
                        for x in &mut agg {
                            *x /= t;
                        }
                        global = nn::fedavg_weighted(&locals, &agg);
                    }
                }
            }
            anyhow::ensure!(nn::all_finite(&global), "global model diverged (NaN/Inf)");
            telemetry.mark("train");
            note_merge(&merge, cancelled);
            // Fault accounting for this merge window (events are stamped
            // relative to the window's simulated start).
            let (wfaults, wevents) = afaults.take_window();
            faults::note_outcome(&wfaults, &wevents);
            telemetry.fault_events(&wevents, sim_total - total);
            obs.note_fault_recovery(wfaults.recovery_s);
            obs.note_async_event(merge.staleness_mean, merge.wait_eliminated_s);
            let event = AggregationEvent {
                seq,
                t_wall_s: sim_total,
                n_updates: merge.contributors.len(),
                n_running: tl.in_flight(),
                staleness_mean: merge.staleness_mean,
                staleness_max: merge.staleness_max,
                buffer_peak: merge.buffer_peak,
                wait_eliminated_s: merge.wait_eliminated_s,
            };
            let (test_loss, test_acc) = if self.should_eval(seq) {
                self.evaluate(&global)?
            } else {
                (f64::NAN, f64::NAN)
            };
            let train_loss = loss_sum / steps.max(1) as f64;
            log_debug!(
                "merge {seq}: alive={} updates={} stale={:.2} train_loss={train_loss:.4} \
                 acc={test_acc:.4} sim={total:.1}s",
                ev.n_alive,
                event.n_updates,
                event.staleness_mean
            );
            let rec = RoundRecord {
                round: seq,
                n_alive: ev.n_alive,
                train_loss,
                test_acc,
                test_loss,
                sim_round_s: total,
                sim_total_s: sim_total,
                t_wall_s: sim_total,
                staleness_mean: merge.staleness_mean,
                faults: wfaults,
                mean_cut: rt.mean_cut,
                stages: rt.stages,
                mk_p50_s: mk.p50_s,
                mk_p90_s: mk.p90_s,
                mk_p99_s: mk.p99_s,
                fairness: obs.ledger.jain(),
            };
            stream_push(streamer, &rec)?;
            records.push(rec);
            let lanes: Vec<(usize, usize, f64)> = self
                .round_engine
                .pair_lanes()
                .iter()
                .map(|&(a, b, t)| (members[a], members[b], t))
                .collect();
            telemetry.end_round(&rt, ev.n_alive, &lanes, sim_total - total);
            telemetry.end_merge(&event);
        }
        Ok(records)
    }
}

/// Push one record to the configured stream sink (no-op when streaming is
/// off).
fn stream_push(streamer: &mut Option<RecordStreamer>, rec: &RoundRecord) -> Result<()> {
    if let Some(s) = streamer.as_mut() {
        s.push(rec).context("streaming round record")?;
    }
    Ok(())
}

/// Synchronous weighted FedAvg with the fault/robustness guards: drop
/// fault-lost contributors, reject non-finite payloads (counting them on
/// `agg_rejected_updates_total`), renormalize the surviving raw weights and
/// average into `global`. When every update is lost or rejected the merge is
/// skipped and the global model carries over. With nothing dropped the
/// arithmetic is bit-identical to the plain weighted FedAvg the drivers
/// always did (same fold order, one normalization).
fn merge_weighted(
    global: &mut Params,
    contributors: &[usize],
    mut locals: Vec<Params>,
    mut weights: Vec<f64>,
    lost: &[usize],
) -> Result<()> {
    if !lost.is_empty() {
        let keep: Vec<bool> = contributors
            .iter()
            .map(|c| lost.binary_search(c).is_err())
            .collect();
        let mut it = keep.iter();
        locals.retain(|_| *it.next().unwrap());
        let mut it = keep.iter();
        weights.retain(|_| *it.next().unwrap());
    }
    let rejected = nn::reject_nonfinite(&mut locals, &mut weights);
    if rejected > 0 {
        registry::count(Counter::AggRejectedUpdates, rejected as u64);
        log_warn!("aggregation: rejected {rejected} non-finite update(s)");
    }
    if locals.is_empty() {
        log_debug!("merge skipped: every update this round was lost or rejected");
        return Ok(());
    }
    let total: f64 = weights.iter().sum();
    anyhow::ensure!(total > 0.0, "no data among participants");
    for x in &mut weights {
        *x /= total;
    }
    *global = nn::fedavg_weighted(&locals, &weights);
    anyhow::ensure!(nn::all_finite(global), "global model diverged (NaN/Inf)");
    Ok(())
}

/// SplitFed variant of [`merge_weighted`]: a client's update is its
/// `(front, back)` half pair under one weight, and is dropped whole when
/// either half is non-finite or the client is fault-lost.
fn merge_split_halves(
    global: &mut Params,
    contributors: &[usize],
    mut fronts: Vec<Params>,
    mut backs: Vec<Params>,
    mut weights: Vec<f64>,
    lost: &[usize],
) -> Result<()> {
    if !lost.is_empty() {
        let keep: Vec<bool> = contributors
            .iter()
            .map(|c| lost.binary_search(c).is_err())
            .collect();
        let mut it = keep.iter();
        fronts.retain(|_| *it.next().unwrap());
        let mut it = keep.iter();
        backs.retain(|_| *it.next().unwrap());
        let mut it = keep.iter();
        weights.retain(|_| *it.next().unwrap());
    }
    let rejected = reject_nonfinite_halves(&mut fronts, &mut backs, &mut weights);
    if rejected > 0 {
        registry::count(Counter::AggRejectedUpdates, rejected as u64);
        log_warn!("aggregation: rejected {rejected} non-finite update(s)");
    }
    if fronts.is_empty() {
        log_debug!("merge skipped: every update this round was lost or rejected");
        return Ok(());
    }
    let total: f64 = weights.iter().sum();
    anyhow::ensure!(total > 0.0, "no data among participants");
    for x in &mut weights {
        *x /= total;
    }
    let front = nn::fedavg_weighted(&fronts, &weights);
    let back = nn::fedavg_weighted(&backs, &weights);
    *global = join_params(&front, &back);
    anyhow::ensure!(nn::all_finite(global), "SplitFed diverged (NaN/Inf)");
    Ok(())
}

/// Drop clients whose front *or* back half is non-finite, keeping the three
/// parallel vectors aligned. Returns the number of clients dropped.
fn reject_nonfinite_halves(
    fronts: &mut Vec<Params>,
    backs: &mut Vec<Params>,
    weights: &mut Vec<f64>,
) -> usize {
    let keep: Vec<bool> = fronts
        .iter()
        .zip(backs.iter())
        .map(|(f, b)| nn::all_finite(f) && nn::all_finite(b))
        .collect();
    if keep.iter().all(|&k| k) {
        return 0;
    }
    let mut it = keep.iter();
    fronts.retain(|_| *it.next().unwrap());
    let mut it = keep.iter();
    backs.retain(|_| *it.next().unwrap());
    let mut it = keep.iter();
    weights.retain(|_| *it.next().unwrap());
    keep.iter().filter(|&&k| !k).count()
}

/// Split a flat model into `(front, back)` at layer `cut`.
pub fn split_params(params: &Params, cut: usize) -> (Params, Params) {
    let front = params[..2 * cut].to_vec();
    let back = params[2 * cut..].to_vec();
    (front, back)
}

/// Rejoin `(front, back)` into a flat model.
pub fn join_params(front: &Params, back: &Params) -> Params {
    let mut out = front.clone();
    out.extend(back.iter().cloned());
    out
}

/// Bound a configured cut against the *training* model's layer count. The
/// config layer already validates cuts against the configured latency
/// profile; the AOT artifacts may disagree with it, so the training drivers
/// re-check here with a proper error instead of the old silent clamp.
fn checked_cut(name: &str, cut: usize, w: usize) -> Result<usize> {
    anyhow::ensure!(
        cut >= 1 && cut < w,
        "{name} = {cut} out of range [1, {}] for the loaded artifacts (W = {w})",
        w - 1
    );
    Ok(cut)
}

/// Convenience: build + run in one call.
pub fn run_experiment(cfg: ExperimentConfig) -> Result<RunResult> {
    Experiment::new(cfg)
        .context("building experiment")?
        .run()
        .context("running experiment")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataDistribution, PairingStrategy};

    fn quick_cfg(algo: Algorithm) -> ExperimentConfig {
        let mut c = ExperimentConfig::preset("quick").unwrap();
        c.algorithm = algo;
        c.rounds = 2;
        c.samples_per_client = 32;
        c.test_samples = 64;
        c
    }

    fn artifacts_ready() -> bool {
        let ok = std::path::Path::new("artifacts/manifest.json").exists();
        if !ok {
            crate::log_warn!("skipping driver test: artifacts/ not built");
        }
        ok
    }

    #[test]
    fn split_join_roundtrip() {
        let p: Params = (0..8).map(|i| vec![i as f32; 3]).collect();
        let (f, b) = split_params(&p, 3);
        assert_eq!(f.len(), 6);
        assert_eq!(b.len(), 2);
        assert_eq!(join_params(&f, &b), p);
    }

    #[test]
    fn fedpairing_quick_run_trains() {
        if !artifacts_ready() {
            return;
        }
        let res = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        assert_eq!(res.rounds.len(), 2);
        assert!(res.final_acc() > 0.0);
        assert!(res.rounds[0].sim_round_s > 0.0);
        assert!(res.total_execs > 0);
        // loss should be finite and generally decreasing across rounds
        assert!(res.rounds[1].train_loss.is_finite());
    }

    #[test]
    fn all_algorithms_quick_run() {
        if !artifacts_ready() {
            return;
        }
        let mut accs = Vec::new();
        for algo in [
            Algorithm::FedPairing,
            Algorithm::VanillaFL,
            Algorithm::VanillaSL,
            Algorithm::SplitFed,
        ] {
            let res = run_experiment(quick_cfg(algo)).unwrap();
            assert_eq!(res.rounds.len(), 2, "{algo:?}");
            assert!(res.final_acc().is_finite(), "{algo:?}");
            accs.push((algo, res.final_acc()));
        }
        crate::log_debug!("quick accs: {accs:?}");
    }

    #[test]
    fn deterministic_runs() {
        if !artifacts_ready() {
            return;
        }
        let a = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        let b = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        assert_eq!(a.final_acc(), b.final_acc());
        assert_eq!(a.rounds[0].train_loss, b.rounds[0].train_loss);
    }

    #[test]
    fn noniid_shards_run() {
        if !artifacts_ready() {
            return;
        }
        let mut cfg = quick_cfg(Algorithm::FedPairing);
        cfg.distribution = DataDistribution::ClassShards {
            classes_per_client: 2,
        };
        cfg.pairing = PairingStrategy::Random;
        let res = run_experiment(cfg).unwrap();
        assert!(res.final_acc().is_finite());
    }

    #[test]
    fn odd_fleet_trains_with_solo() {
        if !artifacts_ready() {
            return;
        }
        let mut cfg = quick_cfg(Algorithm::FedPairing);
        cfg.n_clients = 5; // forces one solo client every round
        let res = run_experiment(cfg).unwrap();
        assert!(res.final_acc().is_finite());
        assert!(res.rounds.iter().all(|r| r.n_alive == 5));
    }

    #[test]
    fn churn_scenario_trains_and_records_alive_counts() {
        if !artifacts_ready() {
            return;
        }
        use crate::config::{ScenarioConfig, ScenarioKind};
        let mut cfg = quick_cfg(Algorithm::FedPairing);
        cfg.n_clients = 6;
        cfg.rounds = 6;
        cfg.scenario = ScenarioConfig::preset(ScenarioKind::LossyRadio);
        let res = run_experiment(cfg).unwrap();
        assert_eq!(res.rounds.len(), 6);
        assert!(res.final_acc().is_finite());
        assert!(res.rounds.iter().all(|r| r.n_alive >= 1));
    }
}
