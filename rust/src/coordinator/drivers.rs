//! Algorithm drivers: the full multi-round FL loops for FedPairing and the
//! three benchmarks (vanilla FL, vanilla SL, SplitFed), all executing the same
//! AOT artifacts through one [`Engine`] and all charged by the same latency
//! simulator — so accuracy curves (Figs. 2–3) and round times (Tables I–II)
//! come from one consistent system.

use crate::config::{Algorithm, ExperimentConfig};
use crate::coordinator::metrics::{RoundRecord, RunResult};
use crate::coordinator::split::train_pair;
use crate::data::loader::{eval_batches, Batch, Loader};
use crate::data::partition::partition;
use crate::data::synth::SynthCifar;
use crate::nn::{self, Params};
use crate::pairing::pair_clients;
use crate::runtime::Engine;
use crate::sim::channel::Channel;
use crate::sim::compute::{aggregation_weights, split_lengths};
use crate::sim::latency::{self, Fleet, Schedule};
use crate::{log_debug, log_info};
use anyhow::{Context, Result};

/// A fully materialized experiment: fleet, data, engine, channel.
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub engine: Engine,
    pub fleet: Fleet,
    pub channel: Channel,
    loaders: Vec<Loader>,
    /// FedAvg weights `a_i`.
    weights: Vec<f64>,
    test: Vec<Batch>,
}

impl Experiment {
    /// Build everything deterministically from the config.
    pub fn new(cfg: ExperimentConfig) -> Result<Experiment> {
        cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
        let engine = Engine::load(&cfg.artifacts_dir)?;
        let mut rng = crate::util::rng::Rng::new(cfg.seed);
        let fleet = Fleet::sample(&cfg, &mut rng);
        let channel = Channel::new(cfg.channel);
        let gen = SynthCifar::new(cfg.seed, cfg.noise_level);
        let shards = partition(
            &mut rng.fork(1),
            cfg.n_clients,
            cfg.samples_per_client,
            &cfg.distribution,
        );
        let train_batch = engine.meta().train_batch;
        let loaders: Vec<Loader> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                Loader::new(
                    gen.clone(),
                    shard,
                    train_batch,
                    crate::util::rng::Rng::with_stream(cfg.seed ^ 0xC11E47, i as u64),
                )
            })
            .collect();
        let weights = aggregation_weights(&fleet.resources());
        let test = eval_batches(&gen.test_set(cfg.test_samples), engine.meta().eval_batch);
        Ok(Experiment {
            cfg,
            engine,
            fleet,
            channel,
            loaders,
            weights,
            test,
        })
    }

    fn schedule(&self) -> Schedule {
        Schedule {
            batch_size: self.engine.meta().train_batch,
            epochs: self.cfg.local_epochs,
        }
    }

    /// Evaluate a model on the shared test set: `(mean_loss, accuracy)`.
    pub fn evaluate(&mut self, params: &Params) -> Result<(f64, f64)> {
        let mut loss_sum = 0f64;
        let mut correct = 0f64;
        let mut rows = 0f64;
        // Upload the model once, reuse the device buffers across test batches.
        let dev = self.engine.upload_params(params, 0)?;
        for b in &self.test {
            let (l, c, n) = self.engine.eval_batch_b(&dev, &b.x, &b.y1hot)?;
            loss_sum += l as f64;
            correct += c as f64;
            rows += n as f64;
        }
        anyhow::ensure!(rows > 0.0, "empty test set");
        Ok((loss_sum / rows, correct / rows))
    }

    fn should_eval(&self, round: usize) -> bool {
        round == self.cfg.rounds
            || (self.cfg.eval_every > 0 && round % self.cfg.eval_every == 0)
    }

    /// Run the configured algorithm to completion.
    pub fn run(&mut self) -> Result<RunResult> {
        let t0 = std::time::Instant::now();
        let rounds = match self.cfg.algorithm {
            Algorithm::FedPairing => self.run_fedpairing()?,
            Algorithm::VanillaFL => self.run_fl()?,
            Algorithm::VanillaSL => self.run_sl()?,
            Algorithm::SplitFed => self.run_splitfed()?,
        };
        Ok(RunResult {
            config: self.cfg.clone(),
            rounds,
            wall_s: t0.elapsed().as_secs_f64(),
            total_execs: self.engine.total_execs(),
        })
    }

    // ------------------------------------------------------------------
    // FedPairing (the paper's system)
    // ------------------------------------------------------------------

    fn run_fedpairing(&mut self) -> Result<Vec<RoundRecord>> {
        let w = self.engine.meta().layers;
        let mut pairing_rng = crate::util::rng::Rng::new(self.cfg.seed ^ 0x9A1F);
        // Initialization phase (paper Sec. II-A.1): pair once, compute
        // (L_i, a_i), distribute the global model.
        let pairs = pair_clients(
            self.cfg.pairing,
            &self.fleet,
            &self.channel,
            self.cfg.alpha,
            self.cfg.beta,
            &mut pairing_rng,
        );
        log_info!(
            "fedpairing: {} pairs via {} strategy",
            pairs.len(),
            self.cfg.pairing
        );
        let splits: Vec<(usize, usize)> = pairs
            .iter()
            .map(|&(i, j)| split_lengths(self.fleet.freqs_hz[i], self.fleet.freqs_hz[j], w))
            .collect();
        // Static fleet → identical per-round latency; compute once.
        let round_time = latency::fedpairing_round(
            &self.fleet,
            &pairs,
            &self.engine.meta().profile(),
            &self.schedule(),
            &self.channel,
            &self.cfg.compute,
            true,
        )
        .total_s;
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        for round in 1..=self.cfg.rounds {
            let mut locals: Vec<Params> = Vec::with_capacity(self.cfg.n_clients);
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            for (pi, &(i, j)) in pairs.iter().enumerate() {
                let (l_i, l_j) = splits[pi];
                // Normalized data weights â_i = N·a_i (≡ 1 for equal shards).
                // The paper's literal eq.(1) scales local grads by a_i ≈ 1/N
                // *and* averages models at the server — a double shrink that
                // makes the net step η/N² (inconsistent with its own Fig. 2,
                // where FedPairing out-converges FL). We keep the *relative*
                // a_i weighting inside the pair and restore the magnitude at
                // aggregation via the standard weighted FedAvg, which is the
                // consistent reading (DESIGN.md §2).
                let n = self.cfg.n_clients as f32;
                let (a_i, a_j) = (
                    self.weights[i] as f32 * n,
                    self.weights[j] as f32 * n,
                );
                // Loaders for i and j (split_at to appease the borrow checker).
                let (li, lj) = {
                    let (lo, hi) = (i.min(j), i.max(j));
                    let (a, b) = self.loaders.split_at_mut(hi);
                    if i < j {
                        (&mut a[lo], &mut b[0])
                    } else {
                        (&mut b[0], &mut a[lo])
                    }
                };
                let out = train_pair(
                    &mut self.engine,
                    &global,
                    li,
                    lj,
                    l_i,
                    l_j,
                    a_i,
                    a_j,
                    self.cfg.lr,
                    self.cfg.local_epochs,
                    self.cfg.overlap_boost,
                )?;
                loss_sum += out.mean_loss * out.n_steps as f64;
                steps += out.n_steps;
                locals.push(out.model_i);
                locals.push(out.model_j);
            }
            // Model aggregation (Sec. II-A.3): with normalized â_i weighting
            // above, the consistent server rule is weighted FedAvg of the 2N
            // local models, each carrying its owner's data weight a_i.
            let mut agg_weights = Vec::with_capacity(locals.len());
            for &(i, j) in &pairs {
                agg_weights.push(self.weights[i]);
                agg_weights.push(self.weights[j]);
            }
            global = nn::fedavg_weighted(&locals, &agg_weights);
            anyhow::ensure!(nn::all_finite(&global), "global model diverged (NaN/Inf)");
            records.push(self.record(round, &global, loss_sum / steps.max(1) as f64, round_time)?);
        }
        Ok(records)
    }

    // ------------------------------------------------------------------
    // Vanilla FL (FedAvg)
    // ------------------------------------------------------------------

    fn run_fl(&mut self) -> Result<Vec<RoundRecord>> {
        let round_time = latency::fl_round(
            &self.fleet,
            &self.engine.meta().profile(),
            &self.schedule(),
            &self.channel,
            &self.cfg.compute,
            true,
        )
        .total_s;
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        for round in 1..=self.cfg.rounds {
            let mut locals: Vec<Params> = Vec::with_capacity(self.cfg.n_clients);
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            for c in 0..self.cfg.n_clients {
                let mut local = global.clone();
                for _ in 0..self.cfg.local_epochs {
                    for b in self.loaders[c].epoch() {
                        let (grads, loss) = self.engine.full_step(&local, &b.x, &b.y1hot)?;
                        nn::sgd_apply(&mut local, &grads, self.cfg.lr);
                        loss_sum += loss as f64;
                        steps += 1;
                    }
                }
                locals.push(local);
            }
            global = nn::fedavg_weighted(&locals, &self.weights);
            anyhow::ensure!(nn::all_finite(&global), "global model diverged (NaN/Inf)");
            records.push(self.record(round, &global, loss_sum / steps.max(1) as f64, round_time)?);
        }
        Ok(records)
    }

    // ------------------------------------------------------------------
    // Vanilla SL (sequential relay)
    // ------------------------------------------------------------------

    fn run_sl(&mut self) -> Result<Vec<RoundRecord>> {
        let cut = self.cfg.sl_cut_layer.clamp(1, self.engine.meta().layers - 1);
        let round_time = latency::sl_round(
            &self.fleet,
            &self.engine.meta().profile(),
            &self.schedule(),
            &self.channel,
            &self.cfg.compute,
            cut,
            self.cfg.compute.server_freq_ghz * 1e9,
        )
        .total_s;
        let global = self.engine.init_params(self.cfg.seed as u32)?;
        let (mut front, mut back) = split_params(&global, cut);
        let mut records = Vec::with_capacity(self.cfg.rounds);
        for round in 1..=self.cfg.rounds {
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            // Clients take sessions sequentially; the client-side model and
            // the server-side model both persist across the relay.
            for c in 0..self.cfg.n_clients {
                let (l, s) = self.split_session(&mut front, &mut back, cut, c)?;
                loss_sum += l;
                steps += s;
            }
            let full = join_params(&front, &back);
            anyhow::ensure!(nn::all_finite(&full), "SL model diverged (NaN/Inf)");
            records.push(self.record(round, &full, loss_sum / steps.max(1) as f64, round_time)?);
        }
        Ok(records)
    }

    // ------------------------------------------------------------------
    // SplitFed
    // ------------------------------------------------------------------

    fn run_splitfed(&mut self) -> Result<Vec<RoundRecord>> {
        let cut = self
            .cfg
            .splitfed_cut_layer
            .clamp(1, self.engine.meta().layers - 1);
        let round_time = latency::splitfed_round(
            &self.fleet,
            &self.engine.meta().profile(),
            &self.schedule(),
            &self.channel,
            &self.cfg.compute,
            cut,
            self.cfg.compute.server_freq_ghz * 1e9,
            true,
        )
        .total_s;
        let mut global = self.engine.init_params(self.cfg.seed as u32)?;
        let mut records = Vec::with_capacity(self.cfg.rounds);
        for round in 1..=self.cfg.rounds {
            let mut fronts: Vec<Params> = Vec::with_capacity(self.cfg.n_clients);
            let mut backs: Vec<Params> = Vec::with_capacity(self.cfg.n_clients);
            let mut loss_sum = 0.0;
            let mut steps = 0usize;
            for c in 0..self.cfg.n_clients {
                // Every client gets a fresh copy of both halves (the server
                // keeps one server-side instance per client, SplitFed-V1).
                let (mut front, mut back) = split_params(&global, cut);
                let (l, s) = self.split_session(&mut front, &mut back, cut, c)?;
                loss_sum += l;
                steps += s;
                fronts.push(front);
                backs.push(back);
            }
            // Fed server averages client-side models; main server averages
            // server-side models (both weighted by a_i).
            let front = nn::fedavg_weighted(&fronts, &self.weights);
            let back = nn::fedavg_weighted(&backs, &self.weights);
            global = join_params(&front, &back);
            anyhow::ensure!(nn::all_finite(&global), "SplitFed diverged (NaN/Inf)");
            records.push(self.record(round, &global, loss_sum / steps.max(1) as f64, round_time)?);
        }
        Ok(records)
    }

    /// One client's split-learning session against the server (shared by SL
    /// and SplitFed): plain unweighted SGD on both halves, per batch.
    fn split_session(
        &mut self,
        front: &mut Params,
        back: &mut Params,
        cut: usize,
        client: usize,
    ) -> Result<(f64, usize)> {
        let mut loss_sum = 0.0;
        let mut steps = 0usize;
        let meta = self.engine.meta();
        let (bt, di, h) = (meta.train_batch, meta.input_dim, meta.hidden);
        for _ in 0..self.cfg.local_epochs {
            for b in self.loaders[client].epoch() {
                // Device buffers shared between the fwd and bwd of this batch.
                let pf = self.engine.upload_params(front, 0)?;
                let pb = self.engine.upload_params(back, cut)?;
                let xb = self.engine.upload_f32(&[bt, di], &b.x)?;
                let act = self.engine.front_fwd_b(cut, &pf, &xb)?;
                let ab = self.engine.upload_f32(&[bt, h], &act)?;
                let logits = self.engine.back_fwd_b(cut, &pb, &ab)?;
                let (loss, g_logits) = self.engine.loss_grad(&logits, &b.y1hot)?;
                let (g_back, g_act) = self.engine.back_bwd_b(cut, &pb, &ab, &g_logits)?;
                let g_front = self.engine.front_bwd_b(cut, &pf, &xb, &g_act)?;
                for (t, g) in front.iter_mut().zip(&g_front) {
                    for (p, &gv) in t.iter_mut().zip(g) {
                        *p -= self.cfg.lr * gv;
                    }
                }
                for (t, g) in back.iter_mut().zip(&g_back) {
                    for (p, &gv) in t.iter_mut().zip(g) {
                        *p -= self.cfg.lr * gv;
                    }
                }
                loss_sum += loss as f64;
                steps += 1;
            }
        }
        Ok((loss_sum, steps))
    }

    /// Assemble a round record (evaluating if scheduled).
    fn record(
        &mut self,
        round: usize,
        model: &Params,
        train_loss: f64,
        round_time: f64,
    ) -> Result<RoundRecord> {
        let (test_loss, test_acc) = if self.should_eval(round) {
            self.evaluate(model)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let sim_total = round_time * round as f64;
        log_debug!(
            "round {round}: train_loss={train_loss:.4} acc={test_acc:.4} sim={round_time:.1}s"
        );
        Ok(RoundRecord {
            round,
            train_loss,
            test_acc,
            test_loss,
            sim_round_s: round_time,
            sim_total_s: sim_total,
        })
    }
}

/// Split a flat model into `(front, back)` at layer `cut`.
pub fn split_params(params: &Params, cut: usize) -> (Params, Params) {
    let front = params[..2 * cut].to_vec();
    let back = params[2 * cut..].to_vec();
    (front, back)
}

/// Rejoin `(front, back)` into a flat model.
pub fn join_params(front: &Params, back: &Params) -> Params {
    let mut out = front.clone();
    out.extend(back.iter().cloned());
    out
}

/// Convenience: build + run in one call.
pub fn run_experiment(cfg: ExperimentConfig) -> Result<RunResult> {
    Experiment::new(cfg)
        .context("building experiment")?
        .run()
        .context("running experiment")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataDistribution, PairingStrategy};

    fn quick_cfg(algo: Algorithm) -> ExperimentConfig {
        let mut c = ExperimentConfig::preset("quick").unwrap();
        c.algorithm = algo;
        c.rounds = 2;
        c.samples_per_client = 32;
        c.test_samples = 64;
        c
    }

    fn artifacts_ready() -> bool {
        let ok = std::path::Path::new("artifacts/manifest.json").exists();
        if !ok {
            eprintln!("skipping driver test: artifacts/ not built");
        }
        ok
    }

    #[test]
    fn split_join_roundtrip() {
        let p: Params = (0..8).map(|i| vec![i as f32; 3]).collect();
        let (f, b) = split_params(&p, 3);
        assert_eq!(f.len(), 6);
        assert_eq!(b.len(), 2);
        assert_eq!(join_params(&f, &b), p);
    }

    #[test]
    fn fedpairing_quick_run_trains() {
        if !artifacts_ready() {
            return;
        }
        let res = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        assert_eq!(res.rounds.len(), 2);
        assert!(res.final_acc() > 0.0);
        assert!(res.rounds[0].sim_round_s > 0.0);
        assert!(res.total_execs > 0);
        // loss should be finite and generally decreasing across rounds
        assert!(res.rounds[1].train_loss.is_finite());
    }

    #[test]
    fn all_algorithms_quick_run() {
        if !artifacts_ready() {
            return;
        }
        let mut accs = Vec::new();
        for algo in [
            Algorithm::FedPairing,
            Algorithm::VanillaFL,
            Algorithm::VanillaSL,
            Algorithm::SplitFed,
        ] {
            let res = run_experiment(quick_cfg(algo)).unwrap();
            assert_eq!(res.rounds.len(), 2, "{algo:?}");
            assert!(res.final_acc().is_finite(), "{algo:?}");
            accs.push((algo, res.final_acc()));
        }
        eprintln!("quick accs: {accs:?}");
    }

    #[test]
    fn deterministic_runs() {
        if !artifacts_ready() {
            return;
        }
        let a = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        let b = run_experiment(quick_cfg(Algorithm::FedPairing)).unwrap();
        assert_eq!(a.final_acc(), b.final_acc());
        assert_eq!(a.rounds[0].train_loss, b.rounds[0].train_loss);
    }

    #[test]
    fn noniid_shards_run() {
        if !artifacts_ready() {
            return;
        }
        let mut cfg = quick_cfg(Algorithm::FedPairing);
        cfg.distribution = DataDistribution::ClassShards {
            classes_per_client: 2,
        };
        cfg.pairing = PairingStrategy::Random;
        let res = run_experiment(cfg).unwrap();
        assert!(res.final_acc().is_finite());
    }
}
