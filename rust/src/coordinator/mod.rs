//! L3 coordination: the FedPairing server/round loop, the split-training
//! protocol, the benchmark algorithm drivers, and metrics sinks.
//!
//! * [`split`] — paper Algorithm 2's pair trainer (eqs. 1–2, 7).
//! * [`drivers`] — full multi-round loops: FedPairing / FL / SL / SplitFed.
//! * [`protocol`] — split-learning message types + byte accounting.
//! * [`metrics`] — per-round records, CSV/JSON persistence.

pub mod drivers;
pub mod metrics;
pub mod protocol;
pub mod split;

pub use drivers::{run_experiment, Experiment};
pub use metrics::{RoundRecord, RunResult};
