//! The split-learning wire protocol: message types exchanged inside a pair
//! (and between client and server for SL/SplitFed), with exact byte-size
//! accounting.
//!
//! The coordinator executes pairs deterministically in virtual time (the
//! latency simulator charges every message below to the eq.-3 channel), so
//! these types both document the protocol and anchor the simulation's
//! byte counts — `tests` assert the latency model and the protocol agree.
//!
//! Label privacy (DESIGN.md §2): the *data owner* computes the loss and the
//! logit gradient locally. Labels never appear in any message.

/// Message kinds of the FedPairing local-training protocol, in order of
/// appearance within one mini-batch step of one direction.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Owner → helper: the split activation `x̄ = ω_(1,L)(x)`.
    Activation {
        batch: usize,
        hidden: usize,
        data: Vec<f32>,
    },
    /// Helper → owner: logits `ŷ` (the paper's "c_j returns ŷ to c_i").
    Logits {
        batch: usize,
        classes: usize,
        data: Vec<f32>,
    },
    /// Owner → helper: `∂l/∂ŷ` (replaces the paper's underspecified "sends
    /// the loss value"; a scalar loss cannot drive backprop).
    LogitGrad {
        batch: usize,
        classes: usize,
        data: Vec<f32>,
    },
    /// Helper → owner: activation cotangent `g_act` of the split boundary.
    ActGrad {
        batch: usize,
        hidden: usize,
        data: Vec<f32>,
    },
    /// Client → server: the trained local model (round upload).
    ModelUpload { n_params: usize },
    /// Server → client: the aggregated global model.
    ModelDownload { n_params: usize },
}

impl Msg {
    /// Payload size in bytes (f32 tensors; headers ignored, consistent with
    /// the latency model).
    pub fn bytes(&self) -> f64 {
        match self {
            Msg::Activation { batch, hidden, .. } | Msg::ActGrad { batch, hidden, .. } => {
                (batch * hidden * 4) as f64
            }
            Msg::Logits { batch, classes, .. } | Msg::LogitGrad { batch, classes, .. } => {
                (batch * classes * 4) as f64
            }
            Msg::ModelUpload { n_params } | Msg::ModelDownload { n_params } => {
                (n_params * 4) as f64
            }
        }
    }

    /// Validate payload length against the declared shape.
    pub fn validate(&self) -> bool {
        match self {
            Msg::Activation { batch, hidden, data } | Msg::ActGrad { batch, hidden, data } => {
                data.len() == batch * hidden
            }
            Msg::Logits { batch, classes, data }
            | Msg::LogitGrad { batch, classes, data } => data.len() == batch * classes,
            Msg::ModelUpload { .. } | Msg::ModelDownload { .. } => true,
        }
    }
}

/// Bytes sent owner→helper per mini-batch step (activation + logit-grad).
pub fn owner_to_helper_bytes(batch: usize, hidden: usize, classes: usize) -> f64 {
    (batch * hidden * 4 + batch * classes * 4) as f64
}

/// Bytes sent helper→owner per mini-batch step (logits + act-grad).
pub fn helper_to_owner_bytes(batch: usize, hidden: usize, classes: usize) -> f64 {
    (batch * classes * 4 + batch * hidden * 4) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_accounting() {
        let m = Msg::Activation {
            batch: 32,
            hidden: 256,
            data: vec![0.0; 32 * 256],
        };
        assert_eq!(m.bytes(), (32 * 256 * 4) as f64);
        assert!(m.validate());
        let m = Msg::Logits {
            batch: 32,
            classes: 10,
            data: vec![0.0; 32 * 10],
        };
        assert_eq!(m.bytes(), (32 * 10 * 4) as f64);
        let m = Msg::ModelUpload { n_params: 1000 };
        assert_eq!(m.bytes(), 4000.0);
    }

    #[test]
    fn validation_catches_wrong_payload() {
        let m = Msg::ActGrad {
            batch: 4,
            hidden: 8,
            data: vec![0.0; 31],
        };
        assert!(!m.validate());
    }

    #[test]
    fn per_step_totals_match_latency_model() {
        // sim::latency's push_split_batches charges act+g_logits up and
        // logits+g_act down; the protocol totals must agree.
        let (b, h, c) = (32, 256, 10);
        let up = owner_to_helper_bytes(b, h, c);
        let down = helper_to_owner_bytes(b, h, c);
        let act = (b * h * 4) as f64;
        let log = (b * c * 4) as f64;
        assert_eq!(up, act + log);
        assert_eq!(down, log + act);
        // symmetric protocol
        assert_eq!(up, down);
    }
}
