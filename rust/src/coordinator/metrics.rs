//! Experiment metrics: per-round records, run summaries, CSV/JSON sinks.
//!
//! Every driver produces a [`RunResult`]; examples and benches render it, and
//! `to_csv`/`to_json` persist it under the configured `out_dir` together with
//! the full config echo for provenance.

use crate::config::ExperimentConfig;
use crate::telemetry::breakdown::{StageBreakdown, STAGE_NAMES};
use crate::util::json::{Json, JsonObj};

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Clients that actually participated this round (static fleets:
    /// `n_clients` every round; dynamic scenarios: the churn-adjusted count).
    pub n_alive: usize,
    /// Mean training loss across all local batches this round.
    pub train_loss: f64,
    /// Top-1 accuracy on the shared test set (NaN when eval skipped).
    pub test_acc: f64,
    /// Mean test loss (NaN when eval skipped).
    pub test_loss: f64,
    /// Simulated wall-clock seconds this round took (latency model).
    pub sim_round_s: f64,
    /// Cumulative simulated seconds since round 1.
    pub sim_total_s: f64,
    /// Mean planned split cut this round: average front length `L_i` over
    /// the FedPairing pairs, the configured cut for SL/SplitFed, NaN for
    /// vanilla FL (see `sim::latency::RoundTime::mean_cut`).
    pub mean_cut: f64,
    /// Stage-attributed breakdown of the round's critical path plus
    /// straggler attribution (see `telemetry::breakdown`). Client ids are in
    /// the universe space of the driver that produced the record.
    pub stages: StageBreakdown,
}

/// A full experiment run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config: ExperimentConfig,
    pub rounds: Vec<RoundRecord>,
    /// Host wall-clock seconds the run actually took.
    pub wall_s: f64,
    /// Total artifact executions (runtime pressure diagnostic).
    pub total_execs: u64,
}

impl RunResult {
    /// Final evaluated accuracy (last non-NaN).
    pub fn final_acc(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .unwrap_or(f64::NAN)
    }

    /// Best evaluated accuracy.
    pub fn best_acc(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Mean simulated seconds per round.
    pub fn mean_round_s(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.sim_round_s).sum::<f64>() / self.rounds.len() as f64
    }

    /// Accuracy trace as `(round, acc)` pairs (evaluated rounds only).
    pub fn acc_curve(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| (r.round, r.test_acc))
            .collect()
    }

    /// Mean participating clients per round.
    pub fn mean_alive(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.n_alive as f64).sum::<f64>() / self.rounds.len() as f64
    }

    /// CSV rendering (header + one row per round). Simulated times use
    /// Rust's default float formatting — the shortest representation that
    /// parses back to the exact value — so post-processing can reproduce the
    /// run's timeline bit for bit; an unplanned `mean_cut` (vanilla FL's
    /// NaN) renders as an empty field.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "round,n_alive,train_loss,test_loss,test_acc,sim_round_s,sim_total_s,mean_cut,crit_a,crit_b,crit_slack_s",
        );
        for name in STAGE_NAMES {
            s.push_str(&format!(",stage_{name}_s"));
        }
        s.push('\n');
        for r in &self.rounds {
            let mean_cut = if r.mean_cut.is_nan() {
                String::new()
            } else {
                format!("{:.3}", r.mean_cut)
            };
            s.push_str(&format!(
                "{},{},{:.6},{:.6},{:.6},{},{},{},{},{},{}",
                r.round,
                r.n_alive,
                r.train_loss,
                r.test_loss,
                r.test_acc,
                r.sim_round_s,
                r.sim_total_s,
                mean_cut,
                r.stages.crit_a,
                r.stages.crit_b,
                r.stages.crit_slack_s
            ));
            for v in r.stages.stage_s {
                s.push_str(&format!(",{v}"));
            }
            s.push('\n');
        }
        s
    }

    /// JSON rendering with config echo.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("config", self.config.to_json());
        o.insert("wall_s", Json::num(self.wall_s));
        o.insert("total_execs", Json::num(self.total_execs as f64));
        o.insert("final_acc", Json::num(self.final_acc()));
        o.insert("best_acc", Json::num(self.best_acc()));
        o.insert("mean_round_s", Json::num(self.mean_round_s()));
        o.insert("mean_alive", Json::num(self.mean_alive()));
        let rounds: Vec<Json> = self
            .rounds
            .iter()
            .map(|r| {
                let mut ro = JsonObj::new();
                ro.insert("round", Json::num(r.round as f64));
                ro.insert("n_alive", Json::num(r.n_alive as f64));
                ro.insert("train_loss", Json::num(r.train_loss));
                ro.insert("test_loss", Json::num(r.test_loss));
                ro.insert("test_acc", Json::num(r.test_acc));
                ro.insert("sim_round_s", Json::num(r.sim_round_s));
                ro.insert("sim_total_s", Json::num(r.sim_total_s));
                ro.insert("mean_cut", Json::num(r.mean_cut));
                ro.insert("stages", r.stages.to_json());
                Json::Obj(ro)
            })
            .collect();
        o.insert("rounds", Json::Arr(rounds));
        Json::Obj(o)
    }

    /// Persist CSV + JSON under `dir` with the run name; returns the paths.
    pub fn save(&self, dir: &str) -> std::io::Result<(String, String)> {
        std::fs::create_dir_all(dir)?;
        let base = format!(
            "{dir}/{}_{}_{}",
            self.config.name,
            self.config.algorithm.name(),
            self.config.distribution.name()
        );
        let csv_path = format!("{base}.csv");
        let json_path = format!("{base}.json");
        std::fs::write(&csv_path, self.to_csv())?;
        std::fs::write(&json_path, self.to_json().to_string_pretty(1))?;
        Ok((csv_path, json_path))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result() -> RunResult {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "t".into();
        let stages1 = StageBreakdown {
            stage_s: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0],
            crit_a: 3,
            crit_b: 7,
            crit_slack_s: 0.5,
        };
        RunResult {
            config: cfg,
            rounds: vec![
                RoundRecord {
                    round: 1,
                    n_alive: 20,
                    train_loss: 2.0,
                    test_acc: 0.3,
                    test_loss: 2.1,
                    sim_round_s: 10.0,
                    sim_total_s: 10.0,
                    mean_cut: 4.0,
                    stages: stages1,
                },
                RoundRecord {
                    round: 2,
                    n_alive: 18,
                    train_loss: 1.5,
                    test_acc: f64::NAN,
                    test_loss: f64::NAN,
                    sim_round_s: 10.0,
                    sim_total_s: 20.0,
                    mean_cut: 4.5,
                    stages: StageBreakdown::default(),
                },
                RoundRecord {
                    round: 3,
                    n_alive: 19,
                    train_loss: 1.2,
                    test_acc: 0.5,
                    test_loss: 1.4,
                    sim_round_s: 12.0,
                    sim_total_s: 32.0,
                    mean_cut: f64::NAN,
                    stages: StageBreakdown::default(),
                },
            ],
            wall_s: 1.0,
            total_execs: 42,
        }
    }

    #[test]
    fn final_and_best_skip_nan() {
        let r = result();
        assert_eq!(r.final_acc(), 0.5);
        assert_eq!(r.best_acc(), 0.5);
        assert_eq!(r.acc_curve(), vec![(1, 0.3), (3, 0.5)]);
    }

    #[test]
    fn mean_round_time() {
        assert!((result().mean_round_s() - 32.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_all_rounds() {
        let csv = result().to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("round,n_alive,"));
        assert!(csv.lines().nth(1).unwrap().starts_with("1,20,"));
    }

    #[test]
    fn csv_times_roundtrip_and_nan_cut_is_empty() {
        let mut r = result();
        r.rounds[0].sim_round_s = 0.1 + 0.2; // 0.30000000000000004
        r.rounds[0].sim_total_s = 1.0 / 3.0;
        let csv = r.to_csv();
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[5].parse::<f64>().unwrap().to_bits(), r.rounds[0].sim_round_s.to_bits());
        assert_eq!(row[6].parse::<f64>().unwrap().to_bits(), r.rounds[0].sim_total_s.to_bits());
        // Vanilla FL's unplanned cut (round 3 fixture) is an empty field, not
        // a bare "NaN" token that trips numeric CSV readers.
        let nan_row: Vec<&str> = csv.lines().nth(3).unwrap().split(',').collect();
        assert_eq!(nan_row[7], "");
    }

    #[test]
    fn csv_and_json_carry_stage_columns() {
        let r = result();
        let header = r.to_csv().lines().next().unwrap().to_string();
        assert!(header.ends_with(
            "crit_a,crit_b,crit_slack_s,stage_front_fp_s,stage_act_tx_s,stage_back_compute_s,\
             stage_grad_tx_s,stage_front_upd_s,stage_uplink_s,stage_server_agg_s"
        ));
        let row1: Vec<String> =
            r.to_csv().lines().nth(1).unwrap().split(',').map(str::to_string).collect();
        assert_eq!(&row1[8..11], ["3", "7", "0.5"]);
        assert_eq!(row1[11], "1");
        let j = r.to_json();
        let stages = j.get("rounds").unwrap().at(0).unwrap().get("stages").unwrap();
        assert_eq!(stages.get("front_fp").and_then(Json::as_f64), Some(1.0));
        assert_eq!(stages.get("crit_b").and_then(Json::as_f64), Some(7.0));
    }

    #[test]
    fn mean_alive_averages_participation() {
        let r = result();
        assert!((r.mean_alive() - (20.0 + 18.0 + 19.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrips_and_has_summary() {
        let j = result().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("final_acc").unwrap().as_f64(), Some(0.5));
        assert_eq!(
            parsed.get("rounds").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(
            parsed
                .get("config")
                .unwrap()
                .get("n_clients")
                .unwrap()
                .as_usize(),
            Some(20)
        );
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fp_metrics_test");
        let dir = dir.to_str().unwrap();
        let (c, j) = result().save(dir).unwrap();
        assert!(std::fs::metadata(&c).unwrap().len() > 0);
        assert!(std::fs::metadata(&j).unwrap().len() > 0);
        let _ = std::fs::remove_dir_all(dir);
    }
}
