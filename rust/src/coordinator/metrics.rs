//! Experiment metrics: per-round records, run summaries, CSV/JSON sinks.
//!
//! Every driver produces a [`RunResult`]; examples and benches render it, and
//! `to_csv`/`to_json` persist it under the configured `out_dir` together with
//! the full config echo for provenance.

use crate::config::ExperimentConfig;
use crate::telemetry::breakdown::{StageBreakdown, STAGE_NAMES};
use crate::util::json::{Json, JsonObj};

/// One communication round's measurements.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Clients that actually participated this round (static fleets:
    /// `n_clients` every round; dynamic scenarios: the churn-adjusted count).
    pub n_alive: usize,
    /// Mean training loss across all local batches this round.
    pub train_loss: f64,
    /// Top-1 accuracy on the shared test set (NaN when eval skipped).
    pub test_acc: f64,
    /// Mean test loss (NaN when eval skipped).
    pub test_loss: f64,
    /// Simulated wall-clock seconds this round took (latency model).
    pub sim_round_s: f64,
    /// Cumulative simulated seconds since round 1.
    pub sim_total_s: f64,
    /// Mean planned split cut this round: average front length `L_i` over
    /// the FedPairing pairs, the configured cut for SL/SplitFed, NaN for
    /// vanilla FL (see `sim::latency::RoundTime::mean_cut`).
    pub mean_cut: f64,
    /// Stage-attributed breakdown of the round's critical path plus
    /// straggler attribution (see `telemetry::breakdown`). Client ids are in
    /// the universe space of the driver that produced the record.
    pub stages: StageBreakdown,
    /// Wall-clock view of the run at this record: cumulative simulated
    /// seconds at commit. Synchronous rounds mirror `sim_total_s`; under
    /// buffered aggregation this is the merge's commit time.
    pub t_wall_s: f64,
    /// Mean staleness (merges behind) over the updates merged here. NaN on
    /// synchronous rounds, 0.0 on async runs that degenerate to sync.
    pub staleness_mean: f64,
    /// Fault/recovery accounting for this round (all zero on fault-free
    /// runs; see `faults` and DESIGN.md §11).
    pub faults: crate::faults::FaultCounters,
    /// Exact nearest-rank p50 of this round's work-unit makespans (pair and
    /// solo totals; async: the merge window's units). NaN when the round
    /// recorded no units (DES backend) — renders as an empty CSV field /
    /// JSON null. See DESIGN.md §12.
    pub mk_p50_s: f64,
    /// Exact nearest-rank p90 work-unit makespan (NaN when unrecorded).
    pub mk_p90_s: f64,
    /// Exact nearest-rank p99 work-unit makespan (NaN when unrecorded).
    pub mk_p99_s: f64,
    /// Jain fairness index over cumulative per-client busy time up to and
    /// including this round, from the run's `ClientLedger` (NaN until any
    /// client has attributed busy time).
    pub fairness: f64,
}

impl RoundRecord {
    /// The shared CSV header (no trailing newline) — one source of truth for
    /// [`RunResult::to_csv`] and the incremental [`RecordStreamer`].
    pub fn csv_header() -> String {
        let mut s = String::from(
            "round,n_alive,train_loss,test_loss,test_acc,sim_round_s,sim_total_s,mean_cut,crit_a,crit_b,crit_slack_s",
        );
        for name in STAGE_NAMES {
            s.push_str(&format!(",stage_{name}_s"));
        }
        s.push_str(",t_wall_s,staleness_mean");
        s.push_str(",n_failed,n_retries,n_lost_updates,recovery_s");
        s.push_str(",mk_p50_s,mk_p90_s,mk_p99_s,fairness");
        s
    }

    /// One CSV row (no trailing newline). Simulated times use Rust's default
    /// float formatting — the shortest representation that parses back to
    /// the exact value — so post-processing can reproduce the run's timeline
    /// bit for bit; `mean_cut`/`staleness_mean` NaNs (vanilla FL / sync
    /// rounds) render as empty fields, not bare "NaN" tokens.
    pub fn csv_row(&self) -> String {
        let mean_cut = if self.mean_cut.is_nan() {
            String::new()
        } else {
            format!("{:.3}", self.mean_cut)
        };
        let staleness = if self.staleness_mean.is_nan() {
            String::new()
        } else {
            format!("{:.3}", self.staleness_mean)
        };
        let mut s = format!(
            "{},{},{:.6},{:.6},{:.6},{},{},{},{},{},{}",
            self.round,
            self.n_alive,
            self.train_loss,
            self.test_loss,
            self.test_acc,
            self.sim_round_s,
            self.sim_total_s,
            mean_cut,
            self.stages.crit_a,
            self.stages.crit_b,
            self.stages.crit_slack_s
        );
        for v in self.stages.stage_s {
            s.push_str(&format!(",{v}"));
        }
        s.push_str(&format!(",{},{staleness}", self.t_wall_s));
        s.push_str(&format!(
            ",{},{},{},{}",
            self.faults.n_failed,
            self.faults.n_retries,
            self.faults.n_lost_updates,
            self.faults.recovery_s
        ));
        // Quantile lanes + fairness use the same shortest-exact formatting as
        // the simulated times, so `fedpairing report` can reproduce them bit
        // for bit from the stream; NaN (no recorded units / no ledger data)
        // renders as an empty field.
        for v in [self.mk_p50_s, self.mk_p90_s, self.mk_p99_s, self.fairness] {
            if v.is_nan() {
                s.push(',');
            } else {
                s.push_str(&format!(",{v}"));
            }
        }
        s
    }

    /// JSON object for this record (shared by [`RunResult::to_json`] and the
    /// JSONL stream; NaNs serialize as `null`).
    pub fn to_json_obj(&self) -> Json {
        let mut ro = JsonObj::new();
        ro.insert("round", Json::num(self.round as f64));
        ro.insert("n_alive", Json::num(self.n_alive as f64));
        ro.insert("train_loss", Json::num(self.train_loss));
        ro.insert("test_loss", Json::num(self.test_loss));
        ro.insert("test_acc", Json::num(self.test_acc));
        ro.insert("sim_round_s", Json::num(self.sim_round_s));
        ro.insert("sim_total_s", Json::num(self.sim_total_s));
        ro.insert("t_wall_s", Json::num(self.t_wall_s));
        ro.insert("staleness_mean", Json::num(self.staleness_mean));
        ro.insert("mean_cut", Json::num(self.mean_cut));
        ro.insert("n_failed", Json::num(self.faults.n_failed as f64));
        ro.insert("n_retries", Json::num(self.faults.n_retries as f64));
        ro.insert("n_lost_updates", Json::num(self.faults.n_lost_updates as f64));
        ro.insert("recovery_s", Json::num(self.faults.recovery_s));
        ro.insert("mk_p50_s", Json::num(self.mk_p50_s));
        ro.insert("mk_p90_s", Json::num(self.mk_p90_s));
        ro.insert("mk_p99_s", Json::num(self.mk_p99_s));
        ro.insert("fairness", Json::num(self.fairness));
        ro.insert("stages", self.stages.to_json());
        Json::Obj(ro)
    }
}

/// A full experiment run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub config: ExperimentConfig,
    pub rounds: Vec<RoundRecord>,
    /// Host wall-clock seconds the run actually took.
    pub wall_s: f64,
    /// Total artifact executions (runtime pressure diagnostic).
    pub total_execs: u64,
    /// The run's distribution observatory — quantile-sketch lanes plus the
    /// per-client fairness ledger (DESIGN.md §12). Held in memory only: it
    /// is exported via `--metrics-out` / printed by the CLI, never
    /// serialized into `to_csv`/`to_json` (the per-round lanes and fairness
    /// on each [`RoundRecord`] are the persisted projection).
    pub observatory: crate::telemetry::ledger::Observatory,
}

impl RunResult {
    /// Final evaluated accuracy (last non-NaN).
    pub fn final_acc(&self) -> f64 {
        self.rounds
            .iter()
            .rev()
            .find(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .unwrap_or(f64::NAN)
    }

    /// Best evaluated accuracy.
    pub fn best_acc(&self) -> f64 {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| r.test_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Mean simulated seconds per round.
    pub fn mean_round_s(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.sim_round_s).sum::<f64>() / self.rounds.len() as f64
    }

    /// Accuracy trace as `(round, acc)` pairs (evaluated rounds only).
    pub fn acc_curve(&self) -> Vec<(usize, f64)> {
        self.rounds
            .iter()
            .filter(|r| !r.test_acc.is_nan())
            .map(|r| (r.round, r.test_acc))
            .collect()
    }

    /// Mean participating clients per round.
    pub fn mean_alive(&self) -> f64 {
        if self.rounds.is_empty() {
            return 0.0;
        }
        self.rounds.iter().map(|r| r.n_alive as f64).sum::<f64>() / self.rounds.len() as f64
    }

    /// CSV rendering (header + one row per round). Simulated times use
    /// Rust's default float formatting — the shortest representation that
    /// parses back to the exact value — so post-processing can reproduce the
    /// run's timeline bit for bit; an unplanned `mean_cut` (vanilla FL's
    /// NaN) renders as an empty field.
    pub fn to_csv(&self) -> String {
        let mut s = RoundRecord::csv_header();
        s.push('\n');
        for r in &self.rounds {
            s.push_str(&r.csv_row());
            s.push('\n');
        }
        s
    }

    /// JSON rendering with config echo.
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("config", self.config.to_json());
        o.insert("wall_s", Json::num(self.wall_s));
        o.insert("total_execs", Json::num(self.total_execs as f64));
        o.insert("final_acc", Json::num(self.final_acc()));
        o.insert("best_acc", Json::num(self.best_acc()));
        o.insert("mean_round_s", Json::num(self.mean_round_s()));
        o.insert("mean_alive", Json::num(self.mean_alive()));
        let rounds: Vec<Json> = self.rounds.iter().map(RoundRecord::to_json_obj).collect();
        o.insert("rounds", Json::Arr(rounds));
        Json::Obj(o)
    }

    /// Persist CSV + JSON under `dir` with the run name; returns the paths.
    pub fn save(&self, dir: &str) -> std::io::Result<(String, String)> {
        std::fs::create_dir_all(dir)?;
        let base = format!(
            "{dir}/{}_{}_{}",
            self.config.name,
            self.config.algorithm.name(),
            self.config.distribution.name()
        );
        let csv_path = format!("{base}.csv");
        let json_path = format!("{base}.json");
        std::fs::write(&csv_path, self.to_csv())?;
        std::fs::write(&json_path, self.to_json().to_string_pretty(1))?;
        Ok((csv_path, json_path))
    }
}

/// Incremental record sink: appends each [`RoundRecord`] to a CSV and a
/// JSONL file as it is produced, instead of buffering the whole run. Memory
/// stays O(1) in the round count, and a killed run keeps every completed
/// round on disk — which is what makes unbounded async event streams (and
/// ROADMAP's memory-diet item) tractable.
///
/// Crash durability: while a run is live the sinks are `.tmp` siblings of
/// the final paths; [`RecordStreamer::finish`] flushes, fsyncs, and
/// atomically renames them into place, so the final `.stream.{csv,jsonl}`
/// either do not exist or are complete. A killed run leaves the `.tmp`
/// siblings behind with every pushed record; [`recover_jsonl`] replays the
/// complete lines of such a (possibly torn) JSONL file.
#[derive(Debug)]
pub struct RecordStreamer {
    csv: std::io::BufWriter<std::fs::File>,
    jsonl: std::io::BufWriter<std::fs::File>,
    csv_path: String,
    jsonl_path: String,
}

impl RecordStreamer {
    /// Open `<dir>/<base>.stream.csv.tmp` (with header) and
    /// `<dir>/<base>.stream.jsonl.tmp`, truncating any previous run.
    /// [`RecordStreamer::finish`] renames them to the final paths.
    pub fn create(dir: &str, base: &str) -> std::io::Result<RecordStreamer> {
        use std::io::Write;
        std::fs::create_dir_all(dir)?;
        let csv_path = format!("{dir}/{base}.stream.csv");
        let jsonl_path = format!("{dir}/{base}.stream.jsonl");
        let mut csv = std::io::BufWriter::new(std::fs::File::create(tmp_path(&csv_path))?);
        writeln!(csv, "{}", RoundRecord::csv_header())?;
        let jsonl = std::io::BufWriter::new(std::fs::File::create(tmp_path(&jsonl_path))?);
        Ok(RecordStreamer {
            csv,
            jsonl,
            csv_path,
            jsonl_path,
        })
    }

    /// Append one record to both sinks and flush — the contract is that a
    /// crash after `push` returns never loses that record (it lives in the
    /// `.tmp` sibling until [`RecordStreamer::finish`] renames it).
    pub fn push(&mut self, r: &RoundRecord) -> std::io::Result<()> {
        use std::io::Write;
        writeln!(self.csv, "{}", r.csv_row())?;
        writeln!(self.jsonl, "{}", r.to_json_obj())?;
        self.csv.flush()?;
        self.jsonl.flush()
    }

    /// The final `(csv, jsonl)` paths the run will be renamed to on
    /// [`RecordStreamer::finish`]; the live sinks are their `.tmp` siblings.
    pub fn paths(&self) -> (&str, &str) {
        (&self.csv_path, &self.jsonl_path)
    }

    /// Flush, fsync, and atomically rename the `.tmp` sinks into place;
    /// returns the final `(csv, jsonl)` paths.
    pub fn finish(mut self) -> std::io::Result<(String, String)> {
        use std::io::Write;
        self.csv.flush()?;
        self.csv.get_ref().sync_all()?;
        self.jsonl.flush()?;
        self.jsonl.get_ref().sync_all()?;
        std::fs::rename(tmp_path(&self.csv_path), &self.csv_path)?;
        std::fs::rename(tmp_path(&self.jsonl_path), &self.jsonl_path)?;
        Ok((self.csv_path, self.jsonl_path))
    }
}

/// `.tmp` sibling of a sink path (same directory, so the rename is atomic).
fn tmp_path(path: &str) -> String {
    format!("{path}.tmp")
}

/// Replay a (possibly torn) `.stream.jsonl` file — e.g. the `.tmp` sibling a
/// killed run left behind — and recover every complete record. A final line
/// truncated mid-write fails to parse and is dropped; everything before it
/// is returned.
pub fn recover_jsonl(path: &str) -> std::io::Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text.lines().filter_map(|l| Json::parse(l).ok()).collect())
}

/// Build the configured stream sink for a run: `Some` when
/// `cfg.stream_out = Some(dir)`, named like [`RunResult::save`] outputs but
/// with a `.stream.{csv,jsonl}` suffix.
pub fn streamer_for(cfg: &ExperimentConfig) -> std::io::Result<Option<RecordStreamer>> {
    let Some(dir) = cfg.stream_out.as_deref() else {
        return Ok(None);
    };
    let base = format!(
        "{}_{}_{}",
        cfg.name,
        cfg.algorithm.name(),
        cfg.distribution.name()
    );
    RecordStreamer::create(dir, &base).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result() -> RunResult {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "t".into();
        let stages1 = StageBreakdown {
            stage_s: [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0],
            crit_a: 3,
            crit_b: 7,
            crit_slack_s: 0.5,
        };
        RunResult {
            config: cfg,
            rounds: vec![
                RoundRecord {
                    round: 1,
                    n_alive: 20,
                    train_loss: 2.0,
                    test_acc: 0.3,
                    test_loss: 2.1,
                    sim_round_s: 10.0,
                    sim_total_s: 10.0,
                    mean_cut: 4.0,
                    stages: stages1,
                    t_wall_s: 10.0,
                    staleness_mean: f64::NAN,
                    faults: Default::default(),
                    mk_p50_s: f64::NAN,
                    mk_p90_s: f64::NAN,
                    mk_p99_s: f64::NAN,
                    fairness: f64::NAN,
                },
                RoundRecord {
                    round: 2,
                    n_alive: 18,
                    train_loss: 1.5,
                    test_acc: f64::NAN,
                    test_loss: f64::NAN,
                    sim_round_s: 10.0,
                    sim_total_s: 20.0,
                    mean_cut: 4.5,
                    stages: StageBreakdown::default(),
                    t_wall_s: 20.0,
                    staleness_mean: f64::NAN,
                    faults: Default::default(),
                    mk_p50_s: 7.5,
                    mk_p90_s: 9.25,
                    mk_p99_s: 10.0,
                    fairness: 0.875,
                },
                RoundRecord {
                    round: 3,
                    n_alive: 19,
                    train_loss: 1.2,
                    test_acc: 0.5,
                    test_loss: 1.4,
                    sim_round_s: 12.0,
                    sim_total_s: 32.0,
                    mean_cut: f64::NAN,
                    stages: StageBreakdown::default(),
                    t_wall_s: 32.0,
                    staleness_mean: 1.25,
                    faults: crate::faults::FaultCounters {
                        n_failed: 2,
                        n_retries: 5,
                        n_lost_updates: 1,
                        recovery_s: 3.5,
                    },
                    mk_p50_s: 8.0,
                    mk_p90_s: 11.5,
                    mk_p99_s: 12.0,
                    fairness: 0.97,
                },
            ],
            wall_s: 1.0,
            total_execs: 42,
            observatory: Default::default(),
        }
    }

    #[test]
    fn final_and_best_skip_nan() {
        let r = result();
        assert_eq!(r.final_acc(), 0.5);
        assert_eq!(r.best_acc(), 0.5);
        assert_eq!(r.acc_curve(), vec![(1, 0.3), (3, 0.5)]);
    }

    #[test]
    fn mean_round_time() {
        assert!((result().mean_round_s() - 32.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn csv_has_all_rounds() {
        let csv = result().to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("round,n_alive,"));
        assert!(csv.lines().nth(1).unwrap().starts_with("1,20,"));
    }

    #[test]
    fn csv_times_roundtrip_and_nan_cut_is_empty() {
        let mut r = result();
        r.rounds[0].sim_round_s = 0.1 + 0.2; // 0.30000000000000004
        r.rounds[0].sim_total_s = 1.0 / 3.0;
        let csv = r.to_csv();
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row[5].parse::<f64>().unwrap().to_bits(), r.rounds[0].sim_round_s.to_bits());
        assert_eq!(row[6].parse::<f64>().unwrap().to_bits(), r.rounds[0].sim_total_s.to_bits());
        // Vanilla FL's unplanned cut (round 3 fixture) is an empty field, not
        // a bare "NaN" token that trips numeric CSV readers.
        let nan_row: Vec<&str> = csv.lines().nth(3).unwrap().split(',').collect();
        assert_eq!(nan_row[7], "");
    }

    #[test]
    fn csv_and_json_carry_stage_columns() {
        let r = result();
        let header = r.to_csv().lines().next().unwrap().to_string();
        assert!(header.ends_with(
            "crit_a,crit_b,crit_slack_s,stage_front_fp_s,stage_act_tx_s,stage_back_compute_s,\
             stage_grad_tx_s,stage_front_upd_s,stage_uplink_s,stage_server_agg_s,\
             t_wall_s,staleness_mean,n_failed,n_retries,n_lost_updates,recovery_s,\
             mk_p50_s,mk_p90_s,mk_p99_s,fairness"
        ));
        let row1: Vec<String> =
            r.to_csv().lines().nth(1).unwrap().split(',').map(str::to_string).collect();
        assert_eq!(&row1[8..11], ["3", "7", "0.5"]);
        assert_eq!(row1[11], "1");
        let j = r.to_json();
        let stages = j.get("rounds").unwrap().at(0).unwrap().get("stages").unwrap();
        assert_eq!(stages.get("front_fp").and_then(Json::as_f64), Some(1.0));
        assert_eq!(stages.get("crit_b").and_then(Json::as_f64), Some(7.0));
    }

    #[test]
    fn mean_alive_averages_participation() {
        let r = result();
        assert!((r.mean_alive() - (20.0 + 18.0 + 19.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrips_and_has_summary() {
        let j = result().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("final_acc").unwrap().as_f64(), Some(0.5));
        assert_eq!(
            parsed.get("rounds").unwrap().as_arr().unwrap().len(),
            3
        );
        assert_eq!(
            parsed
                .get("config")
                .unwrap()
                .get("n_clients")
                .unwrap()
                .as_usize(),
            Some(20)
        );
    }

    #[test]
    fn csv_staleness_is_empty_on_sync_rows_and_numeric_on_async() {
        let csv = result().to_csv();
        // Fixture rounds 1-2 are synchronous (NaN staleness) -> empty field;
        // fault-free rounds render all-zero fault columns; round 1 has no
        // recorded units, so its lanes/fairness are empty trailing fields.
        assert!(csv.lines().nth(1).unwrap().ends_with(",10,,0,0,0,0,,,,"));
        // Round 2 carries exact lanes + fairness in shortest-exact form.
        assert!(csv.lines().nth(2).unwrap().ends_with(",7.5,9.25,10,0.875"));
        // Round 3 carries a real staleness mean and fault accounting.
        assert!(csv.lines().nth(3).unwrap().ends_with(",32,1.250,2,5,1,3.5,8,11.5,12,0.97"));
        let j = result().to_json().to_string();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        let rounds = parsed.get("rounds").unwrap();
        // NaN serializes as null; the async round keeps its value.
        assert!(rounds.at(0).unwrap().get("staleness_mean").unwrap().as_f64().is_none());
        assert_eq!(
            rounds.at(2).unwrap().get("staleness_mean").and_then(Json::as_f64),
            Some(1.25)
        );
        // Quantile lanes follow the same NaN -> null convention.
        assert!(rounds.at(0).unwrap().get("mk_p50_s").unwrap().as_f64().is_none());
        assert_eq!(rounds.at(2).unwrap().get("mk_p99_s").and_then(Json::as_f64), Some(12.0));
        assert_eq!(rounds.at(2).unwrap().get("fairness").and_then(Json::as_f64), Some(0.97));
    }

    #[test]
    fn streamer_appends_records_incrementally() {
        let dir = std::env::temp_dir().join("fp_metrics_stream_test");
        let dir = dir.to_str().unwrap();
        let r = result();
        let mut s = RecordStreamer::create(dir, "t_fed_pairing_iid").unwrap();
        for rec in &r.rounds {
            s.push(rec).unwrap();
        }
        let (csv_path, jsonl_path) = s.finish().unwrap();
        let csv = std::fs::read_to_string(&csv_path).unwrap();
        assert_eq!(csv, r.to_csv(), "streamed CSV must match the batch sink");
        let jsonl = std::fs::read_to_string(&jsonl_path).unwrap();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3);
        for (line, rec) in lines.iter().zip(&r.rounds) {
            let parsed = crate::util::json::Json::parse(line).unwrap();
            assert_eq!(
                parsed.get("round").and_then(Json::as_f64),
                Some(rec.round as f64)
            );
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn streamer_writes_tmp_until_finish_and_truncated_jsonl_recovers() {
        let dir = std::env::temp_dir().join("fp_metrics_stream_durable_test");
        let dir = dir.to_str().unwrap();
        let r = result();
        let mut s = RecordStreamer::create(dir, "t_fed_pairing_iid").unwrap();
        for rec in &r.rounds {
            s.push(rec).unwrap();
        }
        // Before finish: only the `.tmp` siblings exist — a killed run never
        // leaves a torn *final* file.
        let (csv_final, jsonl_final) = {
            let (c, j) = s.paths();
            (c.to_string(), j.to_string())
        };
        assert!(!std::path::Path::new(&csv_final).exists());
        assert!(std::path::Path::new(&tmp_path(&jsonl_final)).exists());
        // A crash mid-write tears the last JSONL line; recovery replays every
        // complete record and drops the torn tail.
        let live = std::fs::read_to_string(tmp_path(&jsonl_final)).unwrap();
        let torn_path = format!("{dir}/torn.stream.jsonl");
        std::fs::write(&torn_path, &live[..live.len() - 7]).unwrap();
        let recovered = recover_jsonl(&torn_path).unwrap();
        assert_eq!(recovered.len(), r.rounds.len() - 1);
        assert_eq!(
            recovered[1].get("round").and_then(Json::as_f64),
            Some(r.rounds[1].round as f64)
        );
        // finish() renames atomically: final paths appear, tmps are gone.
        let (csv_path, jsonl_path) = s.finish().unwrap();
        assert_eq!(csv_path, csv_final);
        assert!(!std::path::Path::new(&tmp_path(&csv_final)).exists());
        assert_eq!(std::fs::read_to_string(&csv_path).unwrap(), r.to_csv());
        assert_eq!(recover_jsonl(&jsonl_path).unwrap().len(), r.rounds.len());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn streamer_for_respects_config_gate() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "gate".into();
        assert!(streamer_for(&cfg).unwrap().is_none());
        let dir = std::env::temp_dir().join("fp_metrics_streamer_for_test");
        cfg.stream_out = Some(dir.to_str().unwrap().to_string());
        let s = streamer_for(&cfg).unwrap().expect("configured -> Some");
        assert!(s.paths().0.ends_with(".stream.csv"));
        assert!(s.paths().1.ends_with(".stream.jsonl"));
        drop(s);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("fp_metrics_test");
        let dir = dir.to_str().unwrap();
        let (c, j) = result().save(dir).unwrap();
        assert!(std::fs::metadata(&c).unwrap().len() > 0);
        assert!(std::fs::metadata(&j).unwrap().len() > 0);
        let _ = std::fs::remove_dir_all(dir);
    }
}
