//! The FedPairing pair trainer — paper Algorithm 2's inner loop, executed
//! against the AOT artifacts.
//!
//! For a pair `(c_i, c_j)` with split lengths `(L_i, L_j)`, each mini-batch
//! step runs two *directions* (both charged concurrently by the latency
//! model; executed deterministically here):
//!
//! ```text
//!   direction i (data of c_i):           direction j (data of c_j):
//!     act   = front_fwd_{L_i}(ω^i, x_i)    act   = front_fwd_{L_j}(ω^j, x_j)
//!     ŷ     = back_fwd_{L_i}(ω^j, act)     ŷ     = back_fwd_{L_j}(ω^i, act)
//!     l,g_ŷ = loss_grad(ŷ, y_i)   [c_i]    l,g_ŷ = loss_grad(ŷ, y_j)   [c_j]
//!     g_bk,g_act = back_bwd(ω^j, …)        g_bk,g_act = back_bwd(ω^i, …)
//!     g_fr  = front_bwd(ω^i, …)            g_fr  = front_bwd(ω^j, …)
//! ```
//!
//! then both models update with eqs. (1)/(2) (+ the eq. (7) overlap boost):
//! `ω^i ← ω^i − η(a_i·g_front_i  +  a_j·g_back_from_j)` where the back grads
//! for `ω^i` come from direction *j* (c_j's data flowing through `ω^i`'s back
//! layers `L_j..W`).

use crate::data::loader::{Batch, Loader};
use crate::nn::{apply_split_update, Params};
use crate::runtime::Engine;
use anyhow::Result;

/// Result of one pair's local-training phase (one round).
#[derive(Debug)]
pub struct PairOutcome {
    pub model_i: Params,
    pub model_j: Params,
    /// Mean training loss over all steps of both directions.
    pub mean_loss: f64,
    /// Mini-batch steps executed (both directions).
    pub n_steps: usize,
}

/// One direction's gradients for one batch.
struct DirGrads {
    /// grads for the data-owner's front layers `[0, l_own)`.
    g_front: Vec<Vec<f32>>,
    /// grads for the helper's back layers `[l_own, W)` *of the helper model*.
    g_back: Vec<Vec<f32>>,
    loss: f64,
}

/// Run one direction's five protocol steps for one batch.
fn run_direction(
    engine: &mut Engine,
    owner_model: &Params,
    helper_model: &Params,
    l_own: usize,
    batch: &Batch,
) -> Result<DirGrads> {
    let meta = engine.meta();
    let (b, di, h) = (meta.train_batch, meta.input_dim, meta.hidden);
    // Upload each model slice and the input once; the forward and backward
    // calls of this batch share the device buffers (§Perf: halves uploads).
    let pf = engine.upload_params(&owner_model[..2 * l_own], 0)?;
    let pb = engine.upload_params(&helper_model[2 * l_own..], l_own)?;
    let xb = engine.upload_f32(&[b, di], &batch.x)?;
    let act = engine.front_fwd_b(l_own, &pf, &xb)?;
    let act_b = engine.upload_f32(&[b, h], &act)?;
    let logits = engine.back_fwd_b(l_own, &pb, &act_b)?;
    let (loss, g_logits) = engine.loss_grad(&logits, &batch.y1hot)?;
    let (g_back, g_act) = engine.back_bwd_b(l_own, &pb, &act_b, &g_logits)?;
    let g_front = engine.front_bwd_b(l_own, &pf, &xb, &g_act)?;
    Ok(DirGrads {
        g_front,
        g_back,
        loss: loss as f64,
    })
}

/// Train a pair for `epochs` local epochs starting from the global model.
///
/// `a_i`/`a_j` are the FedAvg weights applied to each *data source's*
/// gradients (paper: weighted during backward, cached, then applied).
#[allow(clippy::too_many_arguments)]
pub fn train_pair(
    engine: &mut Engine,
    global: &Params,
    loader_i: &mut Loader,
    loader_j: &mut Loader,
    l_i: usize,
    l_j: usize,
    a_i: f32,
    a_j: f32,
    lr: f32,
    epochs: usize,
    overlap_boost: bool,
) -> Result<PairOutcome> {
    let w = engine.meta().layers;
    assert_eq!(l_i + l_j, w, "split lengths must sum to W");
    let mut model_i = global.clone();
    let mut model_j = global.clone();
    let mut loss_sum = 0.0;
    let mut n_steps = 0usize;
    for _ in 0..epochs {
        let batches_i = loader_i.epoch();
        let batches_j = loader_j.epoch();
        let steps = batches_i.len().max(batches_j.len());
        for t in 0..steps {
            // Direction i: c_i's data through ω^i front + ω^j back.
            let dir_i = match batches_i.get(t) {
                Some(b) => Some(run_direction(engine, &model_i, &model_j, l_i, b)?),
                None => None,
            };
            // Direction j: c_j's data through ω^j front + ω^i back.
            let dir_j = match batches_j.get(t) {
                Some(b) => Some(run_direction(engine, &model_j, &model_i, l_j, b)?),
                None => None,
            };
            // Updates (eqs. 1–2, eq. 7). ω^i's front grads come from dir_i,
            // its back grads (layers L_j..W) from dir_j, and vice versa.
            if let (Some(di), Some(dj)) = (&dir_i, &dir_j) {
                apply_split_update(
                    &mut model_i, w, l_i, l_j, &di.g_front, &dj.g_back, a_i, a_j, lr,
                    overlap_boost,
                );
                apply_split_update(
                    &mut model_j, w, l_j, l_i, &dj.g_front, &di.g_back, a_j, a_i, lr,
                    overlap_boost,
                );
            } else if let Some(di) = &dir_i {
                // Unbalanced shards: only c_i had a batch left. Its front
                // grads update ω^i; its back grads update ω^j. No overlap
                // boost (single flow).
                apply_partial(&mut model_i, 0, &di.g_front, a_i, lr);
                apply_partial(&mut model_j, 2 * l_i, &di.g_back, a_i, lr);
            } else if let Some(dj) = &dir_j {
                apply_partial(&mut model_j, 0, &dj.g_front, a_j, lr);
                apply_partial(&mut model_i, 2 * l_j, &dj.g_back, a_j, lr);
            }
            for d in [&dir_i, &dir_j].into_iter().flatten() {
                loss_sum += d.loss;
                n_steps += 1;
            }
        }
    }
    Ok(PairOutcome {
        model_i,
        model_j,
        mean_loss: if n_steps > 0 { loss_sum / n_steps as f64 } else { 0.0 },
        n_steps,
    })
}

/// Apply one flow's gradients to a contiguous tensor range (tail-batch case).
fn apply_partial(model: &mut Params, tensor_off: usize, grads: &[Vec<f32>], a: f32, lr: f32) {
    for (gi, g) in grads.iter().enumerate() {
        let t = &mut model[tensor_off + gi];
        assert_eq!(t.len(), g.len());
        for (p, &gv) in t.iter_mut().zip(g) {
            *p -= lr * a * gv;
        }
    }
}

#[cfg(test)]
mod tests {
    //! Artifact-dependent tests (skipped when `artifacts/` is absent).
    use super::*;
    use crate::config::DataDistribution;
    use crate::data::partition::partition;
    use crate::data::synth::SynthCifar;
    use crate::util::rng::Rng;

    fn setup(samples: usize) -> Option<(Engine, Loader, Loader, Params)> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            crate::log_warn!("skipping split test: artifacts/ not built");
            return None;
        }
        let mut engine = Engine::load("artifacts").unwrap();
        let global = engine.init_params(5).unwrap();
        let gen = SynthCifar::new(3, 0.5);
        let mut rng = Rng::new(4);
        let mut shards = partition(&mut rng, 2, samples, &DataDistribution::Iid);
        let b = engine.meta().train_batch;
        let l_j = Loader::new(gen.clone(), shards.pop().unwrap(), b, Rng::new(6));
        let l_i = Loader::new(gen, shards.pop().unwrap(), b, Rng::new(5));
        Some((engine, l_i, l_j, global))
    }

    #[test]
    fn pair_training_reduces_loss() {
        let Some((mut engine, mut li, mut lj, global)) = setup(64) else {
            return;
        };
        let w = engine.meta().layers;
        let (l_i, l_j) = (w / 2, w - w / 2);
        // a_i = a_j = 0.5 (equal shards); lr boosted since weights scale grads.
        let out1 = train_pair(
            &mut engine, &global, &mut li, &mut lj, l_i, l_j, 0.5, 0.5, 0.2, 1, true,
        )
        .unwrap();
        // Second epoch from the updated model must have lower loss.
        let merged = out1.model_i.clone();
        let out2 = train_pair(
            &mut engine, &merged, &mut li, &mut lj, l_i, l_j, 0.5, 0.5, 0.2, 1, true,
        )
        .unwrap();
        assert!(
            out2.mean_loss < out1.mean_loss,
            "loss did not drop: {} -> {}",
            out1.mean_loss,
            out2.mean_loss
        );
        assert!(crate::nn::all_finite(&out1.model_i));
        assert!(crate::nn::all_finite(&out1.model_j));
        assert_eq!(out1.n_steps, 2 * 2); // 64 samples / 32 batch × 2 directions
    }

    #[test]
    fn asymmetric_split_moves_both_models() {
        let Some((mut engine, mut li, mut lj, global)) = setup(32) else {
            return;
        };
        let w = engine.meta().layers;
        let (l_i, l_j) = (1, w - 1); // extreme split
        let out = train_pair(
            &mut engine, &global, &mut li, &mut lj, l_i, l_j, 0.5, 0.5, 0.1, 1, true,
        )
        .unwrap();
        let diff_i: f64 = out
            .model_i
            .iter()
            .zip(&global)
            .map(|(a, b)| {
                a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs() as f64)
                    .sum::<f64>()
            })
            .sum();
        let diff_j: f64 = out
            .model_j
            .iter()
            .zip(&global)
            .map(|(a, b)| {
                a.iter()
                    .zip(b)
                    .map(|(x, y)| (x - y).abs() as f64)
                    .sum::<f64>()
            })
            .sum();
        assert!(diff_i > 0.0, "model_i unchanged");
        assert!(diff_j > 0.0, "model_j unchanged");
    }

    #[test]
    fn deterministic_pair_training() {
        let Some((mut engine, mut li, mut lj, global)) = setup(32) else {
            return;
        };
        let w = engine.meta().layers;
        let out1 = train_pair(
            &mut engine, &global, &mut li, &mut lj, w / 2, w - w / 2, 0.5, 0.5, 0.1, 1, true,
        )
        .unwrap();
        // Fresh loaders with identical seeds replay identically.
        let Some((mut engine2, mut li2, mut lj2, global2)) = setup(32) else {
            return;
        };
        let out2 = train_pair(
            &mut engine2, &global2, &mut li2, &mut lj2, w / 2, w - w / 2, 0.5, 0.5, 0.1, 1, true,
        )
        .unwrap();
        assert_eq!(out1.model_i[0], out2.model_i[0]);
        assert_eq!(out1.mean_loss, out2.mean_loss);
    }
}
