//! Cross-cutting substrates built from scratch for the offline environment:
//! RNG, JSON, logging, statistics, a property-testing harness, fork-join
//! parallelism, scratch index maps, packed bit sets and the bucket priority
//! queue behind the incremental matcher.

pub mod bitset;
pub mod bucketq;
pub mod index;
pub mod json;
pub mod logging;
pub mod matrix;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
