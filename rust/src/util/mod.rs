//! Cross-cutting substrates built from scratch for the offline environment:
//! RNG, JSON, logging, statistics, a property-testing harness, fork-join
//! parallelism and scratch index maps.

pub mod index;
pub mod json;
pub mod logging;
pub mod matrix;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
