//! Cross-cutting substrates built from scratch for the offline environment:
//! RNG, JSON, logging, statistics and a property-testing harness.

pub mod json;
pub mod logging;
pub mod matrix;
pub mod proptest;
pub mod rng;
pub mod stats;
