//! Compact bit-per-element membership sets for the fleet memory diet.
//!
//! The dynamic fleet used to carry `Vec<bool>` flags (1 byte per client per
//! flag) and `HashSet<usize>` membership sets in the repair path. At 1M
//! clients that is megabytes of cold state and hash churn on the hot path.
//! [`BitSet`] packs the same information 8× denser, iterates set members in
//! ascending order (the order every deterministic pairing loop already
//! requires), and supports `set[i]` reads via `Index` so existing call sites
//! keep their shape.

use std::ops::Index;

/// Fixed-capacity bit set over `0..len`. Out-of-range queries return
/// `false` rather than panicking (mirrors `HashSet::contains`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

static TRUE: bool = true;
static FALSE: bool = false;

impl BitSet {
    /// Empty set with capacity for elements `0..n`.
    pub fn new(n: usize) -> Self {
        BitSet {
            words: vec![0; n.div_ceil(64)],
            len: n,
        }
    }

    /// Set with capacity `n` and exactly `ids` present.
    pub fn from_ids(n: usize, ids: impl IntoIterator<Item = usize>) -> Self {
        let mut s = BitSet::new(n);
        for i in ids {
            s.insert(i);
        }
        s
    }

    /// Set with capacity `n` and every element present.
    pub fn full(n: usize) -> Self {
        let mut s = BitSet::new(n);
        for w in &mut s.words {
            *w = !0;
        }
        if n % 64 != 0 {
            if let Some(last) = s.words.last_mut() {
                *last &= (1u64 << (n % 64)) - 1;
            }
        }
        s
    }

    /// Capacity (NOT the number of set bits — see [`BitSet::count`]).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True iff no bit is set.
    pub fn is_clear(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Number of set bits.
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    #[inline]
    pub fn contains(&self, i: usize) -> bool {
        i < self.len && self.words[i / 64] & (1u64 << (i % 64)) != 0
    }

    #[inline]
    pub fn insert(&mut self, i: usize) {
        debug_assert!(i < self.len, "BitSet::insert out of range: {i}");
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    #[inline]
    pub fn remove(&mut self, i: usize) {
        debug_assert!(i < self.len, "BitSet::remove out of range: {i}");
        self.words[i / 64] &= !(1u64 << (i % 64));
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        if v {
            self.insert(i);
        } else {
            self.remove(i);
        }
    }

    /// Clear every bit, keeping capacity.
    pub fn clear(&mut self) {
        for w in &mut self.words {
            *w = 0;
        }
    }

    /// Ascending iterator over set elements (word-skipping, O(set bits +
    /// words)).
    pub fn iter(&self) -> BitSetIter<'_> {
        BitSetIter {
            set: self,
            word_ix: 0,
            word: self.words.first().copied().unwrap_or(0),
        }
    }

    /// Collect the set elements ascending.
    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }
}

/// `set[i]` read access so `Vec<bool>` call sites keep compiling after the
/// memory diet. Mutation still goes through [`BitSet::set`] / `insert` /
/// `remove` (a bit has no addressable `&mut bool`).
impl Index<usize> for BitSet {
    type Output = bool;
    #[inline]
    fn index(&self, i: usize) -> &bool {
        if self.contains(i) {
            &TRUE
        } else {
            &FALSE
        }
    }
}

pub struct BitSetIter<'a> {
    set: &'a BitSet,
    word_ix: usize,
    word: u64,
}

impl Iterator for BitSetIter<'_> {
    type Item = usize;
    #[inline]
    fn next(&mut self) -> Option<usize> {
        while self.word == 0 {
            self.word_ix += 1;
            if self.word_ix >= self.set.words.len() {
                return None;
            }
            self.word = self.set.words[self.word_ix];
        }
        let bit = self.word.trailing_zeros() as usize;
        self.word &= self.word - 1;
        Some(self.word_ix * 64 + bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn insert_remove_contains() {
        let mut s = BitSet::new(130);
        assert!(!s.contains(0));
        s.insert(0);
        s.insert(64);
        s.insert(129);
        assert!(s.contains(0) && s.contains(64) && s.contains(129));
        assert!(s[64] && !s[63]);
        assert_eq!(s.count(), 3);
        s.remove(64);
        assert!(!s.contains(64));
        assert_eq!(s.to_vec(), vec![0, 129]);
        assert!(!s.contains(1000)); // out of range: false, no panic
    }

    #[test]
    fn full_and_clear() {
        let s = BitSet::full(67);
        assert_eq!(s.count(), 67);
        assert_eq!(s.to_vec(), (0..67).collect::<Vec<_>>());
        let mut s = s;
        s.clear();
        assert!(s.is_clear());
        assert_eq!(s.len(), 67);
    }

    #[test]
    fn iter_matches_reference_under_random_ops() {
        let mut rng = Rng::new(0xB175);
        for n in [1usize, 63, 64, 65, 200, 513] {
            let mut s = BitSet::new(n);
            let mut reference = vec![false; n];
            for _ in 0..4 * n {
                let i = rng.below(n as u64) as usize;
                if rng.below(3) == 0 {
                    s.remove(i);
                    reference[i] = false;
                } else {
                    s.insert(i);
                    reference[i] = true;
                }
            }
            let want: Vec<usize> = (0..n).filter(|&i| reference[i]).collect();
            assert_eq!(s.to_vec(), want, "n={n}");
            assert_eq!(s.count(), want.len());
            for i in 0..n {
                assert_eq!(s[i], reference[i]);
            }
        }
    }

    #[test]
    fn from_ids_round_trip() {
        let s = BitSet::from_ids(100, [3, 97, 42]);
        assert_eq!(s.to_vec(), vec![3, 42, 97]);
    }
}
