//! Flat row-major square matrix — the allocation-friendly replacement for the
//! `Vec<Vec<f64>>` pairwise matrices (one contiguous buffer, one allocation,
//! cache-linear row walks). Used by `sim::geometry::distance_matrix` and
//! `sim::channel::rate_matrix`; the sparse pairing backend avoids these
//! matrices entirely, so at fleet scale nothing O(n²) is ever materialized.

use std::ops::{Index, IndexMut};

/// Dense `n × n` matrix of `f64` in one row-major buffer.
#[derive(Clone, Debug, PartialEq)]
pub struct FlatMatrix {
    n: usize,
    data: Vec<f64>,
}

impl FlatMatrix {
    /// `n × n` matrix with every element set to `fill`.
    pub fn new(n: usize, fill: f64) -> FlatMatrix {
        FlatMatrix {
            n,
            data: vec![fill; n * n],
        }
    }

    /// Side length.
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.n + j] = v;
    }

    /// Set `(i, j)` and `(j, i)` in one call (pairwise matrices are symmetric).
    #[inline]
    pub fn set_sym(&mut self, i: usize, j: usize, v: f64) {
        self.set(i, j, v);
        self.set(j, i, v);
    }

    /// Row `i` as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n..(i + 1) * self.n]
    }

    /// The whole buffer (row-major).
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }
}

impl Index<(usize, usize)> for FlatMatrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n + j]
    }
}

impl IndexMut<(usize, usize)> for FlatMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_fills_and_indexes() {
        let mut m = FlatMatrix::new(3, 0.0);
        assert_eq!(m.n(), 3);
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
        m[(0, 2)] = 5.0;
        m.set(2, 1, 7.0);
        assert_eq!(m[(0, 2)], 5.0);
        assert_eq!(m.get(2, 1), 7.0);
        assert_eq!(m.get(1, 1), 0.0);
    }

    #[test]
    fn set_sym_mirrors() {
        let mut m = FlatMatrix::new(4, 0.0);
        m.set_sym(1, 3, 2.5);
        assert_eq!(m[(1, 3)], 2.5);
        assert_eq!(m[(3, 1)], 2.5);
    }

    #[test]
    fn rows_are_contiguous() {
        let mut m = FlatMatrix::new(3, 0.0);
        for j in 0..3 {
            m.set(1, j, j as f64);
        }
        assert_eq!(m.row(1), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row(0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_bounds_panics() {
        let m = FlatMatrix::new(2, 0.0);
        let _ = m[(2, 0)];
    }
}
