//! Fixed-pool fork-join parallelism (substrate — `rayon` is unavailable
//! offline; see DESIGN.md §2).
//!
//! [`FixedPool::map`] evaluates a pure indexed function over `0..n` on a
//! fixed number of worker threads and returns the results **in index order**.
//! Work is split into contiguous index chunks, one per worker, and every
//! result lands in its own pre-assigned slot — so the output is bit-identical
//! for any thread count, including 1. That determinism contract is what lets
//! the round engine parallelize pair evaluation without perturbing traces.
//!
//! Workers are scoped (fork-join): they are joined before `map` returns, may
//! borrow from the caller's stack, and no thread outlives the call.

use crate::telemetry::registry::{self, Counter, Histo};
use std::num::NonZeroUsize;
use std::time::Instant;

/// A fork-join executor with a fixed worker count.
#[derive(Clone, Copy, Debug)]
pub struct FixedPool {
    threads: usize,
}

impl FixedPool {
    /// `threads = 0` means one worker per available core.
    pub fn new(threads: usize) -> FixedPool {
        let threads = if threads == 0 {
            std::thread::available_parallelism()
                .map(NonZeroUsize::get)
                .unwrap_or(1)
        } else {
            threads
        };
        FixedPool { threads }
    }

    /// Serial executor (one worker); `map` never spawns.
    pub fn serial() -> FixedPool {
        FixedPool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Evaluate `f(0), f(1), …, f(n-1)` across the pool and return the
    /// results in index order. `f` must be pure for the determinism contract
    /// to mean anything — it is called exactly once per index, from an
    /// unspecified worker.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let workers = self.threads.min(n).max(1);
        if workers == 1 {
            // Telemetry: the serial path is one chunk. The enabled check is a
            // single relaxed load; `Instant::now` runs only when it passes.
            let t0 = registry::enabled().then(Instant::now);
            let out: Vec<T> = (0..n).map(f).collect();
            if let Some(t0) = t0 {
                crate::tm_observe!(Histo::PoolChunkNanos, t0.elapsed().as_nanos() as u64);
                crate::tm_count!(Counter::PoolChunks, 1);
            }
            return out;
        }
        let chunk = n.div_ceil(workers);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        std::thread::scope(|scope| {
            for (w, slots) in out.chunks_mut(chunk).enumerate() {
                let f = &f;
                let base = w * chunk;
                scope.spawn(move || {
                    let t0 = registry::enabled().then(Instant::now);
                    for (k, slot) in slots.iter_mut().enumerate() {
                        *slot = Some(f(base + k));
                    }
                    if let Some(t0) = t0 {
                        crate::tm_observe!(Histo::PoolChunkNanos, t0.elapsed().as_nanos() as u64);
                        crate::tm_count!(Counter::PoolChunks, 1);
                    }
                });
            }
        });
        out.into_iter()
            .map(|v| v.expect("pool worker filled every slot"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_threads_resolves_to_cores() {
        assert!(FixedPool::new(0).threads() >= 1);
        assert_eq!(FixedPool::new(3).threads(), 3);
        assert_eq!(FixedPool::serial().threads(), 1);
    }

    #[test]
    fn map_preserves_index_order() {
        for threads in [1, 2, 3, 8, 64] {
            let pool = FixedPool::new(threads);
            let out = pool.map(100, |i| i * i);
            assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>(), "{threads}");
        }
    }

    #[test]
    fn map_is_identical_across_thread_counts() {
        // The determinism contract: any pool shape reproduces the serial map
        // exactly — including f64 results, bit for bit.
        let serial = FixedPool::serial().map(257, |i| (i as f64).sqrt() * 1.7);
        for threads in [2, 4, 7] {
            let par = FixedPool::new(threads).map(257, |i| (i as f64).sqrt() * 1.7);
            assert!(
                serial.iter().zip(&par).all(|(a, b)| a.to_bits() == b.to_bits()),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let pool = FixedPool::new(4);
        assert_eq!(pool.map(0, |i| i), Vec::<usize>::new());
        assert_eq!(pool.map(1, |i| i + 1), vec![1]);
        // More workers than items.
        assert_eq!(pool.map(2, |i| i), vec![0, 1]);
    }

    #[test]
    fn workers_actually_run_concurrently() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let seen = AtomicUsize::new(0);
        FixedPool::new(4).map(64, |i| {
            seen.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(seen.load(Ordering::Relaxed), 64);
    }
}
