//! Property-based testing harness (substrate — the `proptest` crate is not
//! available offline; see DESIGN.md §2).
//!
//! Deterministic: every case derives from a base seed, and a failure report
//! prints the exact seed that reproduces it. Includes a shrinking-lite pass —
//! when a case fails, candidate "smaller" inputs produced by the generator's
//! `shrink` hook are retried to present a minimal counterexample.
//!
//! ```ignore
//! check(100, gen_vec(gen_u64(0, 50), 0, 20), |v| v.len() <= 20);
//! ```

use super::rng::Rng;
use std::fmt::Debug;

/// A value generator: produces a case from an `Rng` and can propose
/// structurally smaller variants of a failing case.
pub struct Gen<T> {
    gen: Box<dyn Fn(&mut Rng) -> T>,
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: 'static> Gen<T> {
    pub fn new(gen: impl Fn(&mut Rng) -> T + 'static) -> Self {
        Gen {
            gen: Box::new(gen),
            shrink: Box::new(|_| Vec::new()),
        }
    }

    pub fn with_shrink(mut self, shrink: impl Fn(&T) -> Vec<T> + 'static) -> Self {
        self.shrink = Box::new(shrink);
        self
    }

    pub fn sample(&self, rng: &mut Rng) -> T {
        (self.gen)(rng)
    }

    pub fn shrink_candidates(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (loses shrinking across the mapping).
    pub fn map<U: 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| f((self.gen)(rng)))
    }
}

/// Run `cases` random cases; panic with a reproducible report on failure.
pub fn check<T: Debug + Clone + 'static>(
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    check_seeded(0xFEDA17 /* default suite seed */, cases, gen, prop)
}

/// `check` with an explicit base seed (used to reproduce failures).
pub fn check_seeded<T: Debug + Clone + 'static>(
    base_seed: u64,
    cases: usize,
    gen: Gen<T>,
    prop: impl Fn(&T) -> bool,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        let input = gen.sample(&mut rng);
        if !prop(&input) {
            // Shrinking-lite: breadth-first over shrink candidates, bounded.
            let mut minimal = input.clone();
            let mut frontier = gen.shrink_candidates(&minimal);
            let mut budget = 1000;
            while budget > 0 {
                budget -= 1;
                let Some(cand) = frontier.pop() else { break };
                if !prop(&cand) {
                    frontier = gen.shrink_candidates(&cand);
                    minimal = cand;
                }
            }
            panic!(
                "property failed at case {case} (seed {seed:#x});\n  original: {input:?}\n  minimal:  {minimal:?}"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Stock generators
// ---------------------------------------------------------------------------

/// Uniform `u64` in `[lo, hi]`, shrinking toward `lo`.
pub fn gen_u64(lo: u64, hi: u64) -> Gen<u64> {
    assert!(lo <= hi);
    Gen::new(move |rng| lo + rng.next_below(hi - lo + 1)).with_shrink(move |&v| {
        let mut out = Vec::new();
        if v > lo {
            out.push(lo);
            out.push(lo + (v - lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    })
}

/// Uniform `usize` in `[lo, hi]`, shrinking toward `lo`.
pub fn gen_usize(lo: usize, hi: usize) -> Gen<usize> {
    gen_u64(lo as u64, hi as u64).map(|v| v as usize)
}

/// Uniform `f64` in `[lo, hi)` (no shrinking).
pub fn gen_f64(lo: f64, hi: f64) -> Gen<f64> {
    assert!(hi >= lo);
    Gen::new(move |rng| rng.range_f64(lo, hi))
}

/// Vector of `inner` with length in `[min_len, max_len]`; shrinks by halving
/// length and by dropping single elements.
pub fn gen_vec<T: Clone + 'static>(
    inner: Gen<T>,
    min_len: usize,
    max_len: usize,
) -> Gen<Vec<T>> {
    assert!(min_len <= max_len);
    let inner = std::rc::Rc::new(inner);
    let g = inner.clone();
    Gen::new(move |rng| {
        let len = min_len + rng.below(max_len - min_len + 1);
        (0..len).map(|_| g.sample(rng)).collect()
    })
    .with_shrink(move |v: &Vec<T>| {
        let mut out = Vec::new();
        if v.len() > min_len {
            out.push(v[..min_len.max(v.len() / 2)].to_vec());
            let mut dropped = v.clone();
            dropped.pop();
            out.push(dropped);
        }
        // Also shrink individual elements (first element only, bounded).
        if let Some(first) = v.first() {
            for cand in inner.shrink_candidates(first).into_iter().take(3) {
                let mut w = v.clone();
                w[0] = cand;
                out.push(w);
            }
        }
        out
    })
}

/// Pair generator.
pub fn gen_pair<A: Clone + 'static, B: Clone + 'static>(
    a: Gen<A>,
    b: Gen<B>,
) -> Gen<(A, B)> {
    Gen::new(move |rng| (a.sample(rng), b.sample(rng)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        check(200, gen_u64(0, 100), |&v| v <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(200, gen_u64(0, 100), |&v| v < 90);
    }

    #[test]
    fn shrinks_toward_minimum() {
        // Catch the panic and inspect the message: minimal counterexample for
        // "v < 50" under gen_u64(0,100) should shrink well below the original.
        let res = std::panic::catch_unwind(|| {
            check(200, gen_u64(0, 100), |&v| v < 50);
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal"), "{msg}");
    }

    #[test]
    fn vec_generator_respects_bounds() {
        check(100, gen_vec(gen_u64(0, 9), 2, 5), |v| {
            v.len() >= 2 && v.len() <= 5 && v.iter().all(|&x| x <= 9)
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut outs = Vec::new();
        for _ in 0..2 {
            let g = gen_u64(0, 1_000_000);
            let mut rng = Rng::new(99);
            outs.push(g.sample(&mut rng));
        }
        assert_eq!(outs[0], outs[1]);
    }

    #[test]
    fn pair_generator() {
        check(50, gen_pair(gen_u64(1, 5), gen_f64(0.0, 1.0)), |(a, b)| {
            (1..=5).contains(a) && (0.0..1.0).contains(b)
        });
    }
}
